#!/usr/bin/env python3
"""Check every Rust target file is registered in Cargo.toml.

The crate keeps its sources under ``rust/`` (not Cargo's default
layout), so test/bench/bin auto-discovery is off and every target
needs an explicit ``[[test]]``/``[[bench]]``/``[[bin]]`` entry. A file
dropped into ``rust/tests/`` without one silently never runs in CI —
this script turns that into a hard failure.

Stdlib-only (no toml module on older runners): the parser only needs
to find ``path = "..."`` entries inside target sections.

Usage: python3 tools/check_targets.py  (from the repo root; exits 1
listing unregistered files, or files registered but missing on disk).
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CARGO = ROOT / "Cargo.toml"

# Directory globbed on disk -> Cargo section that must register it.
CHECKS = [
    ("rust/tests", "test"),
    ("rust/benches", "bench"),
    ("rust/src/bin", "bin"),
]


def registered_paths(cargo_text: str, section: str) -> set:
    """All `path = "..."` values under [[section]] tables."""
    paths = set()
    current = None
    for line in cargo_text.splitlines():
        stripped = line.strip()
        header = re.fullmatch(r"\[\[(\w+)\]\]", stripped)
        if header:
            current = header.group(1)
            continue
        if stripped.startswith("["):  # any other table ends the target
            current = None
            continue
        m = re.fullmatch(r'path\s*=\s*"([^"]+)"', stripped)
        if m and current == section:
            paths.add(m.group(1))
    return paths


def main() -> int:
    cargo_text = CARGO.read_text()
    failures = []
    for directory, section in CHECKS:
        on_disk = {
            str(p.relative_to(ROOT))
            for p in (ROOT / directory).glob("*.rs")
        }
        registered = registered_paths(cargo_text, section)
        for missing in sorted(on_disk - registered):
            failures.append(
                f"{missing}: not registered as a [[{section}]] target in Cargo.toml"
            )
        for stale in sorted(registered - on_disk):
            # Only flag entries that point into the checked directory;
            # e.g. [[bin]] main.rs lives outside rust/src/bin.
            if stale.startswith(directory + "/"):
                failures.append(
                    f"{stale}: registered as [[{section}]] but missing on disk"
                )
    if failures:
        print("Cargo target registration check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("all rust/tests, rust/benches and rust/src/bin targets registered")
    return 0


if __name__ == "__main__":
    sys.exit(main())
