#!/usr/bin/env python3
"""Diff two BENCH_*.json files produced by the bench harness.

Records are matched on the (``op``, ``threads``) pair and compared on
``ns_per_op``; the report prints the percentage delta per pair
(negative = the new file is faster), plus pairs present on only one
side. Use it to eyeball a PR's perf movement:

    python3 tools/bench_diff.py OLD.json NEW.json
    python3 tools/bench_diff.py --threshold 5 OLD.json NEW.json

``--threshold PCT`` exits 1 when any matched pair regressed by more
than PCT percent (for CI gating once baselines are checked in).

Stdlib-only, like every tool in this repo.
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: str) -> dict:
    """Map (op, threads) -> ns_per_op. Duplicate keys keep the last
    record, matching how a reader scanning the file would resolve it."""
    records = json.loads(Path(path).read_text())
    out = {}
    for r in records:
        out[(r["op"], r["threads"])] = float(r["ns_per_op"])
    return out


def fmt_ns(ns: float) -> str:
    if ns < 1e3:
        return f"{ns:.0f}ns"
    if ns < 1e6:
        return f"{ns / 1e3:.1f}µs"
    if ns < 1e9:
        return f"{ns / 1e6:.2f}ms"
    return f"{ns / 1e9:.3f}s"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=None,
        metavar="PCT",
        help="exit 1 if any matched pair regresses by more than PCT%%",
    )
    args = ap.parse_args()

    old, new = load(args.old), load(args.new)
    matched = sorted(set(old) & set(new))
    only_old = sorted(set(old) - set(new))
    only_new = sorted(set(new) - set(old))

    width = max((len(op) for op, _ in matched), default=2) + 2
    print(f"{'op':<{width}} {'thr':>3} {'old':>10} {'new':>10} {'delta':>8}")
    worst = 0.0
    for op, threads in matched:
        a, b = old[(op, threads)], new[(op, threads)]
        delta = (b - a) / a * 100.0 if a else float("inf")
        worst = max(worst, delta)
        print(
            f"{op:<{width}} {threads:>3} {fmt_ns(a):>10} {fmt_ns(b):>10} "
            f"{delta:>+7.1f}%"
        )
    for op, threads in only_old:
        print(f"{op:<{width}} {threads:>3} {fmt_ns(old[(op, threads)]):>10} "
              f"{'-':>10} {'gone':>8}")
    for op, threads in only_new:
        print(f"{op:<{width}} {threads:>3} {'-':>10} "
              f"{fmt_ns(new[(op, threads)]):>10} {'new':>8}")

    print(
        f"\n{len(matched)} matched, {len(only_old)} removed, "
        f"{len(only_new)} added"
    )
    if args.threshold is not None and worst > args.threshold:
        print(f"FAIL: worst regression {worst:+.1f}% exceeds "
              f"{args.threshold:.1f}%", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
