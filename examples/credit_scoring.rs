//! Domain scenario: confidential credit scoring (the paper's §1/§7
//! motivating case — financial data too sensitive to send in clear).
//!
//! A lender runs a Cryptotree server; an applicant's device encrypts
//! their financial features, the lender scores the encrypted
//! application, and only the applicant can read the decision scores.

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, Evaluator, KeyGenerator};
use cryptotree::data::credit;
use cryptotree::forest::metrics::Metrics;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::{finetune_last_layer, FinetuneConfig, NeuralForest};

fn main() {
    // --- the lender trains on historical outcomes -------------------
    let history = credit::generate(20_000, 21);
    let (train, valid) = history.split(0.8, 22);
    let rf = RandomForest::fit(
        &train,
        &RandomForestConfig {
            n_trees: 32,
            ..Default::default()
        },
        23,
    );
    let m_rf = Metrics::from_predictions(&rf.predict_batch(&valid.x), &valid.y);
    println!(
        "lender model: RF accuracy {:.3}, recall {:.3} (defaults are ~7% of data)",
        m_rf.accuracy, m_rf.recall
    );

    let mut nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    finetune_last_layer(&mut nf, &train, &FinetuneConfig::default(), 24);
    let m_nrf = Metrics::from_predictions(&nf.predict_batch(&valid.x), &valid.y);
    println!(
        "deployed NRF:  accuracy {:.3}, recall {:.3} (after last-layer fine-tune)",
        m_nrf.accuracy, m_nrf.recall
    );

    // --- server packs the model; applicant generates keys -----------
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf, history.n_features(), params.slots())
        .expect("pack");
    println!(
        "HRF packed: {} trees, {} slots used, {} Galois keys required",
        model.plan.l,
        model.plan.used_slots,
        model.plan.rotations_needed().len()
    );
    let server = HrfServer::new(model);
    let mut ev = Evaluator::new(ctx.clone());

    let mut kg = KeyGenerator::new(&ctx, 25);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &server.model.plan.rotations_needed());
    let mut applicant = HrfClient::new(Encryptor::new(pk, 26), Decryptor::new(kg.secret_key()));

    // --- three applications scored blind ----------------------------
    for (label, idx) in [("low-risk", 3usize), ("mid", 11), ("high-risk", 4)] {
        // pick a validation row whose truth matches the narrative where possible
        let x = &valid.x[idx];
        let ct = applicant.encrypt_input(&ctx, &enc, &server.model, x);
        let t0 = std::time::Instant::now();
        let outs = server
            .execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
            .into_class_scores();
        let dt = t0.elapsed();
        let (scores, pred) = applicant.decrypt_scores(&ctx, &enc, &outs);
        let plain = nf.predict(x);
        println!(
            "application {label:>9}: encrypted score [ok={:.4}, default={:.4}] -> {} in {dt:?} (plaintext NRF: {})",
            scores[0],
            scores[1],
            if pred == 1 { "DECLINE" } else { "approve" },
            if plain == 1 { "DECLINE" } else { "approve" },
        );
        assert_eq!(pred, plain, "encrypted decision deviated from plaintext model");
    }
    println!("\nThe lender never saw an applicant's features; the applicant never saw the model.");
}
