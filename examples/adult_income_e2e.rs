//! The end-to-end validation driver (Table 2 + §4/§5 statistics).
//!
//! Reproduces, on the synthetic Adult Income dataset (48 842 rows,
//! offline stand-in — see DESIGN.md §Substitutions):
//!
//! * **Table 2**: Accuracy / Precision / Recall / F1 for Linear, RF,
//!   NRF (fine-tuned, tanh) and HRF (encrypted, polynomial);
//! * **§4**: the NRF/HRF agreement percentage (paper: 97.5 %);
//! * **§5**: single-observation encrypted latency (paper: ~3 s on a
//!   2014 laptop).
//!
//! The HRF column is measured by *real homomorphic evaluation* through
//! the coordinator on a validation subsample (encrypting all ~9.8k
//! validation rows would take hours on this single-core box; the
//! subsample size is adjustable via CRYPTOTREE_HRF_SAMPLES).
//!
//! Output is EXPERIMENTS.md-ready markdown.

use cryptotree::bench_harness::print_metric_table;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager};
use cryptotree::data::adult;
use cryptotree::forest::linear::LogRegConfig;
use cryptotree::forest::metrics::{agreement, Metrics};
use cryptotree::forest::{LogisticRegression, RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::{finetune_last_layer, FinetuneConfig, NeuralForest};
use std::sync::Arc;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let rows = env_usize("CRYPTOTREE_ROWS", adult::ADULT_N);
    let n_trees = env_usize("CRYPTOTREE_TREES", 64);
    let hrf_samples = env_usize("CRYPTOTREE_HRF_SAMPLES", 40);
    let t0 = Instant::now();

    println!("# Adult Income end-to-end (rows={rows}, trees={n_trees})\n");
    let ds = adult::generate(rows, 1);
    let (train, valid) = ds.split(0.8, 2);
    println!(
        "- data: {} train / {} valid, positive rate {:.3}",
        train.len(),
        valid.len(),
        valid.y.iter().filter(|&&y| y == 1).count() as f64 / valid.len() as f64
    );

    // ---------------- Linear baseline ------------------------------
    let linear = LogisticRegression::fit(&train, &LogRegConfig::default(), 3);
    let m_linear = Metrics::from_predictions(
        &valid.x.iter().map(|x| linear.predict(x)).collect::<Vec<_>>(),
        &valid.y,
    );
    println!("- [{:6.1?}] linear trained", t0.elapsed());

    // ---------------- Random Forest --------------------------------
    let rf = RandomForest::fit(
        &train,
        &RandomForestConfig {
            n_trees,
            ..Default::default()
        },
        4,
    );
    let m_rf = Metrics::from_predictions(&rf.predict_batch(&valid.x), &valid.y);
    println!("- [{:6.1?}] RF trained (max leaves {})", t0.elapsed(), rf.max_leaves());

    // ---------------- NRF (fine-tuned, tanh) -----------------------
    let a = 3.0;
    let degree = 4;
    let mut nf_tanh = NeuralForest::from_forest(&rf, Activation::Tanh { a });
    finetune_last_layer(&mut nf_tanh, &train, &FinetuneConfig::default(), 5);
    let m_nrf = Metrics::from_predictions(&nf_tanh.predict_batch(&valid.x), &valid.y);
    println!("- [{:6.1?}] NRF fine-tuned (K={})", t0.elapsed(), nf_tanh.k);

    // ---------------- HRF (encrypted, polynomial) ------------------
    let coeffs = chebyshev_fit_tanh(a, degree);
    let nf_poly = nf_tanh.with_activation(Activation::Poly { coeffs });
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf_poly, ds.n_features(), params.slots())
        .expect("packing");
    let plan = model.plan;
    println!(
        "- CKKS {} | packed L={} K={} -> {}/{} slots",
        params.name, plan.l, plan.k, plan.used_slots, plan.slots
    );

    let mut kg = KeyGenerator::new(&ctx, 6);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 7), Decryptor::new(kg.secret_key()));
    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(rlk, gk);
    let server = Arc::new(HrfServer::new(model));
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions,
        None,
    );
    println!("- [{:6.1?}] keys generated, coordinator up", t0.elapsed());

    let n_hrf = hrf_samples.min(valid.len());
    let mut hrf_pred = Vec::with_capacity(n_hrf);
    let mut nrf_pred_sub = Vec::with_capacity(n_hrf);
    let mut poly_pred_sub = Vec::with_capacity(n_hrf);
    let mut latencies = Vec::with_capacity(n_hrf);
    for i in 0..n_hrf {
        let x = &valid.x[i];
        let ct = client.encrypt_input(&ctx, &enc, &server.model, x);
        let t = Instant::now();
        let rx = coord.submit_encrypted(sid, ct).expect("submit");
        let outs = rx.recv().unwrap().expect("hrf eval");
        latencies.push(t.elapsed());
        let (_, pred) = client.decrypt_response(&ctx, &enc, &outs);
        hrf_pred.push(pred);
        nrf_pred_sub.push(nf_tanh.predict(x));
        poly_pred_sub.push(nf_poly.predict(x));
    }
    let truth_sub = &valid.y[..n_hrf];
    let m_hrf = Metrics::from_predictions(&hrf_pred, truth_sub);
    let agree_tanh = agreement(&hrf_pred, &nrf_pred_sub);
    let agree_poly = agreement(&hrf_pred, &poly_pred_sub);
    latencies.sort();
    let mean_lat = latencies.iter().sum::<std::time::Duration>() / n_hrf as u32;
    println!("- [{:6.1?}] {} encrypted inferences done\n", t0.elapsed(), n_hrf);

    // ---------------- Table 2 --------------------------------------
    print_metric_table(
        "Table 2 — Adult Income (validation)",
        &["Model", "Accuracy", "Precision", "Recall", "F1"],
        &[
            m_linear.table_row("Linear"),
            m_rf.table_row("RF"),
            m_nrf.table_row("NRF (fine-tuned, tanh)"),
            m_hrf.table_row(&format!("HRF (encrypted, n={n_hrf})")),
        ],
    );
    println!("\n(HRF row measured on the first {n_hrf} validation rows; paper Table 2 values: Linear .819/.432/.724/.541, RF .834/.386/.876/.536, NRF .845/.547/.762/.637, HRF .842/.491/.796/.607)");

    println!("\n## §4 agreement");
    println!("- HRF vs NRF(tanh):  {:.1}% (paper: 97.5%)", 100.0 * agree_tanh);
    println!("- HRF vs NRF(poly):  {:.1}% (noise-only disagreement)", 100.0 * agree_poly);

    println!("\n## §5 latency (single encrypted observation)");
    println!(
        "- mean {:?} | median {:?} | p95 {:?} (paper: ~3 s on i7-4600U; params {})",
        mean_lat,
        latencies[n_hrf / 2],
        latencies[(n_hrf as f64 * 0.95) as usize],
        params.name
    );
    let snap = coord.metrics.snapshot();
    println!(
        "- coordinator mean latency {:?} over {} requests",
        snap.encrypted_mean, snap.encrypted_completed
    );
    coord.shutdown();
    println!("\n(total runtime {:?})", t0.elapsed());
}
