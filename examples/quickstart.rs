//! Quickstart: one encrypted prediction in ~40 lines of API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, Evaluator, KeyGenerator};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;

fn main() {
    // 1. Train a random forest on (synthetic) Adult Income data.
    let data = adult::generate(4_000, 7);
    let forest = RandomForest::fit(&data, &RandomForestConfig::default(), 8);

    // 2. Convert to a Neural Random Forest with a polynomial
    //    activation (degree-4 Chebyshev fit of tanh(3x)).
    let act = Activation::Poly {
        coeffs: chebyshev_fit_tanh(3.0, 4),
    };
    let nrf = NeuralForest::from_forest(&forest, act);

    // 3. Pack it for CKKS and set up client & server.
    let params = CkksParams::fast(); // N=8192, depth 8 (test-grade security)
    let ctx = CkksContext::new(params.clone());
    let encoder = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nrf, data.n_features(), params.slots())
        .expect("forest fits the slot budget");
    println!(
        "packed {} trees (K={}) into {}/{} slots",
        model.plan.l, model.plan.k, model.plan.used_slots, model.plan.slots
    );

    // Client-side key material; the server only ever sees the
    // evaluation keys (relinearization + Galois).
    let mut keygen = KeyGenerator::new(&ctx, 9);
    let public_key = keygen.gen_public_key(&ctx);
    let relin_key = keygen.gen_relin_key(&ctx);
    let galois_keys = keygen.gen_galois_keys(&ctx, &model.plan.rotations_needed());
    let mut client = HrfClient::new(
        Encryptor::new(public_key, 10),
        Decryptor::new(keygen.secret_key()),
    );
    let server = HrfServer::new(model);
    let mut evaluator = Evaluator::new(ctx.clone());

    // 4. Encrypt one observation, evaluate blind, decrypt the scores.
    let x = &data.x[0];
    let ct = client.encrypt_input(&ctx, &encoder, &server.model, x);
    let t0 = std::time::Instant::now();
    let ex = server.execute(
        &mut evaluator,
        &encoder,
        &EncRequest::single(&ct),
        &relin_key,
        &galois_keys,
    );
    let elapsed = t0.elapsed();
    let ops = ex.counts;
    let score_cts = ex.into_class_scores();
    let (scores, predicted) = client.decrypt_scores(&ctx, &encoder, &score_cts);

    println!("encrypted inference took {elapsed:?}");
    println!(
        "class scores {:?} -> predicted class {predicted} (plaintext RF says {})",
        scores.iter().map(|s| format!("{s:.4}")).collect::<Vec<_>>(),
        forest.predict(x)
    );
    let [l1, l2, l3] = ops.table1_rows();
    println!("homomorphic ops (adds/muls/rots): L1 {l1:?}  L2 {l2:?}  L3 {l3:?}");
}
