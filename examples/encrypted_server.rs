//! Multi-client encrypted serving demo (§5: "several inputs can be
//! handled at the same time using a multi-threaded server").
//!
//! Spawns client threads firing mixed traffic (encrypted HRF requests
//! + plaintext fast-path requests) at the coordinator and reports
//! throughput, latency and batching behaviour for 1 and 2 workers.
//!
//! Ends with a keycache demo: three sessions under a ~2.5-session key
//! budget, showing LRU eviction, the `KeysEvicted` fast-fail, and
//! recovery via re-registration under the same session id.

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager, SubmitError};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::keycache::KeyCacheConfig;
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let ds = adult::generate(3_000, 11);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 16,
            ..Default::default()
        },
        12,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model =
        HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).expect("pack");
    let server = Arc::new(HrfServer::new(model));

    // One registered client session (keys generated client-side).
    let mut kg = KeyGenerator::new(&ctx, 13);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &server.eval_key_requirements(1));
    let decryptor = Decryptor::new(kg.secret_key());

    // Pre-encrypt a pool of requests (client work, off the serving
    // path). The client retains its evaluation keys so it can recover
    // from server-side key eviction (demo below).
    let mut client =
        HrfClient::with_eval_keys(Encryptor::new(pk, 14), decryptor, rlk.clone(), gk.clone());
    let pool: Vec<_> = (0..8)
        .map(|i| client.encrypt_input(&ctx, &enc, &server.model, &ds.x[i]))
        .collect();

    let artifacts = std::path::PathBuf::from("artifacts");
    let artifacts = artifacts.join("manifest.txt").exists().then_some(artifacts);
    if artifacts.is_none() {
        println!("(artifacts/ missing — plaintext path uses Rust slot math; run `make artifacts` for the PJRT fast path)");
    }

    for workers in [1usize, 2] {
        let sessions = Arc::new(SessionManager::new());
        let sid = sessions.register(rlk.clone(), gk.clone());
        let coord = Arc::new(Coordinator::start(
            CoordinatorConfig {
                workers,
                queue_capacity: 256,
                max_batch: 8,
                batch_delay: Duration::from_millis(4),
                ..Default::default()
            },
            ctx.clone(),
            server.clone(),
            sessions,
            artifacts.clone(),
        ));

        let n_enc = 8usize;
        let n_plain = 200usize;
        let t0 = Instant::now();

        // Encrypted traffic from this thread (submission is cheap; the
        // workers do the heavy lifting in parallel).
        let enc_rxs: Vec<_> = (0..n_enc)
            .map(|i| loop {
                match coord.submit_encrypted(sid, pool[i % pool.len()].clone()) {
                    Ok(rx) => break rx,
                    Err(SubmitError::Busy) => std::thread::sleep(Duration::from_millis(5)),
                    Err(e) => panic!("{e:?}"),
                }
            })
            .collect();

        // Plaintext traffic from 4 client threads.
        let mut client_threads = Vec::new();
        for c in 0..4 {
            let coord = coord.clone();
            let xs: Vec<Vec<f64>> = (0..n_plain / 4)
                .map(|i| ds.x[(c * 97 + i) % ds.len()].clone())
                .collect();
            client_threads.push(std::thread::spawn(move || {
                let mut ok = 0usize;
                for x in xs {
                    loop {
                        match coord.submit_plain(x.clone()) {
                            Ok(rx) => {
                                rx.recv().unwrap().expect("plain response");
                                ok += 1;
                                break;
                            }
                            Err(SubmitError::Busy) => {
                                std::thread::sleep(Duration::from_millis(1))
                            }
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                }
                ok
            }));
        }
        let plain_ok: usize = client_threads.into_iter().map(|t| t.join().unwrap()).sum();
        for rx in enc_rxs {
            rx.recv().unwrap().expect("encrypted response");
        }
        let elapsed = t0.elapsed();
        let snap = coord.metrics.snapshot();
        println!(
            "\nworkers={workers}: {n_enc} encrypted + {plain_ok} plain in {elapsed:?}"
        );
        println!(
            "  encrypted: mean {:?}, p95 {:?} | throughput {:.2} enc/s",
            snap.encrypted_mean,
            snap.encrypted_p95,
            n_enc as f64 / elapsed.as_secs_f64()
        );
        println!(
            "  plain: mean {:?} | {} batches, mean fill {:.1}",
            snap.plain_mean, snap.batches_flushed, snap.mean_batch_fill
        );
        match Arc::try_unwrap(coord) {
            Ok(c) => {
                let report = c.shutdown();
                assert!(report.is_clean(), "worker panics: {:?}", report.worker_panics);
            }
            Err(_) => unreachable!("all clients joined"),
        }
    }

    // ---- Keycache: eviction + re-registration under a small budget --
    // Three tenants compete for a budget that holds ~2.5 key sets; the
    // least-recently-used session loses its keys, fails fast with
    // KeysEvicted, and recovers under the SAME session id by pushing
    // its retained keys back — no re-enrolment, no lost state.
    let session_bytes = (rlk.key_bytes() + gk.key_bytes()) as u64;
    let budget = session_bytes * 5 / 2;
    println!(
        "\nkeycache demo: {:.1} MiB per session, budget {:.1} MiB (~2.5 sessions)",
        session_bytes as f64 / (1024.0 * 1024.0),
        budget as f64 / (1024.0 * 1024.0),
    );
    let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
        num_shards: 4,
        budget_bytes: budget,
    }));
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 64,
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions.clone(),
        None,
    );
    let sid_a = sessions.register_keys(client.eval_keys().expect("client retains keys"));
    let _sid_b = sessions.register(rlk.clone(), gk.clone());
    let _sid_c = sessions.register(rlk.clone(), gk.clone()); // evicts sid_a (LRU)
    match coord.submit_encrypted(sid_a, pool[0].clone()) {
        Err(SubmitError::KeysEvicted) => {
            println!("  session {sid_a}: KeysEvicted (expected) — re-registering retained keys");
        }
        other => println!("  session {sid_a}: unexpected submit outcome {other:?}"),
    }
    assert!(
        sessions.reregister_keys(sid_a, client.eval_keys().unwrap()),
        "re-registration must succeed for a known session id"
    );
    let rx = coord
        .submit_encrypted(sid_a, pool[0].clone())
        .expect("submit after re-registration");
    let outs = rx.recv().unwrap().expect("encrypted response");
    let (scores, pred) = client.decrypt_response(&ctx, &enc, &outs);
    println!("  session {sid_a} recovered: class {pred}, scores {scores:?}");
    let snap = coord.metrics.snapshot();
    println!(
        "  keycache: {} hits, {} misses, {} evictions, {} KeysEvicted rejects, resident {:.1} of {:.1} MiB",
        snap.keycache_hits,
        snap.keycache_misses,
        snap.keycache_evictions,
        snap.rejected_keys_evicted,
        snap.keycache_resident_bytes as f64 / (1024.0 * 1024.0),
        budget as f64 / (1024.0 * 1024.0),
    );
    coord.shutdown();
}
