//! Debug tool: pretty-print compiled HRF schedules with their
//! predicted op counts and derived Galois-key requirements, then show
//! what the pass pipeline does to them — per backend.
//!
//!   cargo run --release --example schedule_dump [B]
//!
//! Prints the single-sample schedule, then the folded and unfolded
//! B-sample schedules side by side — the rotation delta between the
//! last two is the extraction fold's C·(B−1) saving. A final section
//! runs the standard pass pipeline (FuseMulRescale) and prints the
//! dry-run (CountingBackend) counts before/after plus an f32
//! SlotBackend execution of both schedules proving the pass is
//! numerically invisible. No HE execution: everything here is the
//! compiler, the pass pipeline and two cheap engine backends.

use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::reshuffle_and_pack;
use cryptotree::hrf::{HrfModel, HrfSchedule};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;
use cryptotree::runtime::{PassPipeline, SlotModelParams, SlotShape};

fn print_counts(label: &str, sched: &HrfSchedule) {
    let c = sched.predicted_counts();
    println!("{label}: predicted op counts (dry-run)");
    for (seg, oc) in [
        ("pack", c.pack),
        ("layer1", c.layer1),
        ("activations", c.activations),
        ("layer2", c.layer2),
        ("layer3", c.layer3),
        ("extract", c.extract),
    ] {
        println!(
            "  {seg:<12} add {:>3}  add_pt {:>3}  mul {:>3}  mul_pt {:>3}  rot {:>3}  rescale {:>3}  relin {:>3}  fused {:>3}",
            oc.add, oc.add_plain, oc.mul, oc.mul_plain, oc.rotate, oc.rescale, oc.relin, oc.fused_mul_rescale
        );
    }
    let t = c.total();
    println!(
        "  {:<12} add {:>3}  add_pt {:>3}  mul {:>3}  mul_pt {:>3}  rot {:>3}  rescale {:>3}  relin {:>3}  fused {:>3}",
        "TOTAL",
        t.add,
        t.add_plain,
        t.mul,
        t.mul_plain,
        t.rotate,
        t.rescale,
        t.relin,
        t.fused_mul_rescale
    );
    let steps: Vec<usize> = sched.rotation_steps().into_iter().collect();
    println!("  galois steps ({}): {steps:?}\n", steps.len());
}

fn main() {
    let b_arg: Option<usize> = std::env::args().nth(1).and_then(|a| a.parse().ok());

    // Small trained model: K and L stay readable in the dump.
    let ds = adult::generate(800, 7);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 4,
            tree: cryptotree::forest::tree::TreeConfig {
                max_depth: 3,
                ..Default::default()
            },
            ..Default::default()
        },
        8,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), 2048).expect("packing");
    let p = model.plan;
    let b = b_arg.unwrap_or(p.groups.min(3)).clamp(1, p.groups);
    println!(
        "plan: K={} L={} C={} | span {} | {} sample groups per ciphertext | dumping B={b}\n",
        p.k, p.l, p.c, p.reduce_span, p.groups
    );

    let single = HrfSchedule::compile(&model, 1, true);
    println!("{single}");
    print_counts("B=1", &single);

    let folded = HrfSchedule::compile(&model, b, true);
    println!("{folded}");
    print_counts(&format!("B={b} folded"), &folded);

    let unfolded = HrfSchedule::compile(&model, b, false);
    println!("{unfolded}");
    print_counts(&format!("B={b} unfolded (legacy slot-0 contract)"), &unfolded);

    let saved = unfolded.predicted_rotations() - folded.predicted_rotations();
    println!(
        "extraction fold: {} - {} = {} rotations saved per batch (C·(B−1) = {})",
        unfolded.predicted_rotations(),
        folded.predicted_rotations(),
        saved,
        p.c * (b - 1)
    );
    assert_eq!(saved as usize, p.c * (b - 1));

    // ---- Pass pipeline: per-backend counts before/after ------------
    let pipeline = PassPipeline::standard();
    println!("\n== pass pipeline {:?} ==\n", pipeline.names());
    let optimized = folded.clone().optimize(pipeline.passes());
    print_counts(&format!("B={b} folded, before passes"), &folded);
    print_counts(&format!("B={b} folded, after passes"), &optimized);
    println!(
        "fusion: {} ops -> {} ops ({} MulPlainCached+Rescale pairs fused)",
        folded.ops.len(),
        optimized.ops.len(),
        folded.ops.len() - optimized.ops.len()
    );

    // SlotBackend: both schedules through the f32 engine — the pass
    // must be numerically invisible on every backend.
    let shape = SlotShape {
        s: p.slots,
        k: p.k,
        c: p.c,
        m: model.act_coeffs.len(),
        b: 8,
    };
    let slot_params = SlotModelParams::from_hrf(&model, shape).expect("slot params");
    let singles: Vec<Vec<f32>> = (0..b)
        .map(|g| {
            reshuffle_and_pack(&model, &ds.x[g])
                .iter()
                .map(|&v| v as f32)
                .collect()
        })
        .collect();
    let rows_raw = slot_params.run_schedule(&folded, &singles);
    let rows_opt = slot_params.run_schedule(&optimized, &singles);
    assert_eq!(rows_raw, rows_opt, "pass changed f32 results");
    println!("slot backend: raw and optimized schedules agree bit-for-bit; scores:");
    for (g, row) in rows_raw.iter().enumerate() {
        println!("  sample {g}: {row:?}");
    }
}
