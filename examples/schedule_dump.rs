//! Debug tool: pretty-print compiled HRF schedules with their
//! predicted op counts and derived Galois-key requirements.
//!
//!   cargo run --release --example schedule_dump [B]
//!
//! Prints the single-sample schedule, then the folded and unfolded
//! B-sample schedules side by side — the rotation delta between the
//! last two is the extraction fold's C·(B−1) saving. No HE execution:
//! everything here is the compiler + the dry-run interpreter.

use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::{HrfModel, HrfSchedule};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;

fn print_counts(label: &str, sched: &HrfSchedule) {
    let c = sched.predicted_counts();
    println!("{label}: predicted op counts (dry-run)");
    for (seg, oc) in [
        ("pack", c.pack),
        ("layer1", c.layer1),
        ("activations", c.activations),
        ("layer2", c.layer2),
        ("layer3", c.layer3),
        ("extract", c.extract),
    ] {
        println!(
            "  {seg:<12} add {:>3}  add_pt {:>3}  mul {:>3}  mul_pt {:>3}  rot {:>3}  rescale {:>3}  relin {:>3}",
            oc.add, oc.add_plain, oc.mul, oc.mul_plain, oc.rotate, oc.rescale, oc.relin
        );
    }
    let t = c.total();
    println!(
        "  {:<12} add {:>3}  add_pt {:>3}  mul {:>3}  mul_pt {:>3}  rot {:>3}  rescale {:>3}  relin {:>3}",
        "TOTAL", t.add, t.add_plain, t.mul, t.mul_plain, t.rotate, t.rescale, t.relin
    );
    let steps: Vec<usize> = sched.rotation_steps().into_iter().collect();
    println!("  galois steps ({}): {steps:?}\n", steps.len());
}

fn main() {
    let b_arg: Option<usize> = std::env::args().nth(1).and_then(|a| a.parse().ok());

    // Small trained model: K and L stay readable in the dump.
    let ds = adult::generate(800, 7);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 4,
            tree: cryptotree::forest::tree::TreeConfig {
                max_depth: 3,
                ..Default::default()
            },
            ..Default::default()
        },
        8,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), 2048).expect("packing");
    let p = model.plan;
    let b = b_arg.unwrap_or(p.groups.min(3)).clamp(1, p.groups);
    println!(
        "plan: K={} L={} C={} | span {} | {} sample groups per ciphertext | dumping B={b}\n",
        p.k, p.l, p.c, p.reduce_span, p.groups
    );

    let single = HrfSchedule::compile(&model, 1, true);
    println!("{single}");
    print_counts("B=1", &single);

    let folded = HrfSchedule::compile(&model, b, true);
    println!("{folded}");
    print_counts(&format!("B={b} folded"), &folded);

    let unfolded = HrfSchedule::compile(&model, b, false);
    println!("{unfolded}");
    print_counts(&format!("B={b} unfolded (legacy slot-0 contract)"), &unfolded);

    let saved = unfolded.predicted_rotations() - folded.predicted_rotations();
    println!(
        "extraction fold: {} - {} = {} rotations saved per batch (C·(B−1) = {})",
        unfolded.predicted_rotations(),
        folded.predicted_rotations(),
        saved,
        p.c * (b - 1)
    );
    assert_eq!(saved as usize, p.c * (b - 1));
}
