"""L2: the NRF slot model in JAX, composed from the Pallas kernels.

This is the computation the Rust coordinator serves on the *plaintext*
fast path (and uses to cross-check the homomorphic path): identical
slot-level dataflow to Algorithm 3, minus encryption. It is lowered
once by ``aot.py`` to HLO text and loaded by ``rust/src/runtime``.

Two entry points:

* ``nrf_slots_forward``  — single observation, (S,) -> (C,);
* ``nrf_slots_forward_batch`` — vmapped over a static batch, the shape
  the coordinator's dynamic batcher feeds.
"""

import jax
import jax.numpy as jnp

from compile.kernels.activation import poly_activation
from compile.kernels.packed_matmul import packed_diag_matmul


def nrf_slots_forward(x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs):
    """(S,) slot vector -> (C,) class scores. See kernels/ref.py."""
    u = poly_activation(x_slots - t_slots, coeffs)
    lin = packed_diag_matmul(u, diags) + b_slots
    v = poly_activation(lin, coeffs)
    return w_masks @ v + betas


def nrf_slots_forward_batch(xs, t_slots, diags, b_slots, w_masks, betas, coeffs):
    """(B, S) -> (B, C): vmap over observations, parameters broadcast."""
    return jax.vmap(
        nrf_slots_forward, in_axes=(0, None, None, None, None, None, None)
    )(xs, t_slots, diags, b_slots, w_masks, betas, coeffs)


def nrf_slots_forward_packed(
    x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs, group_span
):
    """(S,) slot vector packed with S // group_span observations ->
    (G, C) per-observation scores.

    The SIMD sample-group layout of the Rust HE server: the output
    reduction is group-local, so independent observations packed at
    ``group_span`` strides never mix (rust/src/hrf/plan.rs).
    """
    u = poly_activation(x_slots - t_slots, coeffs)
    lin = packed_diag_matmul(u, diags) + b_slots
    v = poly_activation(lin, coeffs)
    s = x_slots.shape[0]
    g = s // group_span
    c = w_masks.shape[0]
    masked = w_masks * v  # (C, S)
    per_group = masked.reshape(c, g, group_span).sum(axis=2)  # (C, G)
    return per_group.T + betas


def example_args(s, k, c, m, batch=None):
    """ShapeDtypeStructs for lowering."""
    f32 = jnp.float32
    x = (
        jax.ShapeDtypeStruct((s,), f32)
        if batch is None
        else jax.ShapeDtypeStruct((batch, s), f32)
    )
    return (
        x,
        jax.ShapeDtypeStruct((s,), f32),
        jax.ShapeDtypeStruct((k, s), f32),
        jax.ShapeDtypeStruct((s,), f32),
        jax.ShapeDtypeStruct((c, s), f32),
        jax.ShapeDtypeStruct((c,), f32),
        jax.ShapeDtypeStruct((m,), f32),
    )
