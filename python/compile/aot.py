"""AOT: lower the L2 slot model to HLO text for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  nrf_slots_s{S}_k{K}_c{C}_m{M}.hlo.txt          single observation
  nrf_slots_b{B}_s{S}_k{K}_c{C}_m{M}.hlo.txt     batched
  manifest.txt                                    shapes for the loader

Python runs only here, at build time (`make artifacts`).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import (
    example_args,
    nrf_slots_forward,
    nrf_slots_forward_batch,
)

# Default configuration: matches the Rust side's `fast`/default HRF
# plans (S = N/2 = 4096 slots, K = 16 leaves, C = 2 classes, degree-4
# activation -> m = 5 coefficients, batch 8).
DEFAULT_S = 4096
DEFAULT_K = 16
DEFAULT_C = 2
DEFAULT_M = 5
DEFAULT_B = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_single(s, k, c, m):
    fn = lambda *a: (nrf_slots_forward(*a),)
    return jax.jit(fn).lower(*example_args(s, k, c, m))


def lower_batch(b, s, k, c, m):
    fn = lambda *a: (nrf_slots_forward_batch(*a),)
    return jax.jit(fn).lower(*example_args(s, k, c, m, batch=b))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--s", type=int, default=DEFAULT_S)
    ap.add_argument("--k", type=int, default=DEFAULT_K)
    ap.add_argument("--c", type=int, default=DEFAULT_C)
    ap.add_argument("--m", type=int, default=DEFAULT_M)
    ap.add_argument("--b", type=int, default=DEFAULT_B)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    s, k, c, m, b = args.s, args.k, args.c, args.m, args.b
    single_name = f"nrf_slots_s{s}_k{k}_c{c}_m{m}.hlo.txt"
    batch_name = f"nrf_slots_b{b}_s{s}_k{k}_c{c}_m{m}.hlo.txt"

    single = to_hlo_text(lower_single(s, k, c, m))
    with open(os.path.join(args.out_dir, single_name), "w") as f:
        f.write(single)
    print(f"wrote {single_name} ({len(single)} chars)")

    batched = to_hlo_text(lower_batch(b, s, k, c, m))
    with open(os.path.join(args.out_dir, batch_name), "w") as f:
        f.write(batched)
    print(f"wrote {batch_name} ({len(batched)} chars)")

    # Loader manifest: key=value lines, parsed by rust/src/runtime.
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(
            "\n".join(
                [
                    f"single={single_name}",
                    f"batch={batch_name}",
                    f"s={s}",
                    f"k={k}",
                    f"c={c}",
                    f"m={m}",
                    f"b={b}",
                    "",
                ]
            )
        )
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()
