"""Generate the golden parity fixture shared by the Python and Rust
test suites.

The fixture pins the *slot-level semantics* of the HRF layout — block
replication, generalized diagonals, group-local output reduction — as
concrete numbers: a tiny synthetic packed model (K=4, L=2, C=2 on 64
slots -> 4 sample groups), three observations packed into groups 0–2,
and the layer-by-layer outputs computed by ``kernels/ref.py`` in
float64. ``python/tests/test_golden_parity.py`` recomputes the layers
through ref.py and must reproduce the stored outputs;
``rust/tests/golden_parity.rs`` builds an ``HrfModel`` from the same
operands and must as well. Both passing proves the two slot models are
the same function.

Regenerate (from python/) with:  python -m compile.export_golden
"""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import nrf_slots_forward_layers_ref

S, K, L, C, D = 64, 4, 2, 2, 6
BLOCK = 2 * K - 1
USED = L * BLOCK
GROUP_SPAN = 1 << (USED - 1).bit_length()
GROUPS = S // GROUP_SPAN
N_SAMPLES = 3
OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "golden", "hrf_parity.json")


def build_model(rng):
    """Random per-tree NRF parameters + the packed slot operands,
    laid out exactly as rust/src/hrf/pack.rs does (replicated into
    every sample group)."""
    taus = rng.integers(0, D, size=(L, K - 1)).tolist()
    t = rng.uniform(-0.5, 0.5, size=(L, K - 1))
    v = rng.uniform(-0.25, 0.25, size=(L, K, K - 1))
    b = rng.uniform(-0.5, 0.5, size=(L, K))
    w = rng.uniform(-0.5, 0.5, size=(L, C, K))
    beta = rng.uniform(-0.2, 0.2, size=(L, C))
    alphas = rng.uniform(0.1, 1.0, size=L)

    t_slots = np.zeros(S)
    diag_slots = np.zeros((K, S))
    b_slots = np.zeros(S)
    w_slots = np.zeros((C, S))
    betas = np.zeros(C)
    for li in range(L):
        for g in range(GROUPS):
            base = g * GROUP_SPAN + li * BLOCK
            for j in range(K - 1):
                t_slots[base + j] = t[li, j]
                t_slots[base + K + j] = t[li, j]
            for j in range(K):
                for p in range(K):
                    col = (p + j) % K
                    diag_slots[j, base + p] = v[li, p, col] if col < K - 1 else 0.0
            for p in range(K):
                b_slots[base + p] = b[li, p]
            for ci in range(C):
                for p in range(K):
                    w_slots[ci, base + p] = alphas[li] * w[li, ci, p]
        for ci in range(C):
            betas[ci] += alphas[li] * beta[li, ci]
    return taus, t_slots, diag_slots, b_slots, w_slots, betas


def pack_inputs(taus, xs):
    """Client-side reshuffle: observation g into sample group g."""
    x_slots = np.zeros(S)
    for g, x in enumerate(xs):
        for li in range(L):
            base = g * GROUP_SPAN + li * BLOCK
            for j, feat in enumerate(taus[li]):
                x_slots[base + j] = x[feat]
                x_slots[base + K + j] = x[feat]
    return x_slots


def main():
    rng = np.random.default_rng(20260731)
    taus, t_slots, diag_slots, b_slots, w_slots, betas = build_model(rng)
    # Degree-4 polynomial with nonzero even terms so the fixture also
    # exercises the constant coefficient.
    coeffs = np.array([0.05, 1.1, -0.07, -0.32, 0.015])
    xs = rng.uniform(0.0, 1.0, size=(N_SAMPLES, D))
    x_slots = pack_inputs(taus, xs)

    u, v, scores = nrf_slots_forward_layers_ref(
        jnp.asarray(x_slots),
        jnp.asarray(t_slots),
        jnp.asarray(diag_slots),
        jnp.asarray(b_slots),
        jnp.asarray(w_slots),
        jnp.asarray(betas),
        jnp.asarray(coeffs),
        GROUP_SPAN,
    )
    assert u.dtype == jnp.float64, "fixture must be generated in float64"

    fixture = {
        "s": S,
        "k": K,
        "l": L,
        "c": C,
        "d": D,
        "group_span": GROUP_SPAN,
        "groups": GROUPS,
        "n_samples": N_SAMPLES,
        "coeffs": coeffs.tolist(),
        "taus": taus,
        "t_slots": t_slots.tolist(),
        "diag_slots": diag_slots.tolist(),
        "b_slots": b_slots.tolist(),
        "w_slots": w_slots.tolist(),
        "betas": betas.tolist(),
        "x_slots": x_slots.tolist(),
        "expect_u": np.asarray(u).tolist(),
        "expect_v": np.asarray(v).tolist(),
        "expect_scores": np.asarray(scores).tolist(),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(fixture, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)} "
          f"(S={S} K={K} L={L} C={C}, {GROUPS} groups, {N_SAMPLES} samples)")


if __name__ == "__main__":
    main()
