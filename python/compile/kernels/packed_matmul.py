"""Pallas L1 kernel: packed matrix multiplication by diagonals.

The paper's Algorithm 1 evaluates all L trees' KxK leaf-localization
matrices simultaneously: K elementwise multiply-accumulates against
rotated copies of the slot vector. This kernel is the TPU adaptation
(DESIGN.md §Hardware-Adaptation):

* the whole slot vector (S <= 8192 f32 = 32 KiB) is staged into VMEM
  once and stays resident across all K iterations — the memory-hierarchy
  restatement of "one ciphertext, many packed operands";
* rotations become ``jnp.roll`` on the in-VMEM vector (the analogue of
  the CKKS Galois rotation, which is "free" relative to HBM traffic);
* the K-step loop is unrolled at trace time (K is static), feeding the
  VPU with elementwise FMAs — there is no dense contraction here, so
  the MXU is deliberately *not* used.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO, which is what
the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, diags_ref, o_ref, *, k):
    u = u_ref[...]
    acc = jnp.zeros_like(u)
    for j in range(k):  # K is static: unrolled, no carried VMEM traffic
        acc = acc + diags_ref[j, :] * jnp.roll(u, -j)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def packed_diag_matmul(u, diags, interpret=True):
    """Sum_j diags[j] * roll_left(u, j) as a Pallas call.

    u: (S,) f32; diags: (K, S) f32 -> (S,) f32.
    """
    k, s = diags.shape
    assert u.shape == (s,), f"shape mismatch: {u.shape} vs {diags.shape}"
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((s,), u.dtype),
        interpret=interpret,
    )(u, diags)
