"""Pallas L1 kernel: slot-wise polynomial activation (Horner).

The HE side evaluates the activation with the power-basis method to
minimize multiplicative depth; in plaintext f32 depth is irrelevant, so
Horner (fewest multiplies, one VMEM-resident pass) is the right shape.
The coefficient vector lives in its own (tiny) VMEM block; the degree
is static so the loop unrolls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, coeffs_ref, o_ref, *, m):
    x = x_ref[...]
    acc = jnp.full_like(x, coeffs_ref[m - 1])
    for i in range(m - 2, -1, -1):  # static unroll: Horner
        acc = acc * x + coeffs_ref[i]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def poly_activation(x, coeffs, interpret=True):
    """Slot-wise sum_i coeffs[i] * x^i. x: (S,), coeffs: (m,) -> (S,)."""
    (m,) = coeffs.shape
    return pl.pallas_call(
        functools.partial(_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, coeffs)
