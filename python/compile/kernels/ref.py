"""Pure-jnp oracles for the Pallas kernels (build-time correctness).

These mirror, slot for slot, the homomorphic dataflow of the Rust HRF
server (``rust/src/hrf/server.rs``):

* ``packed_diag_matmul_ref`` — Algorithm 1: sum over K generalized
  diagonals of the elementwise product with the left-rotated slot
  vector. ``jnp.roll(u, -j)`` is the plaintext analogue of the CKKS
  Galois rotation by ``j``.
* ``poly_activation_ref`` — the degree-m activation polynomial applied
  slot-wise (Horner).
* ``nrf_slots_forward_ref`` — the full Algorithm 3 slot model.
"""

import jax.numpy as jnp


def packed_diag_matmul_ref(u, diags):
    """Sum_j diags[j] * roll_left(u, j).

    u:     (S,)  slot vector
    diags: (K, S) generalized diagonals, zero outside tree blocks
    """
    k = diags.shape[0]
    acc = jnp.zeros_like(u)
    for j in range(k):
        acc = acc + diags[j] * jnp.roll(u, -j)
    return acc


def poly_activation_ref(x, coeffs):
    """Horner evaluation of sum_i coeffs[i] x^i, slot-wise.

    coeffs: (m,) low-order first.
    """
    acc = jnp.zeros_like(x)
    for c in coeffs[::-1]:
        acc = acc * x + c
    return acc


def nrf_slots_forward_ref(x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs):
    """Full NRF slot model (Algorithm 3 dataflow, plaintext).

    x_slots: (S,)   packed replicated input  (client's x-tilde)
    t_slots: (S,)   packed replicated thresholds
    diags:   (K, S) leaf-localization diagonals
    b_slots: (S,)   leaf biases
    w_masks: (C, S) per-class alpha-weighted output masks
    betas:   (C,)   per-class combined biases
    coeffs:  (m,)   activation polynomial
    returns: (C,)   class scores
    """
    u = poly_activation_ref(x_slots - t_slots, coeffs)
    lin = packed_diag_matmul_ref(u, diags) + b_slots
    v = poly_activation_ref(lin, coeffs)
    return w_masks @ v + betas
