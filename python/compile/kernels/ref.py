"""Pure-jnp oracles for the Pallas kernels (build-time correctness).

These mirror, slot for slot, the homomorphic dataflow of the Rust HRF
server (``rust/src/hrf/server.rs``):

* ``packed_diag_matmul_ref`` — Algorithm 1: sum over K generalized
  diagonals of the elementwise product with the left-rotated slot
  vector. ``jnp.roll(u, -j)`` is the plaintext analogue of the CKKS
  Galois rotation by ``j``.
* ``poly_activation_ref`` — the degree-m activation polynomial applied
  slot-wise (Horner).
* ``nrf_slots_forward_ref`` — the full Algorithm 3 slot model.
* ``nrf_slots_forward_groups_ref`` — the sample-group variant: one slot
  vector carries ``S / group_span`` independent observations and the
  output reduction is group-local, mirroring the Rust HE server's
  group-local rotate-and-sum.
"""

import jax.numpy as jnp


def packed_diag_matmul_ref(u, diags):
    """Sum_j diags[j] * roll_left(u, j).

    u:     (S,)  slot vector
    diags: (K, S) generalized diagonals, zero outside tree blocks
    """
    k = diags.shape[0]
    acc = jnp.zeros_like(u)
    for j in range(k):
        acc = acc + diags[j] * jnp.roll(u, -j)
    return acc


def poly_activation_ref(x, coeffs):
    """Horner evaluation of sum_i coeffs[i] x^i, slot-wise.

    coeffs: (m,) low-order first.
    """
    acc = jnp.zeros_like(x)
    for c in coeffs[::-1]:
        acc = acc * x + c
    return acc


def nrf_slots_forward_ref(x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs):
    """Full NRF slot model (Algorithm 3 dataflow, plaintext).

    x_slots: (S,)   packed replicated input  (client's x-tilde)
    t_slots: (S,)   packed replicated thresholds
    diags:   (K, S) leaf-localization diagonals
    b_slots: (S,)   leaf biases
    w_masks: (C, S) per-class alpha-weighted output masks
    betas:   (C,)   per-class combined biases
    coeffs:  (m,)   activation polynomial
    returns: (C,)   class scores
    """
    u = poly_activation_ref(x_slots - t_slots, coeffs)
    lin = packed_diag_matmul_ref(u, diags) + b_slots
    v = poly_activation_ref(lin, coeffs)
    return w_masks @ v + betas


def nrf_slots_forward_layers_ref(
    x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs, group_span
):
    """Group-local slot model, layer by layer.

    Same dataflow as ``nrf_slots_forward_ref`` except the output
    reduction sums each ``group_span``-aligned span separately, so a
    slot vector packed with ``S / group_span`` observations yields one
    score row per observation.

    returns: (u, v, scores) with u, v of shape (S,) and scores of
    shape (G, C), G = S // group_span.
    """
    u = poly_activation_ref(x_slots - t_slots, coeffs)
    lin = packed_diag_matmul_ref(u, diags) + b_slots
    v = poly_activation_ref(lin, coeffs)
    s = x_slots.shape[0]
    g = s // group_span
    c = w_masks.shape[0]
    masked = w_masks * v  # (C, S)
    per_group = masked.reshape(c, g, group_span).sum(axis=2)  # (C, G)
    return u, v, per_group.T + betas  # scores: (G, C)


def nrf_slots_forward_groups_ref(
    x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs, group_span
):
    """Per-group class scores, shape (G, C). See the layers variant."""
    return nrf_slots_forward_layers_ref(
        x_slots, t_slots, diags, b_slots, w_masks, betas, coeffs, group_span
    )[2]
