"""Test bootstrap for the python/ tree.

Two responsibilities:

* put ``python/`` on ``sys.path`` so ``compile.*`` imports resolve no
  matter where pytest is invoked from;
* provide a minimal fallback for ``hypothesis`` when the real package
  is unavailable (offline CI image). The fallback implements exactly
  the surface these tests use — ``given`` with keyword strategies,
  ``settings`` profiles, and ``strategies.integers`` — running a fixed
  number of seeded pseudo-random examples per test. It exists so the
  suite stays runnable everywhere; with real hypothesis installed it is
  inert.
"""

import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

try:  # pragma: no cover - prefer the real thing when present
    import hypothesis  # noqa: F401
except ImportError:  # build the stub module tree
    import types

    _MAX_EXAMPLES = 25

    class _IntStrategy:
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def draw(self, rng):
            return rng.randint(self.min_value, self.max_value)

    def _integers(min_value, max_value):
        return _IntStrategy(min_value, max_value)

    def _given(**strategies):
        def deco(fn):
            # NB: the wrapper must expose a parameter-less signature —
            # pytest would otherwise look for fixtures named after the
            # strategy kwargs (which functools.wraps would leak).
            def wrapper():
                rng = random.Random(0xC0FFEE ^ hash(fn.__name__))
                for _ in range(_MAX_EXAMPLES):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    class _Settings:
        _profiles = {}

        def __init__(self, **kwargs):
            pass

        @classmethod
        def register_profile(cls, name, **kwargs):
            cls._profiles[name] = kwargs

        @classmethod
        def load_profile(cls, name):
            global _MAX_EXAMPLES
            _MAX_EXAMPLES = cls._profiles.get(name, {}).get(
                "max_examples", _MAX_EXAMPLES
            )

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _Settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    hyp.strategies = strategies
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
