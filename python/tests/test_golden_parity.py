"""Golden parity: ref.py must reproduce the stored fixture exactly.

The fixture (tests/golden/hrf_parity.json, written by
``compile.export_golden``) holds a tiny packed HRF model, a slot vector
carrying three observations in sample groups 0-2, and the layer-by-layer
outputs computed in float64. The Rust twin
(rust/tests/golden_parity.rs) checks the same numbers against
``HrfModel::forward_slots_layers`` — both passing proves the Python and
Rust slot models are the same function, layer by layer.
"""

import json
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import (
    nrf_slots_forward_groups_ref,
    nrf_slots_forward_layers_ref,
)

FIXTURE = Path(__file__).parent / "golden" / "hrf_parity.json"
TOL = 1e-9


def load():
    with open(FIXTURE) as f:
        return json.load(f)


def test_ref_reproduces_golden_layers():
    fx = load()
    u, v, scores = nrf_slots_forward_layers_ref(
        jnp.asarray(fx["x_slots"]),
        jnp.asarray(fx["t_slots"]),
        jnp.asarray(fx["diag_slots"]),
        jnp.asarray(fx["b_slots"]),
        jnp.asarray(fx["w_slots"]),
        jnp.asarray(fx["betas"]),
        jnp.asarray(fx["coeffs"]),
        fx["group_span"],
    )
    assert u.dtype == jnp.float64
    np.testing.assert_allclose(u, fx["expect_u"], rtol=0, atol=TOL)
    np.testing.assert_allclose(v, fx["expect_v"], rtol=0, atol=TOL)
    np.testing.assert_allclose(scores, fx["expect_scores"], rtol=0, atol=TOL)


def test_group_scores_shape_and_reduction():
    fx = load()
    scores = nrf_slots_forward_groups_ref(
        jnp.asarray(fx["x_slots"]),
        jnp.asarray(fx["t_slots"]),
        jnp.asarray(fx["diag_slots"]),
        jnp.asarray(fx["b_slots"]),
        jnp.asarray(fx["w_slots"]),
        jnp.asarray(fx["betas"]),
        jnp.asarray(fx["coeffs"]),
        fx["group_span"],
    )
    assert scores.shape == (fx["groups"], fx["c"])
    np.testing.assert_allclose(scores, fx["expect_scores"], rtol=0, atol=TOL)


def test_fixture_layout_invariants():
    """The fixture's operands obey the packed layout the Rust side
    assumes: w masks zero outside leaf slots, thresholds replicated."""
    fx = load()
    k, block, span = fx["k"], 2 * fx["k"] - 1, fx["group_span"]
    used = fx["l"] * block
    w = np.asarray(fx["w_slots"])
    t = np.asarray(fx["t_slots"])
    for g in range(fx["groups"]):
        off = g * span
        # Replication within each tree block.
        for li in range(fx["l"]):
            base = off + li * block
            for j in range(k - 1):
                assert t[base + j] == t[base + k + j]
            assert t[base + k - 1] == 0.0
            assert np.all(w[:, base + k : base + block] == 0.0)
        # Group tail carries no mask mass.
        assert np.all(w[:, off + used : off + span] == 0.0)
