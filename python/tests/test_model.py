"""L2 slot-model correctness and AOT lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.aot import lower_batch, lower_single, to_hlo_text
from compile.kernels.ref import nrf_slots_forward_groups_ref, nrf_slots_forward_ref
from compile.model import (
    example_args,
    nrf_slots_forward,
    nrf_slots_forward_batch,
    nrf_slots_forward_packed,
)

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def make_inputs(s, k, c, m, seed, batch=None):
    rng = np.random.default_rng(seed)
    f = lambda *shape: jnp.asarray(rng.uniform(-1, 1, shape), dtype=jnp.float32)
    x = f(batch, s) if batch else f(s)
    return (x, f(s), f(k, s), f(s), f(c, s), f(c), f(m))


@given(
    s_exp=st.integers(min_value=5, max_value=9),
    k_exp=st.integers(min_value=1, max_value=4),
    c=st.integers(min_value=1, max_value=3),
    m=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_model_matches_ref(s_exp, k_exp, c, m, seed):
    args = make_inputs(2**s_exp, 2**k_exp, c, m, seed)
    got = nrf_slots_forward(*args)
    want = nrf_slots_forward_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_batch_matches_single():
    s, k, c, m, b = 64, 4, 2, 5, 6
    args = make_inputs(s, k, c, m, 42, batch=b)
    batched = nrf_slots_forward_batch(*args)
    assert batched.shape == (b, c)
    for i in range(b):
        single = nrf_slots_forward(args[0][i], *args[1:])
        np.testing.assert_allclose(batched[i], single, rtol=1e-5, atol=1e-6)


def test_packed_groups_match_ref():
    # The kernel-composed packed-group model (one slot vector, many
    # observations, group-local reduction) must agree with the pure-jnp
    # group reference — the same oracle the Rust HE server is checked
    # against.
    s, k, c, m, span = 128, 4, 2, 5, 32
    args = make_inputs(s, k, c, m, 77)
    got = nrf_slots_forward_packed(*args, span)
    want = nrf_slots_forward_groups_ref(*args, span)
    assert got.shape == (s // span, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_output_shapes():
    s, k, c, m = 128, 8, 2, 5
    args = make_inputs(s, k, c, m, 1)
    assert nrf_slots_forward(*args).shape == (c,)


def test_lowering_produces_hlo_text():
    txt = to_hlo_text(lower_single(64, 4, 2, 5))
    assert "HloModule" in txt
    assert "f32[64]" in txt  # input layout survived
    btxt = to_hlo_text(lower_batch(4, 64, 4, 2, 5))
    assert "HloModule" in btxt
    assert "f32[4,64]" in btxt


def test_lowered_single_runs_and_matches():
    # Execute the lowered (AOT) computation via jax and compare to the
    # eager model — guards against lowering/abstraction drift.
    s, k, c, m = 64, 4, 2, 5
    lowered = lower_single(s, k, c, m)
    compiled = lowered.compile()
    args = make_inputs(s, k, c, m, 9)
    (got,) = compiled(*args)
    want = nrf_slots_forward(*args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_example_args_shapes():
    a = example_args(32, 4, 2, 5)
    assert a[0].shape == (32,)
    assert a[2].shape == (4, 32)
    ab = example_args(32, 4, 2, 5, batch=3)
    assert ab[0].shape == (3, 32)
