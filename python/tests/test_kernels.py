"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and values; fixed cases pin the slot-layout
semantics the Rust HRF relies on (rotation direction, block
replication).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.activation import poly_activation
from compile.kernels.packed_matmul import packed_diag_matmul
from compile.kernels.ref import (
    packed_diag_matmul_ref,
    poly_activation_ref,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed, lo=-1.0, hi=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape), dtype=jnp.float32)


# ---------------------------------------------------------------- matmul
@given(
    s_exp=st.integers(min_value=4, max_value=9),
    k_exp=st.integers(min_value=0, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_packed_matmul_matches_ref(s_exp, k_exp, seed):
    s, k = 2**s_exp, 2**k_exp
    u = rand((s,), seed)
    diags = rand((k, s), seed + 1)
    got = packed_diag_matmul(u, diags)
    want = packed_diag_matmul_ref(u, diags)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_packed_matmul_rotation_direction():
    # diag_1 = e_0 selects u[(0+1) % S] = u[1]: left rotation, matching
    # the paper's Rotation(z, l) and the Rust evaluator convention.
    s = 8
    u = jnp.arange(s, dtype=jnp.float32)
    diags = jnp.zeros((2, s), dtype=jnp.float32)
    diags = diags.at[1, 0].set(1.0)
    out = packed_diag_matmul(u, diags)
    assert out[0] == pytest.approx(1.0)  # u[1]


def test_packed_matmul_identity_diagonal():
    s = 16
    u = rand((s,), 3)
    diags = jnp.ones((1, s), dtype=jnp.float32)
    np.testing.assert_allclose(packed_diag_matmul(u, diags), u, rtol=1e-6)


def test_packed_matmul_blockwise_equals_dense_matvec():
    # One 2K-1 block with a replicated input must equal the dense KxK
    # matvec — the property Algorithm 1 is built on.
    k = 4
    block = 2 * k - 1
    rng = np.random.default_rng(7)
    v = rng.uniform(-1, 1, (k, k)).astype(np.float32)
    uvec = rng.uniform(-1, 1, (k,)).astype(np.float32)
    # Replicated block layout: (u_0..u_{k-1} | u_0..u_{k-2})
    u_slots = np.zeros(block, dtype=np.float32)
    u_slots[:k] = uvec
    u_slots[k:] = uvec[: k - 1]
    diags = np.zeros((k, block), dtype=np.float32)
    for j in range(k):
        for p in range(k):
            diags[j, p] = v[p, (p + j) % k]
    out = packed_diag_matmul(jnp.asarray(u_slots), jnp.asarray(diags))
    np.testing.assert_allclose(np.asarray(out[:k]), v @ uvec, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------ activation
@given(
    s_exp=st.integers(min_value=4, max_value=10),
    m=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_activation_matches_ref(s_exp, m, seed):
    s = 2**s_exp
    x = rand((s,), seed)
    coeffs = rand((m,), seed + 2)
    got = poly_activation(x, coeffs)
    want = poly_activation_ref(x, coeffs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_activation_constant_poly():
    x = rand((32,), 5)
    coeffs = jnp.asarray([0.25], dtype=jnp.float32)
    np.testing.assert_allclose(
        poly_activation(x, coeffs), jnp.full((32,), 0.25), rtol=1e-6
    )


def test_activation_linear_poly():
    x = rand((64,), 6)
    coeffs = jnp.asarray([0.5, 2.0], dtype=jnp.float32)
    np.testing.assert_allclose(poly_activation(x, coeffs), 0.5 + 2.0 * x, rtol=1e-5)


def test_activation_matches_numpy_polyval():
    x = rand((128,), 8)
    coeffs = np.array([0.1, 0.9, -0.2, 0.0, -0.3], dtype=np.float32)
    want = np.polyval(coeffs[::-1], np.asarray(x))
    got = poly_activation(x, jnp.asarray(coeffs))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
