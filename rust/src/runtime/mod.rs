//! Runtime for the AOT slot model compiled from `python/compile`.
//!
//! The Rust coordinator uses this for (a) the plaintext fast path
//! (clients who opt out of encryption get the same slot-level model,
//! batched) and (b) an independently-derived numerical cross-check of
//! the homomorphic evaluator. `aot.py`'s `manifest.txt` is the loader
//! contract; execution currently runs on a pure-Rust f32 backend (the
//! PJRT/XLA executor is unavailable offline — see `slot_model.rs`).

pub mod slot_model;

pub use slot_model::{SlotModel, SlotModelParams, SlotShape};
