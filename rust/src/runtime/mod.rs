//! Execution runtime: the schedule engine and the AOT slot model.
//!
//! Since the engine refactor this module is organized around **one
//! schedule, many backends**:
//!
//! * [`engine`] — the execution-engine API. A compiled
//!   [`HrfSchedule`](crate::hrf::HrfSchedule) is replayed by the
//!   single generic [`Engine`](engine::Engine) against any
//!   [`ScheduleBackend`](engine::ScheduleBackend): CKKS ciphertexts
//!   ([`CkksBackend`](engine::CkksBackend), driven by
//!   `HrfServer::execute`), plaintext f32 slots
//!   ([`SlotBackend`](engine::SlotBackend)), or a dry-run op counter
//!   ([`CountingBackend`](engine::CountingBackend), behind the Table-1
//!   predictions and Galois-key derivation). Schedule-level
//!   optimizations are [`SchedulePass`](engine::SchedulePass)es,
//!   written once and valid on every backend.
//! * [`slot_model`] — loader/executor for the AOT slot model compiled
//!   from `python/compile`. The Rust coordinator uses it for (a) the
//!   plaintext fast path (clients who opt out of encryption get the
//!   same slot-level model, batched) and (b) an independently-derived
//!   numerical cross-check of the homomorphic evaluator. `aot.py`'s
//!   `manifest.txt` is the loader contract; execution runs through the
//!   engine's f32 backend (the PJRT/XLA executor is unavailable
//!   offline — restoring it now means implementing `ScheduleBackend`,
//!   not writing a fourth interpreter).

pub mod engine;
pub mod slot_model;

pub use engine::{
    CkksBackend, CountingBackend, Engine, EngineRun, FuseMulRescale, PassPipeline, ScheduleBackend,
    SchedulePass, SlotBackend,
};
pub use slot_model::{SlotModel, SlotModelParams, SlotShape};
