//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas slot
//! model from `artifacts/*.hlo.txt`.
//!
//! The Rust coordinator uses this for (a) the plaintext fast path
//! (clients who opt out of encryption get the same slot-level model,
//! batched) and (b) an independently-derived numerical cross-check of
//! the homomorphic evaluator. HLO text is the interchange format (see
//! aot.py); compilation happens once at load.

pub mod slot_model;

pub use slot_model::{SlotModel, SlotModelParams};
