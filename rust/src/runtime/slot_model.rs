//! Loader/executor for the AOT slot model.
//!
//! `aot.py` writes a `manifest.txt` naming the single-observation and
//! batched computations and their static shapes; [`SlotModel::load`]
//! parses it and serves f32 inference from then on.
//!
//! Offline note: the PJRT/XLA executor (the `xla` crate) is not
//! available in this environment, so the compiled HLO files are treated
//! as opaque artifacts and the computation itself runs through the
//! generic schedule [`Engine`](crate::runtime::engine::Engine) on the
//! f32 [`SlotBackend`](crate::runtime::engine::SlotBackend): the very
//! interpreter the CKKS executor uses replays the same compiled
//! [`HrfSchedule`](crate::hrf::HrfSchedule) over plaintext slot
//! vectors (rotations become cyclic shifts, plaintext muls become
//! element-wise products, rescales are no-ops). Since every backend
//! runs literally one program through one interpreter, the
//! python↔rust golden parity and the HE↔plaintext oracle agreement
//! hold by construction — including for pass-optimized schedules. The
//! manifest stays the loader contract, and restoring a PJRT execution
//! path now means implementing
//! [`ScheduleBackend`](crate::runtime::engine::ScheduleBackend), not
//! writing another interpreter.
//!
//! Batching comes in two flavors, mirroring the HE side:
//!
//! * **outer batch** ([`SlotModel::infer_batch`]) — up to `B` separate
//!   slot vectors, the shape the coordinator's plaintext batcher feeds;
//! * **packed groups** ([`SlotModel::infer_packed`]) — one slot vector
//!   carrying `plan.groups` observations at `group_span` strides, the
//!   plaintext oracle of the batched homomorphic evaluation.

use crate::hrf::schedule::PlainOperand;
use crate::hrf::{HrfModel, HrfSchedule};
use crate::runtime::engine::{Engine, PassPipeline, SlotBackend};
use std::path::Path;

/// Static shape configuration of the compiled model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotShape {
    pub s: usize,
    pub k: usize,
    pub c: usize,
    pub m: usize,
    pub b: usize,
}

/// Model parameters converted once into f32 slot vectors, plus the
/// compiled schedule the plaintext executor walks.
pub struct SlotModelParams {
    t: Vec<f32>,
    diags: Vec<Vec<f32>>,
    b: Vec<f32>,
    w: Vec<Vec<f32>>,
    coeffs: Vec<f32>,
    /// Compiled full-capacity folded schedule (B = groups), optimized
    /// by the standard pass pipeline like the server's: the engine
    /// replays it on the f32 backend and reads scores straight from
    /// the slot-addressed outputs.
    schedule: HrfSchedule,
    /// Number of sample groups per slot vector.
    groups: usize,
    pub shape: SlotShape,
}

impl SlotModelParams {
    /// Pack an [`HrfModel`]'s parameters for a compiled shape. The
    /// HRF plan's slot count must equal the artifact's `S`; the
    /// activation is zero-padded to `m` coefficients.
    pub fn from_hrf(model: &HrfModel, shape: SlotShape) -> Result<Self, String> {
        let p = &model.plan;
        if p.slots != shape.s {
            return Err(format!(
                "HRF packed for {} slots, artifact expects {}",
                p.slots, shape.s
            ));
        }
        if p.k != shape.k {
            return Err(format!("HRF K={} but artifact K={}", p.k, shape.k));
        }
        if p.c != shape.c {
            return Err(format!("HRF C={} but artifact C={}", p.c, shape.c));
        }
        if model.act_coeffs.len() > shape.m {
            return Err(format!(
                "activation degree {} exceeds artifact m={}",
                model.act_coeffs.len() - 1,
                shape.m
            ));
        }
        let f32v = |v: &[f64]| -> Vec<f32> { v.iter().map(|&x| x as f32).collect() };
        let mut coeffs = f32v(&model.act_coeffs);
        coeffs.resize(shape.m, 0.0);
        Ok(SlotModelParams {
            t: f32v(&model.t_slots),
            diags: model.diag_slots.iter().map(|d| f32v(d)).collect(),
            b: f32v(&model.b_slots),
            w: model.w_slots.iter().map(|w| f32v(w)).collect(),
            coeffs,
            schedule: HrfSchedule::compile(model, p.groups, true)
                .optimize(PassPipeline::standard().passes())
                .assume_prepacked(),
            groups: p.groups,
            shape,
        })
    }

    /// Horner evaluation of the padded activation coefficients — the
    /// f32 backend's `poly_activation` primitive.
    pub(crate) fn activation(&self, x: f32) -> f32 {
        let mut acc = 0.0f32;
        for &c in self.coeffs.iter().rev() {
            acc = acc * x + c;
        }
        acc
    }

    /// Resolve a schedule operand to its f32 slot vector — the f32
    /// backend's operand store (mirror of `HrfModel::operand_slots`).
    pub(crate) fn operand(&self, op: PlainOperand) -> &[f32] {
        match op {
            PlainOperand::Thresholds => &self.t,
            PlainOperand::Biases => &self.b,
            PlainOperand::Diag(j) => &self.diags[j],
            PlainOperand::ClassWeights(c) => &self.w[c],
        }
    }

    /// Run an arbitrary compiled schedule over per-sample f32 inputs
    /// through the generic engine, returning `sched.b × C` score rows
    /// (sample-major). `inputs[g]` is sample `g`'s slot vector; the
    /// schedule's `Pack` segment assembles them.
    pub fn run_schedule(&self, sched: &HrfSchedule, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        assert!(
            inputs.len() >= sched.b,
            "schedule packs {} inputs, got {} (use run_schedule_prepacked for one packed vector)",
            sched.b,
            inputs.len()
        );
        self.run_inputs(sched, inputs)
    }

    /// Run a schedule whose whole batch arrives as **one pre-packed**
    /// slot vector: any placement ops the schedule still carries read
    /// the missing inputs as zeros and change nothing (the cached
    /// full-capacity schedule is `assume_prepacked`-stripped of them
    /// entirely).
    pub fn run_schedule_prepacked(&self, sched: &HrfSchedule, packed: &[f32]) -> Vec<Vec<f32>> {
        let inputs = vec![packed.to_vec()];
        self.run_inputs(sched, &inputs)
    }

    fn run_inputs(&self, sched: &HrfSchedule, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut backend = SlotBackend::new(self, inputs);
        let run = Engine::run(sched, &mut backend);
        let scores = Engine::read_outputs(sched, &run, &mut backend);
        let mut rows = vec![vec![0.0f32; self.shape.c]; sched.b];
        for (o, s) in sched.outputs.iter().zip(scores) {
            rows[o.sample][o.class] = s;
        }
        rows
    }

    /// The full slot dataflow of the cached full-capacity schedule on
    /// one pre-packed slot vector. Returns `groups × C` scores.
    fn forward_groups(&self, x_slots: &[f32]) -> Vec<Vec<f32>> {
        self.run_schedule_prepacked(&self.schedule, x_slots)
    }
}

/// Loaded slot-model executor.
pub struct SlotModel {
    pub shape: SlotShape,
}

impl SlotModel {
    /// Load from an artifacts directory (written by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            format!(
                "reading {}/manifest.txt — run `make artifacts` ({e})",
                dir.display()
            )
        })?;
        let get = |key: &str| -> Result<String, String> {
            manifest
                .lines()
                .find_map(|l| l.strip_prefix(&format!("{key}=")))
                .map(str::to_string)
                .ok_or_else(|| format!("manifest missing key {key}"))
        };
        let parse = |key: &str| -> Result<usize, String> {
            get(key)?
                .parse::<usize>()
                .map_err(|e| format!("manifest key {key}: {e}"))
        };
        let shape = SlotShape {
            s: parse("s")?,
            k: parse("k")?,
            c: parse("c")?,
            m: parse("m")?,
            b: parse("b")?,
        };
        Ok(SlotModel { shape })
    }

    /// Single-observation inference: packed slot vector (observation in
    /// group 0) → C scores.
    pub fn infer(&self, x_slots: &[f32], params: &SlotModelParams) -> Result<Vec<f32>, String> {
        if x_slots.len() != self.shape.s {
            return Err(format!(
                "expected {} slots, got {}",
                self.shape.s,
                x_slots.len()
            ));
        }
        Ok(params
            .forward_groups(x_slots)
            .into_iter()
            .next()
            .expect("plan has >= 1 group"))
    }

    /// Batched inference: `n ≤ B` packed slot vectors → per-sample C
    /// scores (the coordinator's plaintext batcher shape).
    pub fn infer_batch(
        &self,
        xs: &[Vec<f32>],
        params: &SlotModelParams,
    ) -> Result<Vec<Vec<f32>>, String> {
        let b = self.shape.b;
        if xs.is_empty() || xs.len() > b {
            return Err(format!("batch size {} outside 1..={b}", xs.len()));
        }
        xs.iter().map(|x| self.infer(x, params)).collect()
    }

    /// Packed-group inference: one slot vector carrying `n_samples`
    /// observations (observation `g` at group offset `g·group_span`) →
    /// per-sample C scores. The plaintext oracle of the batched HE
    /// evaluation.
    pub fn infer_packed(
        &self,
        x_slots: &[f32],
        n_samples: usize,
        params: &SlotModelParams,
    ) -> Result<Vec<Vec<f32>>, String> {
        if x_slots.len() != self.shape.s {
            return Err(format!(
                "expected {} slots, got {}",
                self.shape.s,
                x_slots.len()
            ));
        }
        if n_samples == 0 || n_samples > params.groups {
            return Err(format!(
                "sample count {n_samples} outside 1..={}",
                params.groups
            ));
        }
        let mut rows = params.forward_groups(x_slots);
        rows.truncate(n_samples);
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::hrf::client::{reshuffle_and_pack, reshuffle_and_pack_group};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    fn hrf(slots: usize) -> (crate::data::Dataset, HrfModel) {
        let ds = adult::generate(400, 19);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                ..Default::default()
            },
            20,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, 14, slots).unwrap();
        (ds, hm)
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let (_, hm) = hrf(2048);
        let bad = SlotShape {
            s: 4096,
            k: hm.plan.k,
            c: 2,
            m: 5,
            b: 8,
        };
        assert!(SlotModelParams::from_hrf(&hm, bad).is_err());
        let good = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: 2,
            m: 5,
            b: 8,
        };
        assert!(SlotModelParams::from_hrf(&hm, good).is_ok());
    }

    #[test]
    fn infer_matches_rust_slot_math() {
        let (ds, hm) = hrf(2048);
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let sm = SlotModel { shape };
        for x in ds.x.iter().take(16) {
            let slots = reshuffle_and_pack(&hm, x);
            let slots_f32: Vec<f32> = slots.iter().map(|&v| v as f32).collect();
            let got = sm.infer(&slots_f32, &params).unwrap();
            let want = hm.forward_slots_plain(&slots);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (*g as f64 - w).abs() < 1e-3,
                    "slot-model executor deviates: {got:?} vs {want:?}"
                );
            }
        }
    }

    #[test]
    fn packed_groups_match_per_sample_inference() {
        let (ds, hm) = hrf(2048);
        let n = hm.plan.groups.min(4);
        assert!(n >= 2, "need multiple groups");
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let sm = SlotModel { shape };
        let xs: Vec<Vec<f64>> = ds.x.iter().take(n).cloned().collect();
        let packed = reshuffle_and_pack_group(&hm, &xs);
        let packed_f32: Vec<f32> = packed.iter().map(|&v| v as f32).collect();
        let rows = sm.infer_packed(&packed_f32, n, &params).unwrap();
        for (g, x) in xs.iter().enumerate() {
            let single_slots: Vec<f32> = reshuffle_and_pack(&hm, x)
                .iter()
                .map(|&v| v as f32)
                .collect();
            let single = sm.infer(&single_slots, &params).unwrap();
            for (a, b) in rows[g].iter().zip(&single) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "packed sample {g} deviates: {:?} vs {single:?}",
                    rows[g]
                );
            }
        }
    }

    #[test]
    fn pass_optimized_schedule_is_exact_on_slot_backend() {
        // The fusion pass must be a no-op numerically on the f32
        // backend (rescale is a no-op there), and feeding B separate
        // single-sample vectors through the schedule's own Pack
        // segment must equal the pre-packed fast path bit for bit.
        let (ds, hm) = hrf(2048);
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let n = hm.plan.groups.min(3);
        assert!(n >= 2);
        let xs: Vec<Vec<f64>> = ds.x.iter().take(n).cloned().collect();
        let singles: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| {
                reshuffle_and_pack(&hm, x)
                    .iter()
                    .map(|&v| v as f32)
                    .collect()
            })
            .collect();
        let raw = HrfSchedule::compile(&hm, n, true);
        let fused = raw
            .clone()
            .optimize(crate::runtime::engine::PassPipeline::standard().passes());
        assert!(fused.ops.len() < raw.ops.len(), "pass must fuse");
        let a = params.run_schedule(&raw, &singles);
        let b = params.run_schedule(&fused, &singles);
        assert_eq!(a, b, "fusion changed f32 results");
        // Pack-segment path == pre-packed fast path.
        let packed: Vec<f32> = reshuffle_and_pack_group(&hm, &xs)
            .iter()
            .map(|&v| v as f32)
            .collect();
        let sm = SlotModel { shape };
        let rows = sm.infer_packed(&packed, n, &params).unwrap();
        assert_eq!(&a[..], &rows[..n], "Pack segment deviates from pre-packed input");
    }

    #[test]
    fn schedule_walk_matches_f64_oracle() {
        // The schedule-walking executor must agree with the direct
        // f64 slot math in pack.rs (the golden-parity oracle).
        let (ds, hm) = hrf(2048);
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let n = hm.plan.groups.min(3);
        let xs: Vec<Vec<f64>> = ds.x.iter().take(n).cloned().collect();
        let packed = reshuffle_and_pack_group(&hm, &xs);
        let packed_f32: Vec<f32> = packed.iter().map(|&v| v as f32).collect();
        let rows = params.forward_groups(&packed_f32);
        let oracle = hm.forward_slots_plain_groups(&packed);
        for g in 0..n {
            for (a, b) in rows[g].iter().zip(&oracle[g]) {
                assert!(
                    (*a as f64 - b).abs() < 1e-3,
                    "group {g}: schedule walk {:?} vs oracle {:?}",
                    rows[g],
                    oracle[g]
                );
            }
        }
    }
}
