//! Loader/executor for the AOT slot model.
//!
//! `aot.py` writes a `manifest.txt` naming the single-observation and
//! batched HLO files and their static shapes; [`SlotModel::load`]
//! parses it, compiles both executables on the PJRT CPU client, and
//! serves f32 inference from then on — Python is never involved again.

use crate::hrf::HrfModel;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Static shape configuration of the compiled model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotShape {
    pub s: usize,
    pub k: usize,
    pub c: usize,
    pub m: usize,
    pub b: usize,
}

/// Model parameters converted once into XLA literals.
pub struct SlotModelParams {
    t: xla::Literal,
    diags: xla::Literal,
    b: xla::Literal,
    w: xla::Literal,
    betas: xla::Literal,
    coeffs: xla::Literal,
    pub shape: SlotShape,
}

impl SlotModelParams {
    /// Pack an [`HrfModel`]'s parameters for a compiled shape. The
    /// HRF plan's slot count must equal the artifact's `S`; the
    /// activation is zero-padded to `m` coefficients.
    pub fn from_hrf(model: &HrfModel, shape: SlotShape) -> Result<Self> {
        let p = &model.plan;
        if p.slots != shape.s {
            bail!("HRF packed for {} slots, artifact expects {}", p.slots, shape.s);
        }
        if p.k != shape.k {
            bail!("HRF K={} but artifact K={}", p.k, shape.k);
        }
        if p.c != shape.c {
            bail!("HRF C={} but artifact C={}", p.c, shape.c);
        }
        if model.act_coeffs.len() > shape.m {
            bail!(
                "activation degree {} exceeds artifact m={}",
                model.act_coeffs.len() - 1,
                shape.m
            );
        }
        let f32v = |v: &[f64]| -> Vec<f32> { v.iter().map(|&x| x as f32).collect() };
        let t = xla::Literal::vec1(&f32v(&model.t_slots));
        let flat_diags: Vec<f32> = model
            .diag_slots
            .iter()
            .flat_map(|d| f32v(d))
            .collect();
        let diags =
            xla::Literal::vec1(&flat_diags).reshape(&[shape.k as i64, shape.s as i64])?;
        let b = xla::Literal::vec1(&f32v(&model.b_slots));
        let flat_w: Vec<f32> = model.w_slots.iter().flat_map(|w| f32v(w)).collect();
        let w = xla::Literal::vec1(&flat_w).reshape(&[shape.c as i64, shape.s as i64])?;
        let betas = xla::Literal::vec1(&f32v(&model.betas));
        let mut coeffs_pad = f32v(&model.act_coeffs);
        coeffs_pad.resize(shape.m, 0.0);
        let coeffs = xla::Literal::vec1(&coeffs_pad);
        Ok(SlotModelParams {
            t,
            diags,
            b,
            w,
            betas,
            coeffs,
            shape,
        })
    }
}

/// Compiled PJRT executables for the slot model.
pub struct SlotModel {
    exe_single: xla::PjRtLoadedExecutable,
    exe_batch: xla::PjRtLoadedExecutable,
    pub shape: SlotShape,
}

impl SlotModel {
    /// Load from an artifacts directory (written by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt — run `make artifacts`", dir.display()))?;
        let get = |key: &str| -> Result<String> {
            manifest
                .lines()
                .find_map(|l| l.strip_prefix(&format!("{key}=")))
                .map(str::to_string)
                .ok_or_else(|| anyhow!("manifest missing key {key}"))
        };
        let shape = SlotShape {
            s: get("s")?.parse()?,
            k: get("k")?.parse()?,
            c: get("c")?.parse()?,
            m: get("m")?.parse()?,
            b: get("b")?.parse()?,
        };
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let exe_single = compile(&get("single")?)?;
        let exe_batch = compile(&get("batch")?)?;
        Ok(SlotModel {
            exe_single,
            exe_batch,
            shape,
        })
    }

    /// Single-observation inference: packed slot vector → C scores.
    pub fn infer(&self, x_slots: &[f32], params: &SlotModelParams) -> Result<Vec<f32>> {
        if x_slots.len() != self.shape.s {
            bail!("expected {} slots, got {}", self.shape.s, x_slots.len());
        }
        let x = xla::Literal::vec1(x_slots);
        let result = self.exe_single.execute::<xla::Literal>(&[
            x,
            params.t.clone(),
            params.diags.clone(),
            params.b.clone(),
            params.w.clone(),
            params.betas.clone(),
            params.coeffs.clone(),
        ])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Batched inference: `n ≤ B` packed slot vectors → per-sample C
    /// scores. Inputs are zero-padded to the compiled batch size.
    pub fn infer_batch(
        &self,
        xs: &[Vec<f32>],
        params: &SlotModelParams,
    ) -> Result<Vec<Vec<f32>>> {
        let (b, s, c) = (self.shape.b, self.shape.s, self.shape.c);
        if xs.is_empty() || xs.len() > b {
            bail!("batch size {} outside 1..={b}", xs.len());
        }
        let mut flat = vec![0.0f32; b * s];
        for (i, x) in xs.iter().enumerate() {
            if x.len() != s {
                bail!("expected {s} slots, got {}", x.len());
            }
            flat[i * s..(i + 1) * s].copy_from_slice(x);
        }
        let x = xla::Literal::vec1(&flat).reshape(&[b as i64, s as i64])?;
        let result = self.exe_batch.execute::<xla::Literal>(&[
            x,
            params.t.clone(),
            params.diags.clone(),
            params.b.clone(),
            params.w.clone(),
            params.betas.clone(),
            params.coeffs.clone(),
        ])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let scores = out.to_vec::<f32>()?;
        Ok(xs
            .iter()
            .enumerate()
            .map(|(i, _)| scores[i * c..(i + 1) * c].to_vec())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime tests (loading real artifacts) live in
    // rust/tests/runtime_artifact.rs; here only shape plumbing.
    #[test]
    fn shape_mismatch_is_rejected() {
        use crate::data::adult;
        use crate::forest::{RandomForest, RandomForestConfig};
        use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
        use crate::nrf::NeuralForest;
        let ds = adult::generate(400, 19);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                ..Default::default()
            },
            20,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, 14, 2048).unwrap();
        let bad = SlotShape {
            s: 4096,
            k: hm.plan.k,
            c: 2,
            m: 5,
            b: 8,
        };
        assert!(SlotModelParams::from_hrf(&hm, bad).is_err());
        let good = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: 2,
            m: 5,
            b: 8,
        };
        assert!(SlotModelParams::from_hrf(&hm, good).is_ok());
    }
}
