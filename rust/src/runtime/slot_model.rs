//! Loader/executor for the AOT slot model.
//!
//! `aot.py` writes a `manifest.txt` naming the single-observation and
//! batched computations and their static shapes; [`SlotModel::load`]
//! parses it and serves f32 inference from then on.
//!
//! Offline note: the PJRT/XLA executor (the `xla` crate) is not
//! available in this environment, so the compiled HLO files are treated
//! as opaque artifacts and the computation itself runs as a pure-Rust
//! f32 **walk of the compiled HE schedule**
//! ([`HrfSchedule`](crate::hrf::HrfSchedule)): the same op list the
//! CKKS executor replays is interpreted over plaintext slot vectors
//! (rotations become cyclic shifts, plaintext muls become element-wise
//! products, rescales are no-ops). Since both sides run literally one
//! program, the python↔rust golden parity and the HE↔plaintext oracle
//! agreement hold by construction. The manifest stays the loader
//! contract, so swapping the execution backend back to PJRT is a local
//! change to this file.
//!
//! Batching comes in two flavors, mirroring the HE side:
//!
//! * **outer batch** ([`SlotModel::infer_batch`]) — up to `B` separate
//!   slot vectors, the shape the coordinator's plaintext batcher feeds;
//! * **packed groups** ([`SlotModel::infer_packed`]) — one slot vector
//!   carrying `plan.groups` observations at `group_span` strides, the
//!   plaintext oracle of the batched homomorphic evaluation.

use crate::hrf::schedule::{PlainOperand, ScheduleOp, Segment};
use crate::hrf::{HrfModel, HrfSchedule};
use std::path::Path;

/// Static shape configuration of the compiled model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotShape {
    pub s: usize,
    pub k: usize,
    pub c: usize,
    pub m: usize,
    pub b: usize,
}

/// Model parameters converted once into f32 slot vectors, plus the
/// compiled schedule the plaintext executor walks.
pub struct SlotModelParams {
    t: Vec<f32>,
    diags: Vec<Vec<f32>>,
    b: Vec<f32>,
    w: Vec<Vec<f32>>,
    coeffs: Vec<f32>,
    /// Compiled full-capacity folded schedule (B = groups): the
    /// plaintext executor interprets its Layer/Act segments and reads
    /// scores straight from the slot-addressed outputs.
    schedule: HrfSchedule,
    /// Number of sample groups per slot vector.
    groups: usize,
    pub shape: SlotShape,
}

impl SlotModelParams {
    /// Pack an [`HrfModel`]'s parameters for a compiled shape. The
    /// HRF plan's slot count must equal the artifact's `S`; the
    /// activation is zero-padded to `m` coefficients.
    pub fn from_hrf(model: &HrfModel, shape: SlotShape) -> Result<Self, String> {
        let p = &model.plan;
        if p.slots != shape.s {
            return Err(format!(
                "HRF packed for {} slots, artifact expects {}",
                p.slots, shape.s
            ));
        }
        if p.k != shape.k {
            return Err(format!("HRF K={} but artifact K={}", p.k, shape.k));
        }
        if p.c != shape.c {
            return Err(format!("HRF C={} but artifact C={}", p.c, shape.c));
        }
        if model.act_coeffs.len() > shape.m {
            return Err(format!(
                "activation degree {} exceeds artifact m={}",
                model.act_coeffs.len() - 1,
                shape.m
            ));
        }
        let f32v = |v: &[f64]| -> Vec<f32> { v.iter().map(|&x| x as f32).collect() };
        let mut coeffs = f32v(&model.act_coeffs);
        coeffs.resize(shape.m, 0.0);
        Ok(SlotModelParams {
            t: f32v(&model.t_slots),
            diags: model.diag_slots.iter().map(|d| f32v(d)).collect(),
            b: f32v(&model.b_slots),
            w: model.w_slots.iter().map(|w| f32v(w)).collect(),
            coeffs,
            schedule: HrfSchedule::compile(model, p.groups, true),
            groups: p.groups,
            shape,
        })
    }

    fn activation(&self, x: f32) -> f32 {
        let mut acc = 0.0f32;
        for &c in self.coeffs.iter().rev() {
            acc = acc * x + c;
        }
        acc
    }

    fn operand(&self, op: PlainOperand) -> &[f32] {
        match op {
            PlainOperand::Thresholds => &self.t,
            PlainOperand::Biases => &self.b,
            PlainOperand::Diag(j) => &self.diags[j],
            PlainOperand::ClassWeights(c) => &self.w[c],
        }
    }

    /// The full slot dataflow as a plaintext walk of the compiled
    /// schedule: Layer/Act segments are interpreted over f32 vectors
    /// (`Pack` is skipped — the input arrives pre-packed — and folded
    /// schedules have no `Extract` segment); scores are read from the
    /// schedule's slot-addressed outputs. Returns `groups × C` scores.
    fn forward_groups(&self, x_slots: &[f32]) -> Vec<Vec<f32>> {
        let s = self.shape.s;
        let rotl = |v: &[f32], r: usize| -> Vec<f32> {
            (0..s).map(|i| v[(i + r) % s]).collect()
        };
        let mut regs: Vec<Option<Vec<f32>>> = vec![None; self.schedule.n_regs];
        // The input arrives pre-packed, so the whole Pack segment
        // collapses to loading it into the schedule's input register.
        let r_in = self
            .schedule
            .ops
            .iter()
            .find_map(|(_, op)| match op {
                ScheduleOp::LoadInput { dst, input: 0 } => Some(*dst),
                _ => None,
            })
            .expect("schedule loads input 0");
        regs[r_in] = Some(x_slots.to_vec());
        for (seg, op) in &self.schedule.ops {
            if matches!(seg, Segment::Pack | Segment::Extract) {
                continue;
            }
            match *op {
                ScheduleOp::LoadInput { .. } | ScheduleOp::Hoist { .. } => {}
                ScheduleOp::Rotate { dst, src, step }
                | ScheduleOp::RotateHoisted { dst, src, step }
                | ScheduleOp::ExtractScore {
                    dst,
                    src,
                    slot: step,
                } => {
                    regs[dst] = Some(rotl(regs[src].as_ref().expect("reg"), step));
                }
                ScheduleOp::AddAssign { dst, src } => {
                    let sv = regs[src].clone().expect("reg");
                    let d = regs[dst].as_mut().expect("reg");
                    for (a, b) in d.iter_mut().zip(&sv) {
                        *a += b;
                    }
                }
                ScheduleOp::SubPlain { reg, operand } => {
                    let o = self.operand(operand);
                    let r = regs[reg].as_mut().expect("reg");
                    for (a, b) in r.iter_mut().zip(o) {
                        *a -= b;
                    }
                }
                ScheduleOp::AddPlain { reg, operand } => {
                    let o = self.operand(operand);
                    let r = regs[reg].as_mut().expect("reg");
                    for (a, b) in r.iter_mut().zip(o) {
                        *a += b;
                    }
                }
                ScheduleOp::MulPlainCached { dst, src, operand } => {
                    let prod: Vec<f32> = regs[src]
                        .as_ref()
                        .expect("reg")
                        .iter()
                        .zip(self.operand(operand))
                        .map(|(a, b)| a * b)
                        .collect();
                    regs[dst] = Some(prod);
                }
                ScheduleOp::AddConst { reg, value } => {
                    let v = value as f32;
                    for a in regs[reg].as_mut().expect("reg").iter_mut() {
                        *a += v;
                    }
                }
                ScheduleOp::Rescale { .. } => {}
                ScheduleOp::PolyActivation { dst, src } => {
                    let out: Vec<f32> = regs[src]
                        .as_ref()
                        .expect("reg")
                        .iter()
                        .map(|&x| self.activation(x))
                        .collect();
                    regs[dst] = Some(out);
                }
                ScheduleOp::RotateSumGrouped { dst, src, span } => {
                    let mut acc = regs[src].as_ref().expect("reg").clone();
                    let mut step = 1usize;
                    while step < span {
                        let rot = rotl(&acc, step);
                        for (a, b) in acc.iter_mut().zip(&rot) {
                            *a += b;
                        }
                        step <<= 1;
                    }
                    regs[dst] = Some(acc);
                }
            }
        }
        let mut rows = vec![vec![0.0f32; self.shape.c]; self.groups];
        for o in &self.schedule.outputs {
            rows[o.sample][o.class] = regs[o.reg].as_ref().expect("output reg")[o.slot];
        }
        rows
    }
}

/// Loaded slot-model executor.
pub struct SlotModel {
    pub shape: SlotShape,
}

impl SlotModel {
    /// Load from an artifacts directory (written by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            format!(
                "reading {}/manifest.txt — run `make artifacts` ({e})",
                dir.display()
            )
        })?;
        let get = |key: &str| -> Result<String, String> {
            manifest
                .lines()
                .find_map(|l| l.strip_prefix(&format!("{key}=")))
                .map(str::to_string)
                .ok_or_else(|| format!("manifest missing key {key}"))
        };
        let parse = |key: &str| -> Result<usize, String> {
            get(key)?
                .parse::<usize>()
                .map_err(|e| format!("manifest key {key}: {e}"))
        };
        let shape = SlotShape {
            s: parse("s")?,
            k: parse("k")?,
            c: parse("c")?,
            m: parse("m")?,
            b: parse("b")?,
        };
        Ok(SlotModel { shape })
    }

    /// Single-observation inference: packed slot vector (observation in
    /// group 0) → C scores.
    pub fn infer(&self, x_slots: &[f32], params: &SlotModelParams) -> Result<Vec<f32>, String> {
        if x_slots.len() != self.shape.s {
            return Err(format!(
                "expected {} slots, got {}",
                self.shape.s,
                x_slots.len()
            ));
        }
        Ok(params
            .forward_groups(x_slots)
            .into_iter()
            .next()
            .expect("plan has >= 1 group"))
    }

    /// Batched inference: `n ≤ B` packed slot vectors → per-sample C
    /// scores (the coordinator's plaintext batcher shape).
    pub fn infer_batch(
        &self,
        xs: &[Vec<f32>],
        params: &SlotModelParams,
    ) -> Result<Vec<Vec<f32>>, String> {
        let b = self.shape.b;
        if xs.is_empty() || xs.len() > b {
            return Err(format!("batch size {} outside 1..={b}", xs.len()));
        }
        xs.iter().map(|x| self.infer(x, params)).collect()
    }

    /// Packed-group inference: one slot vector carrying `n_samples`
    /// observations (observation `g` at group offset `g·group_span`) →
    /// per-sample C scores. The plaintext oracle of the batched HE
    /// evaluation.
    pub fn infer_packed(
        &self,
        x_slots: &[f32],
        n_samples: usize,
        params: &SlotModelParams,
    ) -> Result<Vec<Vec<f32>>, String> {
        if x_slots.len() != self.shape.s {
            return Err(format!(
                "expected {} slots, got {}",
                self.shape.s,
                x_slots.len()
            ));
        }
        if n_samples == 0 || n_samples > params.groups {
            return Err(format!(
                "sample count {n_samples} outside 1..={}",
                params.groups
            ));
        }
        let mut rows = params.forward_groups(x_slots);
        rows.truncate(n_samples);
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::hrf::client::{reshuffle_and_pack, reshuffle_and_pack_group};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    fn hrf(slots: usize) -> (crate::data::Dataset, HrfModel) {
        let ds = adult::generate(400, 19);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                ..Default::default()
            },
            20,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, 14, slots).unwrap();
        (ds, hm)
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let (_, hm) = hrf(2048);
        let bad = SlotShape {
            s: 4096,
            k: hm.plan.k,
            c: 2,
            m: 5,
            b: 8,
        };
        assert!(SlotModelParams::from_hrf(&hm, bad).is_err());
        let good = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: 2,
            m: 5,
            b: 8,
        };
        assert!(SlotModelParams::from_hrf(&hm, good).is_ok());
    }

    #[test]
    fn infer_matches_rust_slot_math() {
        let (ds, hm) = hrf(2048);
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let sm = SlotModel { shape };
        for x in ds.x.iter().take(16) {
            let slots = reshuffle_and_pack(&hm, x);
            let slots_f32: Vec<f32> = slots.iter().map(|&v| v as f32).collect();
            let got = sm.infer(&slots_f32, &params).unwrap();
            let want = hm.forward_slots_plain(&slots);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (*g as f64 - w).abs() < 1e-3,
                    "slot-model executor deviates: {got:?} vs {want:?}"
                );
            }
        }
    }

    #[test]
    fn packed_groups_match_per_sample_inference() {
        let (ds, hm) = hrf(2048);
        let n = hm.plan.groups.min(4);
        assert!(n >= 2, "need multiple groups");
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let sm = SlotModel { shape };
        let xs: Vec<Vec<f64>> = ds.x.iter().take(n).cloned().collect();
        let packed = reshuffle_and_pack_group(&hm, &xs);
        let packed_f32: Vec<f32> = packed.iter().map(|&v| v as f32).collect();
        let rows = sm.infer_packed(&packed_f32, n, &params).unwrap();
        for (g, x) in xs.iter().enumerate() {
            let single_slots: Vec<f32> = reshuffle_and_pack(&hm, x)
                .iter()
                .map(|&v| v as f32)
                .collect();
            let single = sm.infer(&single_slots, &params).unwrap();
            for (a, b) in rows[g].iter().zip(&single) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "packed sample {g} deviates: {:?} vs {single:?}",
                    rows[g]
                );
            }
        }
    }

    #[test]
    fn schedule_walk_matches_f64_oracle() {
        // The schedule-walking executor must agree with the direct
        // f64 slot math in pack.rs (the golden-parity oracle).
        let (ds, hm) = hrf(2048);
        let shape = SlotShape {
            s: 2048,
            k: hm.plan.k,
            c: hm.plan.c,
            m: 5,
            b: 8,
        };
        let params = SlotModelParams::from_hrf(&hm, shape).unwrap();
        let n = hm.plan.groups.min(3);
        let xs: Vec<Vec<f64>> = ds.x.iter().take(n).cloned().collect();
        let packed = reshuffle_and_pack_group(&hm, &xs);
        let packed_f32: Vec<f32> = packed.iter().map(|&v| v as f32).collect();
        let rows = params.forward_groups(&packed_f32);
        let oracle = hm.forward_slots_plain_groups(&packed);
        for g in 0..n {
            for (a, b) in rows[g].iter().zip(&oracle[g]) {
                assert!(
                    (*a as f64 - b).abs() < 1e-3,
                    "group {g}: schedule walk {:?} vs oracle {:?}",
                    rows[g],
                    oracle[g]
                );
            }
        }
    }
}
