//! Schedule-DAG parallel execution: lift the linear register IR into
//! an explicit dependency DAG and run independent HE ops concurrently.
//!
//! The compiled [`HrfSchedule`] is a straight-line register program;
//! [`Engine::run`] replays it op-by-op on one thread, so the per-class
//! layer-3 chains (mask → rescale → grouped reduce → bias, fully
//! independent across classes) serialize while the limb-parallel
//! kernels (`CRYPTOTREE_CKKS_WORKERS`) idle between ops. This module
//! adds the second parallelism axis:
//!
//! * [`ScheduleDag::build`] derives the def/use graph from
//!   [`ScheduleOp`] register operands. Locations are registers *and*
//!   per-register hoist slots (a `Hoist` writes the hoist slot,
//!   `RotateHoisted`/`ExtractScore` read it), and edges are exactly
//!   the RAW/WAR/WAW hazards — no segment barriers. `RotateSumGrouped`
//!   fan-in and the `AddAssign` accumulation chains are already
//!   serialized by their register hazards (every `AddAssign` is a
//!   read-modify-write of **both** operands — the CKKS backend adopts
//!   the accumulator's scale into `src`), which is what makes the
//!   parallel replay *bit-identical* to the serial one: every op sees
//!   precisely the operand values program order would hand it, and the
//!   f64 accumulation order never changes.
//! * [`Engine::run_parallel`] is a work-stealing-free dependency-
//!   counting driver: a scoped pool of `op_workers` threads pops ready
//!   ops off a shared priority queue, executes them against a
//!   per-location `RwLock` register file, and decrements successor
//!   in-degrees. Each worker owns its own backend (its own
//!   `Evaluator` + `Scratch` handle into the shared slab pool for
//!   CKKS), so the op hot path takes no lock a hazard edge hasn't
//!   already made uncontended.
//! * [`CostModel`] supplies the ready-queue priority: longest
//!   critical-path-to-exit first, with per-op costs seeded either from
//!   static weights or from a measured [`OpProfile`] (the PR-7
//!   `TimingBackend` table) — the ROADMAP's profile-feedback loop.
//!
//! A panicking worker is surfaced as a typed
//! [`DagExecError::WorkerPanic`] — never a hang: the panic is caught,
//! every worker is woken, and the driver returns the error.

use super::core::{Engine, EngineRun, ScheduleBackend};
use crate::hrf::schedule::{HrfSchedule, ScheduleOp, Segment};
use crate::hrf::server::LayerCounts;
use crate::lockutil::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};
use crate::obs::{OpKind, OpProfile};
use std::collections::{BTreeMap, BinaryHeap};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, RwLock};

/// Environment variable selecting the op-parallel worker count
/// (`1` = serial). Read once per `HrfServer`; see also
/// `CoordinatorConfig::op_workers`.
pub const OP_WORKERS_ENV: &str = "CRYPTOTREE_OP_WORKERS";

/// The `CRYPTOTREE_OP_WORKERS` setting (defaults to 1 = serial;
/// clamped to ≥ 1).
pub fn op_workers_from_env() -> usize {
    std::env::var(OP_WORKERS_ENV)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Which locations one [`ScheduleOp`] reads and writes. Locations are
/// `reg` (register file) and `n_regs + reg` (the register's hoist
/// slot); an in-place update lists its register under `writes` only —
/// the WAW edge to the previous writer carries the read dependency.
struct OpAccess {
    /// Locations read without modification.
    reads: Vec<usize>,
    /// Locations written (pure defs *and* read-modify-writes).
    writes: Vec<usize>,
}

/// Classify `op`'s register/hoist-slot accesses.
///
/// `AddAssign` writes **both** operands: the CKKS backend mutates
/// `src` too (scale adoption), so treating `src` as a pure read would
/// let a concurrent reader observe the mutation. Everything in-place
/// (`SubPlain`, `AddPlain`, `AddConst`, `Rescale`) is a write of its
/// register.
fn op_access(op: &ScheduleOp, n_regs: usize) -> OpAccess {
    let hoist = |r: usize| n_regs + r;
    match *op {
        ScheduleOp::LoadInput { dst, .. } => OpAccess {
            reads: vec![],
            writes: vec![dst],
        },
        ScheduleOp::Rotate { dst, src, .. }
        | ScheduleOp::MulPlainCached { dst, src, .. }
        | ScheduleOp::MulPlainRescale { dst, src, .. }
        | ScheduleOp::PolyActivation { dst, src }
        | ScheduleOp::RotateSumGrouped { dst, src, .. } => OpAccess {
            reads: vec![src],
            writes: vec![dst],
        },
        ScheduleOp::Hoist { src } => OpAccess {
            reads: vec![src],
            writes: vec![hoist(src)],
        },
        ScheduleOp::RotateHoisted { dst, src, .. } | ScheduleOp::ExtractScore { dst, src, .. } => {
            OpAccess {
                reads: vec![src, hoist(src)],
                writes: vec![dst],
            }
        }
        ScheduleOp::AddAssign { dst, src } => OpAccess {
            reads: vec![],
            writes: vec![dst, src],
        },
        ScheduleOp::SubPlain { reg, .. }
        | ScheduleOp::AddPlain { reg, .. }
        | ScheduleOp::AddConst { reg, .. }
        | ScheduleOp::Rescale { reg } => OpAccess {
            reads: vec![],
            writes: vec![reg],
        },
    }
}

/// The [`ScheduleBackend`] method an op dispatches to — the key the
/// [`CostModel`] (and the `TimingBackend` profile it is seeded from)
/// uses. `ExtractScore` executes as a hoisted rotation.
pub fn op_kind(op: &ScheduleOp) -> OpKind {
    match op {
        ScheduleOp::LoadInput { .. } => OpKind::LoadInput,
        ScheduleOp::Rotate { .. } => OpKind::Rotate,
        ScheduleOp::Hoist { .. } => OpKind::Hoist,
        ScheduleOp::RotateHoisted { .. } | ScheduleOp::ExtractScore { .. } => OpKind::RotateHoisted,
        ScheduleOp::AddAssign { .. } => OpKind::AddAssign,
        ScheduleOp::SubPlain { .. } => OpKind::SubPlain,
        ScheduleOp::AddPlain { .. } => OpKind::AddPlain,
        ScheduleOp::MulPlainCached { .. } => OpKind::MulPlainCached,
        ScheduleOp::MulPlainRescale { .. } => OpKind::MulPlainRescale,
        ScheduleOp::AddConst { .. } => OpKind::AddConst,
        ScheduleOp::Rescale { .. } => OpKind::Rescale,
        ScheduleOp::PolyActivation { .. } => OpKind::PolyActivation,
        ScheduleOp::RotateSumGrouped { .. } => OpKind::RotateSumGrouped,
    }
}

/// Shape summary of one schedule's DAG (stamped into coordinator
/// metrics and printed by benches).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DagStats {
    /// Total ops (DAG nodes).
    pub ops: usize,
    /// Critical-path length in waves (serial schedule ⇒ `ops`).
    pub waves: usize,
    /// Widest wave — the op-parallelism the schedule actually exposes.
    pub width: usize,
}

/// The dependency DAG of one compiled schedule: hazard edges over
/// registers and hoist slots. Node `i` is `sched.ops[i]`; every edge
/// points forward in program order, so program order is a topological
/// order and the wave levels come out of one forward pass.
pub struct ScheduleDag {
    /// Hazard predecessors per op (deduplicated, ascending).
    pub preds: Vec<Vec<usize>>,
    /// Hazard successors per op (ascending).
    pub succs: Vec<Vec<usize>>,
    /// Dataflow depth: `wave[i] = 1 + max(wave[preds])`, roots at 0.
    pub wave: Vec<usize>,
    /// Number of waves (critical-path length).
    pub waves: usize,
    /// Maximum ops in any one wave.
    pub width: usize,
}

impl ScheduleDag {
    /// Build the hazard DAG for `sched`.
    pub fn build(sched: &HrfSchedule) -> Self {
        let n = sched.ops.len();
        let n_loc = 2 * sched.n_regs;
        let mut last_writer: Vec<Option<usize>> = vec![None; n_loc];
        let mut readers: Vec<Vec<usize>> = vec![Vec::new(); n_loc];
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];

        for (i, (_, op)) in sched.ops.iter().enumerate() {
            let acc = op_access(op, sched.n_regs);
            let mut p: Vec<usize> = Vec::new();
            // RAW: reads depend on the location's last writer.
            for &loc in &acc.reads {
                if let Some(w) = last_writer[loc] {
                    p.push(w);
                }
                readers[loc].push(i);
            }
            // WAW from the last writer (for an in-place op this *is*
            // the read dependency) and WAR from every standing reader.
            for &loc in &acc.writes {
                if let Some(w) = last_writer[loc] {
                    p.push(w);
                }
                for &r in &readers[loc] {
                    if r != i {
                        p.push(r);
                    }
                }
                last_writer[loc] = Some(i);
                readers[loc].clear();
            }
            p.sort_unstable();
            p.dedup();
            for &w in &p {
                debug_assert!(w < i, "hazard edge must point forward");
                succs[w].push(i);
            }
            preds[i] = p;
        }

        let mut wave = vec![0usize; n];
        for i in 0..n {
            wave[i] = preds[i].iter().map(|&p| wave[p] + 1).max().unwrap_or(0);
        }
        let waves = wave.iter().map(|&w| w + 1).max().unwrap_or(0);
        let mut per_wave = vec![0usize; waves];
        for &w in &wave {
            per_wave[w] += 1;
        }
        let width = per_wave.iter().copied().max().unwrap_or(0);

        ScheduleDag {
            preds,
            succs,
            wave,
            waves,
            width,
        }
    }

    pub fn stats(&self) -> DagStats {
        DagStats {
            ops: self.preds.len(),
            waves: self.waves,
            width: self.width,
        }
    }

    /// Total hazard edges.
    pub fn edges(&self) -> usize {
        self.preds.iter().map(Vec::len).sum()
    }

    /// Structural well-formedness: every edge forward (⇒ acyclic),
    /// `preds`/`succs` mutually consistent, every node wave-labelled
    /// consistently with its predecessors (⇒ every op is scheduled in
    /// some wave and reachable from the root set).
    pub fn validate(&self, sched: &HrfSchedule) -> Result<(), String> {
        let n = self.preds.len();
        if n != sched.ops.len() {
            return Err(format!("{} nodes for {} ops", n, sched.ops.len()));
        }
        for (i, ps) in self.preds.iter().enumerate() {
            for &p in ps {
                if p >= i {
                    return Err(format!("edge {p} -> {i} not forward"));
                }
                if !self.succs[p].contains(&i) {
                    return Err(format!("succs[{p}] missing {i}"));
                }
                if self.wave[i] <= self.wave[p] {
                    return Err(format!(
                        "wave[{i}]={} not after wave[{p}]={}",
                        self.wave[i], self.wave[p]
                    ));
                }
            }
            if ps.is_empty() && self.wave[i] != 0 {
                return Err(format!("root {i} at wave {}", self.wave[i]));
            }
        }
        for (i, ss) in self.succs.iter().enumerate() {
            for &s in ss {
                if !self.preds[s].contains(&i) {
                    return Err(format!("preds[{s}] missing {i}"));
                }
            }
        }
        if self.wave.iter().any(|&w| w >= self.waves) {
            return Err("wave label beyond wave count".into());
        }
        Ok(())
    }
}

/// Per-[`OpKind`] cost weights driving the ready-queue priority
/// (longest critical path to exit first).
///
/// [`CostModel::static_default`] carries hand-seeded relative weights
/// (nanosecond-shaped, from the PR-7 profile tables on the demo
/// parameter sets); [`CostModel::from_profile`] replaces them with
/// *measured* per-kind means from an [`OpProfile`], closing the
/// profile-feedback loop: a profiled run re-seeds the priorities every
/// later parallel run uses.
#[derive(Clone, Debug)]
pub struct CostModel {
    cost: BTreeMap<OpKind, u64>,
}

impl CostModel {
    /// Hand-seeded relative weights. Magnitudes only need to rank:
    /// activation ≫ key-switch chains ≫ hoisted rotate ≫ plain mul ≫
    /// rescale ≫ additive ops.
    pub fn static_default() -> Self {
        let mut cost = BTreeMap::new();
        cost.insert(OpKind::PolyActivation, 4000);
        cost.insert(OpKind::RotateSumGrouped, 2500);
        cost.insert(OpKind::Rotate, 1000);
        cost.insert(OpKind::Hoist, 900);
        cost.insert(OpKind::RotateHoisted, 400);
        cost.insert(OpKind::MulPlainRescale, 250);
        cost.insert(OpKind::MulPlainCached, 150);
        cost.insert(OpKind::Rescale, 120);
        cost.insert(OpKind::SubPlain, 30);
        cost.insert(OpKind::AddPlain, 30);
        cost.insert(OpKind::AddConst, 30);
        cost.insert(OpKind::AddAssign, 20);
        cost.insert(OpKind::LoadInput, 10);
        cost.insert(OpKind::ReadScore, 1);
        CostModel { cost }
    }

    /// Seed from a measured profile: per-kind mean nanoseconds,
    /// aggregated across segments weighted by call count. Kinds the
    /// profile never saw keep the static weight.
    pub fn from_profile(profile: &OpProfile) -> Self {
        let mut calls: BTreeMap<OpKind, u64> = BTreeMap::new();
        let mut nanos: BTreeMap<OpKind, u64> = BTreeMap::new();
        for (&(_, kind), cell) in profile.cells() {
            *calls.entry(kind).or_default() += cell.calls;
            *nanos.entry(kind).or_default() +=
                cell.nanos.mean_value().saturating_mul(cell.calls);
        }
        let mut model = CostModel::static_default();
        for (kind, c) in calls {
            if c > 0 {
                model.cost.insert(kind, (nanos[&kind] / c).max(1));
            }
        }
        model
    }

    /// Cost weight for one op kind (0 if unknown — only possible for a
    /// hand-built model).
    pub fn cost(&self, kind: OpKind) -> u64 {
        self.cost.get(&kind).copied().unwrap_or(0)
    }

    /// Critical-path-to-exit priority per op: `prio[i] = cost(i) +
    /// max(prio[succs])`. Popping the largest first keeps the longest
    /// dependent chain moving while shorter side-chains fill the
    /// remaining workers.
    pub fn priorities(&self, sched: &HrfSchedule, dag: &ScheduleDag) -> Vec<u64> {
        let n = sched.ops.len();
        let mut prio = vec![0u64; n];
        for i in (0..n).rev() {
            let tail = dag.succs[i].iter().map(|&s| prio[s]).max().unwrap_or(0);
            prio[i] = self.cost(op_kind(&sched.ops[i].1)) + tail;
        }
        prio
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::static_default()
    }
}

/// Typed failure of a parallel run. The driver guarantees an `Err` is
/// returned (all workers joined) rather than a hang or an abort.
#[derive(Debug)]
pub enum DagExecError {
    /// A worker panicked executing op `op`; `message` carries the
    /// panic payload when it was a string.
    WorkerPanic { op: usize, message: String },
}

impl fmt::Display for DagExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DagExecError::WorkerPanic { op, message } => {
                write!(f, "DAG worker panicked at op {op}: {message}")
            }
        }
    }
}

impl std::error::Error for DagExecError {}

/// Ready-queue entry: max-heap on priority, ties to the lowest op
/// index (program order).
#[derive(PartialEq, Eq)]
struct ReadyOp {
    prio: u64,
    idx: usize,
}

impl Ord for ReadyOp {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.prio
            .cmp(&other.prio)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

impl PartialOrd for ReadyOp {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Shared driver state: the ready heap behind one mutex+condvar, the
/// per-op in-degree counters, and the first-failure slot.
struct DriverState {
    ready: Mutex<BinaryHeap<ReadyOp>>,
    cv: Condvar,
    indegree: Vec<AtomicU32>,
    remaining: AtomicUsize,
    aborted: AtomicBool,
    failure: Mutex<Option<DagExecError>>,
}

impl DriverState {
    /// Pop the next ready op, blocking until one exists, the run
    /// drains, or a failure aborts it. `None` = stop.
    fn next_op(&self) -> Option<usize> {
        let mut q = lock_unpoisoned(&self.ready);
        loop {
            let done = self.remaining.load(Ordering::Acquire) == 0;
            if done || self.aborted.load(Ordering::Acquire) {
                return None;
            }
            if let Some(op) = q.pop() {
                return Some(op.idx);
            }
            q = self
                .cv
                .wait(q)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Record completion of `idx`: release successors whose in-degree
    /// drains, then wake waiters.
    ///
    /// Every wake path here passes through the `ready` mutex before
    /// `notify_all`: a worker in [`DriverState::next_op`] holds that
    /// mutex from its drain/abort check until `cv.wait` parks it, so
    /// taking the lock (even briefly) guarantees the worker is either
    /// before its check — and will observe the new `remaining` /
    /// queue state — or already waiting and will receive the notify.
    /// Notifying without the lock can fire in that window and the
    /// wakeup is lost; no later notify comes and the run hangs.
    fn complete(&self, idx: usize, dag: &ScheduleDag, prio: &[u64]) {
        let mut released: Vec<ReadyOp> = Vec::new();
        for &s in &dag.succs[idx] {
            if self.indegree[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                released.push(ReadyOp {
                    prio: prio[s],
                    idx: s,
                });
            }
        }
        let drained = self.remaining.fetch_sub(1, Ordering::AcqRel) == 1;
        if !released.is_empty() {
            let mut q = lock_unpoisoned(&self.ready);
            for r in released {
                q.push(r);
            }
            drop(q);
            self.cv.notify_all();
        } else if drained {
            drop(lock_unpoisoned(&self.ready));
            self.cv.notify_all();
        }
    }

    /// Record a worker panic and abort the run.
    fn fail(&self, idx: usize, payload: Box<dyn std::any::Any + Send>) {
        let message = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".into());
        let mut slot = lock_unpoisoned(&self.failure);
        if slot.is_none() {
            *slot = Some(DagExecError::WorkerPanic { op: idx, message });
        }
        drop(slot);
        self.aborted.store(true, Ordering::Release);
        // Same lost-wakeup discipline as `complete`: pass through the
        // ready mutex so a worker between its abort check and
        // `cv.wait` cannot miss this notification.
        drop(lock_unpoisoned(&self.ready));
        self.cv.notify_all();
    }
}

/// Execute one op against the shared lock-per-location register file.
/// Hazard edges guarantee every lock here is uncontended against
/// writers (concurrent *readers* of one register are fine and do
/// share read locks).
fn exec_op<B: ScheduleBackend>(
    backend: &mut B,
    op: &ScheduleOp,
    regs: &[RwLock<Option<B::Value>>],
    hoists: &[RwLock<Option<B::Hoisted>>],
) {
    // One-register transforms share the dst==src handling: an in-place
    // rewrite takes a single write lock; the two-register form computes
    // under a read lock and stores under the write lock.
    macro_rules! unary {
        ($dst:expr, $src:expr, $f:expr) => {{
            let (dst, src) = ($dst, $src);
            if dst == src {
                let mut g = write_unpoisoned(&regs[dst]);
                let r = $f(&mut *backend, g.as_ref().expect("reg"));
                *g = Some(r);
            } else {
                let r = {
                    let g = read_unpoisoned(&regs[src]);
                    $f(&mut *backend, g.as_ref().expect("reg"))
                };
                *write_unpoisoned(&regs[dst]) = Some(r);
            }
        }};
    }
    match *op {
        ScheduleOp::LoadInput { dst, input } => {
            let v = backend.load_input(input);
            *write_unpoisoned(&regs[dst]) = Some(v);
        }
        ScheduleOp::Rotate { dst, src, step } => {
            unary!(dst, src, |b: &mut B, v: &B::Value| b.rotate(v, step))
        }
        ScheduleOp::Hoist { src } => {
            let h = {
                let g = read_unpoisoned(&regs[src]);
                backend.hoist(g.as_ref().expect("reg"))
            };
            *write_unpoisoned(&hoists[src]) = Some(h);
        }
        ScheduleOp::RotateHoisted { dst, src, step }
        | ScheduleOp::ExtractScore {
            dst,
            src,
            slot: step,
        } => {
            let hg = read_unpoisoned(&hoists[src]);
            let h = hg.as_ref().expect("hoisted register");
            unary!(dst, src, |b: &mut B, v: &B::Value| b
                .rotate_hoisted(v, h, step))
        }
        ScheduleOp::AddAssign { dst, src } => {
            assert_ne!(dst, src, "aliasing register pair");
            // Lock in index order; both locks are uncontended (hazard
            // edges order every other toucher of either register).
            let (mut a, mut b) = if dst < src {
                let a = write_unpoisoned(&regs[dst]);
                let b = write_unpoisoned(&regs[src]);
                (a, b)
            } else {
                let b = write_unpoisoned(&regs[src]);
                let a = write_unpoisoned(&regs[dst]);
                (a, b)
            };
            backend.add_assign(a.as_mut().expect("reg"), b.as_mut().expect("reg"));
        }
        ScheduleOp::SubPlain { reg, operand } => {
            let mut g = write_unpoisoned(&regs[reg]);
            backend.sub_plain(g.as_mut().expect("reg"), operand);
        }
        ScheduleOp::AddPlain { reg, operand } => {
            let mut g = write_unpoisoned(&regs[reg]);
            backend.add_plain(g.as_mut().expect("reg"), operand);
        }
        ScheduleOp::MulPlainCached { dst, src, operand } => {
            unary!(dst, src, |b: &mut B, v: &B::Value| b
                .mul_plain_cached(v, operand))
        }
        ScheduleOp::MulPlainRescale { dst, src, operand } => {
            unary!(dst, src, |b: &mut B, v: &B::Value| b
                .mul_plain_rescale(v, operand))
        }
        ScheduleOp::AddConst { reg, value } => {
            let mut g = write_unpoisoned(&regs[reg]);
            backend.add_const(g.as_mut().expect("reg"), value);
        }
        ScheduleOp::Rescale { reg } => {
            let mut g = write_unpoisoned(&regs[reg]);
            backend.rescale(g.as_mut().expect("reg"));
        }
        ScheduleOp::PolyActivation { dst, src } => {
            unary!(dst, src, |b: &mut B, v: &B::Value| b.poly_activation(v))
        }
        ScheduleOp::RotateSumGrouped { dst, src, span } => {
            unary!(dst, src, |b: &mut B, v: &B::Value| b
                .rotate_sum_grouped(v, span))
        }
    }
}

impl Engine {
    /// Replay `sched` with `workers` op-parallel threads, each driving
    /// its own backend from `factory` (called once per worker with the
    /// worker index). Returns the final register file + per-segment
    /// counts (exactly as [`Engine::run`] would) plus the retired
    /// worker backends so callers can reclaim their state (evaluator
    /// counters, scratch pools).
    ///
    /// Semantics are identical to the serial interpreter — hazard
    /// edges reproduce program-order operand visibility op for op, so
    /// for deterministic backends the outputs are **bit-identical** at
    /// any worker count. Panics inside ops are caught and surfaced as
    /// [`DagExecError::WorkerPanic`].
    pub fn run_parallel<B, F>(
        sched: &HrfSchedule,
        dag: &ScheduleDag,
        cost: &CostModel,
        workers: usize,
        factory: F,
    ) -> Result<(EngineRun<B>, Vec<B>), DagExecError>
    where
        B: ScheduleBackend + Send,
        B::Value: Send + Sync,
        B::Hoisted: Send + Sync,
        F: Fn(usize) -> B + Sync,
    {
        let n = sched.ops.len();
        debug_assert_eq!(dag.preds.len(), n, "DAG built for a different schedule");
        let workers = workers.clamp(1, n.max(1));
        let prio = cost.priorities(sched, dag);

        let regs: Vec<RwLock<Option<B::Value>>> =
            (0..sched.n_regs).map(|_| RwLock::new(None)).collect();
        let hoists: Vec<RwLock<Option<B::Hoisted>>> =
            (0..sched.n_regs).map(|_| RwLock::new(None)).collect();

        let mut heap = BinaryHeap::new();
        for (i, ps) in dag.preds.iter().enumerate() {
            if ps.is_empty() {
                heap.push(ReadyOp {
                    prio: prio[i],
                    idx: i,
                });
            }
        }
        let state = DriverState {
            ready: Mutex::new(heap),
            cv: Condvar::new(),
            indegree: dag
                .preds
                .iter()
                .map(|p| AtomicU32::new(p.len() as u32))
                .collect(),
            remaining: AtomicUsize::new(n),
            aborted: AtomicBool::new(false),
            failure: Mutex::new(None),
        };

        let mut counts = LayerCounts::default();
        let mut backends: Vec<B> = Vec::with_capacity(workers);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let state = &state;
                let regs = &regs;
                let hoists = &hoists;
                let prio = &prio;
                let factory = &factory;
                handles.push(scope.spawn(move || {
                    let mut backend = factory(w);
                    let mut local = LayerCounts::default();
                    while let Some(idx) = state.next_op() {
                        let (seg, op) = &sched.ops[idx];
                        backend.on_segment(*seg);
                        let before = backend.op_counts();
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            exec_op(&mut backend, op, regs, hoists)
                        }));
                        match r {
                            Ok(()) => {
                                *local.bucket_mut(*seg) += backend.op_counts().diff(&before);
                                state.complete(idx, dag, prio);
                            }
                            Err(payload) => {
                                state.fail(idx, payload);
                                break;
                            }
                        }
                    }
                    (backend, local)
                }));
            }
            for h in handles {
                // A worker's closure only exits through the loop above,
                // so join can only fail if thread spawning itself
                // failed mid-panic — propagate in that case.
                let (backend, local) = h.join().expect("DAG worker thread");
                counts += local;
                backends.push(backend);
            }
        });

        if let Some(err) = lock_unpoisoned(&state.failure).take() {
            return Err(err);
        }
        let regs: Vec<Option<B::Value>> = regs
            .into_iter()
            .map(|l| l.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner))
            .collect();
        Ok((EngineRun { regs, counts }, backends))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hrf::schedule::{PlainOperand, ScheduleOp};

    fn toy_sched(ops: Vec<(Segment, ScheduleOp)>, n_regs: usize) -> HrfSchedule {
        HrfSchedule {
            b: 1,
            folded: true,
            span: 1,
            n_regs,
            ops,
            outputs: vec![],
            act_counts: Default::default(),
        }
    }

    #[test]
    fn hazards_capture_raw_war_waw() {
        use Segment::Layer2 as S;
        // 0: load r0        1: load r1
        // 2: r2 = rot r0    3: r0 += r1   (WAR on r0 vs op 2's read)
        // 4: r2 = mul r0    (WAW on r2 vs 2, RAW on r0 vs 3)
        let sched = toy_sched(
            vec![
                (S, ScheduleOp::LoadInput { dst: 0, input: 0 }),
                (S, ScheduleOp::LoadInput { dst: 1, input: 1 }),
                (S, ScheduleOp::Rotate { dst: 2, src: 0, step: 1 }),
                (S, ScheduleOp::AddAssign { dst: 0, src: 1 }),
                (
                    S,
                    ScheduleOp::MulPlainCached {
                        dst: 2,
                        src: 0,
                        operand: PlainOperand::Thresholds,
                    },
                ),
            ],
            3,
        );
        let dag = ScheduleDag::build(&sched);
        dag.validate(&sched).unwrap();
        assert_eq!(dag.preds[2], vec![0]);
        assert_eq!(dag.preds[3], vec![0, 1, 2]); // WAW r0, WAW r1, WAR vs reader 2
        assert_eq!(dag.preds[4], vec![2, 3]); // WAW r2, RAW r0
        assert_eq!(dag.wave, vec![0, 0, 1, 2, 3]);
        assert_eq!(dag.width, 2);
    }

    #[test]
    fn hoist_slots_are_separate_locations() {
        use Segment::Layer2 as S;
        // Hoisting r0 must not serialize against an independent def of
        // r1, but a rotate_hoisted on r0 needs both the hoist and r0.
        let sched = toy_sched(
            vec![
                (S, ScheduleOp::LoadInput { dst: 0, input: 0 }),
                (S, ScheduleOp::Hoist { src: 0 }),
                (S, ScheduleOp::LoadInput { dst: 1, input: 1 }),
                (S, ScheduleOp::RotateHoisted { dst: 1, src: 0, step: 2 }),
            ],
            2,
        );
        let dag = ScheduleDag::build(&sched);
        dag.validate(&sched).unwrap();
        assert_eq!(dag.preds[1], vec![0]);
        assert!(dag.preds[2].is_empty(), "independent def must be a root");
        // RAW r0, RAW hoist(r0), WAW r1.
        assert_eq!(dag.preds[3], vec![0, 1, 2]);
        assert!(dag.wave[3] > dag.wave[1]);
    }

    #[test]
    fn priorities_prefer_long_chains() {
        use Segment::Act1 as S;
        // Two roots: op 0 feeds a long activation chain, op 1 is a leaf.
        let sched = toy_sched(
            vec![
                (S, ScheduleOp::LoadInput { dst: 0, input: 0 }),
                (S, ScheduleOp::LoadInput { dst: 1, input: 1 }),
                (S, ScheduleOp::PolyActivation { dst: 0, src: 0 }),
            ],
            2,
        );
        let dag = ScheduleDag::build(&sched);
        let prio = CostModel::static_default().priorities(&sched, &dag);
        assert!(prio[0] > prio[1], "chain head must outrank leaf");
        assert!(prio[0] > prio[2]);
    }

    #[test]
    fn env_parse_defaults_to_serial() {
        // Not set in the test environment unless CI exports it.
        let w = op_workers_from_env();
        assert!(w >= 1);
    }
}
