//! The [`ScheduleBackend`] trait and the generic [`Engine`]
//! interpreter — the single point where
//! [`ScheduleOp`](crate::hrf::schedule::ScheduleOp) variants are
//! dispatched for execution.

use crate::ckks::evaluator::OpCounts;
use crate::hrf::schedule::{HrfSchedule, PlainOperand, Reg, ScheduleOp, Segment};
use crate::hrf::server::LayerCounts;
use std::collections::HashMap;

/// An execution backend for compiled HRF schedules.
///
/// Implementors provide the semantics of each schedule primitive over
/// their own register [`Value`](ScheduleBackend::Value) type; the
/// generic [`Engine`] provides everything else (register file, hoist
/// table, segment accounting, output addressing). A new execution
/// target — a GPU kernel emitter, a PJRT/XLA lowering, a cost model —
/// is one impl of this trait, not a new interpreter.
///
/// Model operands arrive as symbolic [`PlainOperand`]s; each backend
/// resolves them against its own parameter representation (encoded
/// plaintexts for CKKS, f32 slot vectors for the slot model, nothing
/// for the dry run).
pub trait ScheduleBackend {
    /// Contents of one virtual register (one ciphertext / slot vector).
    type Value;
    /// Precomputed key-switch state produced by [`hoist`](Self::hoist)
    /// and consumed by [`rotate_hoisted`](Self::rotate_hoisted).
    type Hoisted;
    /// What [`read_score`](Self::read_score) yields for one
    /// (class, sample) output.
    type Score;

    /// `r[dst] := inputs[input]`.
    fn load_input(&mut self, input: usize) -> Self::Value;
    /// `rot(src, step)` — plain key-switch rotation (cyclic left shift
    /// of the slot vector).
    fn rotate(&mut self, src: &Self::Value, step: usize) -> Self::Value;
    /// Precompute `src`'s key-switch decomposition for subsequent
    /// [`rotate_hoisted`](Self::rotate_hoisted) calls on the same
    /// register.
    fn hoist(&mut self, src: &Self::Value) -> Self::Hoisted;
    /// `rot(src, step)` using `src`'s hoisted decomposition.
    fn rotate_hoisted(
        &mut self,
        src: &Self::Value,
        hoisted: &Self::Hoisted,
        step: usize,
    ) -> Self::Value;
    /// `dst += src` (ct+ct; `src` may adopt `dst`'s scale — the
    /// accumulator discipline — which is why it is `&mut`).
    fn add_assign(&mut self, dst: &mut Self::Value, src: &mut Self::Value);
    /// `reg -= operand` (operand resolved at `reg`'s level & scale).
    fn sub_plain(&mut self, reg: &mut Self::Value, operand: PlainOperand);
    /// `reg += operand` (operand resolved at `reg`'s level & scale).
    fn add_plain(&mut self, reg: &mut Self::Value, operand: PlainOperand);
    /// `src ⊙ operand` (operand resolved at scale Δ through the
    /// backend's operand cache).
    fn mul_plain_cached(&mut self, src: &Self::Value, operand: PlainOperand) -> Self::Value;
    /// Fused `rescale(src ⊙ operand)` — the execution target of the
    /// `FuseMulRescale` pass. The default is the unfused pair, so a
    /// backend only overrides this when it has (or wants to account
    /// for) a genuinely fused kernel.
    fn mul_plain_rescale(&mut self, src: &Self::Value, operand: PlainOperand) -> Self::Value {
        let mut v = self.mul_plain_cached(src, operand);
        self.rescale(&mut v);
        v
    }
    /// `reg += value` (constant resolved at `reg`'s level & scale).
    fn add_const(&mut self, reg: &mut Self::Value, value: f64);
    /// Rescale `reg` by the top chain prime (no-op outside CKKS).
    fn rescale(&mut self, reg: &mut Self::Value);
    /// `P(src)` — the model's activation polynomial.
    fn poly_activation(&mut self, src: &Self::Value) -> Self::Value;
    /// Group-local rotate-and-sum over `span` (`log₂ span` steps; slot
    /// `g·span` of the result holds group `g`'s total).
    fn rotate_sum_grouped(&mut self, src: &Self::Value, span: usize) -> Self::Value;
    /// Read the score a [`ScoreRef`](crate::hrf::schedule::ScoreRef)
    /// addresses out of its register.
    fn read_score(&mut self, value: &Self::Value, slot: usize) -> Self::Score;

    /// Monotone op-counter snapshot. The engine diffs this at segment
    /// boundaries to build per-layer [`LayerCounts`]; backends that do
    /// not meter ops keep the default (all-zero ⇒ zero `LayerCounts`).
    fn op_counts(&self) -> OpCounts {
        OpCounts::default()
    }

    /// Segment-boundary notification: called by [`Engine::run`] right
    /// before the first primitive of each [`Segment`] in the op
    /// stream. The default is a no-op (zero cost for the production
    /// backends); a metering decorator — e.g. the op-profile
    /// `TimingBackend` in [`crate::obs`] — overrides it to attribute
    /// per-primitive timings to pipeline segments.
    fn on_segment(&mut self, _seg: Segment) {}
}

/// Result of one [`Engine::run`]: the final register file plus the
/// per-segment op counts measured through the backend's
/// [`op_counts`](ScheduleBackend::op_counts) snapshots.
pub struct EngineRun<B: ScheduleBackend> {
    /// Final register file; callers move the registers named by
    /// `HrfSchedule::outputs` out (no output value is deep-cloned).
    pub regs: Vec<Option<B::Value>>,
    /// Op counts bucketed by pipeline segment.
    pub counts: LayerCounts,
}

/// Disjoint mutable access to two registers of the engine's file.
fn two_regs<T>(regs: &mut [Option<T>], a: usize, b: usize) -> (&mut T, &mut T) {
    assert_ne!(a, b, "aliasing register pair");
    if a < b {
        let (lo, hi) = regs.split_at_mut(b);
        (lo[a].as_mut().expect("reg a"), hi[0].as_mut().expect("reg b"))
    } else {
        let (lo, hi) = regs.split_at_mut(a);
        (hi[0].as_mut().expect("reg a"), lo[b].as_mut().expect("reg b"))
    }
}

/// The generic schedule interpreter.
pub struct Engine;

impl Engine {
    /// Replay `sched` against `backend`. This match is the **only**
    /// execution dispatch over [`ScheduleOp`] in the codebase: CKKS,
    /// f32 slots and the dry-run counter all funnel through it, so an
    /// op added here (and to the backends' primitive set) exists
    /// everywhere at once.
    pub fn run<B: ScheduleBackend>(sched: &HrfSchedule, backend: &mut B) -> EngineRun<B> {
        let mut regs: Vec<Option<B::Value>> = (0..sched.n_regs).map(|_| None).collect();
        let mut hoists: HashMap<Reg, B::Hoisted> = HashMap::new();
        let mut counts = LayerCounts::default();
        let mut cur_seg = None;
        let mut snap = backend.op_counts();

        for (seg, op) in &sched.ops {
            if cur_seg != Some(*seg) {
                if let Some(s) = cur_seg {
                    *counts.bucket_mut(s) += backend.op_counts().diff(&snap);
                }
                snap = backend.op_counts();
                cur_seg = Some(*seg);
                backend.on_segment(*seg);
            }
            match *op {
                ScheduleOp::LoadInput { dst, input } => {
                    regs[dst] = Some(backend.load_input(input));
                }
                ScheduleOp::Rotate { dst, src, step } => {
                    let r = backend.rotate(regs[src].as_ref().expect("reg"), step);
                    regs[dst] = Some(r);
                }
                ScheduleOp::Hoist { src } => {
                    let h = backend.hoist(regs[src].as_ref().expect("reg"));
                    hoists.insert(src, h);
                }
                ScheduleOp::RotateHoisted { dst, src, step }
                | ScheduleOp::ExtractScore {
                    dst,
                    src,
                    slot: step,
                } => {
                    let h = hoists.get(&src).expect("hoisted register");
                    let r = backend.rotate_hoisted(regs[src].as_ref().expect("reg"), h, step);
                    regs[dst] = Some(r);
                }
                ScheduleOp::AddAssign { dst, src } => {
                    let (d, s) = two_regs(&mut regs, dst, src);
                    backend.add_assign(d, s);
                }
                ScheduleOp::SubPlain { reg, operand } => {
                    backend.sub_plain(regs[reg].as_mut().expect("reg"), operand);
                }
                ScheduleOp::AddPlain { reg, operand } => {
                    backend.add_plain(regs[reg].as_mut().expect("reg"), operand);
                }
                ScheduleOp::MulPlainCached { dst, src, operand } => {
                    let r = backend.mul_plain_cached(regs[src].as_ref().expect("reg"), operand);
                    regs[dst] = Some(r);
                }
                ScheduleOp::MulPlainRescale { dst, src, operand } => {
                    let r = backend.mul_plain_rescale(regs[src].as_ref().expect("reg"), operand);
                    regs[dst] = Some(r);
                }
                ScheduleOp::AddConst { reg, value } => {
                    backend.add_const(regs[reg].as_mut().expect("reg"), value);
                }
                ScheduleOp::Rescale { reg } => {
                    backend.rescale(regs[reg].as_mut().expect("reg"));
                }
                ScheduleOp::PolyActivation { dst, src } => {
                    let r = backend.poly_activation(regs[src].as_ref().expect("reg"));
                    regs[dst] = Some(r);
                }
                ScheduleOp::RotateSumGrouped { dst, src, span } => {
                    let r = backend.rotate_sum_grouped(regs[src].as_ref().expect("reg"), span);
                    regs[dst] = Some(r);
                }
            }
        }
        if let Some(s) = cur_seg {
            *counts.bucket_mut(s) += backend.op_counts().diff(&snap);
        }
        EngineRun { regs, counts }
    }

    /// Read every schedule output through the backend's
    /// [`read_score`](ScheduleBackend::read_score), one entry per
    /// `HrfSchedule::outputs` element (class-major).
    pub fn read_outputs<B: ScheduleBackend>(
        sched: &HrfSchedule,
        run: &EngineRun<B>,
        backend: &mut B,
    ) -> Vec<B::Score> {
        sched
            .outputs
            .iter()
            .map(|o| backend.read_score(run.regs[o.reg].as_ref().expect("output register"), o.slot))
            .collect()
    }
}
