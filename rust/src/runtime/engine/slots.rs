//! [`SlotBackend`] — the plaintext f32 execution backend.
//!
//! Registers are f32 slot vectors: rotations become exact cyclic
//! shifts, plaintext muls become element-wise products, rescales are
//! no-ops and the activation is evaluated by Horner. This replaces the
//! bespoke schedule walker that used to live in
//! [`slot_model`](crate::runtime::slot_model) — the slot model now
//! runs the very same [`Engine`](super::Engine) the CKKS executor
//! runs, so HE↔plaintext parity holds by construction *including* for
//! pass-transformed schedules.

use super::core::ScheduleBackend;
use crate::hrf::schedule::PlainOperand;
use crate::runtime::slot_model::SlotModelParams;

/// f32 slot backend borrowing the converted model parameters and the
/// input slot vectors. Inputs beyond `inputs.len()` read as all-zero
/// vectors, so a pre-packed slot vector can be fed as input 0 to a
/// multi-input schedule: the `Pack` segment's placement rotations then
/// shift zeros and add nothing, leaving the packed input intact.
pub struct SlotBackend<'a> {
    params: &'a SlotModelParams,
    inputs: &'a [Vec<f32>],
    slots: usize,
}

impl<'a> SlotBackend<'a> {
    pub fn new(params: &'a SlotModelParams, inputs: &'a [Vec<f32>]) -> Self {
        let slots = params.shape.s;
        SlotBackend {
            params,
            inputs,
            slots,
        }
    }

    fn rotl(&self, v: &[f32], r: usize) -> Vec<f32> {
        let s = self.slots;
        (0..s).map(|i| v[(i + r) % s]).collect()
    }
}

impl ScheduleBackend for SlotBackend<'_> {
    type Value = Vec<f32>;
    type Hoisted = ();
    type Score = f32;

    fn load_input(&mut self, input: usize) -> Vec<f32> {
        self.inputs
            .get(input)
            .cloned()
            .unwrap_or_else(|| vec![0.0; self.slots])
    }

    fn rotate(&mut self, src: &Vec<f32>, step: usize) -> Vec<f32> {
        self.rotl(src, step)
    }

    fn hoist(&mut self, _src: &Vec<f32>) {}

    fn rotate_hoisted(&mut self, src: &Vec<f32>, _hoisted: &(), step: usize) -> Vec<f32> {
        self.rotl(src, step)
    }

    fn add_assign(&mut self, dst: &mut Vec<f32>, src: &mut Vec<f32>) {
        for (a, b) in dst.iter_mut().zip(src.iter()) {
            *a += b;
        }
    }

    fn sub_plain(&mut self, reg: &mut Vec<f32>, operand: PlainOperand) {
        for (a, b) in reg.iter_mut().zip(self.params.operand(operand)) {
            *a -= b;
        }
    }

    fn add_plain(&mut self, reg: &mut Vec<f32>, operand: PlainOperand) {
        for (a, b) in reg.iter_mut().zip(self.params.operand(operand)) {
            *a += b;
        }
    }

    fn mul_plain_cached(&mut self, src: &Vec<f32>, operand: PlainOperand) -> Vec<f32> {
        src.iter()
            .zip(self.params.operand(operand))
            .map(|(a, b)| a * b)
            .collect()
    }

    // `mul_plain_rescale` keeps the trait default (multiply, then the
    // no-op rescale), so fused and unfused schedules are bit-identical
    // here too.

    fn add_const(&mut self, reg: &mut Vec<f32>, value: f64) {
        let v = value as f32;
        for a in reg.iter_mut() {
            *a += v;
        }
    }

    fn rescale(&mut self, _reg: &mut Vec<f32>) {}

    fn poly_activation(&mut self, src: &Vec<f32>) -> Vec<f32> {
        src.iter().map(|&x| self.params.activation(x)).collect()
    }

    fn rotate_sum_grouped(&mut self, src: &Vec<f32>, span: usize) -> Vec<f32> {
        // Same step order as the HE evaluator's rotate-and-sum, so the
        // f32 accumulation order matches across backends.
        let mut acc = src.clone();
        let mut step = 1usize;
        while step < span {
            let rot = self.rotl(&acc, step);
            for (a, b) in acc.iter_mut().zip(&rot) {
                *a += b;
            }
            step <<= 1;
        }
        acc
    }

    fn read_score(&mut self, value: &Vec<f32>, slot: usize) -> f32 {
        value[slot]
    }
}
