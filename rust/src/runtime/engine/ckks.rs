//! [`CkksBackend`] — the homomorphic execution backend.
//!
//! Ports the CKKS interpreter that used to live as
//! `HrfServer::run_schedule`: registers hold [`Ciphertext`]s, model
//! operands resolve through the server's encoded-plaintext cache
//! (`HrfServer::encode_operand`), and the evaluator's monotone
//! counters back the engine's per-segment accounting, so the measured
//! [`LayerCounts`](crate::hrf::server::LayerCounts) still equal the
//! dry-run prediction op for op.

use super::core::ScheduleBackend;
use crate::ckks::evaluator::{Evaluator, OpCounts};
use crate::ckks::keys::{GaloisKeys, RelinKey};
use crate::ckks::rns::RnsPoly;
use crate::ckks::{Ciphertext, Encoder};
use crate::hrf::schedule::PlainOperand;
use crate::hrf::server::HrfServer;

/// Homomorphic backend: one evaluation session's worth of state. The
/// backend **owns** its [`Evaluator`] (counters + scratch pool) so a
/// DAG worker is a self-contained `Send` unit; key material (`rlk`,
/// `gk`) belongs to the client session and the server contributes the
/// packed model and its plaintext cache by shared reference.
pub struct CkksBackend<'a> {
    server: &'a HrfServer,
    ev: Evaluator,
    enc: &'a Encoder,
    inputs: &'a [Ciphertext],
    rlk: &'a RelinKey,
    gk: &'a GaloisKeys,
}

impl<'a> CkksBackend<'a> {
    pub fn new(
        server: &'a HrfServer,
        ev: Evaluator,
        enc: &'a Encoder,
        inputs: &'a [Ciphertext],
        rlk: &'a RelinKey,
        gk: &'a GaloisKeys,
    ) -> Self {
        CkksBackend {
            server,
            ev,
            enc,
            inputs,
            rlk,
            gk,
        }
    }

    /// Retire the backend, handing back the evaluator (accumulated
    /// counters + warm scratch) to be merged into the caller's.
    pub fn into_evaluator(self) -> Evaluator {
        self.ev
    }
}

impl ScheduleBackend for CkksBackend<'_> {
    type Value = Ciphertext;
    type Hoisted = Vec<RnsPoly>;
    /// A CKKS score never leaves the ciphertext: `read_score` hands
    /// back the (shared) register clone; callers on the hot path move
    /// registers out of the engine's file instead.
    type Score = Ciphertext;

    fn load_input(&mut self, input: usize) -> Ciphertext {
        self.inputs[input].clone()
    }

    fn rotate(&mut self, src: &Ciphertext, step: usize) -> Ciphertext {
        self.ev.rotate(src, step, self.gk)
    }

    fn hoist(&mut self, src: &Ciphertext) -> Vec<RnsPoly> {
        self.ev.hoist(src)
    }

    fn rotate_hoisted(
        &mut self,
        src: &Ciphertext,
        hoisted: &Vec<RnsPoly>,
        step: usize,
    ) -> Ciphertext {
        self.ev.rotate_hoisted(src, hoisted, step, self.gk)
    }

    fn add_assign(&mut self, dst: &mut Ciphertext, src: &mut Ciphertext) {
        // Same-schedule-point scales differ by < 1e-9 relative; adopt
        // the accumulator's (the legacy accumulator discipline).
        src.scale = dst.scale;
        self.ev.add_inplace(dst, src);
    }

    fn sub_plain(&mut self, reg: &mut Ciphertext, operand: PlainOperand) {
        let pt = self
            .server
            .encode_operand(&self.ev.ctx, self.enc, operand, reg.level, reg.scale);
        self.ev.sub_plain_inplace(reg, &pt);
    }

    fn add_plain(&mut self, reg: &mut Ciphertext, operand: PlainOperand) {
        let pt = self
            .server
            .encode_operand(&self.ev.ctx, self.enc, operand, reg.level, reg.scale);
        self.ev.add_plain_inplace(reg, &pt);
    }

    fn mul_plain_cached(&mut self, src: &Ciphertext, operand: PlainOperand) -> Ciphertext {
        let delta = self.ev.ctx.params.scale;
        let pt = self
            .server
            .encode_operand(&self.ev.ctx, self.enc, operand, src.level, delta);
        self.ev.mul_plain(src, &pt)
    }

    fn mul_plain_rescale(&mut self, src: &Ciphertext, operand: PlainOperand) -> Ciphertext {
        let delta = self.ev.ctx.params.scale;
        let pt = self
            .server
            .encode_operand(&self.ev.ctx, self.enc, operand, src.level, delta);
        self.ev.mul_plain_rescale(src, &pt)
    }

    fn add_const(&mut self, reg: &mut Ciphertext, value: f64) {
        let pt = self
            .enc
            .encode_constant(&self.ev.ctx, value, reg.level, reg.scale);
        self.ev.add_plain_inplace(reg, &pt);
    }

    fn rescale(&mut self, reg: &mut Ciphertext) {
        self.ev.rescale(reg);
    }

    fn poly_activation(&mut self, src: &Ciphertext) -> Ciphertext {
        self.ev
            .eval_poly_power_basis(self.enc, src, &self.server.model.act_coeffs, self.rlk)
    }

    fn rotate_sum_grouped(&mut self, src: &Ciphertext, span: usize) -> Ciphertext {
        self.ev.rotate_sum(src, span, self.gk)
    }

    // The slot stays an address — decryption happens client-side.
    fn read_score(&mut self, value: &Ciphertext, _slot: usize) -> Ciphertext {
        value.clone()
    }

    fn op_counts(&self) -> OpCounts {
        self.ev.counts
    }
}
