//! The schedule execution engine: **one program, many backends**.
//!
//! A compiled [`HrfSchedule`](crate::hrf::HrfSchedule) is the repo's
//! portable artifact of the paper's Algorithms 1–3. Before this
//! subsystem existed it was interpreted three separate times — CKKS in
//! `hrf::server`, f32 slots in `runtime::slot_model`, and a dry-run
//! counter in `hrf::schedule` — so every new op or fusion had to be
//! implemented thrice. Now there is exactly **one** interpreter:
//!
//! * [`ScheduleBackend`] (in [`core`]) — the execution-engine API: an
//!   associated register [`Value`](ScheduleBackend::Value) type plus
//!   one method per schedule primitive (`load_input`, `rotate`,
//!   `hoist`/`rotate_hoisted`, `add_assign`, `sub_plain`, `add_plain`,
//!   `mul_plain_cached`, `mul_plain_rescale`, `add_const`, `rescale`,
//!   `poly_activation`, `rotate_sum_grouped`, `read_score`).
//! * [`Engine::run`] — the single generic interpreter; the **only**
//!   place in the codebase that dispatches on
//!   [`ScheduleOp`](crate::hrf::schedule::ScheduleOp) variants for
//!   execution. It owns the register file, the hoisted-digit table and
//!   the per-[`Segment`](crate::hrf::schedule::Segment) op accounting;
//!   backends own nothing but their primitive semantics.
//!
//! Three backends ship today:
//!
//! * [`CkksBackend`] ([`ckks`]) — the homomorphic executor: wraps the
//!   CKKS [`Evaluator`](crate::ckks::evaluator::Evaluator), the
//!   server's encoded-plaintext cache and the session's evaluation
//!   keys. `HrfServer::execute` runs on it.
//! * [`SlotBackend`] ([`slots`]) — plaintext f32 slot vectors:
//!   rotations are cyclic shifts, rescales are no-ops. The slot-model
//!   fast path and the HE↔plaintext oracle run on it.
//! * [`CountingBackend`] ([`counting`]) — a dry run over unit values:
//!   accumulates predicted [`OpCounts`](crate::ckks::evaluator::OpCounts)
//!   and the set of rotation steps. `HrfSchedule::predicted_counts`
//!   and `rotation_steps` (hence Galois-key requirements and the
//!   Table-1 predictions) are thin wrappers over it.
//!
//! A fourth backend is one trait impl away: the ROADMAP's PJRT/XLA
//! executor now means "implement [`ScheduleBackend`] by lowering each
//! primitive to an HLO op", not "write another interpreter".
//!
//! The trait also composes: the op-profile
//! [`TimingBackend`](crate::obs::TimingBackend) *decorates* any
//! backend, timing each primitive and attributing it to the current
//! segment via the [`ScheduleBackend::on_segment`] hook — which the
//! engine calls at every segment boundary and the production backends
//! keep as a free no-op.
//!
//! # Passes
//!
//! [`pass`] adds the optimization layer: a [`SchedulePass`] rewrites a
//! schedule in place and a [`PassPipeline`] sequences passes
//! (`HrfSchedule::optimize`). Because every backend executes the same
//! op list, a peephole transform is written once and holds on all of
//! them — verified by the cross-backend parity tests in
//! `tests/engine_parity.rs`. The first pass, [`FuseMulRescale`], fuses
//! adjacent `MulPlainCached` + `Rescale` pairs into the fused
//! `MulPlainRescale` op (the ROADMAP's schedule-level fusion item);
//! [`ReuseRegisters`] (in [`PassPipeline::aggressive`]) recycles dead
//! register slots down to the schedule's true live peak.
//!
//! # Op-parallel execution
//!
//! [`dag`] lifts the linear op list into its hazard dependency DAG
//! ([`ScheduleDag`]) and adds [`Engine::run_parallel`]: a
//! dependency-counting scoped-thread driver executing independent ops
//! concurrently (priority = critical path under a [`CostModel`],
//! seedable from measured `OpProfile`s), bit-identical to
//! [`Engine::run`] at any worker count and composing with the
//! limb-parallel CKKS kernels. See the module docs for the hazard and
//! determinism argument.

pub mod ckks;
pub mod core;
pub mod counting;
pub mod dag;
pub mod pass;
pub mod slots;

pub use self::core::{Engine, EngineRun, ScheduleBackend};
pub use ckks::CkksBackend;
pub use counting::CountingBackend;
pub use dag::{CostModel, DagExecError, DagStats, ScheduleDag, OP_WORKERS_ENV};
pub use pass::{FuseMulRescale, PassPipeline, ReuseRegisters, SchedulePass};
pub use slots::SlotBackend;
