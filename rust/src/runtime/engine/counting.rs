//! [`CountingBackend`] — the dry-run backend.
//!
//! Registers are unit values; every primitive just books the op counts
//! its CKKS counterpart would meter (and records rotation steps), so
//! one [`Engine::run`](super::Engine::run) over this backend yields
//! the schedule's predicted [`OpCounts`] per segment **and** its
//! Galois-step requirements without touching a ciphertext.
//! `HrfSchedule::predicted_counts` / `rotation_steps` — hence the
//! Table-1 predictions and `HrfServer::eval_key_requirements` — are
//! thin wrappers over it.

use super::core::ScheduleBackend;
use crate::ckks::evaluator::OpCounts;
use crate::hrf::schedule::PlainOperand;
use std::collections::BTreeSet;

/// Dry-run op counter. `act_counts` is the precomputed cost of one
/// activation-polynomial evaluation (`HrfSchedule::act_counts`, a
/// mirror of the power-basis evaluator's counters).
pub struct CountingBackend {
    act_counts: OpCounts,
    counts: OpCounts,
    steps: BTreeSet<usize>,
}

impl CountingBackend {
    pub fn new(act_counts: OpCounts) -> Self {
        CountingBackend {
            act_counts,
            counts: OpCounts::default(),
            steps: BTreeSet::new(),
        }
    }

    /// Every rotation step the replayed schedule performed — the
    /// session's Galois keys must cover exactly this set.
    pub fn into_rotation_steps(self) -> BTreeSet<usize> {
        self.steps
    }

    fn book_rotation(&mut self, step: usize) {
        // Step-0 rotations are identity clones in the evaluator and
        // are neither counted nor keyed there; mirror that.
        if step != 0 {
            self.counts.rotate += 1;
            self.steps.insert(step);
        }
    }
}

impl ScheduleBackend for CountingBackend {
    type Value = ();
    type Hoisted = ();
    type Score = ();

    fn load_input(&mut self, _input: usize) {}

    fn rotate(&mut self, _src: &(), step: usize) {
        self.book_rotation(step);
    }

    fn hoist(&mut self, _src: &()) {}

    fn rotate_hoisted(&mut self, _src: &(), _hoisted: &(), step: usize) {
        self.book_rotation(step);
    }

    fn add_assign(&mut self, _dst: &mut (), _src: &mut ()) {
        self.counts.add += 1;
    }

    fn sub_plain(&mut self, _reg: &mut (), _operand: PlainOperand) {
        self.counts.add_plain += 1;
    }

    fn add_plain(&mut self, _reg: &mut (), _operand: PlainOperand) {
        self.counts.add_plain += 1;
    }

    fn mul_plain_cached(&mut self, _src: &(), _operand: PlainOperand) {
        self.counts.mul_plain += 1;
    }

    fn mul_plain_rescale(&mut self, _src: &(), _operand: PlainOperand) {
        // One fused kernel invocation (mirrors
        // `Evaluator::mul_plain_rescale`'s accounting).
        self.counts.fused_mul_rescale += 1;
    }

    fn add_const(&mut self, _reg: &mut (), _value: f64) {
        self.counts.add_plain += 1;
    }

    fn rescale(&mut self, _reg: &mut ()) {
        self.counts.rescale += 1;
    }

    fn poly_activation(&mut self, _src: &()) {
        self.counts += self.act_counts;
    }

    fn rotate_sum_grouped(&mut self, _src: &(), span: usize) {
        let mut step = 1usize;
        while step < span {
            self.book_rotation(step);
            self.counts.add += 1;
            step <<= 1;
        }
    }

    fn read_score(&mut self, _value: &(), _slot: usize) {}

    fn op_counts(&self) -> OpCounts {
        self.counts
    }
}
