//! Schedule optimization passes.
//!
//! A [`SchedulePass`] is a peephole transform over a compiled
//! [`HrfSchedule`]'s op list; a [`PassPipeline`] sequences passes and
//! is applied through [`HrfSchedule::optimize`]. Because execution is
//! centralized in the generic [`Engine`](super::Engine), a pass is
//! written **once** and holds on every backend — the cross-backend
//! parity tests (`tests/engine_parity.rs`) pin CKKS bit-identity and
//! f32 equality for transformed schedules.
//!
//! Passes must preserve (a) the register dataflow — same values in the
//! output registers — and (b) the slot addressing of
//! `HrfSchedule::outputs`. They may change op counts; the dry-run
//! predictions stay truthful automatically because they are derived
//! from the transformed op list.

use crate::hrf::schedule::{HrfSchedule, ScheduleOp};

/// One in-place schedule rewrite. `Send + Sync` because pipelines live
/// inside the `Arc`-shared `HrfServer`.
pub trait SchedulePass: Send + Sync {
    /// Stable name for logs and dumps.
    fn name(&self) -> &'static str;
    /// Transform `sched` in place; returns whether anything changed.
    fn run(&self, sched: &mut HrfSchedule) -> bool;
}

/// An ordered sequence of passes.
pub struct PassPipeline {
    passes: Vec<Box<dyn SchedulePass>>,
}

impl PassPipeline {
    /// No passes: schedules execute exactly as compiled.
    pub fn empty() -> Self {
        PassPipeline { passes: Vec::new() }
    }

    /// The default production pipeline (currently [`FuseMulRescale`]).
    pub fn standard() -> Self {
        PassPipeline::empty().with(FuseMulRescale)
    }

    /// Append a pass.
    pub fn with(mut self, pass: impl SchedulePass + 'static) -> Self {
        self.passes.push(Box::new(pass));
        self
    }

    /// The passes, in application order (the slice form
    /// [`HrfSchedule::optimize`] consumes).
    pub fn passes(&self) -> &[Box<dyn SchedulePass>] {
        &self.passes
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// Pass names in application order.
    pub fn names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }
}

impl Default for PassPipeline {
    /// [`PassPipeline::standard`] — what `HrfServer::new` installs.
    fn default() -> Self {
        PassPipeline::standard()
    }
}

/// Fuse adjacent `MulPlainCached` + `Rescale` pairs (same register,
/// same segment) into the fused [`ScheduleOp::MulPlainRescale`] op —
/// the ROADMAP's first schedule-level fusion. In the HRF pipeline this
/// catches the per-class layer-3 mask multiplies (C pairs per
/// schedule; the layer-2 diagonal products already share one lazy
/// rescale and are untouched). Execution is bit-identical by
/// construction — the CKKS fused kernel performs exactly the unfused
/// limb math — while the schedule shrinks by one op per pair and the
/// pair is metered as a single fused invocation.
pub struct FuseMulRescale;

impl SchedulePass for FuseMulRescale {
    fn name(&self) -> &'static str {
        "fuse-mul-rescale"
    }

    fn run(&self, sched: &mut HrfSchedule) -> bool {
        let mut out = Vec::with_capacity(sched.ops.len());
        let mut changed = false;
        let mut i = 0;
        while i < sched.ops.len() {
            if i + 1 < sched.ops.len() {
                let (seg_a, op_a) = sched.ops[i];
                let (seg_b, op_b) = sched.ops[i + 1];
                if let (
                    ScheduleOp::MulPlainCached { dst, src, operand },
                    ScheduleOp::Rescale { reg },
                ) = (op_a, op_b)
                {
                    if seg_a == seg_b && reg == dst {
                        out.push((seg_a, ScheduleOp::MulPlainRescale { dst, src, operand }));
                        changed = true;
                        i += 2;
                        continue;
                    }
                }
            }
            out.push(sched.ops[i]);
            i += 1;
        }
        sched.ops = out;
        changed
    }
}
