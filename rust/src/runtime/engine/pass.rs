//! Schedule optimization passes.
//!
//! A [`SchedulePass`] is a peephole transform over a compiled
//! [`HrfSchedule`]'s op list; a [`PassPipeline`] sequences passes and
//! is applied through [`HrfSchedule::optimize`]. Because execution is
//! centralized in the generic [`Engine`](super::Engine), a pass is
//! written **once** and holds on every backend — the cross-backend
//! parity tests (`tests/engine_parity.rs`) pin CKKS bit-identity and
//! f32 equality for transformed schedules.
//!
//! Passes must preserve (a) the register dataflow — same values in the
//! output registers — and (b) the slot addressing of
//! `HrfSchedule::outputs`. They may change op counts; the dry-run
//! predictions stay truthful automatically because they are derived
//! from the transformed op list.

use crate::hrf::schedule::{HrfSchedule, Reg, ScheduleOp};

/// One in-place schedule rewrite. `Send + Sync` because pipelines live
/// inside the `Arc`-shared `HrfServer`.
pub trait SchedulePass: Send + Sync {
    /// Stable name for logs and dumps.
    fn name(&self) -> &'static str;
    /// Transform `sched` in place; returns whether anything changed.
    fn run(&self, sched: &mut HrfSchedule) -> bool;
}

/// An ordered sequence of passes.
pub struct PassPipeline {
    passes: Vec<Box<dyn SchedulePass>>,
}

impl PassPipeline {
    /// No passes: schedules execute exactly as compiled.
    pub fn empty() -> Self {
        PassPipeline { passes: Vec::new() }
    }

    /// The default production pipeline (currently [`FuseMulRescale`]).
    pub fn standard() -> Self {
        PassPipeline::empty().with(FuseMulRescale)
    }

    /// [`standard`](PassPipeline::standard) plus [`ReuseRegisters`]:
    /// the footprint-minimizing pipeline for op-parallel execution,
    /// where concurrent waves hold several live ciphertexts at once
    /// and every recycled register slot is one fewer resident
    /// ciphertext per in-flight request.
    pub fn aggressive() -> Self {
        PassPipeline::standard().with(ReuseRegisters)
    }

    /// Append a pass.
    pub fn with(mut self, pass: impl SchedulePass + 'static) -> Self {
        self.passes.push(Box::new(pass));
        self
    }

    /// The passes, in application order (the slice form
    /// [`HrfSchedule::optimize`] consumes).
    pub fn passes(&self) -> &[Box<dyn SchedulePass>] {
        &self.passes
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// Pass names in application order.
    pub fn names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }
}

impl Default for PassPipeline {
    /// [`PassPipeline::standard`] — what `HrfServer::new` installs.
    fn default() -> Self {
        PassPipeline::standard()
    }
}

/// Fuse adjacent `MulPlainCached` + `Rescale` pairs (same register,
/// same segment) into the fused [`ScheduleOp::MulPlainRescale`] op —
/// the ROADMAP's first schedule-level fusion. In the HRF pipeline this
/// catches the per-class layer-3 mask multiplies (C pairs per
/// schedule; the layer-2 diagonal products already share one lazy
/// rescale and are untouched). Execution is bit-identical by
/// construction — the CKKS fused kernel performs exactly the unfused
/// limb math — while the schedule shrinks by one op per pair and the
/// pair is metered as a single fused invocation.
pub struct FuseMulRescale;

/// Liveness-driven register recycling: rename registers so a slot
/// freed by a value's last use is reused by later defs, shrinking
/// `HrfSchedule::n_regs` from "one slot per pipeline role" to the
/// actual peak number of simultaneously-live ciphertexts.
///
/// A linear scan over the straight-line program: each *pure* def
/// (an op that overwrites its `dst` without needing `dst`'s old
/// value) allocates from a LIFO free list; a value dies — and its
/// slot is freed — at its last use before the next redefinition (or
/// at the op that overwrites it unread). In-place ops (`Rescale`,
/// `AddPlain`, `AddAssign` — which mutates *both* operands) keep
/// their slot. Hoisted key-switch state is keyed by register index,
/// and a register's hoist entries are only ever read while the
/// register itself is live, so renaming keys them consistently.
///
/// Dataflow is preserved exactly (same values flow through renamed
/// slots; a def may land in the slot its own source just vacated,
/// which every backend executes compute-then-store), so outputs stay
/// bit-identical — pinned against the serial engine in
/// `tests/dag_exec_props.rs`. Not part of the standard pipeline: the
/// role-per-slot layout is load-bearing for schedule-dump readability
/// and the register-count invariants of existing tests; install via
/// [`PassPipeline::aggressive`].
pub struct ReuseRegisters;

/// Per-register liveness events of the original program, positions
/// ascending. `uses` are reads *and* in-place updates (plus a
/// virtual use at `ops.len()` for every schedule output); `defs` are
/// pure defs only.
struct Liveness {
    uses: Vec<Vec<usize>>,
    defs: Vec<Vec<usize>>,
}

impl Liveness {
    fn scan(sched: &HrfSchedule) -> Self {
        let n = sched.ops.len();
        let mut uses: Vec<Vec<usize>> = vec![Vec::new(); sched.n_regs];
        let mut defs: Vec<Vec<usize>> = vec![Vec::new(); sched.n_regs];
        for (i, (_, op)) in sched.ops.iter().enumerate() {
            match *op {
                ScheduleOp::LoadInput { dst, .. } => defs[dst].push(i),
                ScheduleOp::Rotate { dst, src, .. }
                | ScheduleOp::RotateHoisted { dst, src, .. }
                | ScheduleOp::ExtractScore { dst, src, .. }
                | ScheduleOp::MulPlainCached { dst, src, .. }
                | ScheduleOp::MulPlainRescale { dst, src, .. }
                | ScheduleOp::PolyActivation { dst, src }
                | ScheduleOp::RotateSumGrouped { dst, src, .. } => {
                    uses[src].push(i);
                    defs[dst].push(i);
                }
                ScheduleOp::Hoist { src } => uses[src].push(i),
                ScheduleOp::AddAssign { dst, src } => {
                    uses[dst].push(i);
                    uses[src].push(i);
                }
                ScheduleOp::SubPlain { reg, .. }
                | ScheduleOp::AddPlain { reg, .. }
                | ScheduleOp::AddConst { reg, .. }
                | ScheduleOp::Rescale { reg } => uses[reg].push(i),
            }
        }
        for o in &sched.outputs {
            uses[o.reg].push(n);
        }
        Liveness { uses, defs }
    }

    /// Is the value in `reg` dead right after position `i` — no use
    /// strictly after `i` before the next pure redefinition?
    fn dead_after(&self, reg: Reg, i: usize) -> bool {
        let next = |v: &[usize]| v.iter().copied().find(|&p| p > i);
        match (next(&self.uses[reg]), next(&self.defs[reg])) {
            (None, _) => true,
            (Some(u), Some(d)) => d < u,
            (Some(_), None) => false,
        }
    }
}

/// Renaming state of the linear scan.
struct Renamer {
    live: Liveness,
    /// old register → currently assigned slot.
    map: Vec<Option<Reg>>,
    /// LIFO free slots (LIFO keeps hot ciphertext buffers hot).
    free: Vec<Reg>,
    n_new: usize,
    changed: bool,
}

impl Renamer {
    /// Rewrite a read (or in-place) operand and free its slot if this
    /// was the value's last use.
    fn use_(&mut self, r: &mut Reg, i: usize) {
        let old = *r;
        let slot = self.map[old].expect("read of undefined register");
        self.changed |= slot != old;
        *r = slot;
        if self.live.dead_after(old, i) {
            self.free.push(self.map[old].take().expect("live slot"));
        }
    }

    /// Rewrite a pure def: the incoming value (if any) dies here and
    /// its slot is immediately reusable — including by this def.
    fn def(&mut self, r: &mut Reg) {
        let old = *r;
        if let Some(slot) = self.map[old].take() {
            self.free.push(slot);
        }
        let slot = self.free.pop().unwrap_or_else(|| {
            let s = self.n_new;
            self.n_new += 1;
            s
        });
        self.changed |= slot != old;
        self.map[old] = Some(slot);
        *r = slot;
    }
}

impl SchedulePass for ReuseRegisters {
    fn name(&self) -> &'static str {
        "reuse-registers"
    }

    fn run(&self, sched: &mut HrfSchedule) -> bool {
        let mut ren = Renamer {
            live: Liveness::scan(sched),
            map: vec![None; sched.n_regs],
            free: Vec::new(),
            n_new: 0,
            changed: false,
        };
        for i in 0..sched.ops.len() {
            let (_, op) = &mut sched.ops[i];
            match op {
                ScheduleOp::LoadInput { dst, .. } => ren.def(dst),
                ScheduleOp::Rotate { dst, src, .. }
                | ScheduleOp::RotateHoisted { dst, src, .. }
                | ScheduleOp::ExtractScore { dst, src, .. }
                | ScheduleOp::MulPlainCached { dst, src, .. }
                | ScheduleOp::MulPlainRescale { dst, src, .. }
                | ScheduleOp::PolyActivation { dst, src }
                | ScheduleOp::RotateSumGrouped { dst, src, .. } => {
                    ren.use_(src, i);
                    ren.def(dst);
                }
                ScheduleOp::Hoist { src } => ren.use_(src, i),
                ScheduleOp::AddAssign { dst, src } => {
                    ren.use_(dst, i);
                    ren.use_(src, i);
                }
                ScheduleOp::SubPlain { reg, .. }
                | ScheduleOp::AddPlain { reg, .. }
                | ScheduleOp::AddConst { reg, .. }
                | ScheduleOp::Rescale { reg } => ren.use_(reg, i),
            }
        }
        for o in &mut sched.outputs {
            o.reg = ren.map[o.reg].expect("schedule output register live at end");
        }
        let changed = ren.changed || ren.n_new != sched.n_regs;
        sched.n_regs = ren.n_new;
        changed
    }
}

impl SchedulePass for FuseMulRescale {
    fn name(&self) -> &'static str {
        "fuse-mul-rescale"
    }

    fn run(&self, sched: &mut HrfSchedule) -> bool {
        let mut out = Vec::with_capacity(sched.ops.len());
        let mut changed = false;
        let mut i = 0;
        while i < sched.ops.len() {
            if i + 1 < sched.ops.len() {
                let (seg_a, op_a) = sched.ops[i];
                let (seg_b, op_b) = sched.ops[i + 1];
                if let (
                    ScheduleOp::MulPlainCached { dst, src, operand },
                    ScheduleOp::Rescale { reg },
                ) = (op_a, op_b)
                {
                    if seg_a == seg_b && reg == dst {
                        out.push((seg_a, ScheduleOp::MulPlainRescale { dst, src, operand }));
                        changed = true;
                        i += 2;
                        continue;
                    }
                }
            }
            out.push(sched.ops[i]);
            i += 1;
        }
        sched.ops = out;
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hrf::schedule::{PlainOperand, ScoreRef, Segment};

    /// A chain with serial-role registers: r0 → r1 → r2, each value
    /// dead as soon as the next is produced, output in r2.
    fn chain_sched() -> HrfSchedule {
        use Segment::Layer2 as S;
        HrfSchedule {
            b: 1,
            folded: true,
            span: 1,
            n_regs: 3,
            ops: vec![
                (S, ScheduleOp::LoadInput { dst: 0, input: 0 }),
                (S, ScheduleOp::PolyActivation { dst: 1, src: 0 }),
                (
                    S,
                    ScheduleOp::MulPlainCached {
                        dst: 2,
                        src: 1,
                        operand: PlainOperand::Thresholds,
                    },
                ),
                (S, ScheduleOp::Rescale { reg: 2 }),
            ],
            outputs: vec![ScoreRef {
                class: 0,
                sample: 0,
                reg: 2,
                slot: 0,
            }],
            act_counts: Default::default(),
        }
    }

    #[test]
    fn reuse_registers_collapses_dead_chain() {
        let mut sched = chain_sched();
        assert!(ReuseRegisters.run(&mut sched));
        // Every def can recycle its dying source: one slot suffices.
        assert_eq!(sched.n_regs, 1);
        assert_eq!(sched.outputs[0].reg, 0);
        for (_, op) in &sched.ops {
            match *op {
                ScheduleOp::LoadInput { dst, .. } => assert_eq!(dst, 0),
                ScheduleOp::PolyActivation { dst, src } => {
                    assert_eq!((dst, src), (0, 0));
                }
                ScheduleOp::MulPlainCached { dst, src, .. } => {
                    assert_eq!((dst, src), (0, 0));
                }
                ScheduleOp::Rescale { reg } => assert_eq!(reg, 0),
                ref other => panic!("unexpected op {other:?}"),
            }
        }
    }

    #[test]
    fn reuse_registers_keeps_concurrent_values_apart() {
        use Segment::Layer2 as S;
        // r0 stays live across the def of r1 (AddAssign reads both),
        // so they must keep distinct slots.
        let mut sched = HrfSchedule {
            b: 1,
            folded: true,
            span: 1,
            n_regs: 4,
            ops: vec![
                (S, ScheduleOp::LoadInput { dst: 0, input: 0 }),
                (S, ScheduleOp::LoadInput { dst: 1, input: 1 }),
                (S, ScheduleOp::AddAssign { dst: 0, src: 1 }),
                (S, ScheduleOp::PolyActivation { dst: 3, src: 0 }),
            ],
            outputs: vec![ScoreRef {
                class: 0,
                sample: 0,
                reg: 3,
                slot: 0,
            }],
            act_counts: Default::default(),
        };
        assert!(ReuseRegisters.run(&mut sched));
        assert_eq!(sched.n_regs, 2);
        let (dst, src) = match sched.ops[2].1 {
            ScheduleOp::AddAssign { dst, src } => (dst, src),
            ref other => panic!("unexpected op {other:?}"),
        };
        assert_ne!(dst, src, "live operands must stay in distinct slots");
    }
}
