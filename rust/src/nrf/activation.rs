//! Activation functions and the Chebyshev polynomial machinery used to
//! approximate `tanh(a·x)` on `[-1, 1]` (paper §3: "polynomial
//! approximation P of degree m of the regular activation φ_a").

/// Activation used by NRF forward passes.
#[derive(Clone, Debug, PartialEq)]
pub enum Activation {
    /// φ(x) = 2·1[x ≥ 0] − 1 — reproduces the tree exactly.
    Hard,
    /// φ_a(x) = tanh(a x).
    Tanh { a: f64 },
    /// Monomial coefficients c_0 + c_1 x + … + c_m x^m on [-1, 1]
    /// (what the HRF evaluates homomorphically).
    Poly { coeffs: Vec<f64> },
}

impl Activation {
    pub fn apply(&self, x: f64) -> f64 {
        match self {
            Activation::Hard => {
                if x >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            Activation::Tanh { a } => (a * x).tanh(),
            Activation::Poly { coeffs } => horner(coeffs, x),
        }
    }

    /// The polynomial CKKS evaluates for this activation (identity for
    /// `Poly`, Chebyshev fit for `Tanh`, panic for `Hard` — hard sign
    /// has no polynomial form).
    pub fn to_poly(&self, degree: usize) -> Vec<f64> {
        match self {
            Activation::Poly { coeffs } => coeffs.clone(),
            Activation::Tanh { a } => chebyshev_fit_tanh(*a, degree),
            Activation::Hard => panic!("hard sign is not polynomial"),
        }
    }
}

/// Evaluate Σ c_i x^i.
pub fn horner(coeffs: &[f64], x: f64) -> f64 {
    let mut acc = 0.0;
    for &c in coeffs.iter().rev() {
        acc = acc * x + c;
    }
    acc
}

/// Chebyshev interpolation of an arbitrary function on [-1, 1],
/// returned as monomial coefficients (degree ≤ 16 keeps the basis
/// conversion numerically safe; HRF uses degree ≤ 8).
pub fn chebyshev_fit<F: Fn(f64) -> f64>(f: F, degree: usize) -> Vec<f64> {
    assert!(degree <= 16, "monomial conversion unstable beyond 16");
    let m = degree + 1;
    // Chebyshev nodes & coefficients.
    let nodes: Vec<f64> = (0..m)
        .map(|i| (std::f64::consts::PI * (i as f64 + 0.5) / m as f64).cos())
        .collect();
    let fvals: Vec<f64> = nodes.iter().map(|&x| f(x)).collect();
    let mut cheb = vec![0.0f64; m];
    for (j, c) in cheb.iter_mut().enumerate() {
        let mut s = 0.0;
        for i in 0..m {
            s += fvals[i]
                * (std::f64::consts::PI * j as f64 * (i as f64 + 0.5) / m as f64).cos();
        }
        *c = 2.0 * s / m as f64;
    }
    cheb[0] *= 0.5;
    // Convert Σ c_j T_j(x) to monomial basis via T recurrence.
    // t_prev = T_{j-1}, t_cur = T_j as monomial coefficient vectors.
    let mut mono = vec![0.0f64; m];
    let mut t_prev = vec![0.0f64; m]; // T_0 = 1
    t_prev[0] = 1.0;
    let mut t_cur = vec![0.0f64; m]; // T_1 = x
    if m > 1 {
        t_cur[1] = 1.0;
    }
    mono[0] += cheb[0] * t_prev[0];
    if m > 1 {
        for (mo, tc) in mono.iter_mut().zip(&t_cur) {
            *mo += cheb[1] * tc;
        }
    }
    for j in 2..m {
        // T_j = 2x T_{j-1} - T_{j-2}
        let mut t_next = vec![0.0f64; m];
        for i in 0..m - 1 {
            t_next[i + 1] += 2.0 * t_cur[i];
        }
        for i in 0..m {
            t_next[i] -= t_prev[i];
        }
        for (mo, tn) in mono.iter_mut().zip(&t_next) {
            *mo += cheb[j] * tn;
        }
        t_prev = t_cur;
        t_cur = t_next;
    }
    mono
}

/// Chebyshev fit of tanh(a·x) on [-1, 1].
pub fn chebyshev_fit_tanh(a: f64, degree: usize) -> Vec<f64> {
    chebyshev_fit(|x| (a * x).tanh(), degree)
}

/// Max |P(x) − tanh(ax)| over a grid — used by tests and the
/// activation-degree ablation.
pub fn fit_error(a: f64, coeffs: &[f64], grid: usize) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..=grid {
        let x = -1.0 + 2.0 * i as f64 / grid as f64;
        worst = worst.max((horner(coeffs, x) - (a * x).tanh()).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hard_sign() {
        let h = Activation::Hard;
        assert_eq!(h.apply(0.3), 1.0);
        assert_eq!(h.apply(0.0), 1.0);
        assert_eq!(h.apply(-0.2), -1.0);
    }

    #[test]
    fn cheb_fit_polynomial_is_exact() {
        // Fitting a degree-3 polynomial with degree 3 must be exact.
        let target = |x: f64| 0.5 - 0.3 * x + 0.25 * x * x - 0.7 * x * x * x;
        let c = chebyshev_fit(target, 3);
        for i in 0..=20 {
            let x = -1.0 + 0.1 * i as f64;
            assert!((horner(&c, x) - target(x)).abs() < 1e-12);
        }
    }

    #[test]
    fn tanh_fit_error_decreases_with_degree() {
        let a = 3.0;
        let e3 = fit_error(a, &chebyshev_fit_tanh(a, 3), 200);
        let e5 = fit_error(a, &chebyshev_fit_tanh(a, 5), 200);
        let e9 = fit_error(a, &chebyshev_fit_tanh(a, 9), 200);
        assert!(e5 < e3);
        assert!(e9 < e5);
        assert!(e9 < 0.08, "degree-9 fit error {e9}");
    }

    #[test]
    fn tanh_fit_is_odd_dominated() {
        // tanh is odd: even monomial coefficients should be ~0.
        let c = chebyshev_fit_tanh(2.0, 6);
        assert!(c[0].abs() < 1e-12);
        assert!(c[2].abs() < 1e-12);
        assert!(c[4].abs() < 1e-12);
        assert!(c[1].abs() > 0.5);
    }

    #[test]
    fn poly_activation_bounded_on_domain() {
        // The HRF requires |P(x)| bounded on [-1,1]; sanity-check a
        // default fit stays within [-1.3, 1.3].
        let c = chebyshev_fit_tanh(3.0, 4);
        for i in 0..=100 {
            let x = -1.0 + 0.02 * i as f64;
            assert!(horner(&c, x).abs() < 1.3);
        }
    }
}
