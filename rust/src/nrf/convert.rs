//! CART tree → Neural-Random-Forest parameters (paper eqs. 1–4).
//!
//! For a tree with `K` leaves and `K−1` internal nodes:
//!
//! * `tau[k]`, `t[k]` — feature index & threshold of comparison `k`;
//! * `v[k'][k] ∈ {±1/2l(k'), 0}` — leaf-localization weights: nonzero
//!   iff comparison `k` lies on the path to leaf `k'`, sign +1 for a
//!   right turn; pre-divided by `2l(k')` together with
//!   `b[k'] = (−l(k') + ½) / 2l(k')` so the linear output of eq. 2
//!   stays in `[-1, 1]` (eq. 3, the paper's normalization for
//!   polynomial activations);
//! * `w[c][k'] = μ_{c,k'}/2`, `beta[c] = ½ Σ_{k'} μ_{c,k'}` — output
//!   weights chosen so that with the ±1 one-hot `v`, the tree output
//!   is exactly the leaf's class distribution `μ_{·,leaf}`.
//!
//! Trees are padded to a common leaf count `K` with "dead" leaves
//! (zero weights, bias −1 ⇒ unit permanently inactive, zero output
//! weight) so all trees share one packed layout (paper §3 assumes
//! "all trees have been padded to the same number of leaves").

use crate::forest::tree::{DecisionTree, Node};

/// NRF parameters of a single tree (already normalized for [-1,1]).
#[derive(Clone, Debug)]
pub struct NeuralTree {
    /// Feature index per comparison (len = n_comparisons ≤ K-1).
    pub tau: Vec<usize>,
    /// Threshold per comparison.
    pub t: Vec<f64>,
    /// Leaf-localization weights, `v[leaf][comparison]`, normalized.
    pub v: Vec<Vec<f64>>,
    /// Leaf biases, normalized.
    pub b: Vec<f64>,
    /// Output weights `w[class][leaf]` (= μ/2; 0 for padded leaves).
    pub w: Vec<Vec<f64>>,
    /// Output biases per class (= ½ Σ μ over real leaves).
    pub beta: Vec<f64>,
    /// Number of real (non-padding) leaves.
    pub real_leaves: usize,
    pub n_classes: usize,
}

impl NeuralTree {
    /// Convert a CART tree. `k_target` pads the leaf count (0 = no
    /// padding). Comparisons are padded to `k_target − 1` with dummy
    /// (feature 0, threshold 0) rows that carry zero weight everywhere.
    pub fn from_tree(tree: &DecisionTree, k_target: usize) -> Self {
        // Enumerate internal nodes (comparisons) and leaves.
        let mut comp_of_node = vec![usize::MAX; tree.nodes.len()];
        let mut tau = Vec::new();
        let mut t = Vec::new();
        let mut leaves = Vec::new(); // node ids
        for (id, n) in tree.nodes.iter().enumerate() {
            match n {
                Node::Internal {
                    feature, threshold, ..
                } => {
                    comp_of_node[id] = tau.len();
                    tau.push(*feature);
                    t.push(*threshold);
                }
                Node::Leaf { .. } => leaves.push(id),
            }
        }
        let n_comp = tau.len();
        let k_real = leaves.len();
        let k = if k_target == 0 {
            k_real
        } else {
            assert!(
                k_target >= k_real,
                "k_target {k_target} < leaves {k_real}"
            );
            k_target
        };
        let n_comp_padded = if k_target == 0 { n_comp } else { k - 1 };
        assert!(n_comp <= n_comp_padded);
        // Pad comparisons with dummies.
        let mut tau_p = tau.clone();
        let mut t_p = t.clone();
        tau_p.resize(n_comp_padded, 0);
        t_p.resize(n_comp_padded, 0.0);

        // Walk root→leaf paths to build V and b.
        let c = tree.n_classes;
        let mut v = vec![vec![0.0f64; n_comp_padded]; k];
        let mut b = vec![0.0f64; k];
        let mut w = vec![vec![0.0f64; k]; c];
        let mut beta = vec![0.0f64; c];

        // DFS with path of (comparison index, went_right).
        let mut stack: Vec<(usize, Vec<(usize, bool)>)> = vec![(tree.root(), Vec::new())];
        let mut leaf_counter = 0usize;
        while let Some((id, path)) = stack.pop() {
            match &tree.nodes[id] {
                Node::Internal { left, right, .. } => {
                    let kc = comp_of_node[id];
                    let mut lp = path.clone();
                    lp.push((kc, false));
                    stack.push((*left, lp));
                    let mut rp = path;
                    rp.push((kc, true));
                    stack.push((*right, rp));
                }
                Node::Leaf { dist, .. } => {
                    let leaf = leaf_counter;
                    leaf_counter += 1;
                    let l = path.len().max(1) as f64;
                    let norm = 2.0 * l;
                    for &(kc, right) in &path {
                        v[leaf][kc] = if right { 1.0 } else { -1.0 } / norm;
                    }
                    b[leaf] = (-l + 0.5) / norm;
                    for ci in 0..c {
                        w[ci][leaf] = dist[ci] / 2.0;
                        beta[ci] += dist[ci] / 2.0;
                    }
                }
            }
        }
        debug_assert_eq!(leaf_counter, k_real);
        // Padded (dead) leaves: zero weights, bias −1 ⇒ φ(−1) ≈ −1,
        // zero output weight ⇒ no contribution.
        for leaf in k_real..k {
            b[leaf] = -1.0;
        }
        NeuralTree {
            tau: tau_p,
            t: t_p,
            v,
            b,
            w,
            beta,
            real_leaves: k_real,
            n_classes: c,
        }
    }

    /// Number of (padded) leaves K.
    pub fn k(&self) -> usize {
        self.b.len()
    }

    /// Number of (padded) comparisons (= K−1 when padded).
    pub fn n_comparisons(&self) -> usize {
        self.tau.len()
    }

    /// Comparison-layer linear output: x_{τ(k)} − t_k (eq. 1, inside φ).
    pub fn comparisons(&self, x: &[f64]) -> Vec<f64> {
        self.tau
            .iter()
            .zip(&self.t)
            .map(|(&f, &thr)| x[f] - thr)
            .collect()
    }

    /// Leaf-localization linear output given activated comparisons u
    /// (eq. 2 inside φ, already normalized into [-1,1]).
    pub fn leaf_scores(&self, u: &[f64]) -> Vec<f64> {
        self.v
            .iter()
            .zip(&self.b)
            .map(|(row, &bias)| row.iter().zip(u).map(|(w, u)| w * u).sum::<f64>() + bias)
            .collect()
    }

    /// Output layer given activated leaf indicators v (eq. 4).
    pub fn output(&self, v_act: &[f64]) -> Vec<f64> {
        (0..self.n_classes)
            .map(|c| {
                self.w[c]
                    .iter()
                    .zip(v_act)
                    .map(|(w, v)| w * v)
                    .sum::<f64>()
                    + self.beta[c]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::tree::{DecisionTree, TreeConfig};
    use crate::nrf::activation::Activation;
    use crate::rng::Xoshiro256pp;

    fn forward_hard(nt: &NeuralTree, x: &[f64]) -> Vec<f64> {
        let act = Activation::Hard;
        let u: Vec<f64> = nt.comparisons(x).iter().map(|&z| act.apply(z)).collect();
        let v: Vec<f64> = nt.leaf_scores(&u).iter().map(|&z| act.apply(z)).collect();
        nt.output(&v)
    }

    #[test]
    fn hard_nrf_equals_tree_exactly() {
        // E7 (Fig. 2): the NRF with hard activations reproduces the
        // tree's output distribution on every input.
        let ds = adult::generate(3_000, 31);
        let mut rng = Xoshiro256pp::new(32);
        for depth in [2usize, 3, 4] {
            let cfg = TreeConfig {
                max_depth: depth,
                ..Default::default()
            };
            let tree = DecisionTree::fit(&ds, &cfg, &mut rng);
            let nt = NeuralTree::from_tree(&tree, 0);
            for x in ds.x.iter().take(300) {
                let expect = tree.predict_proba(x);
                let got = forward_hard(&nt, x);
                for (g, e) in got.iter().zip(&expect) {
                    assert!(
                        (g - e).abs() < 1e-9,
                        "depth {depth}: {got:?} vs {expect:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn padding_preserves_output() {
        let ds = adult::generate(2_000, 33);
        let mut rng = Xoshiro256pp::new(34);
        let tree = DecisionTree::fit(
            &ds,
            &TreeConfig {
                max_depth: 3,
                ..Default::default()
            },
            &mut rng,
        );
        let plain = NeuralTree::from_tree(&tree, 0);
        let padded = NeuralTree::from_tree(&tree, 16);
        assert_eq!(padded.k(), 16);
        assert_eq!(padded.n_comparisons(), 15);
        for x in ds.x.iter().take(200) {
            let a = forward_hard(&plain, x);
            let b = forward_hard(&padded, x);
            for (x1, x2) in a.iter().zip(&b) {
                assert!((x1 - x2).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn eq3_linear_output_in_unit_interval() {
        // Paper eq. 3 after normalization: leaf scores ∈ [-1, 1] for
        // ±1 comparison inputs.
        let ds = adult::generate(2_000, 35);
        let mut rng = Xoshiro256pp::new(36);
        let tree = DecisionTree::fit(&ds, &TreeConfig::default(), &mut rng);
        let nt = NeuralTree::from_tree(&tree, 16);
        let act = Activation::Hard;
        for x in ds.x.iter().take(300) {
            let u: Vec<f64> = nt.comparisons(x).iter().map(|&z| act.apply(z)).collect();
            for &s in &nt.leaf_scores(&u) {
                assert!((-1.0..=1.0).contains(&s), "leaf score {s} out of [-1,1]");
            }
        }
    }

    #[test]
    fn exactly_one_active_leaf() {
        let ds = adult::generate(1_000, 37);
        let mut rng = Xoshiro256pp::new(38);
        let tree = DecisionTree::fit(&ds, &TreeConfig::default(), &mut rng);
        let nt = NeuralTree::from_tree(&tree, 16);
        let act = Activation::Hard;
        for x in ds.x.iter().take(200) {
            let u: Vec<f64> = nt.comparisons(x).iter().map(|&z| act.apply(z)).collect();
            let active = nt
                .leaf_scores(&u)
                .iter()
                .filter(|&&s| s >= 0.0)
                .count();
            assert_eq!(active, 1, "exactly one leaf must activate");
        }
    }
}
