//! Last-layer fine-tuning with label smoothing (paper §4).
//!
//! The paper fine-tunes *only* the output layer ("we only fine tuned
//! the last linear layer, as we do not compute any polynomial after
//! that" — the [-1,1] domain constraint of eqs. 1–3 stays intact) and
//! trains with label smoothing so the winning class score is pushed
//! away from the others, making CKKS noise less likely to flip the
//! argmax (the 97.5 % HRF/NRF agreement).
//!
//! With the lower layers frozen, the problem is softmax regression on
//! the precomputed leaf features (length L·K): plain mini-batch
//! gradient descent suffices.

use super::model::NeuralForest;
use crate::data::Dataset;
use crate::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug)]
pub struct FinetuneConfig {
    pub epochs: usize,
    pub lr: f64,
    pub batch: usize,
    /// Label-smoothing ε (paper cites Szegedy et al. 2016).
    pub label_smoothing: f64,
    pub l2: f64,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            epochs: 20,
            lr: 0.2,
            batch: 128,
            label_smoothing: 0.1,
            l2: 1e-6,
        }
    }
}

fn softmax(z: &[f64]) -> Vec<f64> {
    let m = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = z.iter().map(|&v| (v - m).exp()).collect();
    let s: f64 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Fine-tune the output layer of `nf` in place on `ds`; returns the
/// final mean training cross-entropy.
///
/// Gradients flow into each tree's `w[c][k']` and `beta[c]`,
/// α-weighted exactly as the forward pass combines them.
pub fn finetune_last_layer(
    nf: &mut NeuralForest,
    ds: &Dataset,
    cfg: &FinetuneConfig,
    seed: u64,
) -> f64 {
    let n = ds.len();
    let k = nf.k;
    let c = nf.n_classes;
    let eps = cfg.label_smoothing;

    // Precompute leaf features once — lower layers are frozen.
    let feats: Vec<Vec<f64>> = ds.x.iter().map(|x| nf.leaf_features(x)).collect();

    let mut rng = Xoshiro256pp::new(seed);
    let mut order: Vec<usize> = (0..n).collect();
    let mut last_loss = f64::INFINITY;
    // Gradients arrive α-scaled (α ≈ 1/L); rescale the step so the
    // effective learning rate is independent of the forest size.
    let lr = cfg.lr * nf.trees.len() as f64;
    for _ in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        for chunk in order.chunks(cfg.batch) {
            // Accumulate gradients over the chunk.
            let l_trees = nf.trees.len();
            let mut gw = vec![vec![vec![0.0f64; k]; c]; l_trees];
            let mut gbeta = vec![vec![0.0f64; c]; l_trees];
            for &i in chunk {
                let scores = nf.output_from_features(&feats[i]);
                let probs = softmax(&scores);
                // Smoothed target.
                for ci in 0..c {
                    let target = if ci == ds.y[i] {
                        1.0 - eps + eps / c as f64
                    } else {
                        eps / c as f64
                    };
                    epoch_loss -= target * probs[ci].max(1e-12).ln();
                    let err = probs[ci] - target;
                    // d score_c / d w[l][c][k'] = α_l · v_feat
                    for l in 0..l_trees {
                        let a = nf.alphas[l];
                        let block = &feats[i][l * k..(l + 1) * k];
                        for (g, &v) in gw[l][ci].iter_mut().zip(block) {
                            *g += err * a * v;
                        }
                        gbeta[l][ci] += err * a;
                    }
                }
            }
            let scale = lr / chunk.len() as f64;
            for l in 0..l_trees {
                for ci in 0..c {
                    for (wv, g) in nf.trees[l].w[ci].iter_mut().zip(&gw[l][ci]) {
                        *wv -= scale * g + lr * cfg.l2 * *wv;
                    }
                    nf.trees[l].beta[ci] -= scale * gbeta[l][ci];
                }
            }
        }
        last_loss = epoch_loss / n as f64;
    }
    last_loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{metrics::Metrics, RandomForest, RandomForestConfig};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::model::NeuralForest;

    #[test]
    fn finetune_improves_poly_nrf() {
        // E2 precondition: fine-tuning the last layer recovers the
        // accuracy lost to soft/polynomial activations.
        let ds = adult::generate(6_000, 51);
        let (train, valid) = ds.split(0.8, 52);
        let rf = RandomForest::fit(
            &train,
            &RandomForestConfig {
                n_trees: 24,
                ..Default::default()
            },
            53,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let mut nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });

        let acc = |nf: &NeuralForest| {
            let pred = nf.predict_batch(&valid.x);
            Metrics::from_predictions(&pred, &valid.y).accuracy
        };
        let before = acc(&nf);
        let loss = finetune_last_layer(&mut nf, &train, &FinetuneConfig::default(), 54);
        let after = acc(&nf);
        assert!(loss.is_finite());
        assert!(
            after >= before - 1e-9,
            "fine-tune regressed: {before} -> {after}"
        );
        assert!(after > 0.78, "post-finetune accuracy {after}");
    }

    #[test]
    fn label_smoothing_widens_margins() {
        let ds = adult::generate(3_000, 55);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 8,
                ..Default::default()
            },
            56,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let margin = |nf: &NeuralForest| -> f64 {
            ds.x.iter()
                .take(200)
                .map(|x| {
                    let s = nf.forward(x);
                    (s[0] - s[1]).abs()
                })
                .sum::<f64>()
                / 200.0
        };
        let mut nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let m_before = margin(&nf);
        finetune_last_layer(
            &mut nf,
            &ds,
            &FinetuneConfig {
                epochs: 15,
                ..Default::default()
            },
            57,
        );
        let m_after = margin(&nf);
        assert!(
            m_after > m_before,
            "margins did not widen: {m_before} -> {m_after}"
        );
    }
}
