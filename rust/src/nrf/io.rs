//! NeuralForest serialization — train once, deploy anywhere.
//!
//! A deliberately simple, versioned, line-oriented text format (no
//! serde offline): floats are written with full `{:e}` precision so a
//! round-trip is bit-exact. The *server* ships this file; thresholds
//! and leaf weights stay with the model owner (clients only ever learn
//! τ, the variable-selection map — paper §3).

use super::activation::Activation;
use super::convert::NeuralTree;
use super::model::NeuralForest;
use std::fmt::Write as _;
use std::path::Path;

const MAGIC: &str = "cryptotree-nrf v1";

/// Serialize to the text format.
pub fn to_string(nf: &NeuralForest) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{MAGIC}");
    let (act_tag, act_params): (&str, Vec<f64>) = match &nf.activation {
        Activation::Hard => ("hard", vec![]),
        Activation::Tanh { a } => ("tanh", vec![*a]),
        Activation::Poly { coeffs } => ("poly", coeffs.clone()),
    };
    let _ = writeln!(
        s,
        "forest trees={} k={} classes={} activation={act_tag}",
        nf.trees.len(),
        nf.k,
        nf.n_classes
    );
    let _ = writeln!(s, "act_params {}", join(&act_params));
    let _ = writeln!(s, "alphas {}", join(&nf.alphas));
    for (i, t) in nf.trees.iter().enumerate() {
        let _ = writeln!(s, "tree {i} real_leaves={}", t.real_leaves);
        let tau: Vec<f64> = t.tau.iter().map(|&x| x as f64).collect();
        let _ = writeln!(s, "tau {}", join(&tau));
        let _ = writeln!(s, "t {}", join(&t.t));
        for row in &t.v {
            let _ = writeln!(s, "v {}", join(row));
        }
        let _ = writeln!(s, "b {}", join(&t.b));
        for row in &t.w {
            let _ = writeln!(s, "w {}", join(row));
        }
        let _ = writeln!(s, "beta {}", join(&t.beta));
    }
    s
}

fn join(xs: &[f64]) -> String {
    xs.iter()
        .map(|x| format!("{x:e}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Parse the text format.
pub fn from_str(text: &str) -> Result<NeuralForest, String> {
    let mut lines = text.lines();
    if lines.next() != Some(MAGIC) {
        return Err("bad magic: not a cryptotree-nrf v1 file".into());
    }
    let header = lines.next().ok_or("missing forest header")?;
    let get_kv = |line: &str, key: &str| -> Result<String, String> {
        line.split_whitespace()
            .find_map(|tok| tok.strip_prefix(&format!("{key}=")).map(str::to_string))
            .ok_or(format!("missing {key}= in `{line}`"))
    };
    let n_trees: usize = get_kv(header, "trees")?.parse().map_err(|e| format!("{e}"))?;
    let k: usize = get_kv(header, "k")?.parse().map_err(|e| format!("{e}"))?;
    let n_classes: usize = get_kv(header, "classes")?
        .parse()
        .map_err(|e| format!("{e}"))?;
    let act_tag = get_kv(header, "activation")?;

    let parse_vec = |line: &str, tag: &str| -> Result<Vec<f64>, String> {
        let rest = line
            .strip_prefix(tag)
            .ok_or(format!("expected `{tag}`, got `{line}`"))?;
        rest.split_whitespace()
            .map(|t| t.parse::<f64>().map_err(|e| format!("bad float {t}: {e}")))
            .collect()
    };
    let act_params = parse_vec(lines.next().ok_or("missing act_params")?, "act_params")?;
    let activation = match act_tag.as_str() {
        "hard" => Activation::Hard,
        "tanh" => Activation::Tanh {
            a: *act_params.first().ok_or("tanh needs a parameter")?,
        },
        "poly" => Activation::Poly { coeffs: act_params },
        other => return Err(format!("unknown activation `{other}`")),
    };
    let alphas = parse_vec(lines.next().ok_or("missing alphas")?, "alphas")?;
    if alphas.len() != n_trees {
        return Err(format!("{} alphas for {} trees", alphas.len(), n_trees));
    }

    let mut trees = Vec::with_capacity(n_trees);
    for i in 0..n_trees {
        let th = lines.next().ok_or(format!("missing tree {i} header"))?;
        if !th.starts_with(&format!("tree {i} ")) {
            return Err(format!("expected `tree {i}`, got `{th}`"));
        }
        let real_leaves: usize = get_kv(th, "real_leaves")?
            .parse()
            .map_err(|e| format!("{e}"))?;
        let tau_f = parse_vec(lines.next().ok_or("missing tau")?, "tau")?;
        let tau: Vec<usize> = tau_f.iter().map(|&x| x as usize).collect();
        let t = parse_vec(lines.next().ok_or("missing t")?, "t")?;
        if t.len() != k - 1 {
            return Err(format!("tree {i}: {} thresholds, expected {}", t.len(), k - 1));
        }
        let mut v = Vec::with_capacity(k);
        for _ in 0..k {
            v.push(parse_vec(lines.next().ok_or("missing v row")?, "v")?);
        }
        let b = parse_vec(lines.next().ok_or("missing b")?, "b")?;
        let mut w = Vec::with_capacity(n_classes);
        for _ in 0..n_classes {
            w.push(parse_vec(lines.next().ok_or("missing w row")?, "w")?);
        }
        let beta = parse_vec(lines.next().ok_or("missing beta")?, "beta")?;
        if b.len() != k || beta.len() != n_classes {
            return Err(format!("tree {i}: inconsistent dimensions"));
        }
        trees.push(NeuralTree {
            tau,
            t,
            v,
            b,
            w,
            beta,
            real_leaves,
            n_classes,
        });
    }
    Ok(NeuralForest {
        trees,
        alphas,
        k,
        n_classes,
        activation,
    })
}

/// Save to a file.
pub fn save(nf: &NeuralForest, path: impl AsRef<Path>) -> std::io::Result<()> {
    std::fs::write(path, to_string(nf))
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<NeuralForest, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    from_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::nrf::activation::chebyshev_fit_tanh;

    fn sample_forest() -> NeuralForest {
        let ds = adult::generate(800, 91);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 5,
                ..Default::default()
            },
            92,
        );
        NeuralForest::from_forest(
            &rf,
            Activation::Poly {
                coeffs: chebyshev_fit_tanh(3.0, 4),
            },
        )
    }

    #[test]
    fn roundtrip_is_exact() {
        let nf = sample_forest();
        let text = to_string(&nf);
        let back = from_str(&text).expect("parse");
        assert_eq!(back.k, nf.k);
        assert_eq!(back.n_classes, nf.n_classes);
        assert_eq!(back.alphas, nf.alphas);
        assert_eq!(back.activation, nf.activation);
        // Bit-exact predictions on real inputs.
        let ds = adult::generate(100, 93);
        for x in &ds.x {
            assert_eq!(nf.forward(x), back.forward(x));
        }
    }

    #[test]
    fn file_roundtrip() {
        let nf = sample_forest();
        let path = std::env::temp_dir().join("cryptotree_nrf_io_test.txt");
        save(&nf, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.trees.len(), nf.trees.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(from_str("not a model\n").is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let nf = sample_forest();
        let text = to_string(&nf);
        let cut = &text[..text.len() / 2];
        // Truncation must produce an error, never a silently-partial model.
        assert!(from_str(cut).is_err());
    }

    #[test]
    fn rejects_dimension_mismatch() {
        let nf = sample_forest();
        let mut text = to_string(&nf);
        // Corrupt the header's tree count.
        text = text.replace("trees=5", "trees=6");
        assert!(from_str(&text).is_err());
    }

    #[test]
    fn tanh_and_hard_activations_roundtrip() {
        let mut nf = sample_forest();
        nf.activation = Activation::Tanh { a: 2.5 };
        let back = from_str(&to_string(&nf)).unwrap();
        assert_eq!(back.activation, Activation::Tanh { a: 2.5 });
        nf.activation = Activation::Hard;
        let back = from_str(&to_string(&nf)).unwrap();
        assert_eq!(back.activation, Activation::Hard);
    }
}
