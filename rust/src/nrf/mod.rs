//! Neural Random Forests (paper §2.2, after Biau–Scornet–Welbl 2016).
//!
//! A trained CART tree with `K` leaves becomes a 2-hidden-layer
//! network:
//!
//! 1. comparison layer — `u_k = φ(x_{τ(k)} − t_k)`, one unit per
//!    internal node (eq. 1);
//! 2. leaf-localization layer — `v_{k'} = φ(Σ V_{k,k'} u_k + b_{k'})`,
//!    one unit per leaf, exactly one active (eq. 2), with weights and
//!    bias pre-divided by `2l(k')` so the linear output lies in
//!    `[-1, 1]` (eq. 3) — the precondition for polynomial activations
//!    under CKKS;
//! 3. output layer — per-class dot product with the leaf
//!    distributions (eqs. 4–5).
//!
//! Activations: hard sign (exact tree), `tanh(a·)` (differentiable),
//! or a Chebyshev polynomial fit of `tanh(a·)` (the HE-compatible
//! form). Only the output layer is fine-tuned (paper §4), with label
//! smoothing.

pub mod activation;
pub mod convert;
pub mod finetune;
pub mod io;
pub mod model;

pub use activation::{chebyshev_fit_tanh, Activation};
pub use convert::NeuralTree;
pub use finetune::{finetune_last_layer, FinetuneConfig};
pub use model::NeuralForest;
