//! The Neural Random Forest: all trees of a random forest converted to
//! [`NeuralTree`]s with a shared padded leaf count, evaluated in
//! parallel and α-combined (paper eq. 5).

use super::activation::Activation;
use super::convert::NeuralTree;
use crate::forest::tree::argmax;
use crate::forest::RandomForest;

/// A forest of neural trees with shared K and per-tree weights α.
#[derive(Clone, Debug)]
pub struct NeuralForest {
    pub trees: Vec<NeuralTree>,
    pub alphas: Vec<f64>,
    /// Shared (padded) leaf count.
    pub k: usize,
    pub n_classes: usize,
    /// Activation used in plaintext forward passes.
    pub activation: Activation,
}

impl NeuralForest {
    /// Convert a trained RF. Every tree is padded to the forest's max
    /// leaf count rounded up to the next power of two (power-of-two K
    /// keeps the HRF's rotate-and-sum exact and the slot blocks
    /// aligned).
    pub fn from_forest(rf: &RandomForest, activation: Activation) -> Self {
        let k_max = rf.max_leaves().max(2);
        let k = k_max.next_power_of_two();
        let trees: Vec<NeuralTree> = rf
            .trees
            .iter()
            .map(|t| NeuralTree::from_tree(t, k))
            .collect();
        NeuralForest {
            trees,
            alphas: rf.alphas.clone(),
            k,
            n_classes: rf.n_classes,
            activation,
        }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Per-tree activated leaf indicators — the "feature vector" the
    /// output layer (and its fine-tuning) consumes. Length L·K.
    pub fn leaf_features(&self, x: &[f64]) -> Vec<f64> {
        let mut feats = Vec::with_capacity(self.trees.len() * self.k);
        for nt in &self.trees {
            let u: Vec<f64> = nt
                .comparisons(x)
                .iter()
                .map(|&z| self.activation.apply(z))
                .collect();
            feats.extend(
                nt.leaf_scores(&u)
                    .iter()
                    .map(|&z| self.activation.apply(z)),
            );
        }
        feats
    }

    /// Full forward pass: class scores (paper eq. 5).
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let feats = self.leaf_features(x);
        self.output_from_features(&feats)
    }

    /// Output layer only, from precomputed leaf features.
    pub fn output_from_features(&self, feats: &[f64]) -> Vec<f64> {
        let mut scores = vec![0.0f64; self.n_classes];
        for (l, (nt, &alpha)) in self.trees.iter().zip(&self.alphas).enumerate() {
            let block = &feats[l * self.k..(l + 1) * self.k];
            for c in 0..self.n_classes {
                let dot: f64 = nt.w[c].iter().zip(block).map(|(w, v)| w * v).sum();
                scores[c] += alpha * (dot + nt.beta[c]);
            }
        }
        scores
    }

    pub fn predict(&self, x: &[f64]) -> usize {
        argmax(&self.forward(x))
    }

    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<usize> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Clone with a different activation (e.g. tanh → its polynomial
    /// fit for HE compatibility checks).
    pub fn with_activation(&self, activation: Activation) -> Self {
        let mut nf = self.clone();
        nf.activation = activation;
        nf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::nrf::activation::chebyshev_fit_tanh;

    fn small_forest() -> (crate::data::Dataset, RandomForest) {
        let ds = adult::generate(4_000, 41);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 16,
                ..Default::default()
            },
            42,
        );
        (ds, rf)
    }

    #[test]
    fn hard_nrf_matches_rf_predictions() {
        let (ds, rf) = small_forest();
        let nf = NeuralForest::from_forest(&rf, Activation::Hard);
        assert!(nf.k.is_power_of_two());
        for x in ds.x.iter().take(300) {
            let rf_scores = rf.predict_proba(x);
            let nf_scores = nf.forward(x);
            for (a, b) in rf_scores.iter().zip(&nf_scores) {
                assert!((a - b).abs() < 1e-9, "{rf_scores:?} vs {nf_scores:?}");
            }
        }
    }

    #[test]
    fn tanh_nrf_mostly_agrees_with_rf() {
        let (ds, rf) = small_forest();
        let nf = NeuralForest::from_forest(&rf, Activation::Tanh { a: 8.0 });
        let n = 400;
        let agree = ds
            .x
            .iter()
            .take(n)
            .filter(|x| rf.predict(x) == nf.predict(x))
            .count() as f64
            / n as f64;
        assert!(agree > 0.85, "tanh agreement {agree}");
    }

    #[test]
    fn poly_activation_close_to_tanh_forward() {
        let (ds, rf) = small_forest();
        let a = 3.0;
        let nf_tanh = NeuralForest::from_forest(&rf, Activation::Tanh { a });
        let coeffs = chebyshev_fit_tanh(a, 6);
        let nf_poly = nf_tanh.with_activation(Activation::Poly { coeffs });
        let mut max_dev = 0.0f64;
        for x in ds.x.iter().take(200) {
            let st = nf_tanh.forward(x);
            let sp = nf_poly.forward(x);
            for (a, b) in st.iter().zip(&sp) {
                max_dev = max_dev.max((a - b).abs());
            }
        }
        assert!(max_dev < 0.15, "poly vs tanh deviation {max_dev}");
    }

    #[test]
    fn leaf_features_shape() {
        let (ds, rf) = small_forest();
        let nf = NeuralForest::from_forest(&rf, Activation::Hard);
        let f = nf.leaf_features(&ds.x[0]);
        assert_eq!(f.len(), nf.n_trees() * nf.k);
        // With hard activation features are ±1 and exactly one +1 per tree.
        for l in 0..nf.n_trees() {
            let block = &f[l * nf.k..(l + 1) * nf.k];
            assert_eq!(block.iter().filter(|&&v| v > 0.0).count(), 1);
        }
    }
}
