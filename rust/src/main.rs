//! Cryptotree CLI — train, serve and demo Homomorphic Random Forests.
//!
//! ```text
//! cryptotree demo   [--params fast|default|secure] [--trees N] [--rows N]
//! cryptotree table1 [--k K --trees L]
//! cryptotree info
//! ```
//!
//! `demo` runs the full pipeline end to end (train RF → NRF → fine-tune
//! → pack HRF → encrypted inference through the coordinator) on the
//! synthetic Adult data. The heavier reproductions live in
//! `cargo bench` and `examples/`.

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encryptor, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager};
use cryptotree::data::adult;
use cryptotree::forest::{metrics::Metrics, RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::{finetune_last_layer, FinetuneConfig, NeuralForest};
use std::sync::Arc;

/// Tiny flag parser: --key value pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i + 1 < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                flags.insert(key.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

fn params_by_name(name: &str) -> std::sync::Arc<CkksParams> {
    match name {
        "toy" => CkksParams::toy(),
        "fast" => CkksParams::fast(),
        "secure" => CkksParams::secure128(),
        _ => CkksParams::hrf_default(),
    }
}

fn cmd_info() {
    println!("cryptotree — Homomorphic Random Forests under CKKS");
    for p in [
        CkksParams::toy(),
        CkksParams::fast(),
        CkksParams::hrf_default(),
        CkksParams::secure128(),
    ] {
        println!(
            "  params {:<20} N={:<6} slots={:<6} depth={} logQP={:.0} security={}",
            p.name,
            p.n,
            p.slots(),
            p.depth(),
            p.log_qp(),
            p.security_estimate()
        );
    }
}

fn cmd_demo(args: &Args) {
    let params = params_by_name(&args.get_str("params", "fast"));
    let n_trees: usize = args.get("trees", 16);
    let rows: usize = args.get("rows", 6_000);
    let deg: usize = args.get("degree", if params.depth() >= 8 { 4 } else { 2 });

    println!(
        "== Cryptotree demo ({} trees, params {}) ==",
        n_trees, params.name
    );
    let t0 = std::time::Instant::now();
    let ds = adult::generate(rows, 1);
    let (train, valid) = ds.split(0.8, 2);
    println!(
        "[{:7.2?}] synthetic Adult: {} train / {} valid",
        t0.elapsed(),
        train.len(),
        valid.len()
    );

    let rf = RandomForest::fit(
        &train,
        &RandomForestConfig {
            n_trees,
            ..Default::default()
        },
        3,
    );
    println!(
        "[{:7.2?}] RF trained (K={} leaves max)",
        t0.elapsed(),
        rf.max_leaves()
    );

    let coeffs = chebyshev_fit_tanh(3.0, deg);
    let mut nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
    finetune_last_layer(&mut nf, &train, &FinetuneConfig::default(), 4);
    println!("[{:7.2?}] NRF fine-tuned (K padded to {})", t0.elapsed(), nf.k);

    let ctx = CkksContext::new(params.clone());
    let enc = cryptotree::ckks::Encoder::new(&ctx);
    let model =
        HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).expect("packing");
    let plan = model.plan;
    println!(
        "[{:7.2?}] packed: {} trees x block {} = {} of {} slots",
        t0.elapsed(),
        plan.l,
        plan.block,
        plan.used_slots,
        plan.slots
    );

    let mut kg = KeyGenerator::new(&ctx, 5);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 6), Decryptor::new(kg.secret_key()));
    println!(
        "[{:7.2?}] client keys generated ({} rotations)",
        t0.elapsed(),
        plan.rotations_needed().len()
    );

    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(rlk, gk);
    let server = Arc::new(HrfServer::new(model));
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        ctx.clone(),
        server.clone(),
        sessions.clone(),
        None,
    );

    let n_eval = 5.min(valid.len());
    let mut enc_preds = Vec::new();
    for i in 0..n_eval {
        let ct = client.encrypt_input(&ctx, &enc, &server.model, &valid.x[i]);
        let rx = coord.submit_encrypted(sid, ct).expect("submit");
        let outs = rx.recv().unwrap().expect("eval");
        let (scores, pred) = client.decrypt_response(&ctx, &enc, &outs);
        enc_preds.push(pred);
        println!(
            "  sample {i}: scores {:?} -> class {pred} (truth {})",
            scores.iter().map(|s| format!("{s:.4}")).collect::<Vec<_>>(),
            valid.y[i]
        );
    }
    let nrf_preds: Vec<usize> = (0..n_eval).map(|i| nf.predict(&valid.x[i])).collect();
    let agree = enc_preds
        .iter()
        .zip(&nrf_preds)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "[{:7.2?}] HRF/NRF agreement on {n_eval} encrypted samples: {agree}/{n_eval}",
        t0.elapsed()
    );

    let rf_pred = rf.predict_batch(&valid.x);
    let m = Metrics::from_predictions(&rf_pred, &valid.y);
    println!("RF validation accuracy {:.3} (F1 {:.3})", m.accuracy, m.f1);
    let snapshot = coord.metrics.snapshot();
    println!(
        "coordinator: {} encrypted done, mean latency {:?}",
        snapshot.encrypted_completed, snapshot.encrypted_mean
    );
    coord.shutdown();
}

fn cmd_table1(args: &Args) {
    let k: usize = args.get("k", 16);
    let l: usize = args.get("trees", 64);
    let plan = cryptotree::hrf::HrfPlan::new(k, l, 2, 14, 8192).expect("plan");
    let [l1, l2, l3] = plan.table1_formulas();
    println!("Table 1 (paper formulas) for K={k}, L={l}, C=2:");
    println!(
        "  {:<22} {:>10} {:>15} {:>10}",
        "layer", "additions", "multiplications", "rotations"
    );
    for (name, row) in [
        ("first linear layer", l1),
        ("second linear layer", l2),
        ("third linear layer", l3),
    ] {
        println!("  {:<22} {:>10} {:>15} {:>10}", name, row.0, row.1, row.2);
    }
    println!("(measured counterparts: `cargo bench --bench table1_opcounts`)");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("info");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "demo" => cmd_demo(&args),
        "table1" => cmd_table1(&args),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command `{other}` — use demo | table1 | info");
            std::process::exit(2);
        }
    }
}
