//! Synthetic credit-default dataset — a second structured-data domain
//! exercised by the `credit_scoring` example (the paper's intro
//! motivates financial-sector use).
//!
//! 10 features (utilization, payment history, income, debt ratio, …),
//! binary "defaults within 2 years" target with ≈ 7 % positive rate
//! and threshold-style risk interactions that favour tree ensembles.

use super::dataset::Dataset;
use crate::rng::Xoshiro256pp;

const FEATURES: &[&str] = &[
    "revolving-utilization",
    "age",
    "late-30-59",
    "debt-ratio",
    "monthly-income",
    "open-credit-lines",
    "late-90",
    "real-estate-loans",
    "late-60-89",
    "dependents",
];

/// Generate the synthetic credit dataset (normalized to [0,1]).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let util = rng.next_f64().powf(1.8); // skewed toward low utilization
        let age = rng.normal_ms(48.0, 14.0).clamp(21.0, 96.0);
        let late_30 = if rng.bernoulli(0.16) {
            1.0 + rng.next_index(5) as f64
        } else {
            0.0
        };
        let debt_ratio = (rng.next_f64().powf(2.2) * 2.0).min(2.0);
        let income = rng.normal_ms(6_400.0, 3_800.0).clamp(0.0, 30_000.0);
        let open_lines = rng.normal_ms(8.5, 5.1).round().clamp(0.0, 40.0);
        let late_90 = if rng.bernoulli(0.055) {
            1.0 + rng.next_index(3) as f64
        } else {
            0.0
        };
        let re_loans = rng.next_index(5) as f64;
        let late_60 = if rng.bernoulli(0.05) { 1.0 } else { 0.0 };
        let dependents = rng.next_index(5) as f64;

        // Risk score with hard thresholds (tree-friendly structure).
        let mut score = -3.4
            + 2.6 * (util > 0.9) as u8 as f64
            + 1.3 * (util > 0.5) as u8 as f64
            + 1.8 * late_90.min(1.0)
            + 0.9 * late_30.min(2.0) / 2.0
            + 0.8 * late_60
            + 0.8 * (debt_ratio > 1.0) as u8 as f64
            + 0.7 * (income < 2_500.0) as u8 as f64
            - 0.02 * (age - 35.0).max(0.0);
        score += 0.5 * (util > 0.9 && income < 4_000.0) as u8 as f64;
        let p = 1.0 / (1.0 + (-score).exp());
        y.push(rng.bernoulli(p) as usize);
        x.push(vec![
            util, age, late_30, debt_ratio, income, open_lines, late_90, re_loans, late_60,
            dependents,
        ]);
    }
    let mut ds = Dataset::new(x, y, 2, FEATURES.iter().map(|s| s.to_string()).collect());
    ds.normalize_unit();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_rate() {
        let d = generate(20_000, 3);
        assert_eq!(d.n_features(), 10);
        let pos = d.y.iter().filter(|&&y| y == 1).count() as f64 / d.len() as f64;
        assert!((0.03..=0.15).contains(&pos), "default rate {pos}");
    }

    #[test]
    fn utilization_threshold_signal() {
        let d = generate(20_000, 4);
        let (mut hi, mut hi_pos, mut lo, mut lo_pos) = (0usize, 0usize, 0usize, 0usize);
        for (row, &y) in d.x.iter().zip(&d.y) {
            if row[0] > 0.9 {
                hi += 1;
                hi_pos += y;
            } else {
                lo += 1;
                lo_pos += y;
            }
        }
        assert!(hi_pos as f64 / hi as f64 > 2.0 * lo_pos as f64 / lo as f64);
    }
}
