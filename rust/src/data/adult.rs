//! Synthetic Adult-Income stand-in (offline substitute for UCI Adult).
//!
//! The generator reproduces the aspects of Adult that matter for the
//! paper's evaluation:
//!
//! * 48 842 observations, 14 socio-demographic features — 6 continuous
//!   (age, fnlwgt, education-num, capital-gain, capital-loss,
//!   hours-per-week) and 8 categoricals label-encoded to small integer
//!   codes, everything min-max normalized to `[0,1]` afterwards (the
//!   paper's preprocessing).
//! * a binary target ">50K" with ≈ 24 % positive rate, driven by a
//!   *noisy nonlinear* rule over education/age/hours/capital-gain plus
//!   categorical effects — so that axis-aligned tree ensembles beat a
//!   linear model, which is the structural property Table 2 exercises.
//!
//! Everything is deterministic in the seed.

use super::dataset::Dataset;
use crate::rng::Xoshiro256pp;

/// Marginals loosely matched to UCI Adult.
const FEATURES: &[&str] = &[
    "age",
    "workclass",
    "fnlwgt",
    "education",
    "education-num",
    "marital-status",
    "occupation",
    "relationship",
    "race",
    "sex",
    "capital-gain",
    "capital-loss",
    "hours-per-week",
    "native-country",
];

/// Number of rows in the real dataset; the default size here.
pub const ADULT_N: usize = 48_842;

/// Generate the synthetic Adult dataset (already normalized to [0,1]).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        // --- raw feature draws -------------------------------------
        let age = (rng.normal_ms(38.6, 13.7)).clamp(17.0, 90.0);
        let workclass = rng.next_index(8) as f64; // 8 categories, Private-heavy
        let workclass = if rng.bernoulli(0.70) { 3.0 } else { workclass };
        let fnlwgt = rng.normal_ms(189_000.0, 105_000.0).clamp(12_000.0, 1_490_000.0);
        // education-num 1..16, peaked at HS(9)/some-college(10)
        let education_num = {
            let base = rng.normal_ms(10.1, 2.6).round().clamp(1.0, 16.0);
            base
        };
        let education = education_num - 1.0; // label-encoded school level
        let marital = rng.next_index(7) as f64;
        let married = marital < 2.0 || rng.bernoulli(0.46);
        let marital = if married { 1.0 } else { marital.max(2.0) };
        let occupation = rng.next_index(14) as f64;
        let relationship = if married { 0.0 } else { 1.0 + rng.next_index(4) as f64 };
        let race = if rng.bernoulli(0.855) {
            4.0
        } else {
            rng.next_index(4) as f64
        };
        let sex = if rng.bernoulli(0.669) { 1.0 } else { 0.0 };
        // capital-gain: zero-inflated heavy tail
        let capital_gain = if rng.bernoulli(0.083) {
            (rng.next_f64().powi(3) * 25_000.0 + 2_000.0).min(99_999.0)
        } else {
            0.0
        };
        let capital_loss = if rng.bernoulli(0.047) {
            rng.normal_ms(1_870.0, 380.0).clamp(100.0, 4_356.0)
        } else {
            0.0
        };
        let hours = rng.normal_ms(40.4, 12.3).round().clamp(1.0, 99.0);
        let country = if rng.bernoulli(0.897) {
            38.0
        } else {
            rng.next_index(41) as f64
        };

        // --- noisy nonlinear labelling rule ------------------------
        // Mirrors the real drivers of ">50K": education, age (peaking
        // mid-career), hours, capital gains, marriage; plus occupation
        // interactions. Logistic noise keeps Bayes error realistic.
        let age_peak = (-((age - 47.0) / 14.0).powi(2)).exp(); // mid-career bump
        let edu_hi = ((education_num - 9.0) / 7.0).max(0.0); // college and up
        let mut score = -4.55
            + 3.1 * edu_hi
            + 2.1 * age_peak
            + 0.030 * (hours - 40.0)
            + 2.8 * (capital_gain > 5_000.0) as u8 as f64
            + 0.9 * (capital_loss > 1_500.0) as u8 as f64
            + 1.25 * married as u8 as f64
            + 0.45 * sex
            + 0.55 * ((occupation == 3.0 || occupation == 9.0) as u8 as f64); // exec/prof
        // interaction: long hours only pay off with education
        score += 0.02 * (hours - 40.0).max(0.0) * edu_hi;
        // Sharpen the decision boundary: the real Adult task has a
        // Bayes error low enough for RF ≈ .83 accuracy; 1.8x gain on
        // the logit gets the synthetic task into the same regime while
        // keeping the ~24% positive rate (intercept re-centred below).
        score = 1.8 * (score + 0.30);
        // logistic noise
        let p = 1.0 / (1.0 + (-score).exp());
        let label = rng.bernoulli(p) as usize;

        x.push(vec![
            age,
            workclass,
            fnlwgt,
            education,
            education_num,
            marital,
            occupation,
            relationship,
            race,
            sex,
            capital_gain,
            capital_loss,
            hours,
            country,
        ]);
        y.push(label);
    }
    let mut ds = Dataset::new(
        x,
        y,
        2,
        FEATURES.iter().map(|s| s.to_string()).collect(),
    );
    ds.normalize_unit();
    ds
}

/// Default-size dataset as used by Table 2 reproductions.
pub fn generate_default(seed: u64) -> Dataset {
    generate(ADULT_N, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_normalization() {
        let d = generate(2000, 7);
        assert_eq!(d.len(), 2000);
        assert_eq!(d.n_features(), 14);
        for row in &d.x {
            for &v in row {
                assert!((0.0..=1.0).contains(&v), "feature out of [0,1]: {v}");
            }
        }
    }

    #[test]
    fn positive_rate_near_adult() {
        let d = generate(20_000, 1);
        let pos = d.y.iter().filter(|&&y| y == 1).count() as f64 / d.len() as f64;
        assert!(
            (0.18..=0.30).contains(&pos),
            "positive rate {pos} not Adult-like (~0.24)"
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate(100, 5);
        let b = generate(100, 5);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x[17], b.x[17]);
        let c = generate(100, 6);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn label_depends_nonlinearly_on_features() {
        // Education split should change the positive rate materially —
        // the signal trees exploit.
        let d = generate(20_000, 2);
        let edu_idx = 4;
        let (mut hi, mut hi_pos, mut lo, mut lo_pos) = (0, 0, 0, 0);
        for (row, &y) in d.x.iter().zip(&d.y) {
            if row[edu_idx] > 0.6 {
                hi += 1;
                hi_pos += y;
            } else {
                lo += 1;
                lo_pos += y;
            }
        }
        let hi_rate = hi_pos as f64 / hi as f64;
        let lo_rate = lo_pos as f64 / lo as f64;
        assert!(hi_rate > lo_rate + 0.15, "hi {hi_rate} lo {lo_rate}");
    }
}
