//! In-memory tabular dataset with the normalization the paper uses:
//! every feature (continuous or label-encoded categorical) is mapped
//! to `[0, 1]` — a requirement for the NRF/HRF input domain
//! (`X = [0,1]^d`, paper §2.2).

use crate::rng::Xoshiro256pp;

/// Row-major tabular dataset for classification.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major features, `n_rows × n_features`.
    pub x: Vec<Vec<f64>>,
    /// Class labels in `0..n_classes`.
    pub y: Vec<usize>,
    pub n_classes: usize,
    pub feature_names: Vec<String>,
}

impl Dataset {
    pub fn new(
        x: Vec<Vec<f64>>,
        y: Vec<usize>,
        n_classes: usize,
        feature_names: Vec<String>,
    ) -> Self {
        assert_eq!(x.len(), y.len());
        if let Some(first) = x.first() {
            assert_eq!(first.len(), feature_names.len());
        }
        Dataset {
            x,
            y,
            n_classes,
            feature_names,
        }
    }

    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.feature_names.len()
    }

    /// Min-max normalize every feature to [0, 1] in place; returns the
    /// per-feature (min, max) so a server can normalize future inputs
    /// the same way.
    pub fn normalize_unit(&mut self) -> Vec<(f64, f64)> {
        let d = self.n_features();
        let mut ranges = vec![(f64::INFINITY, f64::NEG_INFINITY); d];
        for row in &self.x {
            for (j, &v) in row.iter().enumerate() {
                ranges[j].0 = ranges[j].0.min(v);
                ranges[j].1 = ranges[j].1.max(v);
            }
        }
        for row in &mut self.x {
            for (j, v) in row.iter_mut().enumerate() {
                let (lo, hi) = ranges[j];
                *v = if hi > lo { (*v - lo) / (hi - lo) } else { 0.0 };
            }
        }
        ranges
    }

    /// Apply previously-computed ranges to a single observation.
    pub fn normalize_row(row: &[f64], ranges: &[(f64, f64)]) -> Vec<f64> {
        row.iter()
            .zip(ranges)
            .map(|(&v, &(lo, hi))| {
                if hi > lo {
                    ((v - lo) / (hi - lo)).clamp(0.0, 1.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Shuffled train/validation split (like the paper's 80/20).
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        let mut rng = Xoshiro256pp::new(seed);
        rng.shuffle(&mut idx);
        let n_train = (self.len() as f64 * train_frac).round() as usize;
        let pick = |ids: &[usize]| Dataset {
            x: ids.iter().map(|&i| self.x[i].clone()).collect(),
            y: ids.iter().map(|&i| self.y[i]).collect(),
            n_classes: self.n_classes,
            feature_names: self.feature_names.clone(),
        };
        (pick(&idx[..n_train]), pick(&idx[n_train..]))
    }

    /// Class prior distribution.
    pub fn class_priors(&self) -> Vec<f64> {
        let mut counts = vec![0usize; self.n_classes];
        for &y in &self.y {
            counts[y] += 1;
        }
        counts
            .iter()
            .map(|&c| c as f64 / self.len().max(1) as f64)
            .collect()
    }

    /// Subsample `n` rows (without replacement).
    pub fn subsample(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256pp::new(seed);
        let ids = rng.sample_indices(self.len(), n);
        Dataset {
            x: ids.iter().map(|&i| self.x[i].clone()).collect(),
            y: ids.iter().map(|&i| self.y[i]).collect(),
            n_classes: self.n_classes,
            feature_names: self.feature_names.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            vec![
                vec![0.0, 10.0],
                vec![5.0, 20.0],
                vec![10.0, 30.0],
                vec![2.5, 15.0],
            ],
            vec![0, 1, 1, 0],
            2,
            vec!["a".into(), "b".into()],
        )
    }

    #[test]
    fn normalize_to_unit_interval() {
        let mut d = toy();
        let ranges = d.normalize_unit();
        assert_eq!(ranges[0], (0.0, 10.0));
        for row in &d.x {
            for &v in row {
                assert!((0.0..=1.0).contains(&v));
            }
        }
        assert_eq!(d.x[2][0], 1.0);
        assert_eq!(d.x[0][1], 0.0);
    }

    #[test]
    fn normalize_row_clamps() {
        let r = Dataset::normalize_row(&[20.0, -5.0], &[(0.0, 10.0), (0.0, 10.0)]);
        assert_eq!(r, vec![1.0, 0.0]);
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let (tr, va) = d.split(0.75, 1);
        assert_eq!(tr.len(), 3);
        assert_eq!(va.len(), 1);
        assert_eq!(tr.n_classes, 2);
    }

    #[test]
    fn priors_sum_to_one() {
        let d = toy();
        let p = d.class_priors();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(p, vec![0.5, 0.5]);
    }
}
