//! Dataset plumbing and synthetic data generators.
//!
//! The paper evaluates on the UCI Adult Income dataset. This
//! environment is offline, so [`adult`] generates a deterministic
//! synthetic stand-in with Adult-like marginals and a noisy nonlinear
//! labelling rule (see DESIGN.md §Substitutions). [`credit`] is a
//! second domain used by the `credit_scoring` example.

pub mod adult;
pub mod credit;
pub mod dataset;

pub use dataset::Dataset;
