//! # Cryptotree
//!
//! A production-oriented reproduction of *"Cryptotree: fast and accurate
//! predictions on encrypted structured data"* (Huynh, 2020).
//!
//! Cryptotree converts trained Random Forests (RF) into Neural Random
//! Forests (NRF, Biau et al. 2016) and evaluates them under the CKKS
//! leveled homomorphic encryption scheme as Homomorphic Random Forests
//! (HRF). Everything the paper depends on is implemented here from
//! scratch:
//!
//! * [`ckks`] — a complete leveled CKKS implementation (RNS/NTT
//!   polynomial arithmetic, canonical-embedding encoder, hybrid
//!   key-switching, rotations, rescaling) with per-operation counters.
//! * [`forest`] — CART decision trees, bagged random forests, a logistic
//!   regression baseline and classification metrics.
//! * [`nrf`] — the RF → Neural Random Forest conversion (paper §2.2),
//!   tanh/polynomial activations, last-layer fine-tuning with label
//!   smoothing.
//! * [`hrf`] — the paper's contribution (§3): slot packing, packed
//!   matrix multiplication by diagonals (Algorithm 1), homomorphic dot
//!   products (Algorithm 2) and full HRF evaluation (Algorithm 3), plus
//!   a CryptoNet-style HE-MLP baseline used in §5.
//! * [`coordinator`] — the L3 serving layer: router, dynamic batcher,
//!   bounded queues with backpressure, per-client key sessions and
//!   worker pool.
//! * [`net`] — the networked serving tier on top of the coordinator:
//!   a length-prefixed, versioned binary wire protocol over TCP,
//!   the thread-per-connection server behind `cryptotree-serve`, and
//!   the blocking client used by `cryptotree-loadgen` and tests.
//! * [`keycache`] — the sharded, memory-budgeted evaluation-key cache
//!   behind those sessions: exact `key_bytes` accounting, per-shard
//!   LRU eviction under a global budget, and the eviction-safe
//!   re-registration protocol (`SubmitError::KeysEvicted`).
//! * [`mem`] — the memory plane: a sharded, size-classed,
//!   byte-budgeted slab pool behind every `Scratch` handle (one
//!   bounded arena for all evaluator temporaries instead of
//!   per-worker warm lists), paired with the keycache disk spill tier
//!   in [`keycache`].
//! * [`obs`] — the observability plane: request-scoped span timelines
//!   through the serving tier (trace ring + wire dump) and a timing
//!   engine backend that profiles HE op wall-time per schedule
//!   segment, both zero-cost when disabled.
//! * [`runtime`] — the schedule execution engine (one generic
//!   interpreter over pluggable `ScheduleBackend`s: CKKS, f32 slots,
//!   dry-run counting; plus the `SchedulePass` optimization pipeline)
//!   and the loader for the AOT-compiled JAX/Pallas slot-model
//!   artifacts, used for the plaintext fast path and cross-checking.
//! * [`data`] — dataset plumbing and the synthetic Adult-Income
//!   generator used in place of the UCI download (offline environment;
//!   see DESIGN.md §Substitutions).
//!
//! Python/JAX/Pallas run only at build time (`make artifacts`); the
//! request path is pure Rust.

// CI runs `cargo clippy -- -D warnings`. Two stylistic lints are
// opted out crate-wide: the RNS/NTT hot loops index several limb
// slices in lockstep (zip chains would obscure the modular math), and
// the serving internals thread many handles by design.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
// `std::simd` explicit-vector variants of the add/sub kernels (see
// `ckks::kernels`); nightly-only, so the default build never sees it.
#![cfg_attr(feature = "wide", feature(portable_simd))]

pub mod bench_harness;
pub mod ckks;
pub mod coordinator;
pub mod data;
pub mod forest;
pub mod hrf;
pub mod keycache;
pub mod lockutil;
pub mod mem;
pub mod net;
pub mod nrf;
pub mod obs;
pub mod rng;
pub mod runtime;
