//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median / p95 statistics,
//! aligned table printing, and a machine-readable JSON writer
//! ([`write_json`], see ROADMAP.md §Benchmarking) used by every
//! `harness = false` bench binary under `rust/benches/`.

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Timing {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `iters` measured
/// runs. The closure's return value is black-boxed to prevent the
/// optimizer from deleting the work.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    Timing {
        name: name.to_string(),
        iters,
        mean,
        median: samples[iters / 2],
        p95: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        min: samples[0],
    }
}

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// One machine-readable benchmark data point: operation name, median
/// nanoseconds per op, the limb-parallel thread count it ran with and
/// the parameter-set label. Serialized by [`write_json`] so the perf
/// trajectory is comparable across PRs.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub op: String,
    pub ns_per_op: f64,
    pub threads: usize,
    pub params: String,
}

impl BenchRecord {
    /// Record a [`Timing`]'s median as ns/op.
    pub fn from_timing(t: &Timing, threads: usize, params: &str) -> Self {
        BenchRecord {
            op: t.name.clone(),
            ns_per_op: t.median.as_secs_f64() * 1e9,
            threads,
            params: params.to_string(),
        }
    }

    /// Record a raw ns/op figure (for throughput-style benches that
    /// measure outside the `bench` helper).
    pub fn from_ns(op: &str, ns_per_op: f64, threads: usize, params: &str) -> Self {
        BenchRecord {
            op: op.to_string(),
            ns_per_op,
            threads,
            params: params.to_string(),
        }
    }
}

/// Minimal JSON string escaping (the crate is dependency-free).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize records as a JSON array (stable field order).
pub fn to_json(records: &[BenchRecord]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"op\": \"{}\", \"ns_per_op\": {:.1}, \"threads\": {}, \"params\": \"{}\"}}{}\n",
            json_escape(&r.op),
            r.ns_per_op,
            r.threads,
            json_escape(&r.params),
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push(']');
    out
}

/// Write records to `path` as JSON (see ROADMAP.md §Benchmarking for
/// the `BENCH_*.json` convention).
pub fn write_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, to_json(records))?;
    println!("wrote {} records to {path}", records.len());
    Ok(())
}

/// Pretty-print a vector of timings as an aligned table.
pub fn print_table(title: &str, rows: &[Timing]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "case", "iters", "mean", "median", "p95", "min"
    );
    for t in rows {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
            t.name,
            t.iters,
            fmt_dur(t.mean),
            fmt_dur(t.median),
            fmt_dur(t.p95),
            fmt_dur(t.min)
        );
    }
}

/// Human duration formatting (µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Print a generic labelled metrics table (used by the table
/// reproductions where the "result" is a metric, not a duration).
pub fn print_metric_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let t = bench("noop-ish", 2, 11, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert_eq!(t.iters, 11);
        assert!(t.min <= t.median && t.median <= t.p95);
        assert!(t.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with("s"));
    }

    #[test]
    fn json_records_render_and_escape() {
        let recs = vec![
            BenchRecord::from_ns("rotate(1)", 1234.56, 4, "fast-n8192-d8"),
            BenchRecord::from_ns("weird \"op\"\\", 1.0, 1, "toy"),
        ];
        let j = to_json(&recs);
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"op\": \"rotate(1)\""));
        assert!(j.contains("\"ns_per_op\": 1234.6"));
        assert!(j.contains("\"threads\": 4"));
        assert!(j.contains("\"params\": \"fast-n8192-d8\""));
        assert!(j.contains("weird \\\"op\\\"\\\\"));
        // exactly one comma separator for two records
        assert_eq!(j.matches("},\n").count(), 1);
    }

    #[test]
    fn from_timing_uses_median() {
        let t = Timing {
            name: "x".into(),
            iters: 3,
            mean: Duration::from_micros(9),
            median: Duration::from_micros(10),
            p95: Duration::from_micros(11),
            min: Duration::from_micros(8),
        };
        let r = BenchRecord::from_timing(&t, 2, "p");
        assert!((r.ns_per_op - 10_000.0).abs() < 1e-6);
        assert_eq!(r.threads, 2);
    }
}
