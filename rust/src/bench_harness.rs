//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median / p95 statistics and
//! aligned table printing, used by every `harness = false` bench binary
//! under `rust/benches/`.

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Timing {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `iters` measured
/// runs. The closure's return value is black-boxed to prevent the
/// optimizer from deleting the work.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    Timing {
        name: name.to_string(),
        iters,
        mean,
        median: samples[iters / 2],
        p95: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        min: samples[0],
    }
}

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Pretty-print a vector of timings as an aligned table.
pub fn print_table(title: &str, rows: &[Timing]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "case", "iters", "mean", "median", "p95", "min"
    );
    for t in rows {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
            t.name,
            t.iters,
            fmt_dur(t.mean),
            fmt_dur(t.median),
            fmt_dur(t.p95),
            fmt_dur(t.min)
        );
    }
}

/// Human duration formatting (µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Print a generic labelled metrics table (used by the table
/// reproductions where the "result" is a metric, not a duration).
pub fn print_metric_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let t = bench("noop-ish", 2, 11, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert_eq!(t.iters, 11);
        assert!(t.min <= t.median && t.median <= t.p95);
        assert!(t.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with("s"));
    }
}
