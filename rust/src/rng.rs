//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so Cryptotree ships
//! its own small PRNG stack:
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Xoshiro256pp`] — the main generator (xoshiro256++, Blackman &
//!   Vigna), used everywhere randomness is needed: bagging, feature
//!   sub-sampling, synthetic data generation, CKKS error sampling and
//!   the in-crate property-test harness.
//!
//! Cryptographic caveat: xoshiro is **not** a CSPRNG. For the CKKS
//! substrate this matters for key/error sampling; a production
//! deployment would swap [`Xoshiro256pp`] for a ChaCha20-based sampler.
//! The scheme logic (noise growth, correctness) is unaffected, which is
//! what this reproduction evaluates. See DESIGN.md §Substitutions.

/// SplitMix64: tiny, solid 64-bit generator used to seed other PRNGs.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast general-purpose 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-thread / per-tree use).
    pub fn split(&mut self) -> Self {
        Self::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire reduction).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Ternary value in {-1, 0, 1} with P(0) = 1/2, P(±1) = 1/4 each
    /// (CKKS secret-key distribution).
    #[inline]
    pub fn ternary(&mut self) -> i64 {
        match self.next_u64() & 3 {
            0 => -1,
            1 => 1,
            _ => 0,
        }
    }

    /// Centered discrete Gaussian with sigma = 3.2 (CKKS error
    /// distribution), via rounded Box–Muller — standard practice for HE
    /// implementations at this sigma.
    #[inline]
    pub fn discrete_gaussian(&mut self, sigma: f64) -> i64 {
        self.normal_ms(0.0, sigma).round() as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_range() {
        let mut r = Xoshiro256pp::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.next_below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn ternary_distribution() {
        let mut r = Xoshiro256pp::new(13);
        let n = 100_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[(r.ternary() + 1) as usize] += 1;
        }
        let p0 = counts[1] as f64 / n as f64;
        assert!((p0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::new(17);
        let s = r.sample_indices(100, 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(19);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
