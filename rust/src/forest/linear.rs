//! Logistic-regression baseline (Table 2's "Linear" row).
//!
//! Binary logistic regression trained by mini-batch gradient descent
//! with L2 regularization — the representative "only linear models are
//! practical under HE" baseline the paper argues beyond.

use crate::data::Dataset;
use crate::rng::Xoshiro256pp;

#[derive(Clone, Debug)]
pub struct LogisticRegression {
    pub w: Vec<f64>,
    pub b: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct LogRegConfig {
    pub epochs: usize,
    pub lr: f64,
    pub l2: f64,
    pub batch: usize,
}

impl Default for LogRegConfig {
    fn default() -> Self {
        LogRegConfig {
            epochs: 30,
            lr: 0.5,
            l2: 1e-5,
            batch: 256,
        }
    }
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl LogisticRegression {
    pub fn fit(ds: &Dataset, cfg: &LogRegConfig, seed: u64) -> Self {
        assert_eq!(ds.n_classes, 2, "binary only");
        let d = ds.n_features();
        let mut w = vec![0.0f64; d];
        let mut b = 0.0f64;
        let mut rng = Xoshiro256pp::new(seed);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        for _ in 0..cfg.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(cfg.batch) {
                let mut gw = vec![0.0f64; d];
                let mut gb = 0.0f64;
                for &i in chunk {
                    let z: f64 = ds.x[i].iter().zip(&w).map(|(x, w)| x * w).sum::<f64>() + b;
                    let err = sigmoid(z) - ds.y[i] as f64;
                    for (g, x) in gw.iter_mut().zip(&ds.x[i]) {
                        *g += err * x;
                    }
                    gb += err;
                }
                let scale = cfg.lr / chunk.len() as f64;
                for (wj, gj) in w.iter_mut().zip(&gw) {
                    *wj -= scale * gj + cfg.lr * cfg.l2 * *wj;
                }
                b -= scale * gb;
            }
        }
        LogisticRegression { w, b }
    }

    pub fn predict_proba(&self, x: &[f64]) -> Vec<f64> {
        let z: f64 = x.iter().zip(&self.w).map(|(x, w)| x * w).sum::<f64>() + self.b;
        let p = sigmoid(z);
        vec![1.0 - p, p]
    }

    pub fn predict(&self, x: &[f64]) -> usize {
        (self.predict_proba(x)[1] >= 0.5) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{adult, Dataset};

    #[test]
    fn separates_linear_data() {
        let mut rng = Xoshiro256pp::new(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..2000 {
            let a = rng.next_f64();
            let b = rng.next_f64();
            x.push(vec![a, b]);
            y.push((a + b > 1.0) as usize);
        }
        let ds = Dataset::new(x, y, 2, vec!["a".into(), "b".into()]);
        let m = LogisticRegression::fit(&ds, &LogRegConfig::default(), 2);
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| m.predict(x) == y)
            .count() as f64
            / ds.len() as f64;
        assert!(acc > 0.95, "linear separable accuracy {acc}");
    }

    #[test]
    fn reasonable_on_adult() {
        let ds = adult::generate(6_000, 21);
        let (tr, va) = ds.split(0.8, 3);
        let m = LogisticRegression::fit(&tr, &LogRegConfig::default(), 4);
        let acc = va
            .x
            .iter()
            .zip(&va.y)
            .filter(|(x, &y)| m.predict(x) == y)
            .count() as f64
            / va.len() as f64;
        assert!(acc > 0.72, "adult linear accuracy {acc}");
    }
}
