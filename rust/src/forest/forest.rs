//! Bootstrap-aggregated Random Forests.
//!
//! Matches the scikit-learn setup the paper uses: each tree sees a
//! bootstrap resample of the training data and examines `mtry ≈ √d`
//! features per split; the ensemble prediction is the α-weighted mean
//! of the per-tree leaf distributions (paper eq. 5, with uniform
//! `α_l = 1/L` by default).

use super::tree::{argmax, DecisionTree, TreeConfig};
use crate::data::Dataset;
use crate::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug)]
pub struct RandomForestConfig {
    pub n_trees: usize,
    pub tree: TreeConfig,
    /// Bootstrap sample size as a fraction of the training set.
    pub bootstrap_frac: f64,
}

impl Default for RandomForestConfig {
    fn default() -> Self {
        RandomForestConfig {
            n_trees: 64,
            tree: TreeConfig {
                max_depth: 4,
                mtry: 0, // set to √d at fit time when 0
                ..Default::default()
            },
            bootstrap_frac: 1.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RandomForest {
    pub trees: Vec<DecisionTree>,
    /// Per-tree weights α_l (paper eq. 5); uniform by default.
    pub alphas: Vec<f64>,
    pub n_classes: usize,
}

impl RandomForest {
    pub fn fit(ds: &Dataset, cfg: &RandomForestConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let mut tree_cfg = cfg.tree;
        if tree_cfg.mtry == 0 {
            tree_cfg.mtry = (ds.n_features() as f64).sqrt().ceil() as usize;
        }
        let n_boot = ((ds.len() as f64) * cfg.bootstrap_frac).round() as usize;
        let mut trees = Vec::with_capacity(cfg.n_trees);
        for _ in 0..cfg.n_trees {
            let mut tree_rng = rng.split();
            // Bootstrap (with replacement).
            let indices: Vec<usize> = (0..n_boot)
                .map(|_| tree_rng.next_index(ds.len()))
                .collect();
            trees.push(DecisionTree::fit_indices(
                ds, &indices, &tree_cfg, &mut tree_rng,
            ));
        }
        let l = trees.len();
        RandomForest {
            trees,
            alphas: vec![1.0 / l as f64; l],
            n_classes: ds.n_classes,
        }
    }

    /// α-weighted mean of tree distributions (paper eq. 5).
    pub fn predict_proba(&self, x: &[f64]) -> Vec<f64> {
        let mut acc = vec![0.0f64; self.n_classes];
        for (t, &a) in self.trees.iter().zip(&self.alphas) {
            for (s, p) in acc.iter_mut().zip(t.predict_proba(x)) {
                *s += a * p;
            }
        }
        acc
    }

    pub fn predict(&self, x: &[f64]) -> usize {
        argmax(&self.predict_proba(x))
    }

    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<usize> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Max leaves over the ensemble — the HRF pads every tree to this K.
    pub fn max_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;

    #[test]
    fn forest_beats_single_tree_on_adult() {
        let ds = adult::generate(8_000, 11);
        let (train, valid) = ds.split(0.8, 1);
        let mut rng = Xoshiro256pp::new(2);
        let tree = DecisionTree::fit(&train, &TreeConfig::default(), &mut rng);
        let rf = RandomForest::fit(
            &train,
            &RandomForestConfig {
                n_trees: 32,
                ..Default::default()
            },
            3,
        );
        let acc = |pred: &dyn Fn(&[f64]) -> usize| {
            valid
                .x
                .iter()
                .zip(&valid.y)
                .filter(|(x, &y)| pred(x) == y)
                .count() as f64
                / valid.len() as f64
        };
        let t_acc = acc(&|x| tree.predict(x));
        let f_acc = acc(&|x| rf.predict(x));
        // Shallow single trees are strong on this task; the forest
        // (mtry=√d) must stay within noise of it and well above the
        // majority-class baseline.
        assert!(f_acc >= t_acc - 0.015, "forest {f_acc} vs tree {t_acc}");
        assert!(f_acc > 0.79, "forest accuracy too low: {f_acc}");
    }

    #[test]
    fn proba_is_distribution() {
        let ds = adult::generate(2_000, 12);
        let rf = RandomForest::fit(&ds, &RandomForestConfig::default(), 4);
        for x in ds.x.iter().take(50) {
            let p = rf.predict_proba(x);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = adult::generate(1_000, 13);
        let a = RandomForest::fit(&ds, &RandomForestConfig::default(), 5);
        let b = RandomForest::fit(&ds, &RandomForestConfig::default(), 5);
        for (x, _) in ds.x.iter().zip(&ds.y).take(64) {
            assert_eq!(a.predict(x), b.predict(x));
        }
    }
}
