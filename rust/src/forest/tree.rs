//! CART decision trees (classification, Gini impurity).
//!
//! Trees are grown depth-first with axis-aligned splits
//! `x[feature] <= threshold → left`, matching the paper's comparison
//! convention (`h_k(x) = x_{τ(k)} - t_k`; positive → right child).
//! Shallow trees are the intended regime: the HRF packs `K` leaves per
//! tree and its homomorphic cost scales with `K`, not with the number
//! of trees (paper §3).

use crate::data::Dataset;
use crate::rng::Xoshiro256pp;

/// Tree node. Indices refer to `DecisionTree::nodes`.
#[derive(Clone, Debug)]
pub enum Node {
    Internal {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
    Leaf {
        /// Class distribution in the leaf (sums to 1).
        dist: Vec<f64>,
        /// Training observations that reached the leaf.
        n: usize,
    },
}

/// Growth limits.
#[derive(Clone, Copy, Debug)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    /// Features examined per split; `0` = all.
    pub mtry: usize,
    /// Max candidate thresholds per feature (quantile subsample).
    pub max_thresholds: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig {
            max_depth: 4,
            min_samples_split: 8,
            min_samples_leaf: 4,
            mtry: 0,
            max_thresholds: 32,
        }
    }
}

/// A trained CART tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
    pub n_classes: usize,
}

fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

impl DecisionTree {
    /// Train on the rows of `ds` selected by `indices`.
    pub fn fit_indices(
        ds: &Dataset,
        indices: &[usize],
        cfg: &TreeConfig,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            n_classes: ds.n_classes,
        };
        let mut idx = indices.to_vec();
        tree.grow(ds, &mut idx, 0, cfg, rng);
        tree
    }

    pub fn fit(ds: &Dataset, cfg: &TreeConfig, rng: &mut Xoshiro256pp) -> Self {
        let all: Vec<usize> = (0..ds.len()).collect();
        Self::fit_indices(ds, &all, cfg, rng)
    }

    fn make_leaf(&mut self, ds: &Dataset, indices: &[usize]) -> usize {
        let mut counts = vec![0usize; ds.n_classes];
        for &i in indices {
            counts[ds.y[i]] += 1;
        }
        let total = indices.len().max(1);
        let dist = counts
            .iter()
            .map(|&c| c as f64 / total as f64)
            .collect();
        self.nodes.push(Node::Leaf {
            dist,
            n: indices.len(),
        });
        self.nodes.len() - 1
    }

    /// Grow a subtree over `indices`; returns the node id.
    fn grow(
        &mut self,
        ds: &Dataset,
        indices: &mut [usize],
        depth: usize,
        cfg: &TreeConfig,
        rng: &mut Xoshiro256pp,
    ) -> usize {
        // Stopping conditions.
        let mut counts = vec![0usize; ds.n_classes];
        for &i in indices.iter() {
            counts[ds.y[i]] += 1;
        }
        let pure = counts.iter().filter(|&&c| c > 0).count() <= 1;
        if depth >= cfg.max_depth || indices.len() < cfg.min_samples_split || pure {
            return self.make_leaf(ds, indices);
        }

        // Candidate features.
        let d = ds.n_features();
        let features: Vec<usize> = if cfg.mtry == 0 || cfg.mtry >= d {
            (0..d).collect()
        } else {
            rng.sample_indices(d, cfg.mtry)
        };

        let parent_gini = gini(&counts, indices.len());
        let mut best: Option<(f64, usize, f64)> = None; // (gain, feature, threshold)
        for &f in &features {
            // Sorted feature values over this node's rows.
            let mut vals: Vec<f64> = indices.iter().map(|&i| ds.x[i][f]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            if vals.len() < 2 {
                continue;
            }
            // Quantile-subsample candidate midpoints.
            let n_cand = (vals.len() - 1).min(cfg.max_thresholds);
            for c in 0..n_cand {
                let pos = (c as f64 + 0.5) / n_cand as f64 * (vals.len() - 1) as f64;
                let k = pos as usize;
                let thr = 0.5 * (vals[k] + vals[k + 1]);
                // Partition counts.
                let mut lc = vec![0usize; ds.n_classes];
                let mut ln = 0usize;
                for &i in indices.iter() {
                    if ds.x[i][f] <= thr {
                        lc[ds.y[i]] += 1;
                        ln += 1;
                    }
                }
                let rn = indices.len() - ln;
                if ln < cfg.min_samples_leaf || rn < cfg.min_samples_leaf {
                    continue;
                }
                let rc: Vec<usize> = counts.iter().zip(&lc).map(|(&t, &l)| t - l).collect();
                let w = indices.len() as f64;
                let gain = parent_gini
                    - (ln as f64 / w) * gini(&lc, ln)
                    - (rn as f64 / w) * gini(&rc, rn);
                if gain > 1e-12 && best.map_or(true, |(g, _, _)| gain > g) {
                    best = Some((gain, f, thr));
                }
            }
        }

        let Some((_, f, thr)) = best else {
            return self.make_leaf(ds, indices);
        };

        // Partition indices in place.
        let mut lo = 0usize;
        let mut hi = indices.len();
        while lo < hi {
            if ds.x[indices[lo]][f] <= thr {
                lo += 1;
            } else {
                hi -= 1;
                indices.swap(lo, hi);
            }
        }
        let split = lo;
        // Reserve the internal node slot, then grow children.
        self.nodes.push(Node::Leaf {
            dist: vec![],
            n: 0,
        }); // placeholder
        let me = self.nodes.len() - 1;
        let (left_idx, right_idx) = indices.split_at_mut(split);
        let left = self.grow(ds, left_idx, depth + 1, cfg, rng);
        let right = self.grow(ds, right_idx, depth + 1, cfg, rng);
        self.nodes[me] = Node::Internal {
            feature: f,
            threshold: thr,
            left,
            right,
        };
        me
    }

    /// Root node id (grow() pushes the root first).
    pub fn root(&self) -> usize {
        0
    }

    /// Class distribution for one observation.
    pub fn predict_proba(&self, x: &[f64]) -> Vec<f64> {
        let mut id = self.root();
        loop {
            match &self.nodes[id] {
                Node::Internal {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    id = if x[*feature] <= *threshold { *left } else { *right };
                }
                Node::Leaf { dist, .. } => return dist.clone(),
            }
        }
    }

    pub fn predict(&self, x: &[f64]) -> usize {
        argmax(&self.predict_proba(x))
    }

    /// Number of leaves.
    pub fn n_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Depth of the deepest leaf.
    pub fn depth(&self) -> usize {
        fn rec(t: &DecisionTree, id: usize) -> usize {
            match &t.nodes[id] {
                Node::Leaf { .. } => 0,
                Node::Internal { left, right, .. } => 1 + rec(t, *left).max(rec(t, *right)),
            }
        }
        rec(self, self.root())
    }
}

pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        // XOR of two thresholds — linearly inseparable, trees need depth 2.
        let mut rng = Xoshiro256pp::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.next_f64();
            let b = rng.next_f64();
            x.push(vec![a, b]);
            y.push(((a > 0.5) ^ (b > 0.5)) as usize);
        }
        Dataset::new(x, y, 2, vec!["a".into(), "b".into()])
    }

    #[test]
    fn learns_axis_threshold_exactly() {
        // y = 1[a > 0.37] — a single split should nail it.
        let mut rng = Xoshiro256pp::new(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..2000 {
            let a = rng.next_f64();
            x.push(vec![a, rng.next_f64()]);
            y.push((a > 0.37) as usize);
        }
        let ds = Dataset::new(x, y, 2, vec!["a".into(), "b".into()]);
        let mut trng = Xoshiro256pp::new(2);
        let cfg = TreeConfig {
            max_depth: 2,
            max_thresholds: 256,
            ..Default::default()
        };
        let t = DecisionTree::fit(&ds, &cfg, &mut trng);
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| t.predict(x) == y)
            .count() as f64
            / ds.len() as f64;
        assert!(acc > 0.99, "threshold accuracy {acc}");
    }

    #[test]
    fn learns_xor_with_depth() {
        // XOR has zero marginal gain at the root (greedy CART relies on
        // sampling noise to pick the first split), so allow depth 6.
        let ds = xor_dataset(2000, 1);
        let mut rng = Xoshiro256pp::new(2);
        let cfg = TreeConfig {
            max_depth: 6,
            min_samples_leaf: 2,
            min_samples_split: 4,
            ..Default::default()
        };
        let t = DecisionTree::fit(&ds, &cfg, &mut rng);
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| t.predict(x) == y)
            .count() as f64
            / ds.len() as f64;
        assert!(acc > 0.85, "XOR accuracy {acc}");
        assert!(t.depth() <= 6);
    }

    #[test]
    fn respects_max_depth_and_leaf_count() {
        let ds = xor_dataset(500, 3);
        let mut rng = Xoshiro256pp::new(4);
        for depth in 1..=4 {
            let cfg = TreeConfig {
                max_depth: depth,
                ..Default::default()
            };
            let t = DecisionTree::fit(&ds, &cfg, &mut rng);
            assert!(t.depth() <= depth);
            assert!(t.n_leaves() <= 1 << depth);
        }
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let ds = Dataset::new(
            vec![vec![0.1], vec![0.2], vec![0.3]],
            vec![1, 1, 1],
            2,
            vec!["a".into()],
        );
        let mut rng = Xoshiro256pp::new(5);
        let t = DecisionTree::fit(&ds, &TreeConfig::default(), &mut rng);
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.predict(&[0.15]), 1);
    }

    #[test]
    fn leaf_distributions_sum_to_one() {
        let ds = xor_dataset(500, 6);
        let mut rng = Xoshiro256pp::new(7);
        let t = DecisionTree::fit(&ds, &TreeConfig::default(), &mut rng);
        for n in &t.nodes {
            if let Node::Leaf { dist, n } = n {
                if *n > 0 {
                    assert!((dist.iter().sum::<f64>() - 1.0).abs() < 1e-9);
                }
            }
        }
    }
}
