//! Binary-classification metrics — the four columns of Table 2.
//!
//! Positive class = 1 (">50K" for Adult). Precision/recall/F1 follow
//! the usual conventions with 0/0 → 0.

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Metrics {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

impl Metrics {
    /// Compute from predictions vs ground truth (positive class = 1).
    pub fn from_predictions(pred: &[usize], truth: &[usize]) -> Self {
        assert_eq!(pred.len(), truth.len());
        let (mut tp, mut fp, mut tn, mut fn_) = (0usize, 0usize, 0usize, 0usize);
        for (&p, &t) in pred.iter().zip(truth) {
            match (p, t) {
                (1, 1) => tp += 1,
                (1, 0) => fp += 1,
                (0, 0) => tn += 1,
                (0, 1) => fn_ += 1,
                _ => panic!("binary metrics on non-binary labels"),
            }
        }
        let total = pred.len().max(1);
        let accuracy = (tp + tn) as f64 / total as f64;
        let precision = if tp + fp > 0 {
            tp as f64 / (tp + fp) as f64
        } else {
            0.0
        };
        let recall = if tp + fn_ > 0 {
            tp as f64 / (tp + fn_) as f64
        } else {
            0.0
        };
        let f1 = if precision + recall > 0.0 {
            2.0 * precision * recall / (precision + recall)
        } else {
            0.0
        };
        Metrics {
            accuracy,
            precision,
            recall,
            f1,
            tp,
            fp,
            tn,
            fn_,
        }
    }

    /// Row formatted like Table 2.
    pub fn table_row(&self, model: &str) -> Vec<String> {
        vec![
            model.to_string(),
            format!("{:.3}", self.accuracy),
            format!("{:.3}", self.precision),
            format!("{:.3}", self.recall),
            format!("{:.3}", self.f1),
        ]
    }
}

/// Fraction of positions where two prediction vectors agree — the
/// paper's NRF/HRF agreement statistic (§4: 97.5 %).
pub fn agreement(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 1.0;
    }
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let m = Metrics::from_predictions(&[1, 0, 1, 0], &[1, 0, 1, 0]);
        assert_eq!(m.accuracy, 1.0);
        assert_eq!(m.precision, 1.0);
        assert_eq!(m.recall, 1.0);
        assert_eq!(m.f1, 1.0);
    }

    #[test]
    fn known_confusion() {
        // tp=2 fp=1 tn=3 fn=2
        let pred = [1, 1, 1, 0, 0, 0, 0, 0];
        let truth = [1, 1, 0, 1, 1, 0, 0, 0];
        let m = Metrics::from_predictions(&pred, &truth);
        assert_eq!((m.tp, m.fp, m.tn, m.fn_), (2, 1, 3, 2));
        assert!((m.accuracy - 5.0 / 8.0).abs() < 1e-12);
        assert!((m.precision - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.recall - 0.5).abs() < 1e-12);
        let f1 = 2.0 * (2.0 / 3.0) * 0.5 / (2.0 / 3.0 + 0.5);
        assert!((m.f1 - f1).abs() < 1e-12);
    }

    #[test]
    fn degenerate_no_positive_predictions() {
        let m = Metrics::from_predictions(&[0, 0], &[1, 0]);
        assert_eq!(m.precision, 0.0);
        assert_eq!(m.recall, 0.0);
        assert_eq!(m.f1, 0.0);
    }

    #[test]
    fn agreement_fraction() {
        assert_eq!(agreement(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(agreement(&[], &[]), 1.0);
    }
}
