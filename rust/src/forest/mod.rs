//! Tree-ensemble learning substrate.
//!
//! The paper trains Random Forests with scikit-learn; here the whole
//! training stack is native Rust so the serving path has no Python
//! dependency:
//!
//! * [`tree`] — CART decision trees (Gini impurity, depth/leaf limits).
//! * [`forest`] — bootstrap-aggregated random forests with per-split
//!   feature subsampling.
//! * [`linear`] — logistic-regression baseline (Table 2's "Linear").
//! * [`metrics`] — accuracy / precision / recall / F1 (Table 2 columns).

pub mod forest;
pub mod linear;
pub mod metrics;
pub mod tree;

pub use forest::{RandomForest, RandomForestConfig};
pub use linear::LogisticRegression;
pub use metrics::Metrics;
pub use tree::{DecisionTree, Node, TreeConfig};
