//! One lock domain of the sharded cache: resident entries, an exact
//! LRU index, and the set of ids the shard remembers.
//!
//! LRU is kept *exact* with a `BTreeMap<tick, id>` keyed by globally
//! unique monotonic ticks (the cache hands one out per insert/touch):
//! the map's first entry is the shard's least-recently-used session,
//! and because ticks come from one global counter, per-shard minima are
//! directly comparable when the cache picks a global eviction victim.
//!
//! Eviction and removal differ on purpose: **evict** drops the keys but
//! keeps the id in `known` (the session survives, its keys must be
//! re-registered), **remove** forgets the id entirely.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

pub(crate) struct Entry<V> {
    pub value: Arc<V>,
    pub bytes: usize,
    /// LRU stamp; also this entry's key in the shard's `lru` index.
    pub tick: u64,
}

pub(crate) struct Shard<V> {
    entries: HashMap<u64, Entry<V>>,
    /// Exact LRU order: tick → id, oldest first. Ticks are unique.
    lru: BTreeMap<u64, u64>,
    /// Ids ever inserted and not explicitly removed. Eviction keeps
    /// them — this is the eviction-safe protocol's memory.
    known: HashSet<u64>,
}

impl<V> Default for Shard<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> Shard<V> {
    pub fn new() -> Self {
        Shard {
            entries: HashMap::new(),
            lru: BTreeMap::new(),
            known: HashSet::new(),
        }
    }

    /// Insert or replace; returns the bytes of a replaced resident
    /// entry so the caller can fix the global gauge.
    pub fn insert(&mut self, id: u64, value: Arc<V>, bytes: usize, tick: u64) -> Option<usize> {
        self.known.insert(id);
        let old = self.entries.insert(id, Entry { value, bytes, tick });
        if let Some(ref e) = old {
            self.lru.remove(&e.tick);
        }
        self.lru.insert(tick, id);
        old.map(|e| e.bytes)
    }

    /// Fetch + touch: refresh the entry's LRU stamp to `tick`.
    pub fn get(&mut self, id: u64, tick: u64) -> Option<Arc<V>> {
        let e = self.entries.get_mut(&id)?;
        self.lru.remove(&e.tick);
        e.tick = tick;
        self.lru.insert(tick, id);
        Some(e.value.clone())
    }

    /// Fetch without touching LRU or stats (introspection only).
    pub fn peek(&self, id: u64) -> Option<Arc<V>> {
        self.entries.get(&id).map(|e| e.value.clone())
    }

    pub fn is_known(&self, id: u64) -> bool {
        self.known.contains(&id)
    }

    /// LRU stamp of the oldest entry other than `keep`.
    pub fn oldest_tick_excluding(&self, keep: Option<u64>) -> Option<u64> {
        self.lru
            .iter()
            .find(|&(_, &id)| Some(id) != keep)
            .map(|(&t, _)| t)
    }

    /// Evict the least-recently-used entry other than `keep`. The id
    /// stays known (evicted ≠ removed). Returns `(id, bytes, value)` —
    /// the value is handed back (not dropped) so the cache can demote
    /// it to the spill tier after releasing this shard's lock.
    pub fn evict_oldest_excluding(&mut self, keep: Option<u64>) -> Option<(u64, usize, Arc<V>)> {
        let (tick, id) = {
            let (&t, &i) = self.lru.iter().find(|&(_, &id)| Some(id) != keep)?;
            (t, i)
        };
        self.lru.remove(&tick);
        let e = self
            .entries
            .remove(&id)
            .expect("lru index entry must be resident");
        Some((id, e.bytes, e.value))
    }

    /// Forget the id entirely. Returns (resident bytes freed, whether
    /// the id was known at all).
    pub fn remove(&mut self, id: u64) -> (Option<usize>, bool) {
        let known = self.known.remove(&id);
        match self.entries.remove(&id) {
            Some(e) => {
                self.lru.remove(&e.tick);
                (Some(e.bytes), known)
            }
            None => (None, known),
        }
    }

    pub fn resident_len(&self) -> usize {
        self.entries.len()
    }

    pub fn known_len(&self) -> usize {
        self.known.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_with(ids: &[u64]) -> Shard<u64> {
        let mut s = Shard::new();
        for (t, &id) in ids.iter().enumerate() {
            s.insert(id, Arc::new(id), 10, t as u64);
        }
        s
    }

    #[test]
    fn lru_order_is_insert_order_until_touched() {
        let mut s = shard_with(&[7, 8, 9]);
        assert_eq!(s.oldest_tick_excluding(None), Some(0));
        assert_eq!(s.evict_oldest_excluding(None), Some((7, 10, Arc::new(7))));
        assert_eq!(s.evict_oldest_excluding(None), Some((8, 10, Arc::new(8))));
        assert_eq!(s.evict_oldest_excluding(None), Some((9, 10, Arc::new(9))));
        assert_eq!(s.evict_oldest_excluding(None), None);
    }

    #[test]
    fn touch_moves_entry_to_back() {
        let mut s = shard_with(&[1, 2, 3]);
        assert!(s.get(1, 100).is_some()); // 1 becomes most-recent
        assert_eq!(s.evict_oldest_excluding(None), Some((2, 10, Arc::new(2))));
        assert_eq!(s.evict_oldest_excluding(None), Some((3, 10, Arc::new(3))));
        assert_eq!(s.evict_oldest_excluding(None), Some((1, 10, Arc::new(1))));
    }

    #[test]
    fn eviction_keeps_id_known_but_remove_forgets() {
        let mut s = shard_with(&[5, 6]);
        s.evict_oldest_excluding(None);
        assert!(s.is_known(5), "evicted id must stay known");
        assert!(s.peek(5).is_none());
        assert!(s.get(5, 50).is_none());
        let (freed, known) = s.remove(5);
        assert_eq!(freed, None);
        assert!(known);
        assert!(!s.is_known(5));
        let (freed, known) = s.remove(6);
        assert_eq!(freed, Some(10));
        assert!(known);
    }

    #[test]
    fn keep_excludes_entry_from_eviction() {
        let mut s = shard_with(&[1, 2]);
        assert_eq!(s.oldest_tick_excluding(Some(1)), Some(1));
        assert_eq!(s.evict_oldest_excluding(Some(1)), Some((2, 10, Arc::new(2))));
        // Only the kept entry remains: nothing evictable.
        assert_eq!(s.evict_oldest_excluding(Some(1)), None);
        assert_eq!(s.oldest_tick_excluding(Some(1)), None);
    }

    #[test]
    fn replace_updates_lru_and_returns_old_bytes() {
        let mut s = shard_with(&[1, 2]);
        let old = s.insert(1, Arc::new(1), 25, 99);
        assert_eq!(old, Some(10));
        assert_eq!(s.resident_len(), 2);
        // 1 was refreshed by the replace; 2 is now oldest.
        assert_eq!(s.evict_oldest_excluding(None), Some((2, 10, Arc::new(2))));
        assert_eq!(s.evict_oldest_excluding(None), Some((1, 25, Arc::new(1))));
    }
}
