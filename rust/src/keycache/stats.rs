//! Cache observability: lock-free counters shared with the serving
//! metrics (`coordinator::metrics` snapshots them without touching any
//! shard lock).

use std::sync::atomic::{AtomicU64, Ordering};

/// Counters for one [`KeyCache`](super::KeyCache). All atomics, so the
/// cache and any number of metric reporters can share an `Arc` of this.
#[derive(Debug, Default)]
pub struct KeyCacheStats {
    /// Lookups that found resident keys (each one refreshes LRU).
    pub hits: AtomicU64,
    /// Lookups for a known session whose keys were evicted.
    pub misses: AtomicU64,
    /// Entries pushed out by the memory budget.
    pub evictions: AtomicU64,
    /// Entries admitted (first registrations + re-registrations).
    pub inserts: AtomicU64,
    /// Current resident key bytes across all shards (gauge).
    pub resident_bytes: AtomicU64,
    /// Current bytes parked in the disk spill tier (gauge; 0 when
    /// spill is disabled).
    pub spilled_bytes: AtomicU64,
    /// Lookups whose keys were reloaded from the spill tier instead of
    /// forcing a client re-upload.
    pub spill_hits: AtomicU64,
    /// Reload attempts that found nothing usable on disk (never
    /// spilled, evicted from the tier, unreadable, or undecodable).
    pub spill_misses: AtomicU64,
    /// Spill files found unreadable or undecodable (each one is
    /// deleted; a subset of `spill_misses`).
    pub spill_corrupt: AtomicU64,
    /// Values serialized to the spill tier on budget eviction.
    pub spill_writes: AtomicU64,
    /// Spilled entries deleted because the spill tier itself hit its
    /// byte cap — those sessions fall back to `KeysEvicted`.
    pub spill_evictions: AtomicU64,
}

impl KeyCacheStats {
    pub fn snapshot(&self) -> KeyCacheStatsSnapshot {
        KeyCacheStatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            spilled_bytes: self.spilled_bytes.load(Ordering::Relaxed),
            spill_hits: self.spill_hits.load(Ordering::Relaxed),
            spill_misses: self.spill_misses.load(Ordering::Relaxed),
            spill_corrupt: self.spill_corrupt.load(Ordering::Relaxed),
            spill_writes: self.spill_writes.load(Ordering::Relaxed),
            spill_evictions: self.spill_evictions.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct KeyCacheStatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub inserts: u64,
    pub resident_bytes: u64,
    pub spilled_bytes: u64,
    pub spill_hits: u64,
    pub spill_misses: u64,
    pub spill_corrupt: u64,
    pub spill_writes: u64,
    pub spill_evictions: u64,
}

impl KeyCacheStatsSnapshot {
    /// hits / (hits + misses); 0 when no session lookups happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// spill_hits / (spill_hits + spill_misses); 0 when no reload was
    /// ever attempted (spill disabled or nothing evicted).
    pub fn spill_hit_rate(&self) -> f64 {
        let total = self.spill_hits + self.spill_misses;
        if total == 0 {
            0.0
        } else {
            self.spill_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_hit_rate() {
        let s = KeyCacheStats::default();
        assert_eq!(s.snapshot().hit_rate(), 0.0);
        s.hits.fetch_add(3, Ordering::Relaxed);
        s.misses.fetch_add(1, Ordering::Relaxed);
        s.resident_bytes.fetch_add(4096, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.hits, 3);
        assert_eq!(snap.resident_bytes, 4096);
        assert!((snap.hit_rate() - 0.75).abs() < 1e-12);
    }
}
