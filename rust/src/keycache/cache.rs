//! The sharded cache proper: shard routing, the global memory budget,
//! and the eviction loop.
//!
//! Locking discipline: at most one shard lock is ever held at a time,
//! acquired poison-recovering ([`crate::lockutil`]) so a panicking
//! holder cannot brick the cache for every later request.
//! The eviction loop scans shards one-by-one for the globally-oldest
//! entry, releases, then re-locks the chosen shard to evict — a benign
//! race (the victim may have been touched or removed in between; the
//! loop just re-checks the gauge and rescans).

use super::shard::Shard;
use super::stats::KeyCacheStats;
use super::KeyCacheConfig;
use crate::lockutil::lock_unpoisoned;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// What a lookup found — the three states of the eviction-safe
/// protocol.
#[derive(Debug)]
pub enum CacheState<V> {
    /// Keys are resident; the lookup refreshed their LRU stamp.
    Resident(Arc<V>),
    /// The id is known but its keys were evicted: the owner must
    /// re-register (same id, fresh key upload).
    Evicted,
    /// Never registered, or explicitly removed.
    Unknown,
}

impl<V> CacheState<V> {
    pub fn is_resident(&self) -> bool {
        matches!(self, CacheState::Resident(_))
    }
}

/// Sharded, memory-budgeted LRU store keyed by session id. See the
/// [module docs](super) for the design.
pub struct KeyCache<V> {
    shards: Vec<Mutex<Shard<V>>>,
    budget_bytes: u64,
    /// Global LRU clock: every insert/touch draws a unique tick.
    clock: AtomicU64,
    stats: Arc<KeyCacheStats>,
}

impl<V> KeyCache<V> {
    pub fn new(cfg: KeyCacheConfig) -> Self {
        let n = cfg.num_shards.max(1);
        KeyCache {
            shards: (0..n).map(|_| Mutex::new(Shard::new())).collect(),
            budget_bytes: cfg.budget_bytes,
            clock: AtomicU64::new(0),
            stats: Arc::new(KeyCacheStats::default()),
        }
    }

    fn shard(&self, id: u64) -> &Mutex<Shard<V>> {
        &self.shards[(id % self.shards.len() as u64) as usize]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Admit (or refresh) `id`'s entry of `bytes` resident bytes, then
    /// evict least-recently-used entries — the new entry excepted —
    /// until the global budget holds again. An entry larger than the
    /// whole budget is still admitted (see module docs).
    pub fn insert(&self, id: u64, value: V, bytes: usize) {
        let tick = self.tick();
        // Gauge updates happen under the same shard lock as the entry
        // mutation: an entry is never visible to eviction before its
        // bytes are charged, so the gauge can never be under-charged
        // and `fetch_sub` on eviction can never wrap.
        {
            let mut sh = lock_unpoisoned(self.shard(id));
            let replaced = sh.insert(id, Arc::new(value), bytes, tick);
            if let Some(old) = replaced {
                self.stats
                    .resident_bytes
                    .fetch_sub(old as u64, Ordering::Relaxed);
            }
            self.stats
                .resident_bytes
                .fetch_add(bytes as u64, Ordering::Relaxed);
        }
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(Some(id));
    }

    /// Resident value for `id`, refreshing its LRU stamp; None on
    /// evicted or unknown ids (use [`KeyCache::lookup`] to tell apart).
    pub fn get(&self, id: u64) -> Option<Arc<V>> {
        match self.lookup(id) {
            CacheState::Resident(v) => Some(v),
            _ => None,
        }
    }

    /// Like [`KeyCache::get`] — refreshes the LRU stamp — but without
    /// counting hit/miss stats. For internal fetches that follow an
    /// already-counted [`KeyCache::lookup`] (e.g. a worker picking up
    /// keys for a request whose submission gate counted the hit), so
    /// the hit rate stays one count per request.
    pub fn get_untracked(&self, id: u64) -> Option<Arc<V>> {
        let tick = self.tick();
        lock_unpoisoned(self.shard(id)).get(id, tick)
    }

    /// Full protocol state for `id`. Resident hits refresh LRU and
    /// count as cache hits; known-but-evicted ids count as misses.
    pub fn lookup(&self, id: u64) -> CacheState<V> {
        let tick = self.tick();
        let mut sh = lock_unpoisoned(self.shard(id));
        if let Some(v) = sh.get(id, tick) {
            drop(sh);
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            CacheState::Resident(v)
        } else if sh.is_known(id) {
            drop(sh);
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            CacheState::Evicted
        } else {
            CacheState::Unknown
        }
    }

    /// State for `id` without touching LRU order or hit/miss counters
    /// (introspection: tests, metrics probes).
    pub fn peek(&self, id: u64) -> CacheState<V> {
        let sh = lock_unpoisoned(self.shard(id));
        if let Some(v) = sh.peek(id) {
            CacheState::Resident(v)
        } else if sh.is_known(id) {
            CacheState::Evicted
        } else {
            CacheState::Unknown
        }
    }

    /// Whether the id was ever registered and not removed (resident or
    /// evicted) — the re-registration gate.
    pub fn is_known(&self, id: u64) -> bool {
        lock_unpoisoned(self.shard(id)).is_known(id)
    }

    /// Forget `id` entirely; returns whether it was known.
    pub fn remove(&self, id: u64) -> bool {
        let mut sh = lock_unpoisoned(self.shard(id));
        let (freed, known) = sh.remove(id);
        if let Some(b) = freed {
            self.stats
                .resident_bytes
                .fetch_sub(b as u64, Ordering::Relaxed);
        }
        known
    }

    /// Number of entries with resident keys.
    pub fn resident_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).resident_len())
            .sum()
    }

    /// Number of known ids (resident + evicted).
    pub fn known_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).known_len())
            .sum()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.stats.resident_bytes.load(Ordering::Relaxed)
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shared counters (hand these to the metrics layer).
    pub fn stats(&self) -> Arc<KeyCacheStats> {
        self.stats.clone()
    }

    /// Evict globally-oldest entries (skipping `keep`) until resident
    /// bytes fit the budget or nothing evictable remains.
    fn enforce_budget(&self, keep: Option<u64>) {
        while self.stats.resident_bytes.load(Ordering::Relaxed) > self.budget_bytes {
            // Globally-oldest entry: ticks are global, so per-shard
            // minima compare directly. One lock at a time.
            let mut best: Option<(usize, u64)> = None;
            for (i, m) in self.shards.iter().enumerate() {
                let oldest = lock_unpoisoned(m).oldest_tick_excluding(keep);
                if let Some(t) = oldest {
                    let better = match best {
                        None => true,
                        Some((_, bt)) => t < bt,
                    };
                    if better {
                        best = Some((i, t));
                    }
                }
            }
            let (i, _) = match best {
                Some(b) => b,
                // Nothing evictable (at most the kept entry resident):
                // the documented over-budget exception.
                None => return,
            };
            let mut sh = lock_unpoisoned(&self.shards[i]);
            match sh.evict_oldest_excluding(keep) {
                Some((_, bytes)) => {
                    // Subtract under the shard lock (see `insert`).
                    self.stats
                        .resident_bytes
                        .fetch_sub(bytes as u64, Ordering::Relaxed);
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // Raced away (touched/removed between scan and lock):
                // re-check the gauge and rescan.
                None => continue,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(shards: usize, budget: u64) -> KeyCache<u64> {
        KeyCache::new(KeyCacheConfig {
            num_shards: shards,
            budget_bytes: budget,
        })
    }

    #[test]
    fn within_budget_nothing_evicts() {
        let c = cache(4, 100);
        for id in 0..10 {
            c.insert(id, id, 10);
        }
        assert_eq!(c.resident_len(), 10);
        assert_eq!(c.resident_bytes(), 100);
        assert_eq!(c.stats().snapshot().evictions, 0);
    }

    #[test]
    fn over_budget_evicts_lru_and_keeps_ids_known() {
        let c = cache(4, 30);
        for id in 0..4 {
            c.insert(id, id, 10);
        }
        // 40 > 30: exactly the oldest (id 0) was evicted.
        assert_eq!(c.resident_bytes(), 30);
        assert!(matches!(c.peek(0), CacheState::Evicted));
        for id in 1..4 {
            assert!(c.peek(id).is_resident(), "id {id} should be resident");
        }
        assert!(c.is_known(0));
        assert_eq!(c.known_len(), 4);
        assert_eq!(c.stats().snapshot().evictions, 1);
    }

    #[test]
    fn touch_protects_from_eviction() {
        let c = cache(2, 30);
        for id in 0..3 {
            c.insert(id, id, 10);
        }
        assert!(c.get(0).is_some()); // 0 becomes most-recent
        c.insert(3, 3, 10); // evicts 1, the LRU
        assert!(c.peek(0).is_resident());
        assert!(matches!(c.peek(1), CacheState::Evicted));
        assert!(c.peek(2).is_resident());
        assert!(c.peek(3).is_resident());
    }

    #[test]
    fn reinsert_after_eviction_restores_residency() {
        let c = cache(1, 20);
        c.insert(0, 0, 10);
        c.insert(1, 1, 10);
        c.insert(2, 2, 10); // evicts 0
        assert!(matches!(c.peek(0), CacheState::Evicted));
        c.insert(0, 0, 10); // re-registration: evicts 1
        assert!(c.peek(0).is_resident());
        assert!(matches!(c.peek(1), CacheState::Evicted));
        assert!(c.peek(2).is_resident());
        assert_eq!(c.resident_bytes(), 20);
    }

    #[test]
    fn oversized_entry_is_admitted_alone() {
        let c = cache(2, 10);
        c.insert(0, 0, 5);
        c.insert(1, 1, 25); // bigger than the whole budget
        assert!(c.peek(1).is_resident(), "oversized entry must be admitted");
        assert!(matches!(c.peek(0), CacheState::Evicted));
        assert_eq!(c.resident_bytes(), 25);
        // The next normal insert pushes it out again.
        c.insert(2, 2, 5);
        assert!(matches!(c.peek(1), CacheState::Evicted));
        assert_eq!(c.resident_bytes(), 5);
    }

    #[test]
    fn remove_frees_bytes_and_forgets() {
        let c = cache(4, u64::MAX);
        c.insert(0, 0, 10);
        assert!(c.remove(0));
        assert_eq!(c.resident_bytes(), 0);
        assert!(matches!(c.peek(0), CacheState::Unknown));
        assert!(!c.remove(0));
        assert!(!c.remove(99));
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let c = cache(1, 10);
        c.insert(0, 0, 10);
        c.insert(1, 1, 10); // evicts 0
        assert!(matches!(c.lookup(1), CacheState::Resident(_)));
        assert!(matches!(c.lookup(0), CacheState::Evicted));
        assert!(matches!(c.lookup(42), CacheState::Unknown));
        let s = c.stats().snapshot();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn replace_resident_adjusts_gauge() {
        let c = cache(2, 100);
        c.insert(0, 0, 40);
        c.insert(0, 7, 10);
        assert_eq!(c.resident_bytes(), 10);
        assert_eq!(c.resident_len(), 1);
        match c.peek(0) {
            CacheState::Resident(v) => assert_eq!(*v, 7),
            other => panic!("expected resident, got {other:?}"),
        }
    }
}
