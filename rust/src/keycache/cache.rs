//! The sharded cache proper: shard routing, the global memory budget,
//! and the eviction loop.
//!
//! Locking discipline: at most one shard lock is ever held at a time,
//! acquired poison-recovering ([`crate::lockutil`]) so a panicking
//! holder cannot brick the cache for every later request.
//! The eviction loop scans shards one-by-one for the globally-oldest
//! entry, releases, then re-locks the chosen shard to evict — a benign
//! race (the victim may have been touched or removed in between; the
//! loop just re-checks the gauge and rescans).
//!
//! With a spill tier enabled ([`KeyCache::enable_spill`]) eviction
//! additionally serializes the victim to disk *after* releasing its
//! shard lock, and a lookup that finds a known-but-evicted id first
//! tries to reload from disk before reporting [`CacheState::Evicted`]
//! — see [`super::spill`] for the tier itself.

use super::shard::Shard;
use super::spill::{SpillCodec, SpillConfig, SpillTier};
use super::stats::KeyCacheStats;
use super::KeyCacheConfig;
use crate::lockutil::lock_unpoisoned;
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// What a lookup found — the states of the eviction-safe protocol.
#[derive(Debug)]
pub enum CacheState<V> {
    /// Keys are resident; the lookup refreshed their LRU stamp.
    Resident(Arc<V>),
    /// The id is known, not resident, but its keys sit in the disk
    /// spill tier. Only [`KeyCache::peek`] reports this state —
    /// [`KeyCache::lookup`] promotes spilled keys back to
    /// `Resident` transparently.
    Spilled,
    /// The id is known but its keys were evicted (and, if a spill
    /// tier exists, are not reloadable from it): the owner must
    /// re-register (same id, fresh key upload).
    Evicted,
    /// Never registered, or explicitly removed.
    Unknown,
}

impl<V> CacheState<V> {
    pub fn is_resident(&self) -> bool {
        matches!(self, CacheState::Resident(_))
    }
}

/// Sharded, memory-budgeted LRU store keyed by session id. See the
/// [module docs](super) for the design.
pub struct KeyCache<V> {
    shards: Vec<Mutex<Shard<V>>>,
    budget_bytes: u64,
    /// Global LRU clock: every insert/touch draws a unique tick.
    clock: AtomicU64,
    stats: Arc<KeyCacheStats>,
    /// The optional disk tier, set at most once (after construction,
    /// so `KeyCacheConfig` stays `Copy` and existing callers are
    /// untouched).
    spill: OnceLock<SpillState<V>>,
}

/// Tier + serialization seam, bundled so they enable atomically.
struct SpillState<V> {
    tier: SpillTier,
    codec: Box<dyn SpillCodec<V>>,
}

impl<V> KeyCache<V> {
    pub fn new(cfg: KeyCacheConfig) -> Self {
        let n = cfg.num_shards.max(1);
        KeyCache {
            shards: (0..n).map(|_| Mutex::new(Shard::new())).collect(),
            budget_bytes: cfg.budget_bytes,
            clock: AtomicU64::new(0),
            stats: Arc::new(KeyCacheStats::default()),
            spill: OnceLock::new(),
        }
    }

    /// Attach the disk spill tier: budget evictions serialize through
    /// `codec` into `cfg.dir` (created, and wiped of stale spill
    /// files) and reload transparently on the next lookup. Idempotent
    /// in effect: returns `Ok(false)` and changes nothing if a tier
    /// was already enabled.
    pub fn enable_spill(&self, cfg: SpillConfig, codec: Box<dyn SpillCodec<V>>) -> io::Result<bool> {
        let tier = SpillTier::new(cfg, self.stats.clone())?;
        Ok(self.spill.set(SpillState { tier, codec }).is_ok())
    }

    /// Whether a spill tier is attached.
    pub fn spill_enabled(&self) -> bool {
        self.spill.get().is_some()
    }

    /// Bytes currently parked in the spill tier (0 when disabled).
    pub fn spilled_bytes(&self) -> u64 {
        self.stats.spilled_bytes.load(Ordering::Relaxed)
    }

    /// Entries currently in the spill tier (0 when disabled).
    pub fn spilled_len(&self) -> usize {
        self.spill.get().map_or(0, |s| s.tier.spilled_len())
    }

    fn shard(&self, id: u64) -> &Mutex<Shard<V>> {
        &self.shards[(id % self.shards.len() as u64) as usize]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Admit (or refresh) `id`'s entry of `bytes` resident bytes, then
    /// evict least-recently-used entries — the new entry excepted —
    /// until the global budget holds again. An entry larger than the
    /// whole budget is still admitted (see module docs).
    pub fn insert(&self, id: u64, value: V, bytes: usize) {
        let tick = self.tick();
        // Gauge updates happen under the same shard lock as the entry
        // mutation: an entry is never visible to eviction before its
        // bytes are charged, so the gauge can never be under-charged
        // and `fetch_sub` on eviction can never wrap.
        {
            let mut sh = lock_unpoisoned(self.shard(id));
            let replaced = sh.insert(id, Arc::new(value), bytes, tick);
            if let Some(old) = replaced {
                self.stats
                    .resident_bytes
                    .fetch_sub(old as u64, Ordering::Relaxed);
            }
            self.stats
                .resident_bytes
                .fetch_add(bytes as u64, Ordering::Relaxed);
        }
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        // A fresh registration supersedes any older spilled copy of
        // this id — drop it so a later reload can't resurrect stale
        // keys.
        if let Some(sp) = self.spill.get() {
            sp.tier.discard(id);
        }
        self.enforce_budget(Some(id));
    }

    /// Resident value for `id`, refreshing its LRU stamp; None on
    /// evicted or unknown ids (use [`KeyCache::lookup`] to tell apart).
    pub fn get(&self, id: u64) -> Option<Arc<V>> {
        match self.lookup(id) {
            CacheState::Resident(v) => Some(v),
            _ => None,
        }
    }

    /// Like [`KeyCache::get`] — refreshes the LRU stamp — but without
    /// counting hit/miss stats. For internal fetches that follow an
    /// already-counted [`KeyCache::lookup`] (e.g. a worker picking up
    /// keys for a request whose submission gate counted the hit), so
    /// the hit rate stays one count per request.
    pub fn get_untracked(&self, id: u64) -> Option<Arc<V>> {
        let tick = self.tick();
        let known = {
            let mut sh = lock_unpoisoned(self.shard(id));
            if let Some(v) = sh.get(id, tick) {
                return Some(v);
            }
            sh.is_known(id)
        };
        if known {
            self.reload_from_spill(id)
        } else {
            None
        }
    }

    /// Full protocol state for `id`. Resident hits refresh LRU and
    /// count as cache hits; known-but-not-resident ids count as RAM
    /// misses, then — with a spill tier enabled — try a transparent
    /// disk reload before reporting [`CacheState::Evicted`]. A
    /// successful reload promotes the keys back to resident (counted
    /// in `spill_hits`, not as a second cache hit).
    pub fn lookup(&self, id: u64) -> CacheState<V> {
        let tick = self.tick();
        let mut sh = lock_unpoisoned(self.shard(id));
        if let Some(v) = sh.get(id, tick) {
            drop(sh);
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            CacheState::Resident(v)
        } else if sh.is_known(id) {
            drop(sh);
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            match self.reload_from_spill(id) {
                Some(v) => CacheState::Resident(v),
                None => CacheState::Evicted,
            }
        } else {
            CacheState::Unknown
        }
    }

    /// State for `id` without touching LRU order, hit/miss counters or
    /// the spill tier's files (introspection: tests, metrics probes).
    pub fn peek(&self, id: u64) -> CacheState<V> {
        let known = {
            let sh = lock_unpoisoned(self.shard(id));
            if let Some(v) = sh.peek(id) {
                return CacheState::Resident(v);
            }
            sh.is_known(id)
        };
        if !known {
            CacheState::Unknown
        } else if self.spill.get().is_some_and(|sp| sp.tier.contains(id)) {
            CacheState::Spilled
        } else {
            CacheState::Evicted
        }
    }

    /// Whether the id was ever registered and not removed (resident or
    /// evicted) — the re-registration gate.
    pub fn is_known(&self, id: u64) -> bool {
        lock_unpoisoned(self.shard(id)).is_known(id)
    }

    /// Forget `id` entirely (RAM and spill tier); returns whether it
    /// was known.
    pub fn remove(&self, id: u64) -> bool {
        let known = {
            let mut sh = lock_unpoisoned(self.shard(id));
            let (freed, known) = sh.remove(id);
            if let Some(b) = freed {
                self.stats
                    .resident_bytes
                    .fetch_sub(b as u64, Ordering::Relaxed);
            }
            known
        };
        if let Some(sp) = self.spill.get() {
            sp.tier.discard(id);
        }
        known
    }

    /// Number of entries with resident keys.
    pub fn resident_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).resident_len())
            .sum()
    }

    /// Number of known ids (resident + evicted).
    pub fn known_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).known_len())
            .sum()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.stats.resident_bytes.load(Ordering::Relaxed)
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shared counters (hand these to the metrics layer).
    pub fn stats(&self) -> Arc<KeyCacheStats> {
        self.stats.clone()
    }

    /// Evict globally-oldest entries (skipping `keep`) until resident
    /// bytes fit the budget or nothing evictable remains.
    fn enforce_budget(&self, keep: Option<u64>) {
        while self.stats.resident_bytes.load(Ordering::Relaxed) > self.budget_bytes {
            // Globally-oldest entry: ticks are global, so per-shard
            // minima compare directly. One lock at a time.
            let mut best: Option<(usize, u64)> = None;
            for (i, m) in self.shards.iter().enumerate() {
                let oldest = lock_unpoisoned(m).oldest_tick_excluding(keep);
                if let Some(t) = oldest {
                    let better = match best {
                        None => true,
                        Some((_, bt)) => t < bt,
                    };
                    if better {
                        best = Some((i, t));
                    }
                }
            }
            let (i, _) = match best {
                Some(b) => b,
                // Nothing evictable (at most the kept entry resident):
                // the documented over-budget exception.
                None => return,
            };
            let mut sh = lock_unpoisoned(&self.shards[i]);
            match sh.evict_oldest_excluding(keep) {
                Some((vid, bytes, value)) => {
                    // Subtract under the shard lock (see `insert`).
                    self.stats
                        .resident_bytes
                        .fetch_sub(bytes as u64, Ordering::Relaxed);
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                    // Demote to disk *after* releasing the shard lock:
                    // serializing multi-MiB keys must not stall every
                    // other request routed to this shard.
                    drop(sh);
                    if let Some(sp) = self.spill.get() {
                        let payload = sp.codec.encode(&value);
                        sp.tier.store(vid, &payload);
                    }
                }
                // Raced away (touched/removed between scan and lock):
                // re-check the gauge and rescan.
                None => continue,
            }
        }
    }

    /// Try to promote `id`'s keys from the spill tier back to
    /// resident. On success the spill file is consumed (a later
    /// eviction re-spills fresh bytes) and the value re-enters the
    /// LRU as most-recent; the resident budget is re-enforced around
    /// it. Any unusable file (unreadable or undecodable) is deleted so
    /// the id degrades cleanly to `Evicted`.
    fn reload_from_spill(&self, id: u64) -> Option<Arc<V>> {
        let sp = self.spill.get()?;
        let value = match sp.tier.load(id).and_then(|bytes| {
            let v = sp.codec.decode(id, &bytes);
            if v.is_none() {
                // Readable but not decodable for this id: corrupt.
                sp.tier.discard(id);
                self.stats.spill_corrupt.fetch_add(1, Ordering::Relaxed);
            }
            v
        }) {
            Some(v) => v,
            None => {
                self.stats.spill_misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        sp.tier.discard(id);
        let bytes = sp.codec.size_bytes(&value);
        let tick = self.tick();
        let value = Arc::new(value);
        {
            let mut sh = lock_unpoisoned(self.shard(id));
            let replaced = sh.insert(id, value.clone(), bytes, tick);
            if let Some(old) = replaced {
                self.stats
                    .resident_bytes
                    .fetch_sub(old as u64, Ordering::Relaxed);
            }
            self.stats
                .resident_bytes
                .fetch_add(bytes as u64, Ordering::Relaxed);
        }
        self.stats.spill_hits.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(Some(id));
        Some(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(shards: usize, budget: u64) -> KeyCache<u64> {
        KeyCache::new(KeyCacheConfig {
            num_shards: shards,
            budget_bytes: budget,
        })
    }

    #[test]
    fn within_budget_nothing_evicts() {
        let c = cache(4, 100);
        for id in 0..10 {
            c.insert(id, id, 10);
        }
        assert_eq!(c.resident_len(), 10);
        assert_eq!(c.resident_bytes(), 100);
        assert_eq!(c.stats().snapshot().evictions, 0);
    }

    #[test]
    fn over_budget_evicts_lru_and_keeps_ids_known() {
        let c = cache(4, 30);
        for id in 0..4 {
            c.insert(id, id, 10);
        }
        // 40 > 30: exactly the oldest (id 0) was evicted.
        assert_eq!(c.resident_bytes(), 30);
        assert!(matches!(c.peek(0), CacheState::Evicted));
        for id in 1..4 {
            assert!(c.peek(id).is_resident(), "id {id} should be resident");
        }
        assert!(c.is_known(0));
        assert_eq!(c.known_len(), 4);
        assert_eq!(c.stats().snapshot().evictions, 1);
    }

    #[test]
    fn touch_protects_from_eviction() {
        let c = cache(2, 30);
        for id in 0..3 {
            c.insert(id, id, 10);
        }
        assert!(c.get(0).is_some()); // 0 becomes most-recent
        c.insert(3, 3, 10); // evicts 1, the LRU
        assert!(c.peek(0).is_resident());
        assert!(matches!(c.peek(1), CacheState::Evicted));
        assert!(c.peek(2).is_resident());
        assert!(c.peek(3).is_resident());
    }

    #[test]
    fn reinsert_after_eviction_restores_residency() {
        let c = cache(1, 20);
        c.insert(0, 0, 10);
        c.insert(1, 1, 10);
        c.insert(2, 2, 10); // evicts 0
        assert!(matches!(c.peek(0), CacheState::Evicted));
        c.insert(0, 0, 10); // re-registration: evicts 1
        assert!(c.peek(0).is_resident());
        assert!(matches!(c.peek(1), CacheState::Evicted));
        assert!(c.peek(2).is_resident());
        assert_eq!(c.resident_bytes(), 20);
    }

    #[test]
    fn oversized_entry_is_admitted_alone() {
        let c = cache(2, 10);
        c.insert(0, 0, 5);
        c.insert(1, 1, 25); // bigger than the whole budget
        assert!(c.peek(1).is_resident(), "oversized entry must be admitted");
        assert!(matches!(c.peek(0), CacheState::Evicted));
        assert_eq!(c.resident_bytes(), 25);
        // The next normal insert pushes it out again.
        c.insert(2, 2, 5);
        assert!(matches!(c.peek(1), CacheState::Evicted));
        assert_eq!(c.resident_bytes(), 5);
    }

    #[test]
    fn remove_frees_bytes_and_forgets() {
        let c = cache(4, u64::MAX);
        c.insert(0, 0, 10);
        assert!(c.remove(0));
        assert_eq!(c.resident_bytes(), 0);
        assert!(matches!(c.peek(0), CacheState::Unknown));
        assert!(!c.remove(0));
        assert!(!c.remove(99));
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let c = cache(1, 10);
        c.insert(0, 0, 10);
        c.insert(1, 1, 10); // evicts 0
        assert!(matches!(c.lookup(1), CacheState::Resident(_)));
        assert!(matches!(c.lookup(0), CacheState::Evicted));
        assert!(matches!(c.lookup(42), CacheState::Unknown));
        let s = c.stats().snapshot();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn replace_resident_adjusts_gauge() {
        let c = cache(2, 100);
        c.insert(0, 0, 40);
        c.insert(0, 7, 10);
        assert_eq!(c.resident_bytes(), 10);
        assert_eq!(c.resident_len(), 1);
        match c.peek(0) {
            CacheState::Resident(v) => assert_eq!(*v, 7),
            other => panic!("expected resident, got {other:?}"),
        }
    }

    // ---- spill tier integration ----

    struct U64Codec;

    impl SpillCodec<u64> for U64Codec {
        fn encode(&self, v: &u64) -> Vec<u8> {
            v.to_le_bytes().to_vec()
        }
        fn decode(&self, _id: u64, b: &[u8]) -> Option<u64> {
            Some(u64::from_le_bytes(b.try_into().ok()?))
        }
        fn size_bytes(&self, _v: &u64) -> usize {
            10 // matches the synthetic sizes the tests insert with
        }
    }

    fn spilling_cache(tag: &str, budget: u64, spill_budget: u64) -> (KeyCache<u64>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "cryptotree-cache-spill-test-{}-{tag}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let c = cache(1, budget);
        let enabled = c
            .enable_spill(
                SpillConfig {
                    dir: dir.clone(),
                    budget_bytes: spill_budget,
                },
                Box::new(U64Codec),
            )
            .expect("spill dir");
        assert!(enabled && c.spill_enabled());
        (c, dir)
    }

    #[test]
    fn evicted_value_spills_and_lookup_reloads_it() {
        let (c, dir) = spilling_cache("reload", 20, 1 << 20);
        c.insert(0, 40, 10);
        c.insert(1, 41, 10);
        c.insert(2, 42, 10); // evicts 0 → spilled
        assert!(matches!(c.peek(0), CacheState::Spilled));
        assert_eq!(c.spilled_len(), 1);
        match c.lookup(0) {
            CacheState::Resident(v) => assert_eq!(*v, 40),
            other => panic!("expected reload, got {other:?}"),
        }
        let s = c.stats().snapshot();
        assert_eq!(s.spill_hits, 1);
        assert_eq!(s.spill_corrupt, 0);
        // The reload promoted 0 and re-enforced the budget: someone
        // else (the then-LRU, id 1) went to disk in its place.
        assert!(c.peek(0).is_resident());
        assert!(matches!(c.peek(1), CacheState::Spilled));
        assert!(c.resident_bytes() <= 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_spill_file_degrades_to_evicted() {
        let (c, dir) = spilling_cache("corrupt", 10, 1 << 20);
        c.insert(0, 40, 10);
        c.insert(1, 41, 10); // evicts 0 → spilled
        std::fs::write(dir.join("0.spill"), b"xyz").unwrap(); // truncated garbage
        assert!(matches!(c.lookup(0), CacheState::Evicted));
        let s = c.stats().snapshot();
        assert_eq!(s.spill_corrupt, 1);
        assert_eq!(s.spill_hits, 0);
        // Re-registration (the plain protocol) still recovers.
        c.insert(0, 40, 10);
        assert!(c.peek(0).is_resident());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn full_spill_tier_falls_back_to_plain_eviction() {
        let (c, dir) = spilling_cache("full", 10, 0); // spill tier can hold nothing
        c.insert(0, 40, 10);
        c.insert(1, 41, 10); // evicts 0; spill refuses the payload
        assert_eq!(c.spilled_len(), 0);
        assert!(matches!(c.peek(0), CacheState::Evicted));
        assert!(matches!(c.lookup(0), CacheState::Evicted));
        assert_eq!(c.stats().snapshot().spill_misses, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reinsert_supersedes_spilled_copy() {
        let (c, dir) = spilling_cache("supersede", 20, 1 << 20);
        c.insert(0, 40, 10);
        c.insert(1, 41, 10);
        c.insert(2, 42, 10); // evicts 0 → spilled
        assert!(matches!(c.peek(0), CacheState::Spilled));
        c.insert(0, 77, 10); // fresh keys for 0; stale spill dropped
        assert!(!dir.join("0.spill").exists());
        match c.lookup(0) {
            CacheState::Resident(v) => assert_eq!(*v, 77),
            other => panic!("expected fresh keys, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn remove_clears_spilled_copy_too() {
        let (c, dir) = spilling_cache("remove", 10, 1 << 20);
        c.insert(0, 40, 10);
        c.insert(1, 41, 10); // evicts 0 → spilled
        assert!(matches!(c.peek(0), CacheState::Spilled));
        assert!(c.remove(0));
        assert!(matches!(c.peek(0), CacheState::Unknown));
        assert_eq!(c.spilled_len(), 0);
        assert!(!dir.join("0.spill").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn get_untracked_also_reloads_from_spill() {
        let (c, dir) = spilling_cache("untracked", 10, 1 << 20);
        c.insert(0, 40, 10);
        c.insert(1, 41, 10); // evicts 0 → spilled
        let v = c.get_untracked(0).expect("reload via get_untracked");
        assert_eq!(*v, 40);
        assert_eq!(c.stats().snapshot().spill_hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
