//! Sharded, memory-budgeted evaluation-key cache — the layer between
//! client key generation and the serving path.
//!
//! # Why a cache, not a map
//!
//! Every client session ships the server its evaluation keys:
//! relinearization plus one Galois key per rotation step. A session
//! registered for packed groups of `B` samples needs
//! `rotations_needed_batched(B)` steps (~2B extra Galois keys), each of
//! them `dnum` pairs of full-basis RNS polynomials — multiple MiB per
//! session on realistic rings. At the "millions of users" scale the
//! ROADMAP targets, an unbounded `HashMap` of key material is the first
//! thing that melts; related encrypted-tree-serving systems treat key
//! storage as *the* scarce server resource. This module makes it one:
//!
//! * **Sharding** — entries map to `session_id % num_shards`, one
//!   `Mutex` per shard, so registration/lookup from many serving
//!   threads never convoys on a single lock.
//! * **Exact byte accounting** — entry sizes come from the
//!   [`key_bytes`](crate::ckks::keys::RelinKey::key_bytes) APIs in
//!   `ckks::keys`, not estimates, and the global resident-bytes gauge
//!   is maintained on every insert/evict/remove.
//! * **LRU eviction under a global budget** — ticks are drawn from one
//!   global counter, so each shard's least-recently-used entry is
//!   comparable across shards; when resident bytes exceed the budget
//!   the globally-oldest entry is evicted (always inside a single
//!   shard lock — locks are never nested).
//! * **Eviction-safe protocol** — eviction drops the *keys*, never the
//!   *session id*: an evicted id stays "known", lookups report
//!   [`CacheState::Evicted`] (→ `SubmitError::KeysEvicted` at the
//!   coordinator), and the client re-registers its retained keys under
//!   the same id ([`SessionManager::reregister`]
//!   (crate::coordinator::session::SessionManager::reregister)) rather
//!   than re-enrolling.
//! * **Disk spill tier** (opt-in, [`KeyCache::enable_spill`]) — budget
//!   eviction demotes keys to a size-capped local directory instead of
//!   discarding them, and the next lookup reloads them transparently;
//!   `KeysEvicted` then means "the spill tier is full too". See
//!   [`spill`] for the layout and crash-safety story.
//!
//! The cache is generic over the stored value so the serving layer can
//! cache [`Session`](crate::coordinator::session::Session)s while the
//! property tests drive the LRU/budget machinery with synthetic sizes.
//!
//! One documented exception to the budget invariant: an entry whose own
//! size exceeds the whole budget is still admitted (refusing it would
//! deadlock that client's protocol); everything else is evicted around
//! it. With entry sizes ≤ budget, `resident_bytes ≤ budget` holds after
//! every single-threaded operation.

pub mod cache;
pub mod shard;
pub mod spill;
pub mod stats;

pub use cache::{CacheState, KeyCache};
pub use spill::{SpillCodec, SpillConfig};
pub use stats::{KeyCacheStats, KeyCacheStatsSnapshot};

/// Tuning for one [`KeyCache`].
#[derive(Clone, Copy, Debug)]
pub struct KeyCacheConfig {
    /// Lock shards; entries map to `session_id % num_shards`.
    pub num_shards: usize,
    /// Global resident-bytes budget across all shards. `u64::MAX`
    /// (the default) disables eviction.
    pub budget_bytes: u64,
}

impl Default for KeyCacheConfig {
    fn default() -> Self {
        KeyCacheConfig {
            num_shards: 16,
            budget_bytes: u64::MAX,
        }
    }
}

impl KeyCacheConfig {
    /// Default sharding with an explicit memory budget.
    pub fn with_budget(budget_bytes: u64) -> Self {
        KeyCacheConfig {
            budget_bytes,
            ..Default::default()
        }
    }
}
