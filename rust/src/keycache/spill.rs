//! Disk spill tier under the cache: RAM → disk → `KeysEvicted`.
//!
//! Without a spill tier the keycache's only pressure valve is a hard
//! [`CacheState::Evicted`](super::CacheState) — a full client
//! re-upload of multi-MiB evaluation keys over the wire. With one,
//! budget eviction *demotes* keys instead of discarding them: the
//! evicted value is serialized (via a caller-supplied [`SpillCodec`],
//! in practice the `net/codec.rs` key encoding) into a size-capped
//! local directory, and the next lookup reloads it transparently.
//! `KeysEvicted` is reserved for "the spill tier is full too" (or was
//! never enabled).
//!
//! # Directory layout
//!
//! One file per spilled session: `<session_id>.spill`, containing the
//! codec's byte encoding of the value. Writes go through
//! `<session_id>.tmp` + atomic rename, so a crash mid-write can never
//! leave a half-written `.spill` file *with the final name*.
//!
//! # Crash-safety
//!
//! The tier is a cache of client-owned, re-uploadable material, so it
//! is deliberately *not* durable: no fsync, and the directory is wiped
//! on construction (session ids restart at 0 per process, so stale
//! files from a previous run must never alias fresh ids). The failure
//! model is: any unreadable or undecodable spill file is deleted and
//! counted in `spill_corrupt`, and the lookup degrades to the plain
//! `Evicted` → re-register protocol. A torn write surviving a rename
//! (crash between rename and data reaching disk) is caught the same
//! way, because the codec validates every residue on decode.
//!
//! # Concurrency
//!
//! One mutex guards the index *and* the file I/O. Spill traffic is the
//! slow path by construction (it only runs on budget eviction and on
//! reload-after-eviction), and serializing it keeps the
//! `spilled_bytes` gauge exact and the store/evict/load interleavings
//! trivially race-free. No shard lock is ever held while the spill
//! lock is taken (the cache encodes values *after* releasing the
//! shard lock).

use super::stats::KeyCacheStats;
use crate::lockutil::lock_unpoisoned;
use std::collections::{BTreeMap, HashMap};
use std::fs;
use std::io;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Serialization seam between the generic cache and the value type.
/// The coordinator implements this for `Session` on top of the wire
/// codec's key encoding (`net::codec::encode_session_keys`).
///
/// `decode` returns `None` for any byte string that does not decode to
/// a valid value **for this id** — the spill tier treats that as a
/// corrupt file, deletes it, and degrades to `Evicted`.
pub trait SpillCodec<V>: Send + Sync {
    fn encode(&self, value: &V) -> Vec<u8>;
    fn decode(&self, id: u64, bytes: &[u8]) -> Option<V>;
    /// In-RAM byte accounting for a reloaded value (what the cache
    /// charges against its resident budget on promotion).
    fn size_bytes(&self, value: &V) -> usize;
}

/// Where and how much to spill.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Directory for `<id>.spill` files. Created (and wiped of stale
    /// spill files) on [`KeyCache::enable_spill`](super::KeyCache::enable_spill).
    pub dir: PathBuf,
    /// Byte cap on the sum of spill file sizes. Evicting past it
    /// deletes the oldest spilled entries — those sessions fall back
    /// to the `KeysEvicted` → re-register protocol.
    pub budget_bytes: u64,
}

struct SpillEntry {
    bytes: u64,
    /// LRU stamp; also this entry's key in `lru`.
    tick: u64,
}

#[derive(Default)]
struct SpillIndex {
    entries: HashMap<u64, SpillEntry>,
    /// tick → id, oldest first. Ticks are unique (one global counter).
    lru: BTreeMap<u64, u64>,
}

impl SpillIndex {
    /// Track `id` at `bytes`/`tick`, returning the bytes of a replaced
    /// entry (same id spilled again) so the caller can fix the gauge.
    fn upsert(&mut self, id: u64, bytes: u64, tick: u64) -> Option<u64> {
        let old = self.entries.insert(id, SpillEntry { bytes, tick });
        if let Some(ref e) = old {
            self.lru.remove(&e.tick);
        }
        self.lru.insert(tick, id);
        old.map(|e| e.bytes)
    }

    fn remove(&mut self, id: u64) -> Option<u64> {
        let e = self.entries.remove(&id)?;
        self.lru.remove(&e.tick);
        Some(e.bytes)
    }

    fn oldest(&self) -> Option<u64> {
        self.lru.values().next().copied()
    }
}

/// The on-disk tier: a size-capped, LRU-evicting directory of
/// serialized values. Owned by [`KeyCache`](super::KeyCache) once
/// spill is enabled; all counters land in the cache's shared
/// [`KeyCacheStats`].
pub(crate) struct SpillTier {
    dir: PathBuf,
    budget_bytes: u64,
    clock: AtomicU64,
    index: Mutex<SpillIndex>,
    stats: Arc<KeyCacheStats>,
}

impl SpillTier {
    /// Create the directory if needed and wipe stale `*.spill`/`*.tmp`
    /// files from a previous process (ids restart at 0 per process).
    pub(crate) fn new(cfg: SpillConfig, stats: Arc<KeyCacheStats>) -> io::Result<Self> {
        fs::create_dir_all(&cfg.dir)?;
        for entry in fs::read_dir(&cfg.dir)? {
            let path = entry?.path();
            let stale = matches!(
                path.extension().and_then(|e| e.to_str()),
                Some("spill") | Some("tmp")
            );
            if stale {
                fs::remove_file(&path).ok();
            }
        }
        Ok(SpillTier {
            dir: cfg.dir,
            budget_bytes: cfg.budget_bytes,
            clock: AtomicU64::new(0),
            index: Mutex::new(SpillIndex::default()),
            stats,
        })
    }

    fn file(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{id}.spill"))
    }

    fn tmp(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{id}.tmp"))
    }

    /// Spill `payload` for `id`. An entry larger than the whole spill
    /// budget is refused outright (its session degrades to the plain
    /// re-register protocol); otherwise oldest entries are deleted
    /// until the payload fits. Write failures (disk full, permissions)
    /// leave no entry behind — the session just isn't spilled.
    pub(crate) fn store(&self, id: u64, payload: &[u8]) {
        let bytes = payload.len() as u64;
        let tick = self.clock.fetch_add(1, Ordering::Relaxed);
        let mut idx = lock_unpoisoned(&self.index);
        if bytes > self.budget_bytes {
            self.remove_locked(&mut idx, id);
            return;
        }
        let tmp = self.tmp(id);
        let ok = fs::write(&tmp, payload)
            .and_then(|()| fs::rename(&tmp, self.file(id)))
            .is_ok();
        if !ok {
            fs::remove_file(&tmp).ok();
            self.remove_locked(&mut idx, id);
            return;
        }
        if let Some(old) = idx.upsert(id, bytes, tick) {
            self.stats.spilled_bytes.fetch_sub(old, Ordering::Relaxed);
        }
        self.stats.spilled_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.stats.spill_writes.fetch_add(1, Ordering::Relaxed);
        // Size cap: delete oldest spilled entries (never the one just
        // written — it is the newest tick by construction).
        while self.stats.spilled_bytes.load(Ordering::Relaxed) > self.budget_bytes {
            let victim = match idx.oldest() {
                Some(v) => v,
                None => break,
            };
            self.remove_locked(&mut idx, victim);
            self.stats.spill_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Read back `id`'s spilled payload. `None` if never spilled,
    /// already evicted from the tier, or unreadable (the file is then
    /// deleted and `spill_corrupt` counted — the caller sees the same
    /// `None` as a plain spill miss).
    pub(crate) fn load(&self, id: u64) -> Option<Vec<u8>> {
        let mut idx = lock_unpoisoned(&self.index);
        idx.entries.get(&id)?;
        match fs::read(self.file(id)) {
            Ok(bytes) => Some(bytes),
            Err(_) => {
                self.remove_locked(&mut idx, id);
                self.stats.spill_corrupt.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Drop `id` from the tier (file + index + gauge). Used when the
    /// value is promoted back to RAM, re-registered fresh, removed, or
    /// found corrupt.
    pub(crate) fn discard(&self, id: u64) {
        let mut idx = lock_unpoisoned(&self.index);
        self.remove_locked(&mut idx, id);
    }

    pub(crate) fn contains(&self, id: u64) -> bool {
        lock_unpoisoned(&self.index).entries.contains_key(&id)
    }

    pub(crate) fn spilled_len(&self) -> usize {
        lock_unpoisoned(&self.index).entries.len()
    }

    fn remove_locked(&self, idx: &mut SpillIndex, id: u64) {
        if let Some(bytes) = idx.remove(id) {
            self.stats.spilled_bytes.fetch_sub(bytes, Ordering::Relaxed);
        }
        fs::remove_file(self.file(id)).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cryptotree-spill-test-{}-{tag}",
            std::process::id()
        ));
        fs::remove_dir_all(&dir).ok();
        dir
    }

    fn tier(tag: &str, budget: u64) -> (SpillTier, Arc<KeyCacheStats>, PathBuf) {
        let dir = tmpdir(tag);
        let stats = Arc::new(KeyCacheStats::default());
        let t = SpillTier::new(
            SpillConfig {
                dir: dir.clone(),
                budget_bytes: budget,
            },
            stats.clone(),
        )
        .expect("spill dir");
        (t, stats, dir)
    }

    #[test]
    fn store_load_roundtrip_and_discard() {
        let (t, stats, dir) = tier("roundtrip", 1 << 20);
        t.store(7, b"relin+galois");
        assert!(t.contains(7));
        assert_eq!(stats.snapshot().spilled_bytes, 12);
        assert_eq!(t.load(7).as_deref(), Some(&b"relin+galois"[..]));
        t.discard(7);
        assert!(!t.contains(7));
        assert_eq!(t.load(7), None);
        assert_eq!(stats.snapshot().spilled_bytes, 0);
        assert!(!dir.join("7.spill").exists());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_evicts_oldest_spilled_entry() {
        let (t, stats, dir) = tier("budget", 10);
        t.store(1, b"aaaa"); // 4 bytes
        t.store(2, b"bbbb"); // 8 total
        t.store(3, b"cccc"); // 12 > 10 → evicts id 1
        assert!(!t.contains(1));
        assert!(t.contains(2) && t.contains(3));
        let s = stats.snapshot();
        assert_eq!(s.spilled_bytes, 8);
        assert_eq!(s.spill_evictions, 1);
        assert_eq!(s.spill_writes, 3);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_payload_is_refused() {
        let (t, stats, dir) = tier("oversize", 4);
        t.store(1, b"too big for the tier");
        assert!(!t.contains(1));
        assert_eq!(stats.snapshot().spilled_bytes, 0);
        assert!(!dir.join("1.spill").exists());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unreadable_file_counts_corrupt_and_clears_entry() {
        let (t, stats, dir) = tier("corrupt", 1 << 20);
        t.store(5, b"payload");
        fs::remove_file(dir.join("5.spill")).unwrap(); // file vanishes out from under the index
        assert_eq!(t.load(5), None);
        assert_eq!(stats.snapshot().spill_corrupt, 1);
        assert!(!t.contains(5));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn construction_wipes_stale_files() {
        let dir = tmpdir("wipe");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("99.spill"), b"stale").unwrap();
        fs::write(dir.join("98.tmp"), b"torn").unwrap();
        let stats = Arc::new(KeyCacheStats::default());
        let t = SpillTier::new(
            SpillConfig {
                dir: dir.clone(),
                budget_bytes: 1 << 20,
            },
            stats,
        )
        .unwrap();
        assert!(!dir.join("99.spill").exists());
        assert!(!dir.join("98.tmp").exists());
        assert_eq!(t.spilled_len(), 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_same_id_replaces_gauge_not_duplicates() {
        let (t, stats, dir) = tier("replace", 1 << 20);
        t.store(4, b"first");
        t.store(4, b"second-longer");
        assert_eq!(stats.snapshot().spilled_bytes, 13);
        assert_eq!(t.spilled_len(), 1);
        assert_eq!(t.load(4).as_deref(), Some(&b"second-longer"[..]));
        fs::remove_dir_all(&dir).ok();
    }
}
