//! Length-prefixed, versioned framing over a byte stream.
//!
//! Every message travels as one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"HRFW"
//! 4       1     protocol version (= PROTOCOL_VERSION)
//! 5       4     payload length, u32 little-endian
//! 9       len   payload (codec-encoded Request / Response)
//! ```
//!
//! The reader enforces an explicit payload-size cap *before*
//! allocating — a lying length prefix cannot make the server allocate
//! unbounded memory — and distinguishes a clean peer close (EOF at a
//! frame boundary) from a truncated frame (EOF inside one).

use std::io::{self, ErrorKind, Read, Write};

/// Frame magic: identifies the HRF wire protocol.
pub const MAGIC: [u8; 4] = *b"HRFW";

/// Wire protocol version; bumped on any incompatible codec change.
///
/// v2: `MetricsSnapshot` gained trailing DAG-executor fields
/// (`dag_ops`/`dag_waves`/`dag_width`). Mixed-version peers fail
/// cleanly at the framing layer instead of misdecoding metrics.
///
/// v3: `MetricsSnapshot` gained trailing memory-plane fields
/// (`slab_resident_bytes`/`slab_hits`/`slab_misses`/
/// `keycache_spilled_bytes`/`keycache_spill_hits`/
/// `keycache_spill_corrupt`).
pub const PROTOCOL_VERSION: u8 = 3;

/// Header bytes preceding every payload (magic + version + length).
pub const HEADER_LEN: usize = 9;

/// Default payload cap (bytes). Generous because evaluation-key
/// uploads dominate: one key-switching key is
/// `(max_level+1) · 2 · (max_level+2) · N · 8` bytes (~2 MiB at
/// N=4096 / depth 4) and a Galois set holds one per rotation step.
/// Configurable per endpoint for bigger rings.
pub const DEFAULT_MAX_FRAME: usize = 256 * 1024 * 1024;

/// Why a frame could not be read (or written).
#[derive(Debug)]
pub enum FrameError {
    /// Underlying I/O failure (including timeouts).
    Io(io::Error),
    /// Clean EOF at a frame boundary: the peer closed the connection.
    Closed,
    /// EOF in the middle of a frame (header or payload cut short).
    Truncated,
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version byte differs from [`PROTOCOL_VERSION`].
    BadVersion(u8),
    /// Declared payload length exceeds the configured cap.
    TooLarge { len: usize, max: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
            FrameError::Closed => f.write_str("peer closed the connection"),
            FrameError::Truncated => f.write_str("connection closed mid-frame"),
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::BadVersion(v) => {
                write!(f, "protocol version {v} (expected {PROTOCOL_VERSION})")
            }
            FrameError::TooLarge { len, max } => {
                write!(f, "frame payload {len} bytes exceeds cap {max}")
            }
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// `read_exact` that reports a mid-frame EOF as [`FrameError::Truncated`].
fn read_exact_frame<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), FrameError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == ErrorKind::UnexpectedEof {
            FrameError::Truncated
        } else {
            FrameError::Io(e)
        }
    })
}

/// Write one frame (header + payload). The payload must fit a u32
/// length prefix; the *reader's* cap is the operative protocol limit.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > u32::MAX as usize {
        return Err(io::Error::new(
            ErrorKind::InvalidInput,
            "frame payload exceeds u32 length prefix",
        ));
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = PROTOCOL_VERSION;
    header[5..9].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame's payload, enforcing `max_len` before allocating.
///
/// A zero-byte read at the very start maps to [`FrameError::Closed`];
/// any later EOF is [`FrameError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R, max_len: usize) -> Result<Vec<u8>, FrameError> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    read_frame_resume(r, first[0], max_len)
}

/// Finish reading a frame whose first header byte was already
/// consumed — the server's poll loop reads one byte with a timeout
/// (to notice shutdown), then switches the stream to blocking and
/// hands the byte here, so a slow client can never desynchronize the
/// stream by timing out mid-frame.
pub fn read_frame_resume<R: Read>(
    r: &mut R,
    first: u8,
    max_len: usize,
) -> Result<Vec<u8>, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    header[0] = first;
    read_exact_frame(r, &mut header[1..])?;
    if header[..4] != MAGIC {
        return Err(FrameError::BadMagic([
            header[0], header[1], header[2], header[3],
        ]));
    }
    if header[4] != PROTOCOL_VERSION {
        return Err(FrameError::BadVersion(header[4]));
    }
    let len = u32::from_le_bytes([header[5], header[6], header[7], header[8]]) as usize;
    if len > max_len {
        return Err(FrameError::TooLarge { len, max: max_len });
    }
    let mut payload = vec![0u8; len];
    read_exact_frame(r, &mut payload)?;
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        assert_eq!(buf.len(), HEADER_LEN + 5);
        let got = read_frame(&mut Cursor::new(&buf), 64).unwrap();
        assert_eq!(got, b"hello");
    }

    #[test]
    fn empty_payload_roundtrips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"").unwrap();
        let got = read_frame(&mut Cursor::new(&buf), 0).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn eof_at_boundary_is_closed_and_mid_frame_is_truncated() {
        let empty: &[u8] = &[];
        assert!(matches!(
            read_frame(&mut Cursor::new(empty), 64),
            Err(FrameError::Closed)
        ));
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        for cut in 1..buf.len() {
            let r = read_frame(&mut Cursor::new(&buf[..cut]), 64);
            assert!(
                matches!(r, Err(FrameError::Truncated)),
                "cut at {cut}: {r:?}"
            );
        }
    }

    #[test]
    fn rejects_bad_magic_version_and_oversize() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hey").unwrap();
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(
            read_frame(&mut Cursor::new(&bad), 64),
            Err(FrameError::BadMagic(_))
        ));
        let mut bad = buf.clone();
        bad[4] = PROTOCOL_VERSION + 1;
        assert!(matches!(
            read_frame(&mut Cursor::new(&bad), 64),
            Err(FrameError::BadVersion(_))
        ));
        // A lying length prefix is rejected before any allocation.
        let mut bad = buf;
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(&bad), 64),
            Err(FrameError::TooLarge { .. })
        ));
    }
}
