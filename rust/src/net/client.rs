//! Blocking wire client for the serving tier.
//!
//! One request/response pair at a time over a single connection —
//! exactly what the load-generator worker and the wire tests need.
//! The interesting bit is [`NetClient::submit_encrypted_recovering`]:
//! the client-side half of the eviction-recovery protocol, looping
//! `KeysEvicted` → `Reregister` → resubmit just like the in-process
//! callers do.

use super::codec::{
    decode_response, encode_request, CodecError, ModelInfo, Request, Response, WireError,
};
use super::frame::{read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME};
use crate::ckks::rns::ContextRef;
use crate::ckks::Ciphertext;
use crate::coordinator::{MetricsSnapshot, SubmitError};
use crate::hrf::client::EvalKeys;
use crate::hrf::EncScores;
use crate::obs::trace::TraceRecord;
use std::net::{TcpStream, ToSocketAddrs};

/// Why a client call failed.
#[derive(Debug)]
pub enum NetError {
    /// Transport-level failure (I/O, framing, protocol version).
    Frame(FrameError),
    /// The response payload did not decode.
    Codec(CodecError),
    /// The server refused the submission (typed; `KeysEvicted` is
    /// recoverable via [`NetClient::reregister`]).
    Submit(SubmitError),
    /// Server-side failure outside the submit protocol.
    Server(String),
    /// The server could not parse our request.
    Protocol(String),
    /// The server answered with a different variant than the request
    /// calls for (names the expected one).
    UnexpectedResponse(&'static str),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Frame(e) => write!(f, "{e}"),
            NetError::Codec(e) => write!(f, "response decode failed: {e}"),
            NetError::Submit(e) => write!(f, "submit refused: {e}"),
            NetError::Server(s) => write!(f, "server error: {s}"),
            NetError::Protocol(s) => write!(f, "protocol error: {s}"),
            NetError::UnexpectedResponse(want) => {
                write!(f, "unexpected response variant (expected {want})")
            }
        }
    }
}

impl std::error::Error for NetError {}

impl From<FrameError> for NetError {
    fn from(e: FrameError) -> Self {
        NetError::Frame(e)
    }
}

impl From<CodecError> for NetError {
    fn from(e: CodecError) -> Self {
        NetError::Codec(e)
    }
}

/// Max `KeysEvicted` → re-register → resubmit attempts before giving
/// up (a tiny cache can evict the keys again between the re-register
/// and the worker picking the request up).
const MAX_RECOVERIES: u32 = 8;

/// Blocking client: one in-flight request per connection.
pub struct NetClient {
    stream: TcpStream,
    ctx: ContextRef,
    max_frame: usize,
}

impl NetClient {
    /// Connect with the default response-frame cap.
    pub fn connect<A: ToSocketAddrs>(addr: A, ctx: ContextRef) -> std::io::Result<NetClient> {
        Self::connect_with(addr, ctx, DEFAULT_MAX_FRAME)
    }

    /// Connect with an explicit response-frame cap (bytes).
    pub fn connect_with<A: ToSocketAddrs>(
        addr: A,
        ctx: ContextRef,
        max_frame: usize,
    ) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient {
            stream,
            ctx,
            max_frame,
        })
    }

    /// Send one request and decode the server's reply, mapping wire
    /// errors to [`NetError`].
    pub fn call(&mut self, req: &Request) -> Result<Response, NetError> {
        write_frame(&mut self.stream, &encode_request(req)).map_err(FrameError::Io)?;
        let payload = read_frame(&mut self.stream, self.max_frame)?;
        match decode_response(&payload, &self.ctx)? {
            Response::Error(WireError::Submit(e)) => Err(NetError::Submit(e)),
            Response::Error(WireError::Server(s)) => Err(NetError::Server(s)),
            Response::Error(WireError::Protocol(s)) => Err(NetError::Protocol(s)),
            resp => Ok(resp),
        }
    }

    /// Fetch model facts (parameter preset, feature count, required
    /// rotation steps).
    pub fn model_info(&mut self) -> Result<ModelInfo, NetError> {
        match self.call(&Request::ModelInfo)? {
            Response::ModelInfo(info) => Ok(info),
            _ => Err(NetError::UnexpectedResponse("ModelInfo")),
        }
    }

    /// Upload evaluation keys; returns the new session id.
    pub fn register_keys(&mut self, keys: &EvalKeys) -> Result<u64, NetError> {
        match self.call(&Request::RegisterKeys { keys: keys.clone() })? {
            Response::Registered { session_id } => Ok(session_id),
            _ => Err(NetError::UnexpectedResponse("Registered")),
        }
    }

    /// Re-upload keys for an evicted session id; `Ok(false)` means
    /// the id is unknown (register afresh instead).
    pub fn reregister(&mut self, session_id: u64, keys: &EvalKeys) -> Result<bool, NetError> {
        match self.call(&Request::Reregister {
            session_id,
            keys: keys.clone(),
        })? {
            Response::Reregistered { ok } => Ok(ok),
            _ => Err(NetError::UnexpectedResponse("Reregistered")),
        }
    }

    /// Score one encrypted observation.
    pub fn submit_encrypted(
        &mut self,
        session_id: u64,
        ct: &Ciphertext,
    ) -> Result<EncScores, NetError> {
        match self.call(&Request::SubmitEncrypted {
            session_id,
            ct: ct.clone(),
        })? {
            Response::EncScores(s) => Ok(s),
            _ => Err(NetError::UnexpectedResponse("EncScores")),
        }
    }

    /// Score a client-packed group of `n_samples` observations.
    pub fn submit_encrypted_packed(
        &mut self,
        session_id: u64,
        ct: &Ciphertext,
        n_samples: usize,
    ) -> Result<EncScores, NetError> {
        match self.call(&Request::SubmitEncryptedPacked {
            session_id,
            ct: ct.clone(),
            n_samples: n_samples as u32,
        })? {
            Response::EncScores(s) => Ok(s),
            _ => Err(NetError::UnexpectedResponse("EncScores")),
        }
    }

    /// Score one encrypted observation, transparently recovering from
    /// key eviction: on `KeysEvicted`, re-register `keys` under the
    /// same session id and resubmit. Returns the scores and how many
    /// recoveries were needed (0 on the happy path).
    pub fn submit_encrypted_recovering(
        &mut self,
        session_id: u64,
        ct: &Ciphertext,
        keys: &EvalKeys,
    ) -> Result<(EncScores, u32), NetError> {
        let mut recoveries = 0;
        loop {
            match self.submit_encrypted(session_id, ct) {
                Ok(scores) => return Ok((scores, recoveries)),
                Err(NetError::Submit(SubmitError::KeysEvicted)) if recoveries < MAX_RECOVERIES => {
                    if !self.reregister(session_id, keys)? {
                        return Err(NetError::Submit(SubmitError::NoSession));
                    }
                    recoveries += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Plaintext fast path (`x` must have the model's feature count).
    pub fn submit_plain(&mut self, x: Vec<f64>) -> Result<Vec<f64>, NetError> {
        match self.call(&Request::SubmitPlain { x })? {
            Response::PlainScores(s) => Ok(s),
            _ => Err(NetError::UnexpectedResponse("PlainScores")),
        }
    }

    /// Scrape the server's metrics snapshot (counters, latency
    /// quantiles, queue/service split, trace-ring totals).
    pub fn metrics_snapshot(&mut self) -> Result<MetricsSnapshot, NetError> {
        match self.call(&Request::MetricsSnapshot)? {
            Response::Metrics(s) => Ok(s),
            _ => Err(NetError::UnexpectedResponse("Metrics")),
        }
    }

    /// Dump the server's span-trace ring (oldest → newest). Empty
    /// when the server runs with `trace_capacity = 0`.
    pub fn trace_dump(&mut self) -> Result<Vec<TraceRecord>, NetError> {
        match self.call(&Request::TraceDump)? {
            Response::Traces(t) => Ok(t),
            _ => Err(NetError::UnexpectedResponse("Traces")),
        }
    }

    /// Ask the server process to shut down cleanly.
    pub fn shutdown_server(&mut self) -> Result<(), NetError> {
        match self.call(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            _ => Err(NetError::UnexpectedResponse("ShuttingDown")),
        }
    }
}
