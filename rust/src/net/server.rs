//! Thread-per-connection TCP front-end for the coordinator.
//!
//! The acceptor runs non-blocking so it can poll the stop flag;
//! handler threads poll the stream's *first* byte with a short read
//! timeout (to notice shutdown between frames) and then read the rest
//! of the frame blocking, so a slow sender can never desynchronize a
//! connection by timing out mid-frame.
//!
//! Backpressure happens at two layers: the coordinator's bounded
//! ingress queue refuses with [`SubmitError::Busy`] (forwarded over
//! the wire), and the acceptor itself enforces a connection cap —
//! above it, a new connection gets a single `Busy` error frame and is
//! closed, counted in `net_rejected_overload`.

use super::codec::{decode_request, encode_response, ModelInfo, Request, Response, WireError};
use super::frame::{read_frame_resume, write_frame, FrameError, DEFAULT_MAX_FRAME};
use crate::ckks::rns::ContextRef;
use crate::coordinator::{panic_message, Coordinator, ShutdownReport, SubmitError};
use crate::hrf::HrfServer;
use crate::lockutil::lock_unpoisoned;
use crate::obs::trace::{TraceKind, TracePhase};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Acceptor and connection-handling knobs.
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Listen address, e.g. `127.0.0.1:0` for an ephemeral port.
    pub addr: String,
    /// Connections above this are refused with a `Busy` error frame.
    pub max_connections: usize,
    /// Per-frame payload cap (bytes) for incoming requests.
    pub max_frame: usize,
    /// Between-frame poll timeout: how quickly an idle connection
    /// notices server shutdown.
    pub read_timeout: Duration,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            addr: "127.0.0.1:0".to_string(),
            max_connections: 64,
            max_frame: DEFAULT_MAX_FRAME,
            read_timeout: Duration::from_millis(200),
        }
    }
}

/// State shared by the acceptor and every connection handler.
struct Shared {
    coord: Arc<Coordinator>,
    server: Arc<HrfServer>,
    ctx: ContextRef,
    /// Set by [`NetServer::shutdown`] (and `Drop`): stop accepting,
    /// drain handlers.
    stop: AtomicBool,
    /// Set when a client sends [`Request::Shutdown`]; observed by
    /// [`NetServer::run_until_shutdown`].
    shutdown_requested: AtomicBool,
    /// Live connection handlers; the acceptor reaps finished ones.
    handlers: Mutex<Vec<JoinHandle<()>>>,
    /// Monotonic id for handler thread names.
    next_conn: AtomicU64,
    max_frame: usize,
    read_timeout: Duration,
    /// Batching target the served rotation-step advertisement
    /// (`ModelInfo::rotations`) must cover.
    enc_batch: usize,
}

/// A running TCP serving tier. Dropping it without calling
/// [`NetServer::shutdown`] stops the acceptor but does not join
/// handlers or shut the coordinator down cleanly.
pub struct NetServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    addr: SocketAddr,
}

impl NetServer {
    /// Bind, spawn the acceptor, and start serving `coord`.
    ///
    /// `enc_batch` should match the coordinator's configured
    /// encrypted batch target: it determines which rotation steps
    /// `ModelInfo` tells clients to generate Galois keys for.
    pub fn start(
        cfg: NetServerConfig,
        ctx: ContextRef,
        server: Arc<HrfServer>,
        coord: Coordinator,
        enc_batch: usize,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            coord: Arc::new(coord),
            server,
            ctx,
            stop: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            handlers: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
            max_frame: cfg.max_frame,
            read_timeout: cfg.read_timeout,
            enc_batch,
        });
        let accept_shared = Arc::clone(&shared);
        let max_connections = cfg.max_connections;
        let accept = thread::Builder::new()
            .name("net-accept".to_string())
            .spawn(move || accept_loop(accept_shared, listener, max_connections))
            .expect("spawn acceptor");
        Ok(NetServer {
            shared,
            accept: Some(accept),
            addr,
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Has a client requested shutdown via [`Request::Shutdown`]?
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown_requested.load(Ordering::Relaxed)
    }

    /// The coordinator's metrics registry — usable after shutdown
    /// consumes the server (grab a clone first).
    pub fn metrics(&self) -> Arc<crate::coordinator::metrics::Metrics> {
        Arc::clone(&self.shared.coord.metrics)
    }

    /// Serve until a client sends [`Request::Shutdown`], then shut
    /// down cleanly and return the merged report.
    pub fn run_until_shutdown(self) -> ShutdownReport {
        while !self.shutdown_requested() {
            thread::sleep(Duration::from_millis(50));
        }
        self.shutdown()
    }

    /// Stop accepting, join every connection handler, then shut the
    /// coordinator down. Network-handler panics are merged into the
    /// coordinator's [`ShutdownReport`] so the serving binary can
    /// exit non-zero on *any* worker panic, HE or network.
    pub fn shutdown(mut self) -> ShutdownReport {
        self.shared.stop.store(true, Ordering::Relaxed);
        let mut report = ShutdownReport::default();
        if let Some(t) = self.accept.take() {
            if let Err(payload) = t.join() {
                report
                    .worker_panics
                    .push(("net-accept".to_string(), panic_message(payload.as_ref())));
            }
        }
        let handlers = std::mem::take(&mut *lock_unpoisoned(&self.shared.handlers));
        for t in handlers {
            let name = t.thread().name().unwrap_or("<unnamed>").to_string();
            if let Err(payload) = t.join() {
                let msg = panic_message(payload.as_ref());
                eprintln!("[net] connection handler `{name}` panicked: {msg}");
                report.worker_panics.push((name, msg));
            }
        }
        // All threads holding `shared` have been joined, so both
        // unwraps succeed and we get the coordinator back by value
        // for its consuming shutdown.
        match Arc::try_unwrap(self.shared) {
            Ok(shared) => match Arc::try_unwrap(shared.coord) {
                Ok(coord) => {
                    let coord_report = coord.shutdown();
                    report.worker_panics.extend(coord_report.worker_panics);
                }
                Err(_) => eprintln!("[net] coordinator still referenced; skipping its shutdown"),
            },
            Err(shared) => {
                shared.stop.store(true, Ordering::Relaxed);
                eprintln!("[net] shared state still referenced; skipping coordinator shutdown");
            }
        }
        report
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

fn accept_loop(shared: Arc<Shared>, listener: TcpListener, max_connections: usize) {
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let metrics = &shared.coord.metrics;
                metrics.net_connections_accepted.fetch_add(1, Ordering::Relaxed);
                let open = {
                    let mut handlers = lock_unpoisoned(&shared.handlers);
                    handlers.retain(|t| !t.is_finished());
                    handlers.len()
                };
                if open >= max_connections {
                    metrics.net_rejected_overload.fetch_add(1, Ordering::Relaxed);
                    refuse_overload(stream);
                    continue;
                }
                let id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
                let conn_shared = Arc::clone(&shared);
                let handle = thread::Builder::new()
                    .name(format!("net-conn-{id}"))
                    .spawn(move || handle_connection(conn_shared, stream))
                    .expect("spawn connection handler");
                lock_unpoisoned(&shared.handlers).push(handle);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("[net] accept error: {e}");
                thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Tell an over-cap connection it is refused, then close it. Mirrors
/// the coordinator's queue-full behaviour: shed load explicitly
/// rather than queue unboundedly.
fn refuse_overload(mut stream: TcpStream) {
    let resp = Response::Error(WireError::Submit(SubmitError::Busy));
    let _ = write_frame(&mut stream, &encode_response(&resp));
}

fn handle_connection(shared: Arc<Shared>, mut stream: TcpStream) {
    // RAII guard: the open-connections gauge comes back down even if
    // the handler panics mid-request.
    let _open = shared.coord.metrics.open_connection();
    serve_connection(&shared, &mut stream);
}

fn serve_connection(shared: &Shared, stream: &mut TcpStream) {
    if stream.set_read_timeout(Some(shared.read_timeout)).is_err() {
        return;
    }
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        // Poll the first header byte with the short timeout so an
        // idle connection notices `stop` promptly...
        let mut first = [0u8; 1];
        let n = match std::io::Read::read(stream, &mut first) {
            Ok(n) => n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => return,
        };
        if n == 0 {
            return; // clean close between frames
        }
        // The request's first byte is on the wire: this is where its
        // span timeline starts (`Accepted`), so decode time is visible
        // as the Accepted → Decoded gap.
        let accepted = Instant::now();
        // ...then read the remainder blocking: a frame in flight is
        // never cut by the poll timeout.
        if stream.set_read_timeout(None).is_err() {
            return;
        }
        let payload = match read_frame_resume(stream, first[0], shared.max_frame) {
            Ok(p) => p,
            Err(err) => {
                // The stream is no longer at a frame boundary (or the
                // peer is speaking another protocol): report and drop
                // the connection.
                let resp = Response::Error(WireError::Protocol(err.to_string()));
                let _ = write_frame(stream, &encode_response(&resp));
                if !matches!(err, FrameError::Closed) {
                    eprintln!("[net] dropping connection: {err}");
                }
                return;
            }
        };
        let resp = match decode_request(&payload, &shared.ctx) {
            // Frame boundary is intact after a codec error, so the
            // connection survives a malformed request.
            Err(err) => Response::Error(WireError::Protocol(err.to_string())),
            Ok(req) => serve_request(shared, req, accepted),
        };
        if write_frame(stream, &encode_response(&resp)).is_err() {
            return;
        }
        if stream.set_read_timeout(Some(shared.read_timeout)).is_err() {
            return;
        }
    }
}

fn serve_request(shared: &Shared, req: Request, accepted: Instant) -> Response {
    let coord = &shared.coord;
    // Submit* requests get a span trace anchored at the first wire
    // byte; stamping `Decoded` here (request already decoded) makes
    // frame read + codec time visible in the timeline.
    let begin = |kind: TraceKind| {
        let mut trace = coord.metrics.trace.begin_from(kind, accepted);
        trace.stamp(TracePhase::Decoded);
        trace
    };
    match req {
        Request::ModelInfo => Response::ModelInfo(model_info(shared)),
        Request::RegisterKeys { keys } => Response::Registered {
            session_id: coord.sessions.register_keys(&keys),
        },
        Request::Reregister { session_id, keys } => Response::Reregistered {
            ok: coord.sessions.reregister_keys(session_id, &keys),
        },
        Request::SubmitEncrypted { session_id, ct } => {
            let trace = begin(TraceKind::Encrypted);
            match coord.submit_encrypted_traced(session_id, ct, trace) {
                Err(e) => Response::Error(WireError::Submit(e)),
                Ok(rx) => match rx.recv() {
                    Ok(Ok(scores)) => Response::EncScores(scores),
                    Ok(Err(e)) => Response::Error(WireError::Submit(e)),
                    Err(_) => Response::Error(WireError::Server(
                        "response channel dropped".to_string(),
                    )),
                },
            }
        }
        Request::SubmitEncryptedPacked {
            session_id,
            ct,
            n_samples,
        } => {
            let trace = begin(TraceKind::Packed);
            match coord.submit_encrypted_packed_traced(session_id, ct, n_samples as usize, trace)
            {
                Err(e) => Response::Error(WireError::Submit(e)),
                Ok(rx) => match rx.recv() {
                    Ok(Ok(scores)) => Response::EncScores(scores),
                    Ok(Err(e)) => Response::Error(WireError::Submit(e)),
                    Err(_) => Response::Error(WireError::Server(
                        "response channel dropped".to_string(),
                    )),
                },
            }
        }
        Request::SubmitPlain { x } => {
            // Validate the feature count *here*: the batcher's
            // reshuffle would otherwise panic on a short vector, and
            // a remote client must not be able to panic a worker.
            let d = shared.server.model.plan.d;
            if x.len() != d {
                return Response::Error(WireError::Protocol(format!(
                    "expected {d} features, got {}",
                    x.len()
                )));
            }
            let trace = begin(TraceKind::Plain);
            match coord.submit_plain_traced(x, trace) {
                Err(e) => Response::Error(WireError::Submit(e)),
                Ok(rx) => match rx.recv() {
                    Ok(Ok(scores)) => Response::PlainScores(scores),
                    Ok(Err(msg)) => Response::Error(WireError::Server(msg)),
                    Err(_) => Response::Error(WireError::Server(
                        "response channel dropped".to_string(),
                    )),
                },
            }
        }
        Request::Shutdown => {
            shared.shutdown_requested.store(true, Ordering::Relaxed);
            Response::ShuttingDown
        }
        Request::MetricsSnapshot => Response::Metrics(coord.metrics.snapshot()),
        Request::TraceDump => Response::Traces(coord.metrics.trace.snapshot()),
    }
}

fn model_info(shared: &Shared) -> ModelInfo {
    let plan = &shared.server.model.plan;
    let mut rotations: Vec<u32> = shared
        .server
        .eval_key_requirements(shared.enc_batch)
        .into_iter()
        .map(|s| s as u32)
        .collect();
    rotations.sort_unstable();
    ModelInfo {
        params_name: shared.ctx.params.name.to_string(),
        n: shared.ctx.n() as u32,
        features: plan.d as u32,
        groups: plan.groups as u32,
        classes: plan.c as u32,
        rotations,
    }
}
