//! `--flag value` parser shared by `cryptotree-serve` and
//! `cryptotree-loadgen` (same shape as the main CLI's, plus bare
//! boolean flags like `--spawn-server`).

use std::collections::HashMap;

/// Parsed command line: `--key value` pairs and bare `--switch`es.
pub struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse everything after the program name / subcommand. A
    /// `--key` followed by a non-flag token takes it as its value;
    /// a `--key` followed by another flag (or nothing) is a boolean
    /// switch.
    pub fn parse(rest: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                let has_value = i + 1 < rest.len() && !rest[i + 1].starts_with("--");
                if has_value {
                    flags.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    /// Typed flag with a default (unparsable values fall back too).
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// String flag with a default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag: `None` when absent. A bare `--key` (no
    /// value — parsed as the switch marker) also counts as absent,
    /// since a marker is never a usable path or address.
    pub fn get_opt_str(&self, key: &str) -> Option<String> {
        self.flags
            .get(key)
            .filter(|v| !v.is_empty() && v.as_str() != "true")
            .cloned()
    }

    /// Was the switch present at all?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn pairs_switches_and_defaults() {
        let a = Args::parse(&argv(&[
            "--processes",
            "4",
            "--spawn-server",
            "--addr",
            "127.0.0.1:7001",
        ]));
        assert_eq!(a.get("processes", 1usize), 4);
        assert!(a.has("spawn-server"));
        assert!(!a.has("shutdown-server"));
        assert_eq!(a.get_str("addr", "x"), "127.0.0.1:7001");
        assert_eq!(a.get("missing", 7u32), 7);
        // A switch parsed as a typed flag falls back to the default.
        assert_eq!(a.get("spawn-server", 3usize), 3);
    }

    #[test]
    fn opt_str_distinguishes_value_switch_and_absent() {
        let a = Args::parse(&argv(&["--spill-dir", "/tmp/x", "--verbose"]));
        assert_eq!(a.get_opt_str("spill-dir").as_deref(), Some("/tmp/x"));
        assert_eq!(a.get_opt_str("verbose"), None); // bare switch
        assert_eq!(a.get_opt_str("missing"), None);
    }
}
