//! Networked serving tier: the HRF coordinator behind a TCP socket.
//!
//! The in-process [`crate::coordinator::Coordinator`] already
//! implements batching, sessions and backpressure; this module puts a
//! wire on it so separate *processes* (and machines) can register
//! evaluation keys and submit encrypted observations:
//!
//! * [`frame`] — length-prefixed, versioned framing (`b"HRFW"` magic,
//!   `u32` payload length, explicit size cap enforced before any
//!   allocation).
//! * [`codec`] — hand-rolled little-endian encoding of the
//!   [`codec::Request`]/[`codec::Response`] enums, validating every
//!   polynomial residue against the server's modulus chain on decode.
//! * [`server`] — thread-per-connection [`server::NetServer`] behind
//!   the `cryptotree-serve` binary: non-blocking acceptor with a
//!   connection cap (overload is *refused* with
//!   [`crate::coordinator::SubmitError::Busy`], not queued), clean
//!   shutdown that joins every handler and surfaces worker panics.
//! * [`client`] — blocking [`client::NetClient`] used by the
//!   `cryptotree-loadgen` harness and the wire tests, including the
//!   `KeysEvicted` → re-register → resubmit recovery loop.
//! * [`workload`] — the deterministic demo model both binaries build
//!   from the same flags, so client-side encryption matches the
//!   served model without shipping model files around.
//! * [`args`] — the tiny `--flag value` parser shared by the two
//!   binaries.
//!
//! One request/response pair per frame; a connection carries any
//! number of frames sequentially. Sessions are identified by the id
//! the server returns at key registration, not by the connection —
//! reconnecting (or a different process) can keep using a session id,
//! which is exactly what the eviction-recovery protocol needs.
//!
//! Observability rides the same wire: [`codec::Request::MetricsSnapshot`]
//! scrapes the coordinator's counters/quantiles and
//! [`codec::Request::TraceDump`] drains a copy of the span-trace ring
//! (see [`crate::obs`]), so a remote harness can explain a request's
//! latency without attaching to the server process.

pub mod args;
pub mod client;
pub mod codec;
pub mod frame;
pub mod server;
pub mod workload;

pub use client::{NetClient, NetError};
pub use codec::{
    decode_request, decode_response, encode_request, encode_response, CodecError, ModelInfo,
    Request, Response, WireError,
};
pub use frame::{
    read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME, HEADER_LEN, MAGIC, PROTOCOL_VERSION,
};
pub use server::{NetServer, NetServerConfig};
