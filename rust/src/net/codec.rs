//! Binary codec for the wire protocol's request/response enums.
//!
//! Hand-rolled little-endian encoding (the crate is dependency-free;
//! no serde). Decoding is *defensive*: every length is bounds-checked
//! before allocation, every tag must be known, and — crucially for an
//! HE server — every polynomial residue is validated against the
//! server's own modulus chain, so a malicious client cannot inject
//! out-of-range limbs into the NTT kernels. Galois *elements* are
//! never trusted from the wire: they are recomputed from the rotation
//! steps (`5^r mod 2N`) on decode.
//!
//! Layout conventions: integers little-endian; `f64` as `to_bits`
//! LE; `Vec`/`String` as a `u32` count followed by the elements;
//! enums as a `u8` tag followed by the variant fields.

use crate::ckks::keys::{GaloisKeys, KswKey, RelinKey};
use crate::ckks::modops::galois_element;
use crate::ckks::rns::{CkksContext, RnsPoly};
use crate::ckks::Ciphertext;
use crate::coordinator::{MetricsSnapshot, SubmitError};
use crate::hrf::client::EvalKeys;
use crate::hrf::EncScores;
use crate::obs::trace::{TraceKind, TraceRecord, N_PHASES};
use std::collections::HashMap;
use std::time::Duration;

/// Why a payload failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Payload ended before a field's bytes (`need` more than `have`).
    Truncated { need: usize, have: usize },
    /// Unknown enum tag.
    BadTag { context: &'static str, tag: u8 },
    /// A field failed validation (range, count cap, modulus check…).
    BadValue(&'static str),
    /// Bytes left over after the message was fully decoded.
    TrailingBytes(usize),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { need, have } => {
                write!(f, "payload truncated: need {need} bytes, have {have}")
            }
            CodecError::BadTag { context, tag } => write!(f, "unknown {context} tag {tag}"),
            CodecError::BadValue(what) => write!(f, "invalid field: {what}"),
            CodecError::TrailingBytes(n) => write!(f, "{n} trailing bytes after message"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---------------------------------------------------------------- caps

/// Cap on decoded string bytes (error messages, parameter names).
const MAX_STR: usize = 4096;
/// Cap on plaintext feature vectors.
const MAX_PLAIN_FEATURES: usize = 65_536;
/// Cap on Galois key entries per session.
const MAX_GALOIS_KEYS: usize = 4096;
/// Cap on key-switching decomposition pairs (≥ modulus chain length).
const MAX_KSW_PAIRS: usize = 64;
/// Cap on per-class score ciphertexts in one response.
const MAX_SCORES: usize = 256;
/// Cap on advertised rotation steps.
const MAX_ROTATIONS: usize = 4096;
/// Cap on trace records in one `Traces` response (well above any
/// sane `trace_capacity`).
const MAX_TRACES: usize = 16_384;

// ------------------------------------------------------------- writing

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

// ------------------------------------------------------------- reading

/// Bounds-checked little-endian cursor over a decoded payload.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let have = self.buf.len() - self.pos;
        if n > have {
            return Err(CodecError::Truncated { need: n, have });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn get_u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn get_u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn get_u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn get_f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    fn get_bool(&mut self, what: &'static str) -> Result<bool, CodecError> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CodecError::BadValue(what)),
        }
    }

    fn get_str(&mut self) -> Result<String, CodecError> {
        let len = self.get_u32()? as usize;
        if len > MAX_STR {
            return Err(CodecError::BadValue("string too long"));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::BadValue("non-UTF-8 string"))
    }

    /// Error if any bytes remain (messages must consume their payload
    /// exactly — trailing garbage suggests a codec mismatch).
    fn finish(&self) -> Result<(), CodecError> {
        let rest = self.buf.len() - self.pos;
        if rest == 0 {
            Ok(())
        } else {
            Err(CodecError::TrailingBytes(rest))
        }
    }
}

// ----------------------------------------------------- crypto payloads

fn put_poly(buf: &mut Vec<u8>, p: &RnsPoly) {
    put_u8(buf, p.level as u8);
    put_u8(buf, p.special as u8);
    put_u8(buf, p.is_ntt as u8);
    for &x in p.data() {
        put_u64(buf, x);
    }
}

/// Decode one polynomial, validating shape *and* every residue
/// against the context's modulus chain (special prime for the last
/// limb when flagged).
fn get_poly(r: &mut ByteReader<'_>, ctx: &CkksContext) -> Result<RnsPoly, CodecError> {
    let level = r.get_u8()? as usize;
    if level >= ctx.params.moduli.len() {
        return Err(CodecError::BadValue("poly level exceeds modulus chain"));
    }
    let special = r.get_bool("poly special flag")?;
    let is_ntt = r.get_bool("poly ntt flag")?;
    let n = ctx.n();
    let n_limbs = RnsPoly::n_limbs(level, special);
    let mut data = vec![0u64; n_limbs * n];
    for li in 0..n_limbs {
        let q = if special && li == n_limbs - 1 {
            ctx.params.special
        } else {
            ctx.params.moduli[li]
        };
        for slot in data[li * n..(li + 1) * n].iter_mut() {
            let v = r.get_u64()?;
            if v >= q {
                return Err(CodecError::BadValue("poly residue out of modulus range"));
            }
            *slot = v;
        }
    }
    Ok(RnsPoly::from_raw_parts(ctx, level, special, is_ntt, data))
}

fn put_ciphertext(buf: &mut Vec<u8>, ct: &Ciphertext) {
    put_u8(buf, ct.level as u8);
    put_f64(buf, ct.scale);
    put_poly(buf, &ct.c0);
    put_poly(buf, &ct.c1);
}

fn get_ciphertext(r: &mut ByteReader<'_>, ctx: &CkksContext) -> Result<Ciphertext, CodecError> {
    let level = r.get_u8()? as usize;
    if level >= ctx.params.moduli.len() {
        return Err(CodecError::BadValue("ciphertext level exceeds modulus chain"));
    }
    let scale = r.get_f64()?;
    if !scale.is_finite() || scale <= 0.0 {
        return Err(CodecError::BadValue("ciphertext scale not finite positive"));
    }
    let c0 = get_poly(r, ctx)?;
    let c1 = get_poly(r, ctx)?;
    for p in [&c0, &c1] {
        if p.level != level || p.special || !p.is_ntt {
            return Err(CodecError::BadValue(
                "ciphertext polys must be NTT, no special limb, at the ciphertext level",
            ));
        }
    }
    Ok(Ciphertext {
        c0,
        c1,
        level,
        scale,
    })
}

fn put_ksw(buf: &mut Vec<u8>, k: &KswKey) {
    put_u32(buf, k.b.len() as u32);
    for p in &k.b {
        put_poly(buf, p);
    }
    for p in &k.a {
        put_poly(buf, p);
    }
}

fn get_ksw(r: &mut ByteReader<'_>, ctx: &CkksContext) -> Result<KswKey, CodecError> {
    let pairs = r.get_u32()? as usize;
    if pairs == 0 || pairs > MAX_KSW_PAIRS {
        return Err(CodecError::BadValue("key-switch pair count out of range"));
    }
    let max_level = ctx.params.max_level();
    let mut read_side = |r: &mut ByteReader<'_>| -> Result<Vec<RnsPoly>, CodecError> {
        (0..pairs)
            .map(|_| {
                let p = get_poly(r, ctx)?;
                // Key polys live in the full basis: max level, special
                // limb appended, NTT form.
                if p.level != max_level || !p.special || !p.is_ntt {
                    return Err(CodecError::BadValue(
                        "key poly must be NTT at max level with special limb",
                    ));
                }
                Ok(p)
            })
            .collect()
    };
    let b = read_side(r)?;
    let a = read_side(r)?;
    Ok(KswKey { b, a })
}

fn put_galois(buf: &mut Vec<u8>, gk: &GaloisKeys) {
    // Deterministic order (sorted steps) so equal key sets encode
    // byte-identically.
    let mut steps: Vec<usize> = gk.keys.keys().copied().collect();
    steps.sort_unstable();
    put_u32(buf, steps.len() as u32);
    for step in steps {
        put_u32(buf, step as u32);
        put_ksw(buf, &gk.keys[&step]);
    }
}

fn get_galois(r: &mut ByteReader<'_>, ctx: &CkksContext) -> Result<GaloisKeys, CodecError> {
    let count = r.get_u32()? as usize;
    if count > MAX_GALOIS_KEYS {
        return Err(CodecError::BadValue("too many Galois keys"));
    }
    let slots = ctx.n() / 2;
    let two_n = 2 * ctx.n();
    let mut keys = HashMap::with_capacity(count);
    let mut elements = HashMap::with_capacity(count);
    for _ in 0..count {
        let step = r.get_u32()? as usize;
        if step == 0 || step >= slots {
            return Err(CodecError::BadValue("rotation step out of range"));
        }
        let ksw = get_ksw(r, ctx)?;
        if keys.insert(step, ksw).is_some() {
            return Err(CodecError::BadValue("duplicate rotation step"));
        }
        // Never trust wire elements: recompute 5^step mod 2N.
        elements.insert(step, galois_element(step, two_n));
    }
    Ok(GaloisKeys { keys, elements })
}

fn put_eval_keys(buf: &mut Vec<u8>, keys: &EvalKeys) {
    put_ksw(buf, &keys.relin.0);
    put_galois(buf, &keys.galois);
}

fn get_eval_keys(r: &mut ByteReader<'_>, ctx: &CkksContext) -> Result<EvalKeys, CodecError> {
    let relin = RelinKey(get_ksw(r, ctx)?);
    let galois = get_galois(r, ctx)?;
    Ok(EvalKeys { relin, galois })
}

/// Byte encoding of one session's evaluation keys — the keycache
/// spill tier's on-disk format ([`crate::keycache::spill`]). Same
/// layout as the wire's key upload, prefixed with the session id so a
/// reload can verify a file belongs to the session it was looked up
/// for (defense against renamed/aliased spill files).
pub fn encode_session_keys(id: u64, relin: &RelinKey, galois: &GaloisKeys) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u64(&mut buf, id);
    put_ksw(&mut buf, &relin.0);
    put_galois(&mut buf, galois);
    buf
}

/// Decode [`encode_session_keys`] bytes with full wire-grade
/// validation: every residue checked against the modulus chain, key
/// polys required to be full-basis NTT with the special limb, Galois
/// elements recomputed from the steps, and no trailing bytes. Returns
/// the embedded session id alongside the keys; the caller must check
/// it matches the id it asked for.
pub fn decode_session_keys(
    payload: &[u8],
    ctx: &CkksContext,
) -> Result<(u64, RelinKey, GaloisKeys), CodecError> {
    let mut r = ByteReader::new(payload);
    let id = r.get_u64()?;
    let relin = RelinKey(get_ksw(&mut r, ctx)?);
    let galois = get_galois(&mut r, ctx)?;
    r.finish()?;
    Ok((id, relin, galois))
}

fn put_enc_scores(buf: &mut Vec<u8>, s: &EncScores) {
    put_u32(buf, s.scores.len() as u32);
    for ct in &s.scores {
        put_ciphertext(buf, ct);
    }
    put_u32(buf, s.slot as u32);
}

fn get_enc_scores(r: &mut ByteReader<'_>, ctx: &CkksContext) -> Result<EncScores, CodecError> {
    let count = r.get_u32()? as usize;
    if count == 0 || count > MAX_SCORES {
        return Err(CodecError::BadValue("score ciphertext count out of range"));
    }
    let scores = (0..count)
        .map(|_| get_ciphertext(r, ctx))
        .collect::<Result<Vec<_>, _>>()?;
    let slot = r.get_u32()? as usize;
    if slot >= ctx.n() / 2 {
        return Err(CodecError::BadValue("score slot out of range"));
    }
    Ok(EncScores { scores, slot })
}

// ------------------------------------------- observability payloads

fn put_duration_us(buf: &mut Vec<u8>, d: Duration) {
    put_u64(buf, d.as_micros() as u64);
}

fn get_duration_us(r: &mut ByteReader<'_>) -> Result<Duration, CodecError> {
    Ok(Duration::from_micros(r.get_u64()?))
}

/// Encode a [`MetricsSnapshot`] in struct declaration order: `u64`
/// counters verbatim, `f64` as bits, `Duration`s as whole µs.
fn put_metrics_snapshot(buf: &mut Vec<u8>, s: &MetricsSnapshot) {
    put_u64(buf, s.encrypted_completed);
    put_u64(buf, s.plain_completed);
    put_u64(buf, s.rejected_backpressure);
    put_u64(buf, s.rejected_no_session);
    put_u64(buf, s.rejected_keys_evicted);
    put_u64(buf, s.batches_flushed);
    put_f64(buf, s.mean_batch_fill);
    put_f64(buf, s.batch_fill_ratio);
    put_u64(buf, s.enc_batches_flushed);
    put_f64(buf, s.mean_enc_batch_fill);
    put_f64(buf, s.enc_batch_fill_ratio);
    put_u64(buf, s.enc_queue_depth);
    put_u64(buf, s.net_connections_accepted);
    put_u64(buf, s.net_connections_open);
    put_u64(buf, s.net_rejected_overload);
    put_u64(buf, s.keycache_hits);
    put_u64(buf, s.keycache_misses);
    put_u64(buf, s.keycache_evictions);
    put_u64(buf, s.keycache_resident_bytes);
    put_duration_us(buf, s.encrypted_mean);
    put_duration_us(buf, s.encrypted_p50);
    put_duration_us(buf, s.encrypted_p95);
    put_duration_us(buf, s.encrypted_p99);
    put_duration_us(buf, s.plain_mean);
    put_duration_us(buf, s.plain_p50);
    put_duration_us(buf, s.plain_p95);
    put_duration_us(buf, s.plain_p99);
    put_duration_us(buf, s.encrypted_queue_mean);
    put_duration_us(buf, s.encrypted_queue_p95);
    put_duration_us(buf, s.encrypted_service_mean);
    put_duration_us(buf, s.encrypted_service_p95);
    put_duration_us(buf, s.plain_queue_mean);
    put_duration_us(buf, s.plain_service_mean);
    put_u64(buf, s.traces_recorded);
    put_u64(buf, s.traces_dropped);
    put_u64(buf, s.dag_ops);
    put_u64(buf, s.dag_waves);
    put_u64(buf, s.dag_width);
    put_u64(buf, s.slab_resident_bytes);
    put_u64(buf, s.slab_hits);
    put_u64(buf, s.slab_misses);
    put_u64(buf, s.keycache_spilled_bytes);
    put_u64(buf, s.keycache_spill_hits);
    put_u64(buf, s.keycache_spill_corrupt);
}

fn get_metrics_snapshot(r: &mut ByteReader<'_>) -> Result<MetricsSnapshot, CodecError> {
    Ok(MetricsSnapshot {
        encrypted_completed: r.get_u64()?,
        plain_completed: r.get_u64()?,
        rejected_backpressure: r.get_u64()?,
        rejected_no_session: r.get_u64()?,
        rejected_keys_evicted: r.get_u64()?,
        batches_flushed: r.get_u64()?,
        mean_batch_fill: r.get_f64()?,
        batch_fill_ratio: r.get_f64()?,
        enc_batches_flushed: r.get_u64()?,
        mean_enc_batch_fill: r.get_f64()?,
        enc_batch_fill_ratio: r.get_f64()?,
        enc_queue_depth: r.get_u64()?,
        net_connections_accepted: r.get_u64()?,
        net_connections_open: r.get_u64()?,
        net_rejected_overload: r.get_u64()?,
        keycache_hits: r.get_u64()?,
        keycache_misses: r.get_u64()?,
        keycache_evictions: r.get_u64()?,
        keycache_resident_bytes: r.get_u64()?,
        encrypted_mean: get_duration_us(r)?,
        encrypted_p50: get_duration_us(r)?,
        encrypted_p95: get_duration_us(r)?,
        encrypted_p99: get_duration_us(r)?,
        plain_mean: get_duration_us(r)?,
        plain_p50: get_duration_us(r)?,
        plain_p95: get_duration_us(r)?,
        plain_p99: get_duration_us(r)?,
        encrypted_queue_mean: get_duration_us(r)?,
        encrypted_queue_p95: get_duration_us(r)?,
        encrypted_service_mean: get_duration_us(r)?,
        encrypted_service_p95: get_duration_us(r)?,
        plain_queue_mean: get_duration_us(r)?,
        plain_service_mean: get_duration_us(r)?,
        traces_recorded: r.get_u64()?,
        traces_dropped: r.get_u64()?,
        dag_ops: r.get_u64()?,
        dag_waves: r.get_u64()?,
        dag_width: r.get_u64()?,
        slab_resident_bytes: r.get_u64()?,
        slab_hits: r.get_u64()?,
        slab_misses: r.get_u64()?,
        keycache_spilled_bytes: r.get_u64()?,
        keycache_spill_hits: r.get_u64()?,
        keycache_spill_corrupt: r.get_u64()?,
    })
}

fn put_trace_record(buf: &mut Vec<u8>, t: &TraceRecord) {
    put_u64(buf, t.id);
    put_u8(
        buf,
        match t.kind {
            TraceKind::Encrypted => 0,
            TraceKind::Packed => 1,
            TraceKind::Plain => 2,
        },
    );
    match t.flush {
        Some((fid, group)) => {
            put_u8(buf, 1);
            put_u64(buf, fid);
            put_u32(buf, group);
        }
        None => put_u8(buf, 0),
    }
    for p in &t.phases {
        match p {
            Some(us) => {
                put_u8(buf, 1);
                put_u64(buf, *us);
            }
            None => put_u8(buf, 0),
        }
    }
}

fn get_trace_record(r: &mut ByteReader<'_>) -> Result<TraceRecord, CodecError> {
    let id = r.get_u64()?;
    let kind = match r.get_u8()? {
        0 => TraceKind::Encrypted,
        1 => TraceKind::Packed,
        2 => TraceKind::Plain,
        tag => {
            return Err(CodecError::BadTag {
                context: "trace kind",
                tag,
            })
        }
    };
    let flush = if r.get_bool("trace flush flag")? {
        Some((r.get_u64()?, r.get_u32()?))
    } else {
        None
    };
    let mut phases = [None; N_PHASES];
    for p in phases.iter_mut() {
        if r.get_bool("trace phase flag")? {
            *p = Some(r.get_u64()?);
        }
    }
    Ok(TraceRecord {
        id,
        kind,
        flush,
        phases,
    })
}

// ------------------------------------------------------------ messages

/// Model facts a client needs before it can build requests.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfo {
    /// CKKS parameter preset name (client must use matching params).
    pub params_name: String,
    /// Ring degree N.
    pub n: u32,
    /// Input features the model expects (`plan.d`).
    pub features: u32,
    /// Sample groups per ciphertext (max packed batch).
    pub groups: u32,
    /// Output classes.
    pub classes: u32,
    /// Rotation steps a session's Galois keys must cover for the
    /// server's configured batching target
    /// (`HrfServer::eval_key_requirements`).
    pub rotations: Vec<u32>,
}

/// Client → server messages.
#[derive(Debug)]
pub enum Request {
    /// Describe the served model (no session needed).
    ModelInfo,
    /// Upload evaluation keys; the response carries the session id.
    RegisterKeys { keys: EvalKeys },
    /// Re-upload keys for an existing id after `KeysEvicted`.
    Reregister { session_id: u64, keys: EvalKeys },
    /// One encrypted observation (`HrfClient::encrypt_input` layout).
    SubmitEncrypted { session_id: u64, ct: Ciphertext },
    /// Client-side packed group (`HrfClient::encrypt_batch` layout).
    SubmitEncryptedPacked {
        session_id: u64,
        ct: Ciphertext,
        n_samples: u32,
    },
    /// Plaintext fast path (features, not slots).
    SubmitPlain { x: Vec<f64> },
    /// Ask the server to stop accepting and shut down cleanly.
    Shutdown,
    /// Scrape the coordinator's [`MetricsSnapshot`] (no session
    /// needed; counters, latency quantiles, queue/service split).
    MetricsSnapshot,
    /// Drain a copy of the span-trace ring (oldest → newest); empty
    /// when the server runs with tracing disabled.
    TraceDump,
}

/// Errors a server reports over the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Typed coordinator refusal (`Busy`, `KeysEvicted`, …) — the
    /// recovery protocol is the same as in-process.
    Submit(SubmitError),
    /// Server-side failure outside the submit protocol.
    Server(String),
    /// The server could not decode the request.
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Submit(e) => write!(f, "submit refused: {e}"),
            WireError::Server(s) => write!(f, "server error: {s}"),
            WireError::Protocol(s) => write!(f, "protocol error: {s}"),
        }
    }
}

/// Server → client messages.
#[derive(Debug)]
pub enum Response {
    ModelInfo(ModelInfo),
    Registered { session_id: u64 },
    /// `ok = false`: the id was never registered (or was removed).
    Reregistered { ok: bool },
    /// Encrypted scores (`EncScores`: per-class ciphertexts + slot).
    EncScores(EncScores),
    /// Plaintext-path scores.
    PlainScores(Vec<f64>),
    Error(WireError),
    /// Acknowledges a `Shutdown` request; the server stops accepting.
    ShuttingDown,
    /// Reply to `Request::MetricsSnapshot`.
    Metrics(MetricsSnapshot),
    /// Reply to `Request::TraceDump`.
    Traces(Vec<TraceRecord>),
}

fn put_submit_error(buf: &mut Vec<u8>, e: SubmitError) {
    let tag = match e {
        SubmitError::Busy => 0u8,
        SubmitError::Closed => 1,
        SubmitError::NoSession => 2,
        SubmitError::KeysEvicted => 3,
        SubmitError::BatchTooLarge => 4,
    };
    put_u8(buf, tag);
}

fn get_submit_error(r: &mut ByteReader<'_>) -> Result<SubmitError, CodecError> {
    match r.get_u8()? {
        0 => Ok(SubmitError::Busy),
        1 => Ok(SubmitError::Closed),
        2 => Ok(SubmitError::NoSession),
        3 => Ok(SubmitError::KeysEvicted),
        4 => Ok(SubmitError::BatchTooLarge),
        tag => Err(CodecError::BadTag {
            context: "submit error",
            tag,
        }),
    }
}

/// Encode a request into a frame payload.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut buf = Vec::new();
    match req {
        Request::ModelInfo => put_u8(&mut buf, 1),
        Request::RegisterKeys { keys } => {
            put_u8(&mut buf, 2);
            put_eval_keys(&mut buf, keys);
        }
        Request::Reregister { session_id, keys } => {
            put_u8(&mut buf, 3);
            put_u64(&mut buf, *session_id);
            put_eval_keys(&mut buf, keys);
        }
        Request::SubmitEncrypted { session_id, ct } => {
            put_u8(&mut buf, 4);
            put_u64(&mut buf, *session_id);
            put_ciphertext(&mut buf, ct);
        }
        Request::SubmitEncryptedPacked {
            session_id,
            ct,
            n_samples,
        } => {
            put_u8(&mut buf, 5);
            put_u64(&mut buf, *session_id);
            put_u32(&mut buf, *n_samples);
            put_ciphertext(&mut buf, ct);
        }
        Request::SubmitPlain { x } => {
            put_u8(&mut buf, 6);
            put_u32(&mut buf, x.len() as u32);
            for &v in x {
                put_f64(&mut buf, v);
            }
        }
        Request::Shutdown => put_u8(&mut buf, 7),
        Request::MetricsSnapshot => put_u8(&mut buf, 8),
        Request::TraceDump => put_u8(&mut buf, 9),
    }
    buf
}

/// Decode a request frame payload against the server's context.
pub fn decode_request(payload: &[u8], ctx: &CkksContext) -> Result<Request, CodecError> {
    let mut r = ByteReader::new(payload);
    let req = match r.get_u8()? {
        1 => Request::ModelInfo,
        2 => Request::RegisterKeys {
            keys: get_eval_keys(&mut r, ctx)?,
        },
        3 => Request::Reregister {
            session_id: r.get_u64()?,
            keys: get_eval_keys(&mut r, ctx)?,
        },
        4 => Request::SubmitEncrypted {
            session_id: r.get_u64()?,
            ct: get_ciphertext(&mut r, ctx)?,
        },
        5 => {
            let session_id = r.get_u64()?;
            let n_samples = r.get_u32()?;
            let ct = get_ciphertext(&mut r, ctx)?;
            Request::SubmitEncryptedPacked {
                session_id,
                ct,
                n_samples,
            }
        }
        6 => {
            let len = r.get_u32()? as usize;
            if len > MAX_PLAIN_FEATURES {
                return Err(CodecError::BadValue("feature vector too long"));
            }
            let x = (0..len)
                .map(|_| r.get_f64())
                .collect::<Result<Vec<_>, _>>()?;
            Request::SubmitPlain { x }
        }
        7 => Request::Shutdown,
        8 => Request::MetricsSnapshot,
        9 => Request::TraceDump,
        tag => return Err(CodecError::BadTag { context: "request", tag }),
    };
    r.finish()?;
    Ok(req)
}

/// Encode a response into a frame payload.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut buf = Vec::new();
    match resp {
        Response::ModelInfo(info) => {
            put_u8(&mut buf, 1);
            put_str(&mut buf, &info.params_name);
            put_u32(&mut buf, info.n);
            put_u32(&mut buf, info.features);
            put_u32(&mut buf, info.groups);
            put_u32(&mut buf, info.classes);
            put_u32(&mut buf, info.rotations.len() as u32);
            for &rot in &info.rotations {
                put_u32(&mut buf, rot);
            }
        }
        Response::Registered { session_id } => {
            put_u8(&mut buf, 2);
            put_u64(&mut buf, *session_id);
        }
        Response::Reregistered { ok } => {
            put_u8(&mut buf, 3);
            put_u8(&mut buf, *ok as u8);
        }
        Response::EncScores(s) => {
            put_u8(&mut buf, 4);
            put_enc_scores(&mut buf, s);
        }
        Response::PlainScores(scores) => {
            put_u8(&mut buf, 5);
            put_u32(&mut buf, scores.len() as u32);
            for &v in scores {
                put_f64(&mut buf, v);
            }
        }
        Response::Error(e) => {
            put_u8(&mut buf, 6);
            match e {
                WireError::Submit(se) => {
                    put_u8(&mut buf, 0);
                    put_submit_error(&mut buf, *se);
                }
                WireError::Server(s) => {
                    put_u8(&mut buf, 1);
                    put_str(&mut buf, s);
                }
                WireError::Protocol(s) => {
                    put_u8(&mut buf, 2);
                    put_str(&mut buf, s);
                }
            }
        }
        Response::ShuttingDown => put_u8(&mut buf, 7),
        Response::Metrics(s) => {
            put_u8(&mut buf, 8);
            put_metrics_snapshot(&mut buf, s);
        }
        Response::Traces(traces) => {
            put_u8(&mut buf, 9);
            put_u32(&mut buf, traces.len() as u32);
            for t in traces {
                put_trace_record(&mut buf, t);
            }
        }
    }
    buf
}

/// Decode a response frame payload against the client's context.
pub fn decode_response(payload: &[u8], ctx: &CkksContext) -> Result<Response, CodecError> {
    let mut r = ByteReader::new(payload);
    let resp = match r.get_u8()? {
        1 => {
            let params_name = r.get_str()?;
            let n = r.get_u32()?;
            let features = r.get_u32()?;
            let groups = r.get_u32()?;
            let classes = r.get_u32()?;
            let count = r.get_u32()? as usize;
            if count > MAX_ROTATIONS {
                return Err(CodecError::BadValue("too many advertised rotations"));
            }
            let rotations = (0..count)
                .map(|_| r.get_u32())
                .collect::<Result<Vec<_>, _>>()?;
            Response::ModelInfo(ModelInfo {
                params_name,
                n,
                features,
                groups,
                classes,
                rotations,
            })
        }
        2 => Response::Registered {
            session_id: r.get_u64()?,
        },
        3 => Response::Reregistered {
            ok: r.get_bool("reregistered flag")?,
        },
        4 => Response::EncScores(get_enc_scores(&mut r, ctx)?),
        5 => {
            let len = r.get_u32()? as usize;
            if len > MAX_SCORES {
                return Err(CodecError::BadValue("score vector too long"));
            }
            let scores = (0..len)
                .map(|_| r.get_f64())
                .collect::<Result<Vec<_>, _>>()?;
            Response::PlainScores(scores)
        }
        6 => {
            let e = match r.get_u8()? {
                0 => WireError::Submit(get_submit_error(&mut r)?),
                1 => WireError::Server(r.get_str()?),
                2 => WireError::Protocol(r.get_str()?),
                tag => return Err(CodecError::BadTag { context: "wire error", tag }),
            };
            Response::Error(e)
        }
        7 => Response::ShuttingDown,
        8 => Response::Metrics(get_metrics_snapshot(&mut r)?),
        9 => {
            let count = r.get_u32()? as usize;
            if count > MAX_TRACES {
                return Err(CodecError::BadValue("too many trace records"));
            }
            let traces = (0..count)
                .map(|_| get_trace_record(&mut r))
                .collect::<Result<Vec<_>, _>>()?;
            Response::Traces(traces)
        }
        tag => return Err(CodecError::BadTag { context: "response", tag }),
    };
    r.finish()?;
    Ok(resp)
}
