//! Deterministic demo model shared by `cryptotree-serve` and
//! `cryptotree-loadgen`.
//!
//! The wire protocol ships ciphertexts and keys, not models — the
//! client must encrypt against the *same* packing plan the server
//! evaluates. Both binaries therefore rebuild the model from the same
//! flags (`--params/--trees/--depth/--rows/--seed`): every stage is
//! seeded, so equal flags give bit-identical models in different
//! processes. (A client can sanity-check the match via
//! [`crate::net::codec::ModelInfo`]: parameter preset name, ring
//! degree, feature count.)

use crate::ckks::params::ParamsRef;
use crate::ckks::rns::{CkksContext, ContextRef};
use crate::ckks::CkksParams;
use crate::data::{adult, Dataset};
use crate::forest::tree::TreeConfig;
use crate::forest::{RandomForest, RandomForestConfig};
use crate::hrf::{HrfModel, HrfServer};
use crate::net::args::Args;
use crate::nrf::activation::Activation;
use crate::nrf::NeuralForest;
use std::sync::Arc;

/// Everything the flags determine, parsed once.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    /// Parameter preset: `demo` (default, depth-4 N=4096), `toy`,
    /// `fast`, `secure`, or anything else for the paper's default.
    pub params: String,
    /// Forest size.
    pub trees: usize,
    /// Tree depth cap.
    pub depth: usize,
    /// Synthetic Adult-Income rows to generate.
    pub rows: usize,
    /// Master seed (data, forest fit, keygen offsets derive from it).
    pub seed: u64,
}

impl WorkloadSpec {
    /// Read the shared model flags (both binaries accept the same
    /// set, so a serve line can be turned into a loadgen line by
    /// swapping the binary name).
    pub fn from_args(args: &Args) -> Self {
        WorkloadSpec {
            params: args.get_str("params", "demo"),
            trees: args.get("trees", 4usize),
            depth: args.get("depth", 2usize),
            rows: args.get("rows", 200usize),
            seed: args.get("seed", 615u64),
        }
    }
}

/// A built serving workload: CKKS context, HRF server, and the
/// dataset the load generator draws observations from.
pub struct Workload {
    pub params: ParamsRef,
    pub ctx: ContextRef,
    pub server: Arc<HrfServer>,
    pub data: Dataset,
}

/// Resolve a `--params` flag value to a parameter preset.
pub fn params_by_name(name: &str) -> ParamsRef {
    match name {
        // Serving demo: shallow chain keeps keygen and per-request
        // HE work small enough for CI smoke runs.
        "demo" => Arc::new(CkksParams::build("serve-n4096-d4", 4096, 60, 40, 4, 3.2)),
        "toy" => CkksParams::toy(),
        "fast" => CkksParams::fast(),
        "secure" => CkksParams::secure128(),
        _ => CkksParams::hrf_default(),
    }
}

/// Build the workload for a spec. Deterministic: same spec → same
/// model, in any process.
pub fn build(spec: &WorkloadSpec) -> Workload {
    let params = params_by_name(&spec.params);
    let ctx = CkksContext::new(params.clone());
    let data = adult::generate(spec.rows, spec.seed);
    let rf = RandomForest::fit(
        &data,
        &RandomForestConfig {
            n_trees: spec.trees,
            tree: TreeConfig {
                max_depth: spec.depth,
                ..Default::default()
            },
            ..Default::default()
        },
        spec.seed + 1,
    );
    // Identity activation: serving-tier work is dominated by the wire
    // and the HE linear algebra; a deeper activation only raises the
    // level budget without exercising more of the protocol.
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: vec![0.0, 1.0],
        },
    );
    let model = HrfModel::from_neural_forest(&nf, data.n_features(), params.slots())
        .expect("workload model must fit the slot budget");
    Workload {
        params,
        ctx,
        server: Arc::new(HrfServer::new(model)),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_spec_same_model() {
        let spec = WorkloadSpec {
            params: "demo".to_string(),
            trees: 2,
            depth: 2,
            rows: 64,
            seed: 7,
        };
        let a = build(&spec);
        let b = build(&spec);
        assert_eq!(a.params.name, b.params.name);
        assert_eq!(a.server.model.plan, b.server.model.plan);
        assert_eq!(a.data.x, b.data.x);
        // The packed operands themselves must agree, not just shapes:
        // clients encrypt against their local copy of the plan.
        assert_eq!(
            a.server.eval_key_requirements(2),
            b.server.eval_key_requirements(2)
        );
    }
}
