//! Key material: secret / public / relinearization / Galois keys, and
//! the hybrid key-switching core they share.
//!
//! Key-switching (the expensive primitive behind both relinearization
//! and slot rotation) uses per-RNS-limb decomposition with one special
//! prime `P` (SEAL-style, `dnum = L`):
//!
//! For a source secret `s'` (either `s²` or `s(X^g)`) the switching key
//! is, per chain limb `j`:
//!
//! ```text
//!   ksk_j = ( -a_j·s + e_j + P·T_j·s' ,  a_j )   over basis Q·P
//! ```
//!
//! with `T_j = (Q/q_j)·[(Q/q_j)^{-1}]_{q_j}` the CRT unit (≡ δ_ij mod
//! q_i). Switching a component `d` (mod `Q_ℓ`) computes
//! `Σ_j [d]_{q_j} · ksk_j`, then divides by `P` (mod-down). The noise
//! added is ≈ `(ℓ+1)·N·q_max·σ / P` — about 2^-6 for default
//! parameters, i.e. far below the encoding scale.

use super::kernels;
use super::modops::{barrett_reduce_64, galois_element, mul_mod};
use super::parallel;
use super::rns::{CkksContext, RnsPoly};
use super::scratch::Scratch;
use crate::rng::Xoshiro256pp;
use std::collections::HashMap;

/// Secret key: ternary `s`, stored in NTT form over the full basis
/// (all chain primes + special).
#[derive(Clone)]
pub struct SecretKey {
    pub s: RnsPoly,
}

/// Public key `(b, a)` with `b = -a·s + e`, NTT form, full chain (no
/// special limb).
#[derive(Clone)]
pub struct PublicKey {
    pub b: RnsPoly,
    pub a: RnsPoly,
}

/// One key-switching key: per chain limb `j`, a pair over basis Q·P.
#[derive(Clone, Debug)]
pub struct KswKey {
    /// b_j components (NTT, special limb last).
    pub b: Vec<RnsPoly>,
    /// a_j components (NTT, special limb last).
    pub a: Vec<RnsPoly>,
}

/// Relinearization key: switch `s²` → `s`.
#[derive(Clone, Debug)]
pub struct RelinKey(pub KswKey);

/// Galois keys: rotation step → switching key for `s(X^{5^r})` → `s`.
#[derive(Clone, Debug)]
pub struct GaloisKeys {
    pub keys: HashMap<usize, KswKey>,
    /// Galois element per rotation step (5^r mod 2N).
    pub elements: HashMap<usize, usize>,
}

impl GaloisKeys {
    /// Rotation steps this key set covers, in canonical form (sorted,
    /// deduplicated) — usable directly as a cache key.
    pub fn supported_rotations(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.keys.keys().copied().collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Exact resident byte count: the limb payload of every rotation's
    /// switching key plus the Galois-element table. This is what the
    /// `keycache` subsystem charges a session for its Galois keys.
    pub fn key_bytes(&self) -> usize {
        self.keys.values().map(KswKey::key_bytes).sum::<usize>()
            + self.elements.len() * 2 * std::mem::size_of::<usize>()
    }
}

/// Canonical form of a rotation-step request: sorted, deduplicated,
/// zero steps dropped. Key generation consumes this form, so two
/// sessions asking for the same steps in any order or multiplicity
/// produce the same key set — and identical `key_bytes()` accounting.
pub fn canonical_rotations(rotations: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = rotations.iter().copied().filter(|&r| r != 0).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Heap bytes of one RNS polynomial's residue limbs — the payload that
/// dominates key memory (per-key metadata is a few machine words).
fn poly_bytes(p: &RnsPoly) -> usize {
    p.data().len() * std::mem::size_of::<u64>()
}

impl KswKey {
    /// Exact resident byte count of this switching key's limb payload.
    pub fn key_bytes(&self) -> usize {
        self.b.iter().chain(self.a.iter()).map(poly_bytes).sum()
    }
}

impl RelinKey {
    /// Exact resident byte count (see [`KswKey::key_bytes`]).
    pub fn key_bytes(&self) -> usize {
        self.0.key_bytes()
    }
}

/// Generates all key material from a seeded RNG (client side).
pub struct KeyGenerator {
    sk: SecretKey,
    rng: Xoshiro256pp,
}

impl KeyGenerator {
    pub fn new(ctx: &CkksContext, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let max = ctx.params.max_level();
        let mut s = RnsPoly::sample_ternary(ctx, &mut rng, max, true);
        s.to_ntt(ctx);
        KeyGenerator {
            sk: SecretKey { s },
            rng,
        }
    }

    pub fn secret_key(&self) -> SecretKey {
        self.sk.clone()
    }

    pub fn gen_public_key(&mut self, ctx: &CkksContext) -> PublicKey {
        let max = ctx.params.max_level();
        let a = RnsPoly::sample_uniform(ctx, &mut self.rng, max, false, true);
        let mut e = RnsPoly::sample_error(ctx, &mut self.rng, max, false);
        e.to_ntt(ctx);
        // b = -a*s + e
        let mut s = self.sk.s.clone();
        s.restrict(max);
        let mut b = a.clone();
        b.mul_assign(ctx, &s);
        b.neg_assign(ctx);
        b.add_assign(ctx, &e);
        PublicKey { b, a }
    }

    /// Core: generate a switching key for source secret `s_src`
    /// (full-basis NTT poly) → the generator's secret `s`.
    fn gen_ksw(&mut self, ctx: &CkksContext, s_src: &RnsPoly) -> KswKey {
        let max = ctx.params.max_level();
        let n_chain = max + 1;
        let p_special = ctx.params.special;
        // The key embeds P·T_j·s_src, where T_j = (Q/q_j)·[(Q/q_j)^{-1}]_{q_j}
        // is the CRT unit: T_j ≡ δ_ij (mod q_i). Residues of P·T_j:
        //   mod q_i (i≠j): 0       mod q_j: P mod q_j       mod P: 0
        // so the scalar is (P mod q_j) on limb j and 0 elsewhere.
        let mut bs = Vec::with_capacity(n_chain);
        let mut as_ = Vec::with_capacity(n_chain);
        let full_s = &self.sk.s; // level=max, special=true, NTT
        for j in 0..n_chain {
            let a_j = RnsPoly::sample_uniform(ctx, &mut self.rng, max, true, true);
            let mut e_j = RnsPoly::sample_error(ctx, &mut self.rng, max, true);
            e_j.to_ntt(ctx);
            // b_j = -a_j*s + e_j + P*T_j*s_src
            let mut b_j = a_j.clone();
            b_j.mul_assign(ctx, full_s);
            b_j.neg_assign(ctx);
            b_j.add_assign(ctx, &e_j);
            // P*T_j mod q_i = (P mod q_i) * (T_j mod q_i) = (P mod q_i)*δ_ij
            // P*T_j mod P = 0
            let mut pt_s = s_src.clone();
            // multiply limb-wise by the scalar (P*T_j mod modulus of limb)
            {
                let n_limbs = pt_s.active_limbs();
                for li in 0..n_limbs {
                    let is_special = li == n_limbs - 1;
                    // P*T_j mod q_i = (P mod q_i)·δ_ij ; P*T_j mod P = 0,
                    // so the special limb and all limbs i≠j become zero.
                    if is_special || li != j {
                        pt_s.limb_mut(li).fill(0);
                    } else {
                        let modulus = ctx.q(li);
                        let scalar = p_special % modulus;
                        for x in pt_s.limb_mut(li).iter_mut() {
                            *x = mul_mod(*x, scalar, modulus);
                        }
                    }
                }
            }
            b_j.add_assign(ctx, &pt_s);
            bs.push(b_j);
            as_.push(a_j);
        }
        KswKey { b: bs, a: as_ }
    }

    /// Relinearization key (s² → s).
    pub fn gen_relin_key(&mut self, ctx: &CkksContext) -> RelinKey {
        let mut s2 = self.sk.s.clone();
        let s_copy = self.sk.s.clone();
        s2.mul_assign(ctx, &s_copy);
        RelinKey(self.gen_ksw(ctx, &s2))
    }

    /// Galois keys for the given left-rotation steps. The request is
    /// canonicalized first ([`canonical_rotations`]): duplicates and
    /// zero steps are ignored, and generation order is the sorted
    /// order, so equal requests yield equal key sets byte-for-byte.
    pub fn gen_galois_keys(&mut self, ctx: &CkksContext, rotations: &[usize]) -> GaloisKeys {
        let two_n = 2 * ctx.n();
        let mut keys = HashMap::new();
        let mut elements = HashMap::new();
        for r in canonical_rotations(rotations) {
            let g = galois_element(r, two_n);
            // source secret: s(X^g)
            let mut s_rot = self.sk.s.clone();
            s_rot.automorphism(ctx, g);
            let ksw = self.gen_ksw(ctx, &s_rot);
            keys.insert(r, ksw);
            elements.insert(r, g);
        }
        GaloisKeys { keys, elements }
    }
}

/// Apply a switching key to a component `d` (mod Q_ℓ, NTT form):
/// returns `(c0', c1')` at the same level such that
/// `c0' + c1'·s ≈ d·s_src`.
///
/// Hot path: the per-digit products are multiply-accumulated straight
/// against the stored key limbs (no key clones — §Perf step 1), and
/// mod-down stays in the NTT domain except for the special limb
/// (§Perf step 2).
pub fn apply_ksw(
    ctx: &CkksContext,
    d: &RnsPoly,
    ksw: &KswKey,
    scratch: &mut Scratch,
) -> (RnsPoly, RnsPoly) {
    debug_assert!(d.is_ntt);
    debug_assert!(!d.special);
    let mut d_coeff = d.clone_in(scratch);
    d_coeff.from_ntt(ctx);
    let digits = decompose(ctx, &d_coeff, scratch);
    d_coeff.recycle(scratch);
    let out = apply_ksw_decomposed(ctx, &digits, ksw, scratch);
    for digit in digits {
        digit.recycle(scratch);
    }
    out
}

/// Decompose a coefficient-form poly into its NTT'd RNS digits, each
/// lifted to the full working basis Q_ℓ ∪ {P}. Shared by plain
/// key-switching and hoisted rotations (which reuse one decomposition
/// across many rotations). Digits fan across the context's workers
/// (each digit is independent); the serial path draws its buffers from
/// `scratch`.
pub fn decompose(ctx: &CkksContext, d_coeff: &RnsPoly, scratch: &mut Scratch) -> Vec<RnsPoly> {
    debug_assert!(!d_coeff.is_ntt);
    let level = d_coeff.level;
    let workers = ctx.workers();
    if workers <= 1 {
        (0..=level)
            .map(|j| lift_digit(ctx, d_coeff, j, Some(&mut *scratch)))
            .collect()
    } else {
        parallel::par_map(workers, level + 1, |j| lift_digit(ctx, d_coeff, j, None))
    }
}

/// Lift chain limb `j` of `d_coeff` to the full working basis and NTT
/// it — one key-switch digit. Per-coefficient reductions use the
/// Barrett single-word kernel (the digit values are already < q_j).
fn lift_digit(
    ctx: &CkksContext,
    d_coeff: &RnsPoly,
    j: usize,
    scratch: Option<&mut Scratch>,
) -> RnsPoly {
    let level = d_coeff.level;
    let src = d_coeff.limb(j);
    let mut lifted = match scratch {
        Some(s) => RnsPoly::zero_in(ctx, level, true, false, s),
        None => RnsPoly::zero(ctx, level, true, false),
    };
    let n_limbs = lifted.active_limbs();
    for li in 0..n_limbs {
        let (modulus, r_hi) = if li == n_limbs - 1 {
            (ctx.params.special, ctx.barrett_ratio_special().1)
        } else {
            (ctx.q(li), ctx.barrett_ratio(li).1)
        };
        let dst = lifted.limb_mut(li);
        for (x, &v) in dst.iter_mut().zip(src.iter()) {
            *x = barrett_reduce_64(v, modulus, r_hi);
        }
    }
    // Serial NTT: when digits fan out in parallel, each digit owns one
    // thread already — nesting limb fan-out would oversubscribe.
    lifted.to_ntt_serial(ctx);
    lifted
}

/// Inner product of NTT'd digits with a switching key, followed by
/// mod-down: the core of every key-switch. The multiply-accumulate
/// runs limb-parallel straight against the stored key limbs (no key
/// clones — §Perf step 1) and mod-down stays in the NTT domain except
/// for the special limb (§Perf step 2).
pub fn apply_ksw_decomposed(
    ctx: &CkksContext,
    digits: &[RnsPoly],
    ksw: &KswKey,
    scratch: &mut Scratch,
) -> (RnsPoly, RnsPoly) {
    let level = digits[0].level;
    let max = ctx.params.max_level();
    let mut acc0 = RnsPoly::zero_in(ctx, level, true, true, scratch);
    let mut acc1 = RnsPoly::zero_in(ctx, level, true, true, scratch);
    mac_all(ctx, &mut acc0, digits, &ksw.b, max);
    mac_all(ctx, &mut acc1, digits, &ksw.a, max);
    acc0.mod_down_special_ntt(ctx);
    acc1.mod_down_special_ntt(ctx);
    (acc0, acc1)
}

/// acc += Σ_j digits[j] ⊙ keys[j], mapping the working basis (chain
/// 0..=level + special) onto the key's full basis (chain 0..=max +
/// special). Limb-outer so the limbs fan across workers; within one
/// limb the digits accumulate in index order, so the result is
/// identical for every worker count.
///
/// **Lazy MAC** (§Perf step 7): the per-digit products accumulate into
/// a per-coefficient `(lo, hi)` u128 pair with *no* per-term
/// reductions, then reduce **once** with `barrett_reduce_128` — so the
/// whole inner product performs exactly one Barrett reduction per
/// (coefficient, limb) regardless of digit count, instead of a
/// reduction plus `add_mod` for every digit. Safe because the digit
/// count is bounded by `kernels::mac_headroom(q)` derived from the
/// actual prime width (`params::build` asserts it for every prime;
/// re-asserted here per limb). The single-reduction sum is fully
/// reduced and congruent to the old per-term chain mod q, so the
/// output is bit-identical.
fn mac_all(ctx: &CkksContext, acc: &mut RnsPoly, digits: &[RnsPoly], keys: &[RnsPoly], max: usize) {
    let n_limbs = acc.active_limbs();
    let n = ctx.n();
    debug_assert!(acc.special && n_limbs == acc.level + 2);
    parallel::for_each_limb_with(ctx.workers(), n, acc.data_mut(), |acc128, li, a| {
        let (q, ratio, key_li) = if li == n_limbs - 1 {
            (ctx.params.special, ctx.barrett_ratio_special(), max + 1)
        } else {
            (ctx.q(li), ctx.barrett_ratio(li), li)
        };
        // +1: the carried-in accumulator word joins the product terms.
        debug_assert!(
            digits.len() + 1 <= kernels::mac_headroom(q),
            "digit count exceeds the lazy-MAC headroom for q={q}"
        );
        acc128.clear();
        acc128.resize(2 * n, 0);
        let (lo, hi) = acc128.split_at_mut(n);
        lo.copy_from_slice(a);
        for (digit, key) in digits.iter().zip(keys.iter()) {
            kernels::mac_acc_slice(lo, hi, digit.limb(li), key.limb(key_li), 2 * q);
        }
        kernels::reduce_acc_slice(a, lo, hi, q, ratio);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoder::Encoder;
    use crate::ckks::encrypt::{Decryptor, Encryptor};
    use crate::ckks::params::CkksParams;
    use crate::ckks::rns::CkksContext;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn public_key_relation() {
        // b + a*s should be small (the error poly).
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, 5);
        let pk = kg.gen_public_key(&ctx);
        let mut s = kg.secret_key().s;
        s.restrict(ctx.params.max_level());
        let mut t = pk.a.clone();
        t.mul_assign(&ctx, &s);
        t.add_assign(&ctx, &pk.b);
        t.from_ntt(&ctx);
        let coeffs = t.to_centered_f64(&ctx);
        let max = coeffs.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        assert!(max < 8.0 * ctx.params.sigma, "pk error too large: {max}");
    }

    #[test]
    fn keyswitch_identity() {
        // Switching d with key for s_src=s must return (c0,c1) with
        // c0 + c1*s ≈ d*s.
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, 6);
        let s_full = kg.secret_key().s;
        let ksw = kg.gen_ksw(&ctx, &s_full);

        let mut rng = Xoshiro256pp::new(60);
        let level = ctx.params.max_level();
        let d = RnsPoly::sample_uniform(&ctx, &mut rng, level, false, true);
        let mut scratch = Scratch::new();
        let (c0, c1) = apply_ksw(&ctx, &d, &ksw, &mut scratch);

        let mut s = s_full.clone();
        s.restrict(level);

        // expected = d*s ; got = c0 + c1*s ; difference must be small.
        let mut expected = d.clone();
        expected.mul_assign(&ctx, &s);
        let mut got = c1.clone();
        got.mul_assign(&ctx, &s);
        got.add_assign(&ctx, &c0);
        got.sub_assign(&ctx, &expected);
        got.from_ntt(&ctx);
        let coeffs = got.to_centered_f64(&ctx);
        let max = coeffs.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        // noise bound ≈ (ℓ+1)·N·q·σ/P + mod-down rounding ≈ small
        assert!(max < 1e6, "keyswitch noise too large: {max}");
    }

    #[test]
    fn canonical_rotations_sorts_dedups_drops_zero() {
        assert_eq!(canonical_rotations(&[5, 1, 3, 0, 1, 5]), vec![1, 3, 5]);
        assert_eq!(canonical_rotations(&[]), Vec::<usize>::new());
        assert_eq!(canonical_rotations(&[0, 0]), Vec::<usize>::new());
    }

    #[test]
    fn galois_keygen_ignores_duplicates_and_order() {
        let ctx = CkksContext::new(CkksParams::toy());
        let gk_messy = KeyGenerator::new(&ctx, 9).gen_galois_keys(&ctx, &[3, 1, 3, 0, 1]);
        let gk_clean = KeyGenerator::new(&ctx, 9).gen_galois_keys(&ctx, &[1, 3]);
        assert_eq!(gk_messy.supported_rotations(), vec![1, 3]);
        assert_eq!(
            gk_messy.supported_rotations(),
            gk_clean.supported_rotations()
        );
        // Same seed + canonicalized generation order → byte-identical
        // accounting (and identical key material).
        assert_eq!(gk_messy.key_bytes(), gk_clean.key_bytes());
        for r in [1usize, 3] {
            assert_eq!(
                gk_messy.keys[&r].b[0].limb(0),
                gk_clean.keys[&r].b[0].limb(0),
                "rotation {r}: key material differs"
            );
        }
    }

    #[test]
    fn key_bytes_matches_exact_formula() {
        // KswKey: one (b, a) pair per chain limb, each a full-basis
        // poly of max+2 limbs × N coefficients × 8 bytes.
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, 10);
        let max = ctx.params.max_level();
        let n = ctx.n();
        let ksw_bytes = (max + 1) * 2 * (max + 2) * n * 8;
        let rlk = kg.gen_relin_key(&ctx);
        assert_eq!(rlk.key_bytes(), ksw_bytes);
        let gk = kg.gen_galois_keys(&ctx, &[1, 2, 4]);
        assert_eq!(
            gk.key_bytes(),
            3 * ksw_bytes + 3 * 2 * std::mem::size_of::<usize>()
        );
        // Galois keys dominate a session: more rotations, more bytes.
        let gk_small = kg.gen_galois_keys(&ctx, &[1]);
        assert!(gk_small.key_bytes() < gk.key_bytes());
    }

    #[test]
    fn galois_key_rotation_end_to_end() {
        let ctx = CkksContext::new(CkksParams::toy());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 7);
        let pk = kg.gen_public_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, &[1, 3]);
        let mut encryptor = Encryptor::new(pk, 70);
        let decryptor = Decryptor::new(kg.secret_key());

        let n = enc.slots();
        let z: Vec<f64> = (0..n).map(|i| ((i * 13) % 101) as f64 / 101.0).collect();
        let ct = encryptor.encrypt_slots(&ctx, &enc, &z);

        let mut scratch = Scratch::new();
        for &r in &[1usize, 3] {
            let g = gk.elements[&r];
            let ksw = &gk.keys[&r];
            // rotate: apply automorphism to c0, c1; keyswitch c1.
            let mut c0 = ct.c0.clone();
            let mut c1 = ct.c1.clone();
            c0.automorphism(&ctx, g);
            c1.automorphism(&ctx, g);
            let (k0, k1) = apply_ksw(&ctx, &c1, ksw, &mut scratch);
            let mut r0 = c0;
            r0.add_assign(&ctx, &k0);
            let out = crate::ckks::encrypt::Ciphertext {
                c0: r0,
                c1: k1,
                level: ct.level,
                scale: ct.scale,
            };
            let back = decryptor.decrypt_slots(&ctx, &enc, &out);
            for i in 0..n {
                let expect = z[(i + r) % n];
                assert!(
                    (back[i] - expect).abs() < 1e-5,
                    "rot {r} slot {i}: {} vs {expect}",
                    back[i]
                );
            }
        }
    }
}
