//! CKKS parameter sets and NTT-friendly prime generation.
//!
//! A parameter set fixes the ring degree `N`, the ciphertext modulus
//! chain `q_0, …, q_L` (RNS primes), one special prime `p` used only
//! inside key-switching, and the encoding scale `Δ`.
//!
//! Prime selection: every prime must satisfy `q ≡ 1 (mod 2N)` so the
//! negacyclic NTT exists, and `q < 2^62` so the division-free
//! Barrett/Shoup kernels in [`super::modops`] are exact with a single
//! conditional subtraction (the data plane relies on this bound).
//! Rescaling primes are chosen as close as possible to `Δ` so the
//! scale stays ≈ `Δ` after each rescale (drift is tracked exactly; see
//! `Ciphertext::scale`).

use super::modops::is_prime;
use std::sync::Arc;

/// Fixed parameters for one CKKS context.
#[derive(Clone, Debug)]
pub struct CkksParams {
    /// Ring degree (power of two). Slot count is `N/2`.
    pub n: usize,
    /// Ciphertext modulus chain, `q_0` first. `q_0` is the "anchor"
    /// prime (~2^60); the rest are rescaling primes (~Δ).
    pub moduli: Vec<u64>,
    /// Special prime for hybrid key-switching (~2^60). Never holds
    /// message mass.
    pub special: u64,
    /// Encoding scale Δ (power of two).
    pub scale: f64,
    /// Error std-dev for encryption noise.
    pub sigma: f64,
    /// Human label for reports.
    pub name: &'static str,
}

pub type ParamsRef = Arc<CkksParams>;

impl CkksParams {
    /// Number of slots (`N/2`).
    pub fn slots(&self) -> usize {
        self.n / 2
    }

    /// Maximum usable level (level = index of the last active prime;
    /// fresh ciphertexts start at `max_level`).
    pub fn max_level(&self) -> usize {
        self.moduli.len() - 1
    }

    /// Multiplicative depth available (number of rescales possible).
    pub fn depth(&self) -> usize {
        self.moduli.len() - 1
    }

    /// Total log2 of the modulus chain incl. special prime — security
    /// is a function of (N, logQP).
    pub fn log_qp(&self) -> f64 {
        self.moduli.iter().map(|&q| (q as f64).log2()).sum::<f64>()
            + (self.special as f64).log2()
    }

    /// Rough security estimate from the homomorphicencryption.org
    /// standard table (ternary secret, classical): max logQP for
    /// 128-bit security by ring degree.
    pub fn security_estimate(&self) -> &'static str {
        let max128 = match self.n {
            4096 => 109.0,
            8192 => 218.0,
            16384 => 438.0,
            32768 => 881.0,
            _ => 0.0,
        };
        if self.log_qp() <= max128 {
            ">=128-bit"
        } else if self.log_qp() <= max128 * 1.25 {
            "~100-bit (dev default; see DESIGN.md §6)"
        } else {
            "INSECURE (test-only parameters)"
        }
    }

    /// Generate `count` distinct primes ≡ 1 (mod 2n), each as close as
    /// possible to `2^bits`, excluding any in `taken`.
    pub fn gen_primes(n: usize, bits: u32, count: usize, taken: &mut Vec<u64>) -> Vec<u64> {
        let two_n = (2 * n) as u64;
        let target = 1u64 << bits;
        // March outward from the target in steps of 2N, alternating
        // above/below, keeping q ≡ 1 (mod 2N).
        let base = (target / two_n) * two_n + 1;
        let mut found = Vec::with_capacity(count);
        let mut step = 0u64;
        while found.len() < count {
            step += 1;
            for cand in [base + step * two_n, base.wrapping_sub(step * two_n)] {
                if found.len() == count {
                    break;
                }
                if cand < (1 << (bits - 1)) || cand >= (1u64 << 62) {
                    continue;
                }
                if is_prime(cand) && !taken.contains(&cand) {
                    taken.push(cand);
                    found.push(cand);
                }
            }
            assert!(step < 1_000_000, "prime search exhausted");
        }
        found
    }

    /// Build a parameter set: one ~2^q0_bits anchor prime, `depth`
    /// rescaling primes near the scale, one special prime.
    pub fn build(
        name: &'static str,
        n: usize,
        q0_bits: u32,
        scale_bits: u32,
        depth: usize,
        sigma: f64,
    ) -> Self {
        assert!(n.is_power_of_two());
        let mut taken = Vec::new();
        let q0 = Self::gen_primes(n, q0_bits, 1, &mut taken);
        let qs = Self::gen_primes(n, scale_bits, depth, &mut taken);
        let special = Self::gen_primes(n, q0_bits, 1, &mut taken)[0];
        let mut moduli = q0;
        moduli.extend(qs);
        // Barrett/Shoup kernel domain (see module docs).
        assert!(
            moduli.iter().chain([&special]).all(|&q| q < 1 << 62),
            "modulus outside the Barrett kernel domain"
        );
        // Lazy-MAC headroom (see `kernels` module docs): the key-switch
        // inner product accumulates up to depth+1 digit products plus
        // the carried-in accumulator word into one u128 per coefficient
        // before its single reduction, so every prime's width must
        // leave room for that many (2q−1)² terms.
        let needed = depth + 2;
        for &q in moduli.iter().chain([&special]) {
            assert!(
                super::kernels::mac_headroom(q) >= needed,
                "prime {q} too wide for the lazy key-switch MAC \
                 ({needed} accumulator terms needed)"
            );
        }
        CkksParams {
            n,
            moduli,
            special,
            scale: (1u64 << scale_bits) as f64,
            sigma,
            name,
        }
    }

    /// Tiny parameters for unit tests. **Insecure**.
    pub fn toy() -> ParamsRef {
        Arc::new(Self::build("toy-n4096-d2", 4096, 60, 40, 2, 3.2))
    }

    /// Small parameters with the full depth-8 chain for degree-4
    /// activation HRFs; used in integration tests and demos. Security
    /// is well below 128-bit at this ring degree — test-grade only.
    pub fn fast() -> ParamsRef {
        Arc::new(Self::build("fast-n8192-d8", 8192, 60, 40, 8, 3.2))
    }

    /// Default HRF parameters: depth 8 (degree-4 activations twice +
    /// two plaintext muls), N=2^14. ~110-bit security; the same chain
    /// under `secure128()` meets 128-bit. See DESIGN.md §6.
    pub fn hrf_default() -> ParamsRef {
        Arc::new(Self::build("hrf-n16384-d8", 16384, 60, 40, 8, 3.2))
    }

    /// Deployment-grade 128-bit parameters (2× slower on this testbed).
    pub fn secure128() -> ParamsRef {
        Arc::new(Self::build("secure128-n32768-d8", 32768, 60, 40, 8, 3.2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primes_are_ntt_friendly_and_distinct() {
        let p = CkksParams::toy();
        let two_n = (2 * p.n) as u64;
        let mut all = p.moduli.clone();
        all.push(p.special);
        for &q in &all {
            assert!(is_prime(q), "{q} not prime");
            assert_eq!(q % two_n, 1, "{q} != 1 mod 2N");
            assert!(q < 1 << 62, "{q} outside Barrett kernel domain");
        }
        let mut dedup = all.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), all.len());
    }

    #[test]
    fn rescale_primes_near_scale() {
        let p = CkksParams::fast();
        for &q in &p.moduli[1..] {
            let drift = (q as f64 / p.scale).log2().abs();
            assert!(drift < 0.01, "rescale prime {q} drifts {drift} bits");
        }
    }

    #[test]
    fn depth_and_levels() {
        let p = CkksParams::fast();
        assert_eq!(p.depth(), 8);
        assert_eq!(p.max_level(), 8);
        assert_eq!(p.slots(), 4096);
    }

    #[test]
    fn security_labels() {
        assert_eq!(CkksParams::secure128().security_estimate(), ">=128-bit");
        assert!(CkksParams::toy().security_estimate().contains("INSECURE"));
    }
}
