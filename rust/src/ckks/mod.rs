//! A self-contained leveled CKKS implementation (Cheon–Kim–Kim–Song).
//!
//! This is the substrate the paper outsourced to Microsoft SEAL; here it
//! is built from scratch so the whole HRF stack is auditable and
//! dependency-free:
//!
//! * [`modops`] — 64-bit modular arithmetic primitives (Barrett/Shoup
//!   kernels; `mul_mod` is the division-based test oracle).
//! * [`params`] — parameter sets + NTT-friendly prime generation
//!   (every prime < 2^62, the Barrett kernel domain).
//! * [`kernels`] — explicitly-chunked, lazy-reduction batch kernels
//!   over whole limbs (the element-wise hot loops; domain conventions
//!   in the module doc).
//! * [`ntt`] — negacyclic number-theoretic transform per RNS prime
//!   (Harvey lazy butterflies, cache-blocked sweeps).
//! * [`rns`] — RNS ("double-CRT") polynomials with flat contiguous
//!   limb storage, per-prime Barrett/Shoup tables and base conversions.
//! * [`scratch`] — checkout façade over the shared slab pool
//!   ([`crate::mem`]) for evaluator temporaries.
//! * [`parallel`] — dependency-free limb-parallel executor
//!   (`std::thread::scope`; worker count on `CkksContext`, default 1).
//! * [`encoder`] — canonical-embedding encoder: `C^{N/2}` slots ↔ `R_Q`.
//! * [`keys`] — secret/public/relinearization/Galois keys; hybrid
//!   key-switching with one special prime.
//! * [`encrypt`] — encryption / decryption.
//! * [`evaluator`] — homomorphic ops (add/sub/mul/mul_plain/rescale/
//!   rotate/poly-eval) with per-operation counters (Table 1 of the
//!   paper is regenerated from these counters).
//!
//! Design notes
//! ------------
//! * All ciphertext polynomials are kept in NTT form; plaintexts are
//!   converted on encode. Rescale and automorphisms round-trip through
//!   coefficient form.
//! * Key-switching uses per-limb RNS decomposition with a single
//!   special prime `P` (SEAL-style "hybrid" with `dnum = L`): the added
//!   noise is `≈ ℓ·N·q_max·σ / P`, negligible for `P ≈ 2^60`.
//! * The scale is a power of two (default `2^40`); rescaling divides by
//!   the dropped prime, which is chosen within `2^±10` of the scale so
//!   scale drift stays bounded (tracked exactly in `Ciphertext::scale`).

pub mod encoder;
pub mod encrypt;
pub mod evaluator;
pub mod kernels;
pub mod keys;
pub mod modops;
pub mod ntt;
pub mod parallel;
pub mod params;
pub mod rns;
pub mod scratch;

pub use encoder::Encoder;
pub use encrypt::{Ciphertext, Decryptor, Encryptor, Plaintext};
pub use evaluator::{Evaluator, OpCounts};
pub use keys::{GaloisKeys, KeyGenerator, PublicKey, RelinKey, SecretKey};
pub use params::CkksParams;
pub use scratch::{Scratch, ScratchPool};
