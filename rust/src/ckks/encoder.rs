//! Canonical-embedding encoder: complex slot vectors ↔ ring elements.
//!
//! CKKS encodes `z ∈ C^{N/2}` as the (rounded, Δ-scaled) polynomial
//! `m(X)` whose evaluations at the primitive 2N-th roots of unity
//! `ζ^{5^j}` equal `z_j`. Slot j ↔ root `ζ^{5^j}` makes the Galois
//! automorphism `X → X^5` act as a cyclic rotation of the slots — this
//! is exactly the "Rotation" of the paper's Algorithms 1–3.
//!
//! The transform is the HEAAN-style "special FFT" over the orbit of 5
//! (O(n log n); a plain DFT would cost O(n²) ≈ seconds at N = 2^14).

use super::encrypt::Plaintext;
use super::rns::{CkksContext, RnsPoly};

/// Complex number (no external deps).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }
    #[inline]
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
    #[inline]
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
    #[inline]
    pub fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
    #[inline]
    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }
}

/// Encoder for a fixed context.
pub struct Encoder {
    n: usize,
    slots: usize,
    /// rot_group[j] = 5^j mod 2N.
    rot_group: Vec<usize>,
    /// ksi_pows[k] = exp(2πi k / 2N), k in [0, 2N].
    ksi_pows: Vec<C64>,
}

impl Encoder {
    pub fn new(ctx: &CkksContext) -> Self {
        let n = ctx.n();
        let slots = n / 2;
        let m = 2 * n;
        let mut rot_group = Vec::with_capacity(slots);
        let mut five_pow = 1usize;
        for _ in 0..slots {
            rot_group.push(five_pow);
            five_pow = (five_pow * 5) % m;
        }
        let mut ksi_pows = Vec::with_capacity(m + 1);
        for k in 0..=m {
            let theta = 2.0 * std::f64::consts::PI * k as f64 / m as f64;
            ksi_pows.push(C64::new(theta.cos(), theta.sin()));
        }
        Encoder {
            n,
            slots,
            rot_group,
            ksi_pows,
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    fn bit_reverse(vals: &mut [C64]) {
        let n = vals.len();
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                vals.swap(i, j);
            }
        }
    }

    /// Slot values -> embedding coefficients (inverse special FFT).
    fn emb_inv(&self, vals: &mut [C64]) {
        let n = vals.len();
        let m = 2 * self.n;
        let mut len = n;
        while len >= 1 {
            let lenh = len >> 1;
            let lenq = len << 2;
            if lenh == 0 {
                break;
            }
            let gap = m / lenq;
            let mut i = 0;
            while i < n {
                for j in 0..lenh {
                    let idx = (lenq - (self.rot_group[j] % lenq)) * gap;
                    let u = vals[i + j].add(vals[i + j + lenh]);
                    let v = vals[i + j].sub(vals[i + j + lenh]).mul(self.ksi_pows[idx]);
                    vals[i + j] = u;
                    vals[i + j + lenh] = v;
                }
                i += len;
            }
            len >>= 1;
        }
        Self::bit_reverse(vals);
        let inv_n = 1.0 / n as f64;
        for v in vals.iter_mut() {
            *v = v.scale(inv_n);
        }
    }

    /// Embedding coefficients -> slot values (forward special FFT).
    fn emb(&self, vals: &mut [C64]) {
        let n = vals.len();
        let m = 2 * self.n;
        Self::bit_reverse(vals);
        let mut len = 2usize;
        while len <= n {
            let lenh = len >> 1;
            let lenq = len << 2;
            let gap = m / lenq;
            let mut i = 0;
            while i < n {
                for j in 0..lenh {
                    let idx = (self.rot_group[j] % lenq) * gap;
                    let u = vals[i + j];
                    let v = vals[i + j + lenh].mul(self.ksi_pows[idx]);
                    vals[i + j] = u.add(v);
                    vals[i + j + lenh] = u.sub(v);
                }
                i += len;
            }
            len <<= 1;
        }
    }

    /// Encode complex slots (length ≤ N/2; zero-padded) into a
    /// plaintext at `level` with scale `scale`.
    pub fn encode_complex(
        &self,
        ctx: &CkksContext,
        z: &[C64],
        level: usize,
        scale: f64,
    ) -> Plaintext {
        assert!(z.len() <= self.slots, "too many slots");
        let mut vals = vec![C64::default(); self.slots];
        vals[..z.len()].copy_from_slice(z);
        self.emb_inv(&mut vals);
        // m_i = round(Δ·Re w_i); m_{i+n/2} = round(Δ·Im w_i)
        let mut coeffs = vec![0i128; self.n];
        for i in 0..self.slots {
            coeffs[i] = (vals[i].re * scale).round() as i128;
            coeffs[i + self.slots] = (vals[i].im * scale).round() as i128;
        }
        let mut poly = RnsPoly::from_signed_wide(ctx, &coeffs, level, false);
        poly.to_ntt(ctx);
        Plaintext { poly, scale }
    }

    /// Encode real slots.
    pub fn encode(&self, ctx: &CkksContext, z: &[f64], level: usize, scale: f64) -> Plaintext {
        let zc: Vec<C64> = z.iter().map(|&x| C64::new(x, 0.0)).collect();
        self.encode_complex(ctx, &zc, level, scale)
    }

    /// Encode the same real value in every slot. O(N): constant
    /// polynomial — no FFT needed.
    pub fn encode_constant(
        &self,
        ctx: &CkksContext,
        value: f64,
        level: usize,
        scale: f64,
    ) -> Plaintext {
        let mut coeffs = vec![0i128; self.n];
        coeffs[0] = (value * scale).round() as i128;
        let mut poly = RnsPoly::from_signed_wide(ctx, &coeffs, level, false);
        poly.to_ntt(ctx);
        Plaintext { poly, scale }
    }

    /// Decode a plaintext back to complex slots.
    pub fn decode_complex(&self, ctx: &CkksContext, pt: &Plaintext) -> Vec<C64> {
        let mut poly = pt.poly.clone();
        poly.from_ntt(ctx);
        let coeffs = poly.to_centered_f64(ctx);
        let inv_scale = 1.0 / pt.scale;
        let mut vals: Vec<C64> = (0..self.slots)
            .map(|i| C64::new(coeffs[i] * inv_scale, coeffs[i + self.slots] * inv_scale))
            .collect();
        self.emb(&mut vals);
        vals
    }

    /// Decode real parts of the slots.
    pub fn decode(&self, ctx: &CkksContext, pt: &Plaintext) -> Vec<f64> {
        self.decode_complex(ctx, pt).iter().map(|c| c.re).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;
    use crate::ckks::rns::CkksContext;
    use crate::rng::Xoshiro256pp;

    fn setup() -> (std::sync::Arc<CkksContext>, Encoder) {
        let ctx = CkksContext::new(CkksParams::toy());
        let enc = Encoder::new(&ctx);
        (ctx, enc)
    }

    #[test]
    fn encode_decode_roundtrip() {
        let (ctx, enc) = setup();
        let mut rng = Xoshiro256pp::new(21);
        let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let pt = enc.encode(&ctx, &z, ctx.params.max_level(), ctx.params.scale);
        let back = enc.decode(&ctx, &pt);
        for i in 0..z.len() {
            assert!(
                (back[i] - z[i]).abs() < 1e-8,
                "slot {i}: {} vs {}",
                back[i],
                z[i]
            );
        }
    }

    #[test]
    fn encode_constant_matches_full_encode() {
        let (ctx, enc) = setup();
        let lvl = ctx.params.max_level();
        let pt_c = enc.encode_constant(&ctx, 0.375, lvl, ctx.params.scale);
        let back = enc.decode(&ctx, &pt_c);
        for &v in back.iter().take(16) {
            assert!((v - 0.375).abs() < 1e-9);
        }
    }

    #[test]
    fn plaintext_add_is_slotwise_add() {
        let (ctx, enc) = setup();
        let lvl = ctx.params.max_level();
        let mut rng = Xoshiro256pp::new(22);
        let a: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut pa = enc.encode(&ctx, &a, lvl, ctx.params.scale);
        let pb = enc.encode(&ctx, &b, lvl, ctx.params.scale);
        pa.poly.add_assign(&ctx, &pb.poly);
        let back = enc.decode(&ctx, &pa);
        for i in 0..a.len() {
            assert!((back[i] - (a[i] + b[i])).abs() < 1e-7, "slot {i}");
        }
    }

    #[test]
    fn plaintext_mul_is_slotwise_mul() {
        // Polynomial ring product == slot-wise product (the SIMD property).
        let (ctx, enc) = setup();
        let lvl = ctx.params.max_level();
        let mut rng = Xoshiro256pp::new(23);
        let a: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut pa = enc.encode(&ctx, &a, lvl, ctx.params.scale);
        let pb = enc.encode(&ctx, &b, lvl, ctx.params.scale);
        pa.poly.mul_assign(&ctx, &pb.poly);
        pa.scale *= pb.scale;
        let back = enc.decode(&ctx, &pa);
        for i in 0..a.len() {
            assert!(
                (back[i] - a[i] * b[i]).abs() < 1e-6,
                "slot {i}: {} vs {}",
                back[i],
                a[i] * b[i]
            );
        }
    }

    #[test]
    fn automorphism_five_rotates_slots_left() {
        let (ctx, enc) = setup();
        let lvl = ctx.params.max_level();
        let z: Vec<f64> = (0..enc.slots()).map(|i| (i % 97) as f64 / 97.0).collect();
        let mut pt = enc.encode(&ctx, &z, lvl, ctx.params.scale);
        pt.poly.automorphism(&ctx, 5);
        let back = enc.decode(&ctx, &pt);
        // X -> X^5 should rotate slots by one position (direction pinned here).
        let n = enc.slots();
        let mut left_ok = true;
        let mut right_ok = true;
        for i in 0..n {
            if (back[i] - z[(i + 1) % n]).abs() > 1e-7 {
                left_ok = false;
            }
            if (back[i] - z[(i + n - 1) % n]).abs() > 1e-7 {
                right_ok = false;
            }
        }
        assert!(
            left_ok || right_ok,
            "automorphism by 5 is not a slot rotation"
        );
        // Document the convention the rest of the stack relies on:
        assert!(left_ok, "convention: X->X^5 rotates slots LEFT by 1");
    }
}
