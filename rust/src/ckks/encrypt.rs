//! Plaintext / ciphertext containers, encryption and decryption.
//!
//! A ciphertext is a pair `(c0, c1)` of RNS polynomials in NTT form
//! with `c0 + c1·s ≈ Δ·m (mod Q_level)`. The exact running scale is
//! tracked in `scale` (it drifts slightly from Δ after rescales because
//! chain primes are only ≈ Δ; all consumers use the tracked value, so
//! the drift never becomes error).

use super::encoder::{C64, Encoder};
use super::keys::{PublicKey, SecretKey};
use super::rns::{CkksContext, RnsPoly};
use crate::rng::Xoshiro256pp;

/// Encoded message (NTT form).
#[derive(Clone, Debug)]
pub struct Plaintext {
    pub poly: RnsPoly,
    pub scale: f64,
}

/// CKKS ciphertext: (c0, c1), NTT form, with level & scale metadata.
#[derive(Clone, Debug)]
pub struct Ciphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    pub level: usize,
    pub scale: f64,
}

impl Ciphertext {
    /// Serialized size in bytes (2 polys × limbs × N × 8B) — used by
    /// the coordinator for transport accounting.
    pub fn size_bytes(&self) -> usize {
        2 * self.c0.data().len() * 8
    }
}

/// Public-key encryptor (client side).
pub struct Encryptor {
    pk: PublicKey,
    rng: Xoshiro256pp,
}

impl Encryptor {
    pub fn new(pk: PublicKey, seed: u64) -> Self {
        Encryptor {
            pk,
            rng: Xoshiro256pp::new(seed),
        }
    }

    /// Encrypt a plaintext: ct = v·(b,a) + (m + e0, e1).
    pub fn encrypt(&mut self, ctx: &CkksContext, pt: &Plaintext) -> Ciphertext {
        let level = pt.poly.level;
        let mut v = RnsPoly::sample_ternary(ctx, &mut self.rng, level, false);
        v.to_ntt(ctx);
        let mut e0 = RnsPoly::sample_error(ctx, &mut self.rng, level, false);
        e0.to_ntt(ctx);
        let mut e1 = RnsPoly::sample_error(ctx, &mut self.rng, level, false);
        e1.to_ntt(ctx);

        let mut c0 = self.pk.b.clone();
        c0.drop_to_level_ntt(ctx, level);
        c0.mul_assign(ctx, &v);
        c0.add_assign(ctx, &e0);
        c0.add_assign(ctx, &pt.poly);

        let mut c1 = self.pk.a.clone();
        c1.drop_to_level_ntt(ctx, level);
        c1.mul_assign(ctx, &v);
        c1.add_assign(ctx, &e1);

        Ciphertext {
            c0,
            c1,
            level,
            scale: pt.scale,
        }
    }

    /// Convenience: encode + encrypt real slots at top level.
    pub fn encrypt_slots(
        &mut self,
        ctx: &CkksContext,
        enc: &Encoder,
        z: &[f64],
    ) -> Ciphertext {
        let pt = enc.encode(ctx, z, ctx.params.max_level(), ctx.params.scale);
        self.encrypt(ctx, &pt)
    }
}

/// Secret-key decryptor (client side).
pub struct Decryptor {
    sk: SecretKey,
}

impl Decryptor {
    pub fn new(sk: SecretKey) -> Self {
        Decryptor { sk }
    }

    /// Decrypt: m = c0 + c1·s.
    pub fn decrypt(&self, ctx: &CkksContext, ct: &Ciphertext) -> Plaintext {
        let mut s = self.sk.s.clone();
        s.restrict(ct.level);
        let mut m = ct.c1.clone();
        m.mul_assign(ctx, &s);
        m.add_assign(ctx, &ct.c0);
        Plaintext {
            poly: m,
            scale: ct.scale,
        }
    }

    /// Decrypt + decode real slots.
    pub fn decrypt_slots(&self, ctx: &CkksContext, enc: &Encoder, ct: &Ciphertext) -> Vec<f64> {
        enc.decode(ctx, &self.decrypt(ctx, ct))
    }

    /// Decrypt + decode complex slots.
    pub fn decrypt_slots_complex(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        ct: &Ciphertext,
    ) -> Vec<C64> {
        enc.decode_complex(ctx, &self.decrypt(ctx, ct))
    }
}

impl RnsPoly {
    /// Truncate an NTT-form key-level poly (no special limb use) down
    /// to `level` — valid because limbs are independent in both
    /// coefficient and NTT form.
    pub fn drop_to_level_ntt(&mut self, _ctx: &CkksContext, level: usize) {
        debug_assert!(!self.special);
        self.drop_to_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::keys::KeyGenerator;
    use crate::ckks::params::CkksParams;
    use crate::ckks::rns::CkksContext;

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let ctx = CkksContext::new(CkksParams::toy());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 42);
        let pk = kg.gen_public_key(&ctx);
        let mut encryptor = Encryptor::new(pk, 777);
        let decryptor = Decryptor::new(kg.secret_key());

        let mut rng = Xoshiro256pp::new(31);
        let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let ct = encryptor.encrypt_slots(&ctx, &enc, &z);
        let back = decryptor.decrypt_slots(&ctx, &enc, &ct);
        for i in 0..z.len() {
            assert!(
                (back[i] - z[i]).abs() < 1e-6,
                "slot {i}: {} vs {}",
                back[i],
                z[i]
            );
        }
    }

    #[test]
    fn fresh_noise_is_small() {
        let ctx = CkksContext::new(CkksParams::toy());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 1);
        let pk = kg.gen_public_key(&ctx);
        let mut encryptor = Encryptor::new(pk, 2);
        let decryptor = Decryptor::new(kg.secret_key());
        let z = vec![0.5f64; 8];
        let ct = encryptor.encrypt_slots(&ctx, &enc, &z);
        let back = decryptor.decrypt_slots(&ctx, &enc, &ct);
        let err: f64 = (0..8).map(|i| (back[i] - 0.5).abs()).fold(0.0, f64::max);
        // fresh encryption error ~ sigma*N/scale << 1e-6
        assert!(err < 1e-6, "fresh noise too large: {err}");
    }
}
