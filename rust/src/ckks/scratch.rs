//! Checkout façade over the shared slab pool (§Memory plane).
//!
//! With flat limb storage ([`crate::ckks::rns::RnsPoly`]) one
//! polynomial is exactly one `Vec<u64>`, so recycling vectors removes
//! the allocation from every temporary the evaluator makes:
//! key-switch decompositions, hoisted-rotation digit copies,
//! NTT-domain automorphism double buffers, tensor-product temporaries
//! and retired polynomial-activation powers.
//!
//! [`Scratch`] used to *own* those recycled vectors (one private warm
//! list per [`crate::ckks::Evaluator`]), which multiplied peak idle
//! memory by `op_workers × ckks_workers`. It is now a thin handle into
//! the process-wide [`crate::mem::SlabPool`]: `take`/`put` delegate to
//! the pool's sharded, size-classed free lists under one global byte
//! budget. Each handle is pinned to a *home* shard (round-robin at
//! construction) so concurrent workers land on different locks; the
//! hot path touches exactly one uncontended mutex per checkout.
//!
//! The `&mut self` signatures are kept even though the handle itself
//! is stateless — they document the single-owner discipline of the
//! evaluator hot paths and keep every call site unchanged.

use crate::mem::SlabPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A handle into the shared slab pool, pinned to one home shard.
///
/// Cloning yields a handle to the *same* pool and home shard (used by
/// [`crate::ckks::Evaluator::split_off`] so worker evaluators inherit
/// the parent's pool). `Scratch::default()`/[`Scratch::new`] attach to
/// the global pool; tests use [`Scratch::in_pool`] with a private one.
#[derive(Clone)]
pub struct Scratch {
    pool: Arc<SlabPool>,
    home: usize,
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch::new()
    }
}

impl Scratch {
    /// A handle into the process-wide pool ([`crate::mem::global_pool`]).
    pub fn new() -> Self {
        Scratch::in_pool(crate::mem::global_pool().clone())
    }

    /// A handle into a specific pool (tests / isolated workloads).
    pub fn in_pool(pool: Arc<SlabPool>) -> Self {
        // Round-robin home-shard assignment across all handles in the
        // process: concurrent workers (who each construct their own
        // handle) land on distinct shards.
        static NEXT_HOME: AtomicUsize = AtomicUsize::new(0);
        let home = NEXT_HOME.fetch_add(1, Ordering::Relaxed) % pool.num_shards();
        Scratch { pool, home }
    }

    /// A buffer of exactly `len` zeroed words (recycled if available).
    pub fn take(&mut self, len: usize) -> Vec<u64> {
        self.pool.take(self.home, len)
    }

    /// A buffer holding a copy of `src` (single memcpy, no zeroing).
    pub fn take_copy(&mut self, src: &[u64]) -> Vec<u64> {
        self.pool.take_copy(self.home, src)
    }

    /// Return a buffer to the pool (trimmed/dropped past the budget).
    pub fn put(&mut self, buf: Vec<u64>) {
        self.pool.put(self.home, buf);
    }

    /// Idle buffers in this handle's home shard (test hook).
    pub fn pooled(&self) -> usize {
        self.pool.idle_buffers_in(self.home)
    }

    /// The backing pool (test/introspection hook).
    pub fn pool(&self) -> &Arc<SlabPool> {
        &self.pool
    }

    /// Historical API from the evaluator-owned pool era, kept so
    /// `Evaluator::merge` still compiles against older callers: with a
    /// shared backing pool a retiring worker's buffers are *already*
    /// in the arena, so there is nothing to drain.
    pub fn absorb(&mut self, _other: Scratch) {}
}

/// Shared checkout point for op-parallel execution, kept as a façade:
/// DAG workers still call `checkout`/`restore` around a request, but
/// both now just mint/drop [`Scratch`] handles — the warm buffers
/// themselves live in the global [`crate::mem::SlabPool`] and survive
/// across requests (and across *servers*) under one byte budget.
#[derive(Default)]
pub struct ScratchPool;

impl ScratchPool {
    pub fn new() -> Self {
        ScratchPool
    }

    /// A fresh handle into the global pool (its own home shard).
    pub fn checkout(&self) -> Scratch {
        Scratch::new()
    }

    /// Retire a handle. The buffers it returned via `put` are already
    /// resident in the shared pool; dropping the handle is enough.
    pub fn restore(&self, _scratch: Scratch) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn private_pool() -> Arc<SlabPool> {
        Arc::new(SlabPool::new(2, 1 << 20))
    }

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        let pool = private_pool();
        let mut s = Scratch::in_pool(pool.clone());
        let mut b = s.take(16);
        b.iter_mut().for_each(|x| *x = 7);
        let cap = b.capacity();
        s.put(b);
        assert_eq!(s.pooled(), 1);
        let b2 = s.take(8);
        assert!(b2.capacity() >= 8 && cap >= b2.capacity());
        assert!(b2.iter().all(|&x| x == 0), "recycled buffer not zeroed");
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn take_copy_matches_source() {
        let mut s = Scratch::in_pool(private_pool());
        s.put(vec![9u64; 32]);
        let src: Vec<u64> = (0..10).collect();
        let b = s.take_copy(&src);
        assert_eq!(b, src);
    }

    #[test]
    fn clones_share_the_backing_pool() {
        let mut s = Scratch::in_pool(private_pool());
        let mut w = s.clone();
        s.put(vec![0u64; 64]);
        let b = w.take(64); // same pool + home shard: hit, not alloc
        assert_eq!(b.len(), 64);
        assert_eq!(s.pool().stats().snapshot().hits, 1);
    }

    #[test]
    fn handles_in_same_pool_share_buffers_across_shards() {
        let pool = private_pool();
        let mut a = Scratch::in_pool(pool.clone());
        let mut b = Scratch::in_pool(pool.clone());
        a.put(vec![1u64; 128]);
        let got = b.take(128); // steal-scan finds a's buffer
        assert!(got.iter().all(|&x| x == 0));
        assert_eq!(pool.idle_buffers(), 0);
    }

    #[test]
    fn pool_budget_bounds_resident_bytes() {
        let pool = Arc::new(SlabPool::new(1, 1024));
        let mut s = Scratch::in_pool(pool.clone());
        for _ in 0..10 {
            s.put(vec![0u64; 64]); // 512 B each; budget fits two
        }
        assert!(pool.resident_bytes() <= 1024);
        assert_eq!(pool.audit_resident_bytes(), pool.resident_bytes());
    }

    #[test]
    fn scratch_pool_facade_mints_global_handles() {
        let sp = ScratchPool::new();
        let s = sp.checkout();
        assert!(Arc::ptr_eq(s.pool(), crate::mem::global_pool()));
        sp.restore(s);
    }
}
