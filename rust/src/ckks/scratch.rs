//! Reusable limb-buffer pool (§Perf: scratch reuse).
//!
//! With flat limb storage ([`crate::ckks::rns::RnsPoly`]) one
//! polynomial is exactly one `Vec<u64>`, so a tiny pool of recycled
//! vectors removes the allocation from every temporary the evaluator
//! makes: key-switch decompositions, hoisted-rotation digit copies,
//! NTT-domain automorphism double buffers, tensor-product temporaries
//! and retired polynomial-activation powers. The pool is owned by
//! [`crate::ckks::Evaluator`] (one per worker thread) and threaded by
//! `&mut` through the hot entry points — never shared, never locked.
//!
//! Buffers of different lengths coexist: ciphertext levels shrink as a
//! pipeline rescales, and [`Scratch::take`] resizes whatever buffer it
//! pops. The pool is capped so a deep one-off expression cannot pin
//! memory forever.

/// Upper bound on pooled buffers; beyond this, returned buffers are
/// simply dropped. 64 vastly exceeds the live-temporary high-water
/// mark of any evaluator op (a key-switch holds `level + 3` polys).
const MAX_POOLED: usize = 64;

/// A pool of reusable `u64` limb buffers.
#[derive(Default)]
pub struct Scratch {
    bufs: Vec<Vec<u64>>,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch::default()
    }

    /// A buffer of exactly `len` zeroed words (recycled if available).
    pub fn take(&mut self, len: usize) -> Vec<u64> {
        match self.bufs.pop() {
            Some(mut b) => {
                b.clear();
                b.resize(len, 0);
                b
            }
            None => vec![0u64; len],
        }
    }

    /// A buffer holding a copy of `src` (single memcpy, no zeroing).
    pub fn take_copy(&mut self, src: &[u64]) -> Vec<u64> {
        match self.bufs.pop() {
            Some(mut b) => {
                b.clear();
                b.extend_from_slice(src);
                b
            }
            None => src.to_vec(),
        }
    }

    /// Return a buffer to the pool (dropped if the pool is full).
    pub fn put(&mut self, buf: Vec<u64>) {
        if buf.capacity() > 0 && self.bufs.len() < MAX_POOLED {
            self.bufs.push(buf);
        }
    }

    /// Number of buffers currently pooled (test/introspection hook).
    pub fn pooled(&self) -> usize {
        self.bufs.len()
    }

    /// Drain another pool's buffers into this one (bounded by
    /// `MAX_POOLED`; excess buffers are dropped). Used when a worker
    /// evaluator retires and its warm buffers flow back to the shared
    /// [`ScratchPool`].
    pub fn absorb(&mut self, mut other: Scratch) {
        while let Some(b) = other.bufs.pop() {
            if self.bufs.len() >= MAX_POOLED {
                break;
            }
            self.put(b);
        }
    }
}

/// A small shared pool of [`Scratch`] instances for op-parallel
/// execution: each DAG worker checks one out for the lifetime of a
/// request and restores it afterwards, so warm limb buffers survive
/// across requests without any per-op locking (the lock is touched
/// twice per worker per request, never on the op hot path).
///
/// Bounded: at most [`ScratchPool::MAX_IDLE`] idle pools are retained;
/// checkout beyond the retained set simply creates a fresh empty
/// `Scratch` (allocation then happens lazily on first use).
pub struct ScratchPool {
    idle: std::sync::Mutex<Vec<Scratch>>,
}

impl ScratchPool {
    /// Upper bound on idle retained `Scratch` pools. Sized for the
    /// realistic op-worker × coordinator-worker product; beyond it,
    /// restored pools are dropped.
    pub const MAX_IDLE: usize = 32;

    pub fn new() -> Self {
        ScratchPool {
            idle: std::sync::Mutex::new(Vec::new()),
        }
    }

    /// Check out a scratch pool (warm if one is idle, fresh otherwise).
    pub fn checkout(&self) -> Scratch {
        crate::lockutil::lock_unpoisoned(&self.idle)
            .pop()
            .unwrap_or_default()
    }

    /// Return a scratch pool after use (dropped if at capacity).
    pub fn restore(&self, scratch: Scratch) {
        let mut idle = crate::lockutil::lock_unpoisoned(&self.idle);
        if idle.len() < Self::MAX_IDLE {
            idle.push(scratch);
        }
    }

    /// Number of idle pools currently retained (test hook).
    pub fn idle(&self) -> usize {
        crate::lockutil::lock_unpoisoned(&self.idle).len()
    }
}

impl Default for ScratchPool {
    fn default() -> Self {
        ScratchPool::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        let mut s = Scratch::new();
        let mut b = s.take(16);
        b.iter_mut().for_each(|x| *x = 7);
        let cap = b.capacity();
        s.put(b);
        assert_eq!(s.pooled(), 1);
        let b2 = s.take(8);
        assert!(b2.capacity() >= 8 && cap >= b2.capacity());
        assert!(b2.iter().all(|&x| x == 0), "recycled buffer not zeroed");
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn take_copy_matches_source() {
        let mut s = Scratch::new();
        s.put(vec![9u64; 32]);
        let src: Vec<u64> = (0..10).collect();
        let b = s.take_copy(&src);
        assert_eq!(b, src);
    }

    #[test]
    fn pool_is_bounded() {
        let mut s = Scratch::new();
        for _ in 0..(MAX_POOLED + 10) {
            s.put(vec![0u64; 4]);
        }
        assert_eq!(s.pooled(), MAX_POOLED);
    }
}
