//! 64-bit modular arithmetic primitives.
//!
//! All CKKS primes are < 2^61, so sums of two residues never overflow
//! u64 and products fit in u128. Multiplication uses either a plain
//! u128 reduction or Shoup's precomputed-quotient trick on NTT hot
//! paths (see [`crate::ckks::ntt`]).

/// x + y mod m (inputs reduced).
#[inline(always)]
pub fn add_mod(x: u64, y: u64, m: u64) -> u64 {
    let s = x + y;
    if s >= m {
        s - m
    } else {
        s
    }
}

/// x - y mod m (inputs reduced).
#[inline(always)]
pub fn sub_mod(x: u64, y: u64, m: u64) -> u64 {
    if x >= y {
        x - y
    } else {
        x + m - y
    }
}

/// -x mod m (input reduced).
#[inline(always)]
pub fn neg_mod(x: u64, m: u64) -> u64 {
    if x == 0 {
        0
    } else {
        m - x
    }
}

/// x * y mod m via u128 division.
///
/// This is the **test oracle**: the `%` on a 128-bit value lowers to a
/// libcall (`__umodti3`) and costs an order of magnitude more than the
/// Barrett/Shoup kernels below, so no per-coefficient hot loop may use
/// it. `tests/modops_kernels.rs` pins every fast kernel against this
/// function across all parameter-set primes.
#[inline(always)]
pub fn mul_mod(x: u64, y: u64, m: u64) -> u64 {
    ((x as u128 * y as u128) % m as u128) as u64
}

/// Barrett constant for modulus `m`: `floor(2^128 / m)` as (lo, hi)
/// words. Requires `m` odd (all CKKS primes), so the `-1` in the
/// numerator never changes the quotient.
#[inline]
pub fn barrett_precompute(m: u64) -> (u64, u64) {
    debug_assert!(m > 1 && m % 2 == 1);
    let r = u128::MAX / m as u128; // == floor(2^128 / m) for odd m
    (r as u64, (r >> 64) as u64)
}

/// Reduce a full 128-bit value `hi·2^64 + lo` mod `m` without any
/// division (SEAL-style base-2^64 Barrett). Exact for every 128-bit
/// input provided `m < 2^62` — which [`crate::ckks::params`] enforces
/// for every chain and special prime.
#[inline(always)]
pub fn barrett_reduce_128(lo: u64, hi: u64, m: u64, ratio: (u64, u64)) -> u64 {
    let (r0, r1) = ratio;
    // q̂ = floor((hi·2^64 + lo) · ratio / 2^128), computed in 64-bit
    // words; the true quotient exceeds q̂ by at most 1, so one
    // conditional subtraction fully reduces.
    let carry = ((lo as u128 * r0 as u128) >> 64) as u64;
    let t = lo as u128 * r1 as u128;
    let s = (t as u64 as u128) + carry as u128;
    let tmp1 = s as u64;
    let tmp3 = ((t >> 64) as u64).wrapping_add((s >> 64) as u64);
    let t = hi as u128 * r0 as u128;
    let s = tmp1 as u128 + (t as u64 as u128);
    let carry2 = ((t >> 64) as u64).wrapping_add((s >> 64) as u64);
    let q = hi
        .wrapping_mul(r1)
        .wrapping_add(tmp3)
        .wrapping_add(carry2);
    let res = lo.wrapping_sub(q.wrapping_mul(m));
    if res >= m {
        res - m
    } else {
        res
    }
}

/// x * y mod m via [`barrett_reduce_128`] — the element-wise multiply
/// kernel for operands that change every iteration (ct⊙ct, ct⊙pt,
/// key-switch inner products). Inputs need not be reduced.
#[inline(always)]
pub fn mul_mod_barrett(x: u64, y: u64, m: u64, ratio: (u64, u64)) -> u64 {
    let p = x as u128 * y as u128;
    barrett_reduce_128(p as u64, (p >> 64) as u64, m, ratio)
}

/// Lazy variant of [`barrett_reduce_128`]: the final conditional
/// subtraction is skipped, so the result lands in `[0, 2m)`. The
/// quotient estimate q̂ undershoots the true quotient by at most 1 for
/// `m < 2^62`, which is exactly the one conditional this omits.
#[inline(always)]
pub fn barrett_reduce_128_lazy(lo: u64, hi: u64, m: u64, ratio: (u64, u64)) -> u64 {
    let (r0, r1) = ratio;
    let carry = ((lo as u128 * r0 as u128) >> 64) as u64;
    let t = lo as u128 * r1 as u128;
    let s = (t as u64 as u128) + carry as u128;
    let tmp1 = s as u64;
    let tmp3 = ((t >> 64) as u64).wrapping_add((s >> 64) as u64);
    let t = hi as u128 * r0 as u128;
    let s = tmp1 as u128 + (t as u64 as u128);
    let carry2 = ((t >> 64) as u64).wrapping_add((s >> 64) as u64);
    let q = hi
        .wrapping_mul(r1)
        .wrapping_add(tmp3)
        .wrapping_add(carry2);
    lo.wrapping_sub(q.wrapping_mul(m))
}

/// x * y mod m in the **lazy** `[0, 2m)` output domain (the
/// [`barrett_reduce_128_lazy`] form of [`mul_mod_barrett`]). Feed the
/// result only into consumers that tolerate lazy inputs — see the
/// domain conventions in [`crate::ckks::kernels`].
#[inline(always)]
pub fn mul_mod_barrett_lazy(x: u64, y: u64, m: u64, ratio: (u64, u64)) -> u64 {
    let p = x as u128 * y as u128;
    barrett_reduce_128_lazy(p as u64, (p >> 64) as u64, m, ratio)
}

/// Reduce a single word mod `m` using only the high Barrett word
/// (`ratio.1` from [`barrett_precompute`]). Exact for any `x < 2^64`
/// with `m < 2^62` — replaces the `u64 % u64` in limb lifts and
/// centered-remainder adjustments.
#[inline(always)]
pub fn barrett_reduce_64(x: u64, m: u64, ratio_hi: u64) -> u64 {
    let q = ((x as u128 * ratio_hi as u128) >> 64) as u64;
    let res = x.wrapping_sub(q.wrapping_mul(m));
    if res >= m {
        res - m
    } else {
        res
    }
}

/// Shoup precomputation for multiplying by a fixed operand `y`:
/// returns floor(y * 2^64 / m).
#[inline(always)]
pub fn shoup_precompute(y: u64, m: u64) -> u64 {
    (((y as u128) << 64) / m as u128) as u64
}

/// Shoup modular multiplication: x * y mod m where `y_shoup` was
/// produced by [`shoup_precompute`]. Result fully reduced.
#[inline(always)]
pub fn mul_mod_shoup(x: u64, y: u64, y_shoup: u64, m: u64) -> u64 {
    let r = mul_mod_shoup_lazy(x, y, y_shoup, m);
    if r >= m {
        r - m
    } else {
        r
    }
}

/// Lazy Shoup multiplication: result in [0, 2m). Valid for any x
/// (Harvey); used by the lazy NTT butterflies.
#[inline(always)]
pub fn mul_mod_shoup_lazy(x: u64, y: u64, y_shoup: u64, m: u64) -> u64 {
    let q = ((x as u128 * y_shoup as u128) >> 64) as u64;
    (x.wrapping_mul(y)).wrapping_sub(q.wrapping_mul(m))
}

/// x^e mod m by square-and-multiply.
pub fn pow_mod(mut x: u64, mut e: u64, m: u64) -> u64 {
    let mut acc: u64 = 1;
    x %= m;
    while e > 0 {
        if e & 1 == 1 {
            acc = mul_mod(acc, x, m);
        }
        x = mul_mod(x, x, m);
        e >>= 1;
    }
    acc
}

/// Modular inverse of x mod prime m (Fermat).
pub fn inv_mod(x: u64, m: u64) -> u64 {
    pow_mod(x, m - 2, m)
}

/// Deterministic Miller–Rabin, exact for all u64 with this witness set.
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == p {
            return true;
        }
        if n % p == 0 {
            return false;
        }
    }
    let mut d = n - 1;
    let mut r = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        r += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..r - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Galois element for a left-rotation by `step` slots: `5^step mod 2N`
/// (the canonical-embedding convention: X→X^5 rotates slots left by
/// one). Single source of truth shared by key generation and the
/// permutation-cache prewarm.
pub fn galois_element(step: usize, two_n: usize) -> usize {
    pow_mod(5, step as u64, two_n as u64) as usize
}

/// Find a generator of the 2N-th roots of unity mod prime q
/// (q ≡ 1 mod 2N): returns ψ with ψ^(2N) = 1 and ψ^N = -1.
pub fn primitive_2nth_root(q: u64, two_n: u64) -> u64 {
    debug_assert_eq!((q - 1) % two_n, 0);
    let cofactor = (q - 1) / two_n;
    // Try small candidates; g^cofactor has order dividing 2N. It is a
    // primitive 2N-th root iff its N-th power is -1 (i.e. order exactly 2N).
    let mut g = 2u64;
    loop {
        let cand = pow_mod(g, cofactor, q);
        if cand != 1 && pow_mod(cand, two_n / 2, q) == q - 1 {
            return cand;
        }
        g += 1;
        debug_assert!(g < 1000, "no primitive root found (q not prime?)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    const P: u64 = (1 << 40) + 0x1_0001; // not prime; used for add/sub only

    #[test]
    fn add_sub_roundtrip() {
        let mut r = Xoshiro256pp::new(1);
        for _ in 0..1000 {
            let x = r.next_below(P);
            let y = r.next_below(P);
            let s = add_mod(x, y, P);
            assert_eq!(sub_mod(s, y, P), x);
            assert_eq!(add_mod(sub_mod(x, y, P), y, P), x);
        }
    }

    #[test]
    fn mul_matches_u128() {
        let mut r = Xoshiro256pp::new(2);
        let m = 0x0FFF_FFFF_FFFF_FFC5; // large odd modulus
        for _ in 0..1000 {
            let x = r.next_below(m);
            let y = r.next_below(m);
            assert_eq!(mul_mod(x, y, m), ((x as u128 * y as u128) % m as u128) as u64);
        }
    }

    #[test]
    fn shoup_matches_plain() {
        let mut r = Xoshiro256pp::new(3);
        let m = 0x1FFF_FFFF_FFFF_FF9B;
        for _ in 0..1000 {
            let x = r.next_below(m);
            let y = r.next_below(m);
            let ys = shoup_precompute(y, m);
            assert_eq!(mul_mod_shoup(x, y, ys, m), mul_mod(x, y, m));
        }
    }

    #[test]
    fn pow_and_inverse() {
        assert!(is_prime(1_000_000_007));
        assert!(is_prime(0xFFFF_FFFF_FFFF_FFC5)); // largest u64 prime
        assert!(!is_prime(1_000_000_009u64 * 3));
        let m = 1_000_000_007u64;
        let mut r = Xoshiro256pp::new(4);
        for _ in 0..200 {
            let x = 1 + r.next_below(m - 1);
            assert_eq!(mul_mod(x, inv_mod(x, m), m), 1);
        }
    }

    #[test]
    fn primitive_root_properties() {
        // q = 1 mod 2N for N=1024: pick q = 12289 * ... use small known:
        // 12289 = 1 + 3*2^12 supports 2N up to 4096.
        let q = 12289u64;
        let two_n = 4096u64;
        let psi = primitive_2nth_root(q, two_n);
        assert_eq!(pow_mod(psi, two_n, q), 1);
        assert_eq!(pow_mod(psi, two_n / 2, q), q - 1);
    }
}
