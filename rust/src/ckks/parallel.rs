//! Dependency-free limb-parallel executor (§Perf step 6).
//!
//! Every expensive CKKS loop is embarrassingly parallel across RNS
//! limbs: forward/inverse NTTs, element-wise ring multiplies, Galois
//! permutations, key-switch inner products and the rescale/mod-down
//! adjustments all touch one limb at a time, and with flat limb
//! storage each limb is one disjoint stride-`N` chunk of a single
//! `Vec<u64>`. The helpers here fan those chunks across
//! `std::thread::scope` workers with a static round-robin partition —
//! no work stealing, no shared mutable state, no dependencies — so the
//! output is **bit-identical for every worker count by construction**
//! (pinned by `tests/modops_kernels.rs`).
//!
//! Worker count comes from the caller (the context's setting, see
//! [`crate::ckks::rns::CkksContext::set_workers`]); `workers <= 1`
//! runs the plain serial loop with zero threading overhead, which is
//! the default everywhere.
//!
//! Threads are scoped — spawned and joined per invocation, ~10–30 µs
//! per worker. That amortizes over the NTT-dominated ops that dominate
//! an evaluation (key-switch decomposition, mod-down, rotations) but
//! can eat the gain on the cheapest element-wise sweeps at small N;
//! a persistent pool is the natural next step if profiles demand it.

use std::thread;

/// Run `f(limb_index, limb_chunk)` over each stride-`n` chunk of
/// `data`, fanned across up to `workers` scoped threads.
pub fn for_each_limb<F>(workers: usize, n: usize, data: &mut [u64], f: F)
where
    F: Fn(usize, &mut [u64]) + Sync,
{
    for_each_limb_with(workers, n, data, |_buf, li, chunk| f(li, chunk));
}

/// Like [`for_each_limb`] but hands every worker a private reusable
/// `Vec<u64>` buffer (for per-limb temporaries such as the mod-down
/// remainder poly) — one allocation per worker, not per limb.
pub fn for_each_limb_with<F>(workers: usize, n: usize, data: &mut [u64], f: F)
where
    F: Fn(&mut Vec<u64>, usize, &mut [u64]) + Sync,
{
    debug_assert!(n > 0 && data.len() % n == 0);
    let n_limbs = data.len() / n;
    let workers = workers.clamp(1, n_limbs.max(1));
    if workers == 1 {
        let mut buf = Vec::new();
        for (li, chunk) in data.chunks_mut(n).enumerate() {
            f(&mut buf, li, chunk);
        }
        return;
    }
    // Static round-robin partition of the limb chunks.
    let mut lots: Vec<Vec<(usize, &mut [u64])>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        lots.push(Vec::with_capacity(n_limbs / workers + 1));
    }
    for (li, chunk) in data.chunks_mut(n).enumerate() {
        lots[li % workers].push((li, chunk));
    }
    let f = &f;
    thread::scope(|s| {
        let mine = lots.remove(0);
        for lot in lots {
            s.spawn(move || {
                let mut buf = Vec::new();
                for (li, chunk) in lot {
                    f(&mut buf, li, chunk);
                }
            });
        }
        // The calling thread works lot 0 instead of idling.
        let mut buf = Vec::new();
        for (li, chunk) in mine {
            f(&mut buf, li, chunk);
        }
    });
}

/// Run `f(limb_index, chunk0, chunk1, chunk2)` over the stride-`n`
/// chunks of three equal-length buffers in lockstep, fanned across up
/// to `workers` scoped threads — the driver for kernels with multiple
/// limb outputs (the fused ct×ct tensor writes d0/d1/d2 in one pass).
/// Same static round-robin partition as [`for_each_limb`], so the
/// output is bit-identical at every worker count.
pub fn for_each_limb3<F>(
    workers: usize,
    n: usize,
    d0: &mut [u64],
    d1: &mut [u64],
    d2: &mut [u64],
    f: F,
) where
    F: Fn(usize, &mut [u64], &mut [u64], &mut [u64]) + Sync,
{
    debug_assert!(n > 0 && d0.len() % n == 0);
    debug_assert!(d0.len() == d1.len() && d0.len() == d2.len());
    let n_limbs = d0.len() / n;
    let workers = workers.clamp(1, n_limbs.max(1));
    if workers == 1 {
        for (li, ((c0, c1), c2)) in d0
            .chunks_mut(n)
            .zip(d1.chunks_mut(n))
            .zip(d2.chunks_mut(n))
            .enumerate()
        {
            f(li, c0, c1, c2);
        }
        return;
    }
    type Lot<'a> = Vec<(usize, &'a mut [u64], &'a mut [u64], &'a mut [u64])>;
    let mut lots: Vec<Lot<'_>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        lots.push(Vec::with_capacity(n_limbs / workers + 1));
    }
    for (li, ((c0, c1), c2)) in d0
        .chunks_mut(n)
        .zip(d1.chunks_mut(n))
        .zip(d2.chunks_mut(n))
        .enumerate()
    {
        lots[li % workers].push((li, c0, c1, c2));
    }
    let f = &f;
    thread::scope(|s| {
        let mine = lots.remove(0);
        for lot in lots {
            s.spawn(move || {
                for (li, c0, c1, c2) in lot {
                    f(li, c0, c1, c2);
                }
            });
        }
        for (li, c0, c1, c2) in mine {
            f(li, c0, c1, c2);
        }
    });
}

/// `(0..count).map(f)` fanned across up to `workers` scoped threads;
/// results are returned in index order regardless of scheduling.
pub fn par_map<T, F>(workers: usize, count: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, count.max(1));
    if workers == 1 {
        return (0..count).map(f).collect();
    }
    let f = &f;
    let mut out: Vec<Option<T>> = (0..count).map(|_| None).collect();
    thread::scope(|s| {
        let handles: Vec<_> = (1..workers)
            .map(|w| {
                s.spawn(move || {
                    let mut res = Vec::new();
                    let mut i = w;
                    while i < count {
                        res.push((i, f(i)));
                        i += workers;
                    }
                    res
                })
            })
            .collect();
        let mut i = 0;
        while i < count {
            out[i] = Some(f(i));
            i += workers;
        }
        for h in handles {
            for (i, v) in h.join().expect("limb worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|o| o.expect("index covered")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_each_limb_is_worker_count_invariant() {
        let n = 64;
        let limbs = 7;
        let base: Vec<u64> = (0..(n * limbs) as u64).collect();
        let run = |workers: usize| {
            let mut d = base.clone();
            for_each_limb(workers, n, &mut d, |li, chunk| {
                for x in chunk.iter_mut() {
                    *x = x.wrapping_mul(li as u64 + 3).wrapping_add(1);
                }
            });
            d
        };
        let serial = run(1);
        for w in [2usize, 3, 4, 16] {
            assert_eq!(run(w), serial, "workers={w}");
        }
    }

    #[test]
    fn worker_buffers_are_private() {
        let n = 8;
        let mut d = vec![0u64; n * 5];
        for_each_limb_with(4, n, &mut d, |buf, li, chunk| {
            // A dirty buffer from another limb would corrupt the sums.
            buf.clear();
            buf.resize(n, li as u64);
            for (x, b) in chunk.iter_mut().zip(buf.iter()) {
                *x += b;
            }
        });
        for (li, chunk) in d.chunks(n).enumerate() {
            assert!(chunk.iter().all(|&x| x == li as u64), "limb {li}");
        }
    }

    #[test]
    fn for_each_limb3_is_worker_count_invariant() {
        let n = 32;
        let limbs = 5;
        let base: Vec<u64> = (0..(n * limbs) as u64).collect();
        let run = |workers: usize| {
            let mut a = base.clone();
            let mut b = base.clone();
            let mut c = base.clone();
            for_each_limb3(workers, n, &mut a, &mut b, &mut c, |li, c0, c1, c2| {
                for i in 0..n {
                    let s = c0[i].wrapping_add(li as u64);
                    c0[i] = s;
                    c1[i] = s.wrapping_mul(3);
                    c2[i] = s ^ c1[i];
                }
            });
            (a, b, c)
        };
        let serial = run(1);
        for w in [2usize, 3, 8] {
            assert_eq!(run(w), serial, "workers={w}");
        }
    }

    #[test]
    fn par_map_orders_results() {
        for w in [1usize, 2, 5] {
            let got = par_map(w, 23, |i| i * i);
            let want: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(got, want, "workers={w}");
        }
    }

    #[test]
    fn degenerate_sizes() {
        let mut empty: Vec<u64> = vec![];
        for_each_limb(4, 8, &mut empty, |_, _| panic!("no chunks"));
        assert!(par_map(4, 0, |i| i).is_empty());
        let mut one = vec![1u64; 4];
        for_each_limb(8, 4, &mut one, |li, c| {
            assert_eq!(li, 0);
            c[0] = 9;
        });
        assert_eq!(one[0], 9);
    }
}
