//! Negacyclic number-theoretic transform over Z_q[X]/(X^N + 1).
//!
//! Standard Cooley–Tukey / Gentleman–Sande butterflies with the ψ-twist
//! folded into the twiddle tables (Longa–Naehrig layout), one table per
//! RNS prime. Twiddles are stored with Shoup precomputations so the hot
//! loop is two multiplies and no `%`.

#[cfg(test)]
use super::modops::{add_mod, mul_mod, sub_mod};
use super::modops::{inv_mod, mul_mod_shoup_lazy, pow_mod, primitive_2nth_root, shoup_precompute};

/// Cache-block length for butterfly sweeps (§Perf step 7): 2048 × u64 =
/// 16 KiB, half a typical 32 KiB L1D, leaving room for twiddles. For
/// `n` beyond this, the transforms run the out-of-block stages globally
/// and then finish each contiguous block depth-first, so every stage of
/// the tail streams from L1 instead of re-walking the whole poly per
/// stage. `n <= NTT_BLOCK` degenerates to the monolithic sweep.
const NTT_BLOCK: usize = 1 << 11;

/// Precomputed NTT tables for one prime modulus.
#[derive(Clone, Debug)]
pub struct NttTable {
    pub q: u64,
    pub n: usize,
    /// ψ^bitrev(i) for forward transform.
    psi: Vec<u64>,
    psi_shoup: Vec<u64>,
    /// ψ^{-bitrev(i)} for inverse transform.
    inv_psi: Vec<u64>,
    inv_psi_shoup: Vec<u64>,
    /// N^{-1} mod q.
    inv_n: u64,
    inv_n_shoup: u64,
}

fn bit_reverse(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    pub fn new(q: u64, n: usize) -> Self {
        assert!(n.is_power_of_two());
        let two_n = (2 * n) as u64;
        assert_eq!(q % two_n, 1, "q must be 1 mod 2N");
        let psi_root = primitive_2nth_root(q, two_n);
        let inv_psi_root = inv_mod(psi_root, q);
        let bits = n.trailing_zeros();
        let mut psi = vec![0u64; n];
        let mut inv_psi = vec![0u64; n];
        for i in 0..n {
            let r = bit_reverse(i, bits);
            psi[i] = pow_mod(psi_root, r as u64, q);
            inv_psi[i] = pow_mod(inv_psi_root, r as u64, q);
        }
        let psi_shoup = psi.iter().map(|&w| shoup_precompute(w, q)).collect();
        let inv_psi_shoup = inv_psi.iter().map(|&w| shoup_precompute(w, q)).collect();
        let inv_n = inv_mod(n as u64, q);
        NttTable {
            q,
            n,
            psi,
            psi_shoup,
            inv_psi,
            inv_psi_shoup,
            inv_n,
            inv_n_shoup: shoup_precompute(inv_n, q),
        }
    }

    /// In-place forward negacyclic NTT (coefficient -> evaluation,
    /// bit-reversed output order internally; callers treat the result
    /// as an opaque evaluation-domain vector).
    ///
    /// Harvey-style lazy butterflies (§Perf step 4): intermediate
    /// values live in [0, 4q) and are only fully reduced in the final
    /// pass, removing two conditional subtractions per butterfly.
    /// Requires q < 2^62 (all parameter sets: q ≤ ~2^60).
    /// Cache-blocked (§Perf step 7): Cooley–Tukey stages whose
    /// sub-transforms exceed `NTT_BLOCK` run globally; once the
    /// sub-transforms fit one block, each contiguous block finishes all
    /// of its remaining stages depth-first (twiddle index
    /// `m_local·base + i_local` with `base = n/len + block_index` —
    /// exactly the global index the monolithic sweep would use), with
    /// the final 4q→q reduction folded into the per-block pass. Pure
    /// reordering of independent butterflies → bit-identical output.
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let q = self.q;
        let two_q = 2 * q;
        let mut t = self.n;
        let mut m = 1usize;
        // Global stages while one sub-transform exceeds a cache block.
        while m < self.n && t > NTT_BLOCK {
            t >>= 1;
            for i in 0..m {
                let w = self.psi[m + i];
                let ws = self.psi_shoup[m + i];
                let (lo, hi) = a[2 * i * t..2 * (i + 1) * t].split_at_mut(t);
                Self::ct_butterflies(lo, hi, w, ws, q, two_q);
            }
            m <<= 1;
        }
        // Remaining sub-transforms are independent contiguous blocks of
        // length t; finish each depth-first while it stays in L1.
        let nb = self.n / t;
        for (bi, block) in a.chunks_mut(t).enumerate() {
            self.ct_block(block, nb + bi);
            for x in block.iter_mut() {
                let mut v = *x;
                if v >= two_q {
                    v -= two_q;
                }
                if v >= q {
                    v -= q;
                }
                *x = v;
            }
        }
    }

    /// All Cooley–Tukey stages of one independent sub-transform
    /// (`base = n/len + block_index` maps local twiddle positions onto
    /// the global bit-reversed table; `base == 1` is the full array).
    fn ct_block(&self, a: &mut [u64], base: usize) {
        let q = self.q;
        let two_q = 2 * q;
        let len = a.len();
        let mut t = len;
        let mut m = 1usize;
        while m < len {
            t >>= 1;
            for i in 0..m {
                let idx = m * base + i;
                let w = self.psi[idx];
                let ws = self.psi_shoup[idx];
                let (lo, hi) = a[2 * i * t..2 * (i + 1) * t].split_at_mut(t);
                Self::ct_butterflies(lo, hi, w, ws, q, two_q);
            }
            m <<= 1;
        }
    }

    /// One twiddle group of Harvey lazy CT butterflies over zipped
    /// lower/upper halves (values < 4q in flight).
    #[inline(always)]
    fn ct_butterflies(lo: &mut [u64], hi: &mut [u64], w: u64, ws: u64, q: u64, two_q: u64) {
        for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
            // invariant: values < 4q
            let mut u = *x;
            if u >= two_q {
                u -= two_q; // < 2q
            }
            let v = mul_mod_shoup_lazy(*y, w, ws, q); // < 2q
            *x = u + v; // < 4q
            *y = u + two_q - v; // < 4q
        }
    }

    /// In-place inverse negacyclic NTT (evaluation -> coefficient),
    /// lazy Gentleman–Sande butterflies (values < 2q in flight).
    ///
    /// Accepts inputs in the **lazy** `[0, 2q)` domain (see
    /// [`crate::ckks::kernels`]) — the butterflies hold values < 2q
    /// regardless, and the final `inv_n` Shoup pass reduces exactly, so
    /// lazy and reduced representatives of the same residues produce
    /// bit-identical output.
    ///
    /// Cache-blocked like [`Self::forward`], mirrored: the early
    /// (small-span) Gentleman–Sande stages run depth-first per
    /// contiguous block, then the out-of-block merge stages run
    /// globally.
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let q = self.q;
        let two_q = 2 * q;
        let bsize = self.n.min(NTT_BLOCK);
        let nb = self.n / bsize;
        for (bi, block) in a.chunks_mut(bsize).enumerate() {
            self.gs_block(block, nb + bi);
        }
        // Global merge stages spanning more than one block.
        let mut t = bsize;
        let mut m = nb;
        while m > 1 {
            let h = m >> 1;
            for i in 0..h {
                let w = self.inv_psi[h + i];
                let ws = self.inv_psi_shoup[h + i];
                let (lo, hi) = a[2 * i * t..2 * (i + 1) * t].split_at_mut(t);
                Self::gs_butterflies(lo, hi, w, ws, q, two_q);
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            let v = mul_mod_shoup_lazy(*x, self.inv_n, self.inv_n_shoup, q);
            *x = if v >= q { v - q } else { v };
        }
    }

    /// All in-block Gentleman–Sande stages of one contiguous block
    /// (`base = n/len + block_index`, same twiddle-index algebra as
    /// [`Self::ct_block`]; `base == 1` is the full array).
    fn gs_block(&self, a: &mut [u64], base: usize) {
        let q = self.q;
        let two_q = 2 * q;
        let len = a.len();
        let mut t = 1usize;
        let mut m = len;
        while m > 1 {
            let h = m >> 1;
            for i in 0..h {
                let idx = h * base + i;
                let w = self.inv_psi[idx];
                let ws = self.inv_psi_shoup[idx];
                let (lo, hi) = a[2 * i * t..2 * (i + 1) * t].split_at_mut(t);
                Self::gs_butterflies(lo, hi, w, ws, q, two_q);
            }
            t <<= 1;
            m = h;
        }
    }

    /// One twiddle group of lazy GS butterflies over zipped halves
    /// (values < 2q in flight).
    #[inline(always)]
    fn gs_butterflies(lo: &mut [u64], hi: &mut [u64], w: u64, ws: u64, q: u64, two_q: u64) {
        for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
            // invariant: values < 2q
            let u = *x;
            let v = *y;
            let mut s = u + v; // < 4q
            if s >= two_q {
                s -= two_q; // < 2q
            }
            *x = s;
            // (u - v + 2q) < 4q; lazy Shoup gives < 2q
            *y = mul_mod_shoup_lazy(u + two_q - v, w, ws, q);
        }
    }
}

/// Schoolbook negacyclic convolution (O(N^2)) — test oracle only.
#[cfg(test)]
pub fn negacyclic_mul_naive(a: &[u64], b: &[u64], q: u64) -> Vec<u64> {
    let n = a.len();
    let mut out = vec![0u64; n];
    for i in 0..n {
        for j in 0..n {
            let p = mul_mod(a[i], b[j], q);
            let k = i + j;
            if k < n {
                out[k] = add_mod(out[k], p, q);
            } else {
                out[k - n] = sub_mod(out[k - n], p, q);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn table(n: usize) -> NttTable {
        // 0x0FFF... prime congruent 1 mod 2n: generate via params helper.
        let mut taken = vec![];
        let q = crate::ckks::params::CkksParams::gen_primes(n, 50, 1, &mut taken)[0];
        NttTable::new(q, n)
    }

    /// The pre-blocking monolithic sweeps, kept verbatim as the
    /// reference the cache-blocked transforms must match bit-for-bit.
    fn monolithic_forward(t: &NttTable, a: &mut [u64]) {
        let q = t.q;
        let two_q = 2 * q;
        let mut tt = t.n;
        let mut m = 1usize;
        while m < t.n {
            tt >>= 1;
            for i in 0..m {
                let w = t.psi[m + i];
                let ws = t.psi_shoup[m + i];
                let j1 = 2 * i * tt;
                for j in j1..j1 + tt {
                    let mut u = a[j];
                    if u >= two_q {
                        u -= two_q;
                    }
                    let v = mul_mod_shoup_lazy(a[j + tt], w, ws, q);
                    a[j] = u + v;
                    a[j + tt] = u + two_q - v;
                }
            }
            m <<= 1;
        }
        for x in a.iter_mut() {
            let mut v = *x;
            if v >= two_q {
                v -= two_q;
            }
            if v >= q {
                v -= q;
            }
            *x = v;
        }
    }

    fn monolithic_inverse(t: &NttTable, a: &mut [u64]) {
        let q = t.q;
        let two_q = 2 * q;
        let mut tt = 1usize;
        let mut m = t.n;
        while m > 1 {
            let h = m >> 1;
            let mut j1 = 0usize;
            for i in 0..h {
                let w = t.inv_psi[h + i];
                let ws = t.inv_psi_shoup[h + i];
                for j in j1..j1 + tt {
                    let u = a[j];
                    let v = a[j + tt];
                    let mut s = u + v;
                    if s >= two_q {
                        s -= two_q;
                    }
                    a[j] = s;
                    a[j + tt] = mul_mod_shoup_lazy(u + two_q - v, w, ws, q);
                }
                j1 += 2 * tt;
            }
            tt <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            let v = mul_mod_shoup_lazy(*x, t.inv_n, t.inv_n_shoup, q);
            *x = if v >= q { v - q } else { v };
        }
    }

    #[test]
    fn blocked_matches_monolithic_beyond_block_size() {
        // 8192 > NTT_BLOCK = 2048: the blocked code path (global stages
        // + per-block depth-first finish) must be bit-identical to the
        // monolithic sweep on both directions. 2048 pins the
        // degenerate single-block path against the same reference.
        for n in [NTT_BLOCK, 4 * NTT_BLOCK] {
            let t = table(n);
            let mut r = Xoshiro256pp::new(0xB10C + n as u64);
            let orig: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let mut blocked = orig.clone();
            let mut mono = orig.clone();
            t.forward(&mut blocked);
            monolithic_forward(&t, &mut mono);
            assert_eq!(blocked, mono, "forward n={n}");
            t.inverse(&mut blocked);
            monolithic_inverse(&t, &mut mono);
            assert_eq!(blocked, mono, "inverse n={n}");
            assert_eq!(blocked, orig, "roundtrip n={n}");
        }
    }

    #[test]
    fn inverse_accepts_lazy_domain_inputs() {
        // Lazy [0, 2q) representatives of the same residues must give
        // bit-identical coefficients (the contract mul_assign_lazy +
        // rescale relies on).
        let n = 256;
        let t = table(n);
        let mut r = Xoshiro256pp::new(77);
        let reduced: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
        let mut lazy: Vec<u64> = reduced
            .iter()
            .map(|&x| if r.next_below(2) == 1 { x + t.q } else { x })
            .collect();
        let mut base = reduced.clone();
        t.inverse(&mut base);
        t.inverse(&mut lazy);
        assert_eq!(lazy, base);
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for n in [8usize, 64, 1024] {
            let t = table(n);
            let mut r = Xoshiro256pp::new(n as u64);
            let orig: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let mut a = orig.clone();
            t.forward(&mut a);
            assert_ne!(a, orig);
            t.inverse(&mut a);
            assert_eq!(a, orig);
        }
    }

    #[test]
    fn pointwise_equals_negacyclic_convolution() {
        for n in [8usize, 32, 128] {
            let t = table(n);
            let mut r = Xoshiro256pp::new(99 + n as u64);
            let a: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let b: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let expect = negacyclic_mul_naive(&a, &b, t.q);
            let mut fa = a.clone();
            let mut fb = b.clone();
            t.forward(&mut fa);
            t.forward(&mut fb);
            let mut fc: Vec<u64> = fa
                .iter()
                .zip(&fb)
                .map(|(&x, &y)| mul_mod(x, y, t.q))
                .collect();
            t.inverse(&mut fc);
            assert_eq!(fc, expect, "n={n}");
        }
    }

    #[test]
    fn linearity() {
        let n = 256;
        let t = table(n);
        let mut r = Xoshiro256pp::new(7);
        let a: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
        let b: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| add_mod(x, y, t.q)).collect();
        let (mut fa, mut fb, mut fs) = (a.clone(), b.clone(), sum.clone());
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut fs);
        for i in 0..n {
            assert_eq!(fs[i], add_mod(fa[i], fb[i], t.q));
        }
    }

    #[test]
    fn x_times_x_n_minus_1_wraps_negatively() {
        // (X) * (X^{N-1}) = X^N = -1 in the negacyclic ring.
        let n = 16;
        let t = table(n);
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        a[1] = 1;
        b[n - 1] = 1;
        let mut fa = a.clone();
        let mut fb = b.clone();
        t.forward(&mut fa);
        t.forward(&mut fb);
        let mut fc: Vec<u64> = fa.iter().zip(&fb).map(|(&x, &y)| mul_mod(x, y, t.q)).collect();
        t.inverse(&mut fc);
        let mut expect = vec![0u64; n];
        expect[0] = t.q - 1; // -1
        assert_eq!(fc, expect);
    }
}
