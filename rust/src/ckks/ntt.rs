//! Negacyclic number-theoretic transform over Z_q[X]/(X^N + 1).
//!
//! Standard Cooley–Tukey / Gentleman–Sande butterflies with the ψ-twist
//! folded into the twiddle tables (Longa–Naehrig layout), one table per
//! RNS prime. Twiddles are stored with Shoup precomputations so the hot
//! loop is two multiplies and no `%`.

#[cfg(test)]
use super::modops::{add_mod, mul_mod, sub_mod};
use super::modops::{inv_mod, pow_mod, primitive_2nth_root, shoup_precompute};

/// Precomputed NTT tables for one prime modulus.
#[derive(Clone, Debug)]
pub struct NttTable {
    pub q: u64,
    pub n: usize,
    /// ψ^bitrev(i) for forward transform.
    psi: Vec<u64>,
    psi_shoup: Vec<u64>,
    /// ψ^{-bitrev(i)} for inverse transform.
    inv_psi: Vec<u64>,
    inv_psi_shoup: Vec<u64>,
    /// N^{-1} mod q.
    inv_n: u64,
    inv_n_shoup: u64,
}

fn bit_reverse(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    pub fn new(q: u64, n: usize) -> Self {
        assert!(n.is_power_of_two());
        let two_n = (2 * n) as u64;
        assert_eq!(q % two_n, 1, "q must be 1 mod 2N");
        let psi_root = primitive_2nth_root(q, two_n);
        let inv_psi_root = inv_mod(psi_root, q);
        let bits = n.trailing_zeros();
        let mut psi = vec![0u64; n];
        let mut inv_psi = vec![0u64; n];
        for i in 0..n {
            let r = bit_reverse(i, bits);
            psi[i] = pow_mod(psi_root, r as u64, q);
            inv_psi[i] = pow_mod(inv_psi_root, r as u64, q);
        }
        let psi_shoup = psi.iter().map(|&w| shoup_precompute(w, q)).collect();
        let inv_psi_shoup = inv_psi.iter().map(|&w| shoup_precompute(w, q)).collect();
        let inv_n = inv_mod(n as u64, q);
        NttTable {
            q,
            n,
            psi,
            psi_shoup,
            inv_psi,
            inv_psi_shoup,
            inv_n,
            inv_n_shoup: shoup_precompute(inv_n, q),
        }
    }

    /// In-place forward negacyclic NTT (coefficient -> evaluation,
    /// bit-reversed output order internally; callers treat the result
    /// as an opaque evaluation-domain vector).
    ///
    /// Harvey-style lazy butterflies (§Perf step 4): intermediate
    /// values live in [0, 4q) and are only fully reduced in the final
    /// pass, removing two conditional subtractions per butterfly.
    /// Requires q < 2^62 (all parameter sets: q ≤ ~2^60).
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let q = self.q;
        let two_q = 2 * q;
        let mut t = self.n;
        let mut m = 1usize;
        while m < self.n {
            t >>= 1;
            for i in 0..m {
                let w = self.psi[m + i];
                let ws = self.psi_shoup[m + i];
                let j1 = 2 * i * t;
                for j in j1..j1 + t {
                    // invariant: a[*] < 4q
                    let mut u = a[j];
                    if u >= two_q {
                        u -= two_q; // < 2q
                    }
                    let v = super::modops::mul_mod_shoup_lazy(a[j + t], w, ws, q); // < 2q
                    a[j] = u + v; // < 4q
                    a[j + t] = u + two_q - v; // < 4q
                }
            }
            m <<= 1;
        }
        for x in a.iter_mut() {
            let mut v = *x;
            if v >= two_q {
                v -= two_q;
            }
            if v >= q {
                v -= q;
            }
            *x = v;
        }
    }

    /// In-place inverse negacyclic NTT (evaluation -> coefficient),
    /// lazy Gentleman–Sande butterflies (values < 2q in flight).
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let q = self.q;
        let two_q = 2 * q;
        let mut t = 1usize;
        let mut m = self.n;
        while m > 1 {
            let h = m >> 1;
            let mut j1 = 0usize;
            for i in 0..h {
                let w = self.inv_psi[h + i];
                let ws = self.inv_psi_shoup[h + i];
                for j in j1..j1 + t {
                    // invariant: a[*] < 2q
                    let u = a[j];
                    let v = a[j + t];
                    let mut s = u + v; // < 4q
                    if s >= two_q {
                        s -= two_q; // < 2q
                    }
                    a[j] = s;
                    // (u - v + 2q) < 4q; lazy Shoup gives < 2q
                    a[j + t] =
                        super::modops::mul_mod_shoup_lazy(u + two_q - v, w, ws, q);
                }
                j1 += 2 * t;
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            let v = super::modops::mul_mod_shoup_lazy(*x, self.inv_n, self.inv_n_shoup, q);
            *x = if v >= q { v - q } else { v };
        }
    }
}

/// Schoolbook negacyclic convolution (O(N^2)) — test oracle only.
#[cfg(test)]
pub fn negacyclic_mul_naive(a: &[u64], b: &[u64], q: u64) -> Vec<u64> {
    let n = a.len();
    let mut out = vec![0u64; n];
    for i in 0..n {
        for j in 0..n {
            let p = mul_mod(a[i], b[j], q);
            let k = i + j;
            if k < n {
                out[k] = add_mod(out[k], p, q);
            } else {
                out[k - n] = sub_mod(out[k - n], p, q);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn table(n: usize) -> NttTable {
        // 0x0FFF... prime congruent 1 mod 2n: generate via params helper.
        let mut taken = vec![];
        let q = crate::ckks::params::CkksParams::gen_primes(n, 50, 1, &mut taken)[0];
        NttTable::new(q, n)
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for n in [8usize, 64, 1024] {
            let t = table(n);
            let mut r = Xoshiro256pp::new(n as u64);
            let orig: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let mut a = orig.clone();
            t.forward(&mut a);
            assert_ne!(a, orig);
            t.inverse(&mut a);
            assert_eq!(a, orig);
        }
    }

    #[test]
    fn pointwise_equals_negacyclic_convolution() {
        for n in [8usize, 32, 128] {
            let t = table(n);
            let mut r = Xoshiro256pp::new(99 + n as u64);
            let a: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let b: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
            let expect = negacyclic_mul_naive(&a, &b, t.q);
            let mut fa = a.clone();
            let mut fb = b.clone();
            t.forward(&mut fa);
            t.forward(&mut fb);
            let mut fc: Vec<u64> = fa
                .iter()
                .zip(&fb)
                .map(|(&x, &y)| mul_mod(x, y, t.q))
                .collect();
            t.inverse(&mut fc);
            assert_eq!(fc, expect, "n={n}");
        }
    }

    #[test]
    fn linearity() {
        let n = 256;
        let t = table(n);
        let mut r = Xoshiro256pp::new(7);
        let a: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
        let b: Vec<u64> = (0..n).map(|_| r.next_below(t.q)).collect();
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| add_mod(x, y, t.q)).collect();
        let (mut fa, mut fb, mut fs) = (a.clone(), b.clone(), sum.clone());
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut fs);
        for i in 0..n {
            assert_eq!(fs[i], add_mod(fa[i], fb[i], t.q));
        }
    }

    #[test]
    fn x_times_x_n_minus_1_wraps_negatively() {
        // (X) * (X^{N-1}) = X^N = -1 in the negacyclic ring.
        let n = 16;
        let t = table(n);
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        a[1] = 1;
        b[n - 1] = 1;
        let mut fa = a.clone();
        let mut fb = b.clone();
        t.forward(&mut fa);
        t.forward(&mut fb);
        let mut fc: Vec<u64> = fa.iter().zip(&fb).map(|(&x, &y)| mul_mod(x, y, t.q)).collect();
        t.inverse(&mut fc);
        let mut expect = vec![0u64; n];
        expect[0] = t.q - 1; // -1
        assert_eq!(fc, expect);
    }
}
