//! RNS ("double-CRT") polynomials over the CKKS modulus chain.
//!
//! A [`RnsPoly`] stores one residue limb per active prime — all limbs
//! in **one contiguous `Vec<u64>`** with stride `N` (§Perf step 6:
//! flat limb storage), so cloning a polynomial is one allocation and
//! every kernel is one cache-friendly sweep. The active basis is
//! `q_0..q_level` plus, transiently during key-switching, the special
//! prime. Polynomials live either in coefficient form or in NTT
//! (evaluation) form; element-wise ring multiplication requires NTT
//! form.
//!
//! The module also owns [`CkksContext`] (parameter set + NTT tables +
//! per-prime Barrett constants + Shoup tables for the loop-invariant
//! rescale/mod-down multipliers + the limb-parallel worker knob) and
//! the exact CRT → centered big-integer → f64 reconstruction used on
//! decode ([`BigUintLite`], [`CrtRecon`]).
//!
//! No per-coefficient hot loop performs a u128 `%`: every element-wise
//! sweep routes through the batch kernels in [`super::kernels`]
//! (Barrett multiplies, the lazy `[0, 2q)` fused chains, rescale /
//! mod-down adjustments), single-word reductions use
//! [`super::modops::barrett_reduce_64`], and loop-invariant
//! multipliers (rescale and mod-down inverses, scalar broadcasts) use
//! Shoup multiplication. `modops::mul_mod` survives as the test
//! oracle only.

use super::kernels;
use super::modops::{
    add_mod, barrett_precompute, inv_mod, mul_mod, mul_mod_shoup, neg_mod, shoup_precompute,
    sub_mod,
};
use super::ntt::NttTable;
use super::parallel;
use super::params::ParamsRef;
use super::scratch::Scratch;
use crate::lockutil::{read_unpoisoned, write_unpoisoned};
use crate::rng::Xoshiro256pp;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared immutable context: parameters, NTT tables (one per chain
/// prime + special), and per-level precomputations.
pub struct CkksContext {
    pub params: ParamsRef,
    /// NTT tables for moduli[0..] (chain order).
    pub tables: Vec<NttTable>,
    /// NTT table for the special key-switching prime.
    pub special_table: NttTable,
    /// inv(q_j) mod q_i for rescale: inv_q_to[j][i] = q_j^{-1} mod q_i (i < j).
    inv_q_to: Vec<Vec<u64>>,
    /// Shoup companions of `inv_q_to` (loop-invariant rescale multiplier).
    inv_q_to_shoup: Vec<Vec<u64>>,
    /// inv(special) mod q_i.
    inv_special: Vec<u64>,
    /// Shoup companions of `inv_special`.
    inv_special_shoup: Vec<u64>,
    /// Barrett constant floor(2^128/q_i) per chain prime (lo, hi).
    barrett: Vec<(u64, u64)>,
    /// Barrett constant of the special prime.
    barrett_special: (u64, u64),
    /// ψ-exponent of each NTT output slot: slot i holds c(ψ^{ntt_exp[i]}).
    /// The pattern is determined by the butterfly structure alone, so
    /// one table serves every prime.
    ntt_exp: Vec<usize>,
    /// Inverse map: odd exponent e (mod 2N) → NTT slot index.
    exp_to_slot: Vec<u32>,
    /// Cached NTT-domain Galois permutations, keyed by Galois element.
    galois_perms: std::sync::RwLock<std::collections::HashMap<usize, Arc<Vec<u32>>>>,
    /// Limb-parallel worker count for the heavy per-limb loops
    /// (1 = serial; see [`CkksContext::set_workers`]).
    workers: AtomicUsize,
}

pub type ContextRef = Arc<CkksContext>;

/// Environment override for the limb-parallel worker count.
pub const WORKERS_ENV: &str = "CRYPTOTREE_CKKS_WORKERS";

impl CkksContext {
    pub fn new(params: ParamsRef) -> ContextRef {
        let n = params.n;
        let tables: Vec<NttTable> = params.moduli.iter().map(|&q| NttTable::new(q, n)).collect();
        let special_table = NttTable::new(params.special, n);
        let inv_q_to: Vec<Vec<u64>> = params
            .moduli
            .iter()
            .enumerate()
            .map(|(j, &qj)| {
                params.moduli[..j]
                    .iter()
                    .map(|&qi| inv_mod(qj % qi, qi))
                    .collect()
            })
            .collect();
        let inv_q_to_shoup: Vec<Vec<u64>> = inv_q_to
            .iter()
            .enumerate()
            .map(|(j, row)| {
                row.iter()
                    .zip(&params.moduli[..j])
                    .map(|(&inv, &qi)| shoup_precompute(inv, qi))
                    .collect()
            })
            .collect();
        let inv_special: Vec<u64> = params
            .moduli
            .iter()
            .map(|&qi| inv_mod(params.special % qi, qi))
            .collect();
        let inv_special_shoup = inv_special
            .iter()
            .zip(&params.moduli)
            .map(|(&inv, &qi)| shoup_precompute(inv, qi))
            .collect();
        let barrett = params.moduli.iter().map(|&q| barrett_precompute(q)).collect();
        let barrett_special = barrett_precompute(params.special);
        // Probe the NTT's evaluation order: NTT(X) gives ψ^{e_i} in
        // slot i; match against the power table to recover e_i.
        let (ntt_exp, exp_to_slot) = {
            let q = params.moduli[0];
            let t = &tables[0];
            let mut probe = vec![0u64; n];
            probe[1] = 1; // the monomial X
            t.forward(&mut probe);
            let two_n = 2 * n;
            let psi = {
                // recover ψ as the value with exponent 1: build the
                // power→exponent map from any generator found in slot 0
                // wouldn't be unique; instead rebuild ψ directly.
                super::modops::primitive_2nth_root(q, two_n as u64)
            };
            let mut pow_to_exp = std::collections::HashMap::with_capacity(two_n);
            let mut acc = 1u64;
            for e in 0..two_n {
                pow_to_exp.insert(acc, e);
                acc = super::modops::mul_mod(acc, psi, q);
            }
            let ntt_exp: Vec<usize> = probe
                .iter()
                .map(|v| *pow_to_exp.get(v).expect("NTT slot is not a ψ power"))
                .collect();
            let mut exp_to_slot = vec![u32::MAX; two_n];
            for (i, &e) in ntt_exp.iter().enumerate() {
                exp_to_slot[e] = i as u32;
            }
            (ntt_exp, exp_to_slot)
        };
        let workers = std::env::var(WORKERS_ENV)
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&w| w >= 1)
            .unwrap_or(1);
        Arc::new(CkksContext {
            params,
            tables,
            special_table,
            inv_q_to,
            inv_q_to_shoup,
            inv_special,
            inv_special_shoup,
            barrett,
            barrett_special,
            ntt_exp,
            exp_to_slot,
            galois_perms: std::sync::RwLock::new(std::collections::HashMap::new()),
            workers: AtomicUsize::new(workers),
        })
    }

    /// NTT-domain permutation for the Galois automorphism X→X^g:
    /// `out[i] = in[perm[i]]` applied per limb (cached per g).
    pub fn galois_perm(&self, g: usize) -> Arc<Vec<u32>> {
        if let Some(p) = read_unpoisoned(&self.galois_perms).get(&g) {
            return p.clone();
        }
        let two_n = 2 * self.n();
        let perm: Vec<u32> = self
            .ntt_exp
            .iter()
            .map(|&e| {
                let src_exp = (e * g) % two_n;
                let j = self.exp_to_slot[src_exp];
                debug_assert!(j != u32::MAX, "even exponent in Galois map");
                j
            })
            .collect();
        let perm = Arc::new(perm);
        write_unpoisoned(&self.galois_perms).insert(g, perm.clone());
        perm
    }

    /// Pre-populate the Galois-permutation cache for the given
    /// **rotation steps** (converted internally to Galois elements
    /// `5^r mod 2N`), so a serving hot path only ever takes the read
    /// side of the permutation lock. Idempotent; zero steps are
    /// ignored.
    pub fn galois_perm_prewarm(&self, steps: &[usize]) {
        let two_n = 2 * self.n();
        for &r in steps {
            if r == 0 {
                continue;
            }
            let _ = self.galois_perm(super::modops::galois_element(r, two_n));
        }
    }

    /// Number of Galois permutations currently cached (test hook).
    pub fn galois_perms_cached(&self) -> usize {
        read_unpoisoned(&self.galois_perms).len()
    }

    pub fn n(&self) -> usize {
        self.params.n
    }

    /// Modulus of chain limb `i`.
    pub fn q(&self, i: usize) -> u64 {
        self.params.moduli[i]
    }

    /// Limb-parallel worker count used by the heavy per-limb loops.
    pub fn workers(&self) -> usize {
        self.workers.load(Ordering::Relaxed)
    }

    /// Set the limb-parallel worker count (1 = serial, the default;
    /// initial value may come from the `CRYPTOTREE_CKKS_WORKERS` env
    /// var). Outputs are bit-identical for every setting — limbs are
    /// independent — so this is purely a throughput knob.
    pub fn set_workers(&self, workers: usize) {
        self.workers.store(workers.max(1), Ordering::Relaxed);
    }

    /// Barrett constant of chain prime `i`.
    #[inline]
    pub(crate) fn barrett_ratio(&self, i: usize) -> (u64, u64) {
        self.barrett[i]
    }

    /// Barrett constant of the special prime.
    #[inline]
    pub(crate) fn barrett_ratio_special(&self) -> (u64, u64) {
        self.barrett_special
    }

    /// (modulus, Barrett constant) of limb `li` in a poly with
    /// `n_limbs` active limbs, `special` flagging a special last limb.
    #[inline]
    fn limb_modulus(&self, li: usize, n_limbs: usize, special: bool) -> (u64, (u64, u64)) {
        if special && li == n_limbs - 1 {
            (self.params.special, self.barrett_special)
        } else {
            (self.params.moduli[li], self.barrett[li])
        }
    }
}

/// Polynomial in RNS representation, flat limb storage: limb `i`
/// occupies `data[i*n .. (i+1)*n]`, chain order, special last when
/// present.
#[derive(Clone, Debug)]
pub struct RnsPoly {
    /// Highest active chain-prime index; active chain limbs = level+1.
    pub level: usize,
    /// Whether a special-prime limb is appended after the chain limbs.
    pub special: bool,
    /// NTT (evaluation) form?
    pub is_ntt: bool,
    /// Ring degree (limb stride).
    pub(crate) n: usize,
    /// All residue limbs, contiguous.
    pub(crate) data: Vec<u64>,
}

impl RnsPoly {
    pub fn n_limbs(level: usize, special: bool) -> usize {
        level + 1 + special as usize
    }

    /// Reassemble a polynomial from its serialized parts — the wire
    /// codec's ([`crate::net`]) deserialization entry point. `data` is
    /// the flat limb payload in [`RnsPoly::data`] order.
    ///
    /// # Panics
    ///
    /// If `level` exceeds the context's modulus chain or `data` is not
    /// exactly `n_limbs(level, special) * ctx.n()` residues. Residue
    /// *range* validation (each value < its limb modulus) is the
    /// caller's job — the net codec checks every residue against the
    /// context before calling.
    pub fn from_raw_parts(
        ctx: &CkksContext,
        level: usize,
        special: bool,
        is_ntt: bool,
        data: Vec<u64>,
    ) -> Self {
        assert!(
            level < ctx.params.moduli.len(),
            "level exceeds the modulus chain"
        );
        assert_eq!(
            data.len(),
            Self::n_limbs(level, special) * ctx.n(),
            "flat limb payload length mismatch"
        );
        RnsPoly {
            level,
            special,
            is_ntt,
            n: ctx.n(),
            data,
        }
    }

    /// Number of limbs currently stored.
    #[inline]
    pub fn active_limbs(&self) -> usize {
        debug_assert!(self.n > 0);
        self.data.len() / self.n
    }

    /// The whole flat limb payload (limb `i` at `data[i*n..(i+1)*n]`).
    /// Two polys with equal flags and equal `data()` are bit-identical.
    #[inline]
    pub fn data(&self) -> &[u64] {
        &self.data
    }

    /// Residue limb `i` (read).
    #[inline]
    pub fn limb(&self, i: usize) -> &[u64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Residue limb `i` (write).
    #[inline]
    pub fn limb_mut(&mut self, i: usize) -> &mut [u64] {
        &mut self.data[i * self.n..(i + 1) * self.n]
    }

    /// The whole flat limb payload, mutable (crate kernels only).
    #[inline]
    pub(crate) fn data_mut(&mut self) -> &mut [u64] {
        &mut self.data
    }

    /// Two distinct limbs mutably at once (`i < j`).
    #[inline]
    pub fn limbs_pair_mut(&mut self, i: usize, j: usize) -> (&mut [u64], &mut [u64]) {
        debug_assert!(i < j);
        let n = self.n;
        let (head, tail) = self.data.split_at_mut(j * n);
        (&mut head[i * n..(i + 1) * n], &mut tail[..n])
    }

    /// Give the limb buffer back to a scratch pool.
    pub fn recycle(self, scratch: &mut Scratch) {
        scratch.put(self.data);
    }

    /// Consume into the raw limb buffer.
    pub fn into_data(self) -> Vec<u64> {
        self.data
    }

    pub fn zero(ctx: &CkksContext, level: usize, special: bool, is_ntt: bool) -> Self {
        RnsPoly {
            level,
            special,
            is_ntt,
            n: ctx.n(),
            data: vec![0u64; Self::n_limbs(level, special) * ctx.n()],
        }
    }

    /// Zero poly whose buffer comes from (and can return to) `scratch`.
    pub fn zero_in(
        ctx: &CkksContext,
        level: usize,
        special: bool,
        is_ntt: bool,
        scratch: &mut Scratch,
    ) -> Self {
        RnsPoly {
            level,
            special,
            is_ntt,
            n: ctx.n(),
            data: scratch.take(Self::n_limbs(level, special) * ctx.n()),
        }
    }

    /// Clone whose buffer comes from `scratch` (single memcpy).
    pub fn clone_in(&self, scratch: &mut Scratch) -> Self {
        RnsPoly {
            level: self.level,
            special: self.special,
            is_ntt: self.is_ntt,
            n: self.n,
            data: scratch.take_copy(&self.data),
        }
    }

    fn modulus_of(&self, ctx: &CkksContext, limb: usize) -> u64 {
        if self.special && limb == self.active_limbs() - 1 {
            ctx.params.special
        } else {
            ctx.params.moduli[limb]
        }
    }

    /// Build from small signed coefficients (keys, errors).
    pub fn from_signed(ctx: &CkksContext, coeffs: &[i64], level: usize, special: bool) -> Self {
        let mut p = Self::zero(ctx, level, special, false);
        let nl = p.active_limbs();
        for li in 0..nl {
            let q = p.modulus_of(ctx, li);
            let limb = p.limb_mut(li);
            for (x, &c) in limb.iter_mut().zip(coeffs.iter()) {
                *x = if c >= 0 {
                    (c as u64) % q
                } else {
                    // neg_mod keeps c ≡ 0 (mod q) at 0 without the
                    // former second `% q` pass.
                    neg_mod(((-c) as u64) % q, q)
                };
            }
        }
        p
    }

    /// Build from big signed coefficients (encoded plaintexts). i128
    /// covers every scale this library produces (|coeff| < 2^120).
    pub fn from_signed_wide(
        ctx: &CkksContext,
        coeffs: &[i128],
        level: usize,
        special: bool,
    ) -> Self {
        let mut p = Self::zero(ctx, level, special, false);
        let nl = p.active_limbs();
        for li in 0..nl {
            let q = p.modulus_of(ctx, li) as i128;
            let limb = p.limb_mut(li);
            for (x, &c) in limb.iter_mut().zip(coeffs.iter()) {
                *x = c.rem_euclid(q) as u64;
            }
        }
        p
    }

    /// Uniform random poly over the active basis (public-key `a`,
    /// key-switching randomness).
    pub fn sample_uniform(
        ctx: &CkksContext,
        rng: &mut Xoshiro256pp,
        level: usize,
        special: bool,
        is_ntt: bool,
    ) -> Self {
        let mut p = Self::zero(ctx, level, special, is_ntt);
        let nl = p.active_limbs();
        for li in 0..nl {
            let q = p.modulus_of(ctx, li);
            for x in p.limb_mut(li).iter_mut() {
                *x = rng.next_below(q);
            }
        }
        p
    }

    /// Ternary secret polynomial (coeff domain).
    pub fn sample_ternary(
        ctx: &CkksContext,
        rng: &mut Xoshiro256pp,
        level: usize,
        special: bool,
    ) -> Self {
        let coeffs: Vec<i64> = (0..ctx.n()).map(|_| rng.ternary()).collect();
        Self::from_signed(ctx, &coeffs, level, special)
    }

    /// Discrete-Gaussian error polynomial (coeff domain).
    pub fn sample_error(
        ctx: &CkksContext,
        rng: &mut Xoshiro256pp,
        level: usize,
        special: bool,
    ) -> Self {
        let sigma = ctx.params.sigma;
        let coeffs: Vec<i64> = (0..ctx.n()).map(|_| rng.discrete_gaussian(sigma)).collect();
        Self::from_signed(ctx, &coeffs, level, special)
    }

    /// NTT/iNTT every limb, fanned over `workers` threads.
    fn ntt_limbs(&mut self, ctx: &CkksContext, workers: usize, forward: bool) {
        let nl = self.active_limbs();
        let special = self.special;
        parallel::for_each_limb(workers, self.n, &mut self.data, |li, chunk| {
            let table = if special && li == nl - 1 {
                &ctx.special_table
            } else {
                &ctx.tables[li]
            };
            if forward {
                table.forward(chunk);
            } else {
                table.inverse(chunk);
            }
        });
    }

    pub fn to_ntt(&mut self, ctx: &CkksContext) {
        if self.is_ntt {
            return;
        }
        self.ntt_limbs(ctx, ctx.workers(), true);
        self.is_ntt = true;
    }

    /// `to_ntt` pinned to the calling thread — used inside already
    /// limb-parallel sections to avoid nested thread fan-out.
    pub(crate) fn to_ntt_serial(&mut self, ctx: &CkksContext) {
        if self.is_ntt {
            return;
        }
        self.ntt_limbs(ctx, 1, true);
        self.is_ntt = true;
    }

    pub fn from_ntt(&mut self, ctx: &CkksContext) {
        if !self.is_ntt {
            return;
        }
        self.ntt_limbs(ctx, ctx.workers(), false);
        self.is_ntt = false;
    }

    fn assert_compat(&self, other: &Self) {
        debug_assert_eq!(self.level, other.level);
        debug_assert_eq!(self.special, other.special);
        debug_assert_eq!(self.is_ntt, other.is_ntt);
    }

    pub fn add_assign(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        for li in 0..self.active_limbs() {
            let q = self.modulus_of(ctx, li);
            let b = other.limb(li);
            let a = self.limb_mut(li);
            kernels::add_mod_slice(a, b, q);
        }
    }

    pub fn sub_assign(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        for li in 0..self.active_limbs() {
            let q = self.modulus_of(ctx, li);
            let b = other.limb(li);
            let a = self.limb_mut(li);
            kernels::sub_mod_slice(a, b, q);
        }
    }

    pub fn neg_assign(&mut self, ctx: &CkksContext) {
        for li in 0..self.active_limbs() {
            let q = self.modulus_of(ctx, li);
            for x in self.limb_mut(li).iter_mut() {
                *x = neg_mod(*x, q);
            }
        }
    }

    /// Double in place: `self = 2·self` — the aliasing-safe form of
    /// `add_assign(self, self)` (bit-identical result).
    pub fn double_assign(&mut self, ctx: &CkksContext) {
        for li in 0..self.active_limbs() {
            let q = self.modulus_of(ctx, li);
            for x in self.limb_mut(li).iter_mut() {
                *x = add_mod(*x, *x, q);
            }
        }
    }

    /// Element-wise ring multiplication; both operands must be in NTT
    /// form. Barrett kernel (no u128 `%`), limb-parallel.
    pub fn mul_assign(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        debug_assert!(self.is_ntt, "ring mul requires NTT form");
        let nl = self.active_limbs();
        let special = self.special;
        parallel::for_each_limb(ctx.workers(), self.n, &mut self.data, |li, a| {
            let (q, ratio) = ctx.limb_modulus(li, nl, special);
            kernels::mul_mod_slice(a, other.limb(li), q, ratio);
        });
    }

    /// Element-wise ring multiplication leaving residues in the **lazy**
    /// `[0, 2q)` domain (one conditional subtraction per coefficient
    /// skipped — see the domain rules in [`super::kernels`]). The
    /// caller must immediately feed `self` into a fully-reducing
    /// consumer; in practice that is [`Self::rescale`], whose inverse
    /// NTT accepts lazy inputs and whose output is exactly reduced, so
    /// the fused mul-plain → rescale chain stays bit-identical to the
    /// unfused path.
    pub(crate) fn mul_assign_lazy(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        debug_assert!(self.is_ntt, "ring mul requires NTT form");
        debug_assert!(!self.special, "lazy mul is a ciphertext-path kernel");
        let nl = self.active_limbs();
        let special = self.special;
        parallel::for_each_limb(ctx.workers(), self.n, &mut self.data, |li, a| {
            let (q, ratio) = ctx.limb_modulus(li, nl, special);
            kernels::mul_mod_slice_lazy(a, other.limb(li), q, ratio);
        });
    }

    /// Fused ct×ct dyadic tensor: returns
    /// `(a0·b0, a0·b1 + a1·b0, a1·b1)` computed in one limb-parallel
    /// pass that reads each operand limb exactly once
    /// ([`kernels::tensor_limb`]; the cross term reduces once from its
    /// 128-bit sum). All operands must be NTT-form ciphertext polys
    /// (no special limb) at the same level.
    pub(crate) fn tensor(
        ctx: &CkksContext,
        a0: &Self,
        a1: &Self,
        b0: &Self,
        b1: &Self,
        scratch: &mut Scratch,
    ) -> (Self, Self, Self) {
        a0.assert_compat(a1);
        a0.assert_compat(b0);
        a0.assert_compat(b1);
        debug_assert!(a0.is_ntt && !a0.special, "tensor needs NTT ct polys");
        let level = a0.level;
        let n = a0.n;
        let mut d0 = Self::zero_in(ctx, level, false, true, scratch);
        let mut d1 = Self::zero_in(ctx, level, false, true, scratch);
        let mut d2 = Self::zero_in(ctx, level, false, true, scratch);
        parallel::for_each_limb3(
            ctx.workers(),
            n,
            &mut d0.data,
            &mut d1.data,
            &mut d2.data,
            |li, o0, o1, o2| {
                let q = ctx.q(li);
                let ratio = ctx.barrett_ratio(li);
                kernels::tensor_limb(
                    a0.limb(li),
                    a1.limb(li),
                    b0.limb(li),
                    b1.limb(li),
                    o0,
                    o1,
                    o2,
                    q,
                    ratio,
                );
            },
        );
        (d0, d1, d2)
    }

    /// Fused squaring tensor: `(a0², 2·a0·a1, a1²)` in one
    /// limb-parallel pass ([`kernels::square_limb`]) — no operand
    /// clones, and the doubled cross term reduces once.
    pub(crate) fn tensor_square(
        ctx: &CkksContext,
        a0: &Self,
        a1: &Self,
        scratch: &mut Scratch,
    ) -> (Self, Self, Self) {
        a0.assert_compat(a1);
        debug_assert!(a0.is_ntt && !a0.special, "tensor needs NTT ct polys");
        let level = a0.level;
        let n = a0.n;
        let mut d0 = Self::zero_in(ctx, level, false, true, scratch);
        let mut d1 = Self::zero_in(ctx, level, false, true, scratch);
        let mut d2 = Self::zero_in(ctx, level, false, true, scratch);
        parallel::for_each_limb3(
            ctx.workers(),
            n,
            &mut d0.data,
            &mut d1.data,
            &mut d2.data,
            |li, o0, o1, o2| {
                let q = ctx.q(li);
                let ratio = ctx.barrett_ratio(li);
                kernels::square_limb(a0.limb(li), a1.limb(li), o0, o1, o2, q, ratio);
            },
        );
        (d0, d1, d2)
    }

    /// Multiply by a scalar integer (same in every limb). The reduced
    /// scalar is loop-invariant per limb → Shoup multiplication.
    pub fn mul_scalar_assign(&mut self, ctx: &CkksContext, s: u64) {
        for li in 0..self.active_limbs() {
            let q = self.modulus_of(ctx, li);
            let sq = s % q;
            let sq_shoup = shoup_precompute(sq, q);
            for x in self.limb_mut(li).iter_mut() {
                *x = mul_mod_shoup(*x, sq, sq_shoup, q);
            }
        }
    }

    /// Drop down to `new_level` by discarding upper chain limbs (no
    /// scaling) — used to align operand levels before add/mul.
    pub fn drop_to_level(&mut self, new_level: usize) {
        debug_assert!(new_level <= self.level);
        debug_assert!(!self.special);
        self.data.truncate((new_level + 1) * self.n);
        self.level = new_level;
    }

    /// Keep only chain limbs `0..=level`: drops the special limb and
    /// any upper chain limbs (key material → working basis).
    pub fn restrict(&mut self, level: usize) {
        debug_assert!(level <= self.level);
        self.data.truncate((level + 1) * self.n);
        self.level = level;
        self.special = false;
    }

    /// Rescale: divide by the top chain prime `q_level` with centered
    /// rounding, dropping one level. Input/output in coefficient form
    /// handled internally (caller may pass NTT form; returned in NTT
    /// form if input was).
    pub fn rescale(&mut self, ctx: &CkksContext) {
        debug_assert!(!self.special);
        debug_assert!(self.level >= 1, "cannot rescale at level 0");
        let was_ntt = self.is_ntt;
        self.from_ntt(ctx);
        let old_level = self.level;
        let q_last = ctx.q(old_level);
        let half = q_last / 2;
        let n = self.n;
        let (head, tail) = self.data.split_at_mut(old_level * n);
        let last: &[u64] = &tail[..n];
        let inv_row = &ctx.inv_q_to[old_level];
        let inv_shoup_row = &ctx.inv_q_to_shoup[old_level];
        parallel::for_each_limb(ctx.workers(), n, head, |li, limb| {
            let q = ctx.q(li);
            let (_, r_hi) = ctx.barrett[li];
            let (inv, inv_sh) = (inv_row[li], inv_shoup_row[li]);
            kernels::rescale_adjust_slice(limb, last, q, r_hi, q_last, half, inv, inv_sh);
        });
        self.data.truncate(old_level * n);
        self.level = old_level - 1;
        if was_ntt {
            self.to_ntt(ctx);
        }
    }

    /// Mod-down: divide by the special prime with centered rounding,
    /// removing the special limb (end of key-switching).
    pub fn mod_down_special(&mut self, ctx: &CkksContext) {
        debug_assert!(self.special);
        let was_ntt = self.is_ntt;
        self.from_ntt(ctx);
        let p = ctx.params.special;
        let half = p / 2;
        let n = self.n;
        let chain = (self.level + 1) * n;
        let (head, tail) = self.data.split_at_mut(chain);
        let last: &[u64] = &tail[..n];
        let inv_row = &ctx.inv_special;
        let inv_shoup_row = &ctx.inv_special_shoup;
        parallel::for_each_limb(ctx.workers(), n, head, |li, limb| {
            let q = ctx.q(li);
            let (_, r_hi) = ctx.barrett[li];
            let (inv, inv_sh) = (inv_row[li], inv_shoup_row[li]);
            kernels::rescale_adjust_slice(limb, last, q, r_hi, p, half, inv, inv_sh);
        });
        self.data.truncate(chain);
        self.special = false;
        if was_ntt {
            self.to_ntt(ctx);
        }
    }

    /// Mod-down by the special prime for an **NTT-form** poly, leaving
    /// it in NTT form. Only the special limb round-trips through
    /// coefficient space: the centered remainder `r` is NTT'd once per
    /// chain limb instead of converting every limb both ways
    /// (1 + (ℓ+1) NTTs per poly instead of 2(ℓ+2) — §Perf step 2).
    /// Limb-parallel with one remainder buffer per worker.
    pub fn mod_down_special_ntt(&mut self, ctx: &CkksContext) {
        debug_assert!(self.special);
        debug_assert!(self.is_ntt);
        let p = ctx.params.special;
        let half = p / 2;
        let n = self.n;
        let chain = (self.level + 1) * n;
        let (head, tail) = self.data.split_at_mut(chain);
        let last = &mut tail[..n];
        ctx.special_table.inverse(last);
        let last: &[u64] = last;
        let inv_row = &ctx.inv_special;
        let inv_shoup_row = &ctx.inv_special_shoup;
        parallel::for_each_limb_with(ctx.workers(), n, head, |r_mod_q, li, limb| {
            let q = ctx.q(li);
            let (_, r_hi) = ctx.barrett[li];
            let (inv, inv_sh) = (inv_row[li], inv_shoup_row[li]);
            r_mod_q.clear();
            r_mod_q.resize(n, 0);
            // r centered: r <= p/2 -> subtract r ; r > p/2 -> add p - r
            kernels::centered_neg_slice(r_mod_q, last, p, half, q, r_hi);
            ctx.tables[li].forward(r_mod_q);
            kernels::add_then_mul_shoup_slice(limb, r_mod_q, q, inv, inv_sh);
        });
        self.data.truncate(chain);
        self.special = false;
    }

    /// Galois automorphism X -> X^g (g odd), coefficient domain
    /// internally; preserves the caller's NTT-form flag. For odd `g`
    /// the index map is a permutation, so every slot is written
    /// exactly once from a single reusable source buffer.
    pub fn automorphism(&mut self, ctx: &CkksContext, g: usize) {
        let was_ntt = self.is_ntt;
        self.from_ntt(ctx);
        let n = ctx.n();
        let two_n = 2 * n;
        debug_assert_eq!(g % 2, 1);
        let nl = self.active_limbs();
        let mut src = vec![0u64; n];
        for li in 0..nl {
            let q = self.modulus_of(ctx, li);
            let limb = self.limb_mut(li);
            src.copy_from_slice(limb);
            for (i, &v) in src.iter().enumerate() {
                let j = (i * g) % two_n;
                if j < n {
                    limb[j] = v;
                } else {
                    limb[j - n] = neg_mod(v, q);
                }
            }
        }
        if was_ntt {
            self.to_ntt(ctx);
        }
    }

    /// Galois automorphism applied **in the NTT domain**: a pure slot
    /// permutation (evaluation points get permuted, signs absorbed).
    /// Used by hoisted rotations (§Perf step 3). Permutes out-of-place
    /// into a scratch buffer (limb-parallel) and recycles the old one.
    pub fn automorphism_ntt(&mut self, ctx: &CkksContext, perm: &[u32], scratch: &mut Scratch) {
        let permuted = Self::automorphism_ntt_from(self, ctx, perm, scratch);
        let old = std::mem::replace(&mut self.data, permuted.data);
        scratch.put(old);
    }

    /// Out-of-place NTT-domain automorphism: build the permuted poly
    /// directly from `src` into a pool buffer — the hoisted-rotation
    /// hot path uses this to skip the intermediate clone entirely.
    pub fn automorphism_ntt_from(
        src: &RnsPoly,
        ctx: &CkksContext,
        perm: &[u32],
        scratch: &mut Scratch,
    ) -> RnsPoly {
        debug_assert!(src.is_ntt);
        let n = src.n;
        let mut out = scratch.take(src.data.len());
        parallel::for_each_limb(ctx.workers(), n, &mut out, |li, dst| {
            let s = &src.data[li * n..(li + 1) * n];
            for (d, &p) in dst.iter_mut().zip(perm.iter()) {
                *d = s[p as usize];
            }
        });
        RnsPoly {
            level: src.level,
            special: src.special,
            is_ntt: true,
            n,
            data: out,
        }
    }

    /// Exact centered CRT reconstruction of every coefficient as f64
    /// (coefficient form required). Used only on decode. The
    /// mixed-radix digit buffer and residue gather buffer are reused
    /// across all N coefficients.
    pub fn to_centered_f64(&self, ctx: &CkksContext) -> Vec<f64> {
        debug_assert!(!self.is_ntt);
        debug_assert!(!self.special);
        let primes: Vec<u64> = (0..=self.level).map(|i| ctx.q(i)).collect();
        let recon = CrtRecon::new(&primes);
        let n = ctx.n();
        let k = primes.len();
        let mut out = vec![0.0f64; n];
        let mut residues = vec![0u64; k];
        let mut digits = vec![0u64; k];
        for (i, o) in out.iter_mut().enumerate() {
            for (li, r) in residues.iter_mut().enumerate() {
                *r = self.data[li * self.n + i];
            }
            *o = recon.centered_f64_with(&residues, &mut digits);
        }
        out
    }
}

// ---------------------------------------------------------------------
// CRT reconstruction via Garner's mixed-radix algorithm + a tiny
// unsigned big integer for the final centered comparison.
// ---------------------------------------------------------------------

/// Little-endian base-2^64 unsigned integer (decode-path only).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUintLite(pub Vec<u64>);

impl BigUintLite {
    pub fn zero() -> Self {
        BigUintLite(vec![])
    }

    pub fn from_u64(x: u64) -> Self {
        if x == 0 {
            BigUintLite(vec![])
        } else {
            BigUintLite(vec![x])
        }
    }

    fn trim(&mut self) {
        while self.0.last() == Some(&0) {
            self.0.pop();
        }
    }

    pub fn mul_u64(&self, m: u64) -> Self {
        let mut out = Vec::with_capacity(self.0.len() + 1);
        let mut carry: u128 = 0;
        for &d in &self.0 {
            let v = d as u128 * m as u128 + carry;
            out.push(v as u64);
            carry = v >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn add_u64(&self, a: u64) -> Self {
        let mut out = self.0.clone();
        let mut carry = a;
        for d in out.iter_mut() {
            let (s, c) = d.overflowing_add(carry);
            *d = s;
            carry = c as u64;
            if carry == 0 {
                break;
            }
        }
        if carry > 0 {
            out.push(carry);
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn add(&self, other: &Self) -> Self {
        let (long, short) = if self.0.len() >= other.0.len() {
            (&self.0, &other.0)
        } else {
            (&other.0, &self.0)
        };
        let mut out = long.clone();
        let mut carry = 0u64;
        for i in 0..out.len() {
            let b = if i < short.len() { short[i] } else { 0 };
            let (s1, c1) = out[i].overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
            if carry == 0 && i >= short.len() {
                break;
            }
        }
        if carry > 0 {
            out.push(carry);
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    /// self - other, requires self >= other.
    pub fn sub(&self, other: &Self) -> Self {
        debug_assert!(self.cmp_big(other) != std::cmp::Ordering::Less);
        let mut out = self.0.clone();
        let mut borrow = 0u64;
        for i in 0..out.len() {
            let b = if i < other.0.len() { other.0[i] } else { 0 };
            let (d1, b1) = out[i].overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out[i] = d2;
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn cmp_big(&self, other: &Self) -> std::cmp::Ordering {
        if self.0.len() != other.0.len() {
            return self.0.len().cmp(&other.0.len());
        }
        for i in (0..self.0.len()).rev() {
            match self.0[i].cmp(&other.0[i]) {
                std::cmp::Ordering::Equal => continue,
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    }

    pub fn shr1(&self) -> Self {
        let mut out = vec![0u64; self.0.len()];
        let mut carry = 0u64;
        for i in (0..self.0.len()).rev() {
            out[i] = (self.0[i] >> 1) | (carry << 63);
            carry = self.0[i] & 1;
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn to_f64(&self) -> f64 {
        let mut v = 0.0f64;
        for &d in self.0.iter().rev() {
            v = v * 1.8446744073709552e19 + d as f64; // 2^64
        }
        v
    }
}

/// Garner-style CRT reconstruction over a fixed prime basis. All the
/// O(k²) per-(i,j) radix products are precomputed once in
/// [`CrtRecon::new`] (with Shoup companions), so reconstructing one
/// coefficient is k(k−1)/2 Shoup multiplies and no divisions.
pub struct CrtRecon {
    primes: Vec<u64>,
    /// inv_prefix[i] = (q_0*...*q_{i-1})^{-1} mod q_i, with Shoup.
    inv_prefix: Vec<(u64, u64)>,
    /// radix[i][j] = (q_0*...*q_{j-1}) mod q_i with Shoup, j < i,
    /// flattened row-major (row i starts at i(i-1)/2).
    radix: Vec<(u64, u64)>,
    /// q_big = product of all primes; half = floor(q_big/2)
    q_big: BigUintLite,
    half: BigUintLite,
    /// prefix products as bigints: prefix[i] = q_0*...*q_{i-1}
    prefix: Vec<BigUintLite>,
}

impl CrtRecon {
    pub fn new(primes: &[u64]) -> Self {
        let k = primes.len();
        let mut inv_prefix = Vec::with_capacity(k);
        let mut radix = Vec::with_capacity(k * (k.saturating_sub(1)) / 2);
        for (i, &qi) in primes.iter().enumerate() {
            let mut prod = 1u64;
            for &qj in &primes[..i] {
                radix.push((prod, shoup_precompute(prod, qi)));
                prod = mul_mod(prod, qj % qi, qi);
            }
            let inv = if i == 0 { 1 } else { inv_mod(prod, qi) };
            inv_prefix.push((inv, shoup_precompute(inv, qi)));
        }
        let mut prefix = Vec::with_capacity(k);
        let mut acc = BigUintLite::from_u64(1);
        for &q in primes {
            prefix.push(acc.clone());
            acc = acc.mul_u64(q);
        }
        let q_big = acc;
        let half = q_big.shr1();
        CrtRecon {
            primes: primes.to_vec(),
            inv_prefix,
            radix,
            q_big,
            half,
            prefix,
        }
    }

    /// Reconstruct x in [0, Q) from residues (each reduced mod its
    /// prime), return centered value (x or x - Q) as f64.
    pub fn centered_f64(&self, residues: &[u64]) -> f64 {
        let mut digits = vec![0u64; self.primes.len()];
        self.centered_f64_with(residues, &mut digits)
    }

    /// [`CrtRecon::centered_f64`] with a caller-provided digit buffer
    /// (`len == primes.len()`) so bulk decodes allocate nothing per
    /// coefficient.
    pub fn centered_f64_with(&self, residues: &[u64], digits: &mut [u64]) -> f64 {
        // Garner: mixed-radix digits a_i with
        //   x = a_0 + a_1 q_0 + a_2 q_0 q_1 + ...
        let k = self.primes.len();
        debug_assert_eq!(digits.len(), k);
        for i in 0..k {
            let qi = self.primes[i];
            debug_assert!(residues[i] < qi, "unreduced residue");
            // t = (r_i - (a_0 + a_1 q_0 + ...)) * inv_prefix mod q_i
            let row = &self.radix[i * (i.saturating_sub(1)) / 2..];
            let mut acc = 0u64;
            for j in 0..i {
                let (r, r_sh) = row[j];
                // Shoup multiply is exact for any u64 left operand, so
                // the digit needs no pre-reduction mod q_i.
                acc = add_mod(acc, mul_mod_shoup(digits[j], r, r_sh, qi), qi);
            }
            let t = sub_mod(residues[i], acc, qi);
            let (inv, inv_sh) = self.inv_prefix[i];
            digits[i] = mul_mod_shoup(t, inv, inv_sh, qi);
        }
        // Assemble bigint.
        let mut x = BigUintLite::zero();
        for i in 0..k {
            x = x.add(&self.prefix[i].mul_u64(digits[i]));
        }
        // Center.
        if x.cmp_big(&self.half) == std::cmp::Ordering::Greater {
            -(self.q_big.sub(&x).to_f64())
        } else {
            x.to_f64()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;

    fn ctx() -> ContextRef {
        CkksContext::new(CkksParams::toy())
    }

    #[test]
    fn signed_roundtrip_via_crt() {
        let c = ctx();
        let vals: Vec<i64> = vec![0, 1, -1, 123456789, -987654321, i32::MAX as i64];
        let mut coeffs = vec![0i64; c.n()];
        coeffs[..vals.len()].copy_from_slice(&vals);
        let p = RnsPoly::from_signed(&c, &coeffs, c.params.max_level(), false);
        let back = p.to_centered_f64(&c);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(back[i], v as f64, "coeff {i}");
        }
    }

    #[test]
    fn wide_roundtrip() {
        let c = ctx();
        let vals: Vec<i128> = vec![1i128 << 90, -(1i128 << 90) - 12345, (1i128 << 99) + 7];
        let mut coeffs = vec![0i128; c.n()];
        coeffs[..vals.len()].copy_from_slice(&vals);
        let p = RnsPoly::from_signed_wide(&c, &coeffs, c.params.max_level(), false);
        let back = p.to_centered_f64(&c);
        for (i, &v) in vals.iter().enumerate() {
            let rel = (back[i] - v as f64).abs() / (v as f64).abs();
            assert!(rel < 1e-12, "coeff {i}: {} vs {}", back[i], v);
        }
    }

    #[test]
    fn ntt_roundtrip_preserves() {
        let c = ctx();
        let mut rng = Xoshiro256pp::new(5);
        let mut p = RnsPoly::sample_uniform(&c, &mut rng, 1, false, false);
        let orig = p.clone();
        p.to_ntt(&c);
        p.from_ntt(&c);
        assert_eq!(p.data(), orig.data());
    }

    #[test]
    fn flat_limb_accessors_are_consistent() {
        let c = ctx();
        let mut rng = Xoshiro256pp::new(55);
        let mut p = RnsPoly::sample_uniform(&c, &mut rng, c.params.max_level(), true, false);
        let nl = p.active_limbs();
        assert_eq!(nl, RnsPoly::n_limbs(p.level, p.special));
        assert_eq!(p.data().len(), nl * c.n());
        for li in 0..nl {
            let want: Vec<u64> = p.data()[li * c.n()..(li + 1) * c.n()].to_vec();
            assert_eq!(p.limb(li), &want[..], "limb {li}");
        }
        let (a, b) = p.limbs_pair_mut(0, nl - 1);
        a[0] = 1;
        b[0] = 2;
        assert_eq!(p.limb(0)[0], 1);
        assert_eq!(p.limb(nl - 1)[0], 2);
    }

    #[test]
    fn add_mul_consistency_with_integers() {
        // (small a) * (small b) via NTT == integer negacyclic product.
        let c = ctx();
        let n = c.n();
        let mut rng = Xoshiro256pp::new(6);
        let a_c: Vec<i64> = (0..n).map(|_| rng.next_below(100) as i64 - 50).collect();
        let b_c: Vec<i64> = (0..n).map(|_| rng.next_below(100) as i64 - 50).collect();
        let mut a = RnsPoly::from_signed(&c, &a_c, 1, false);
        let mut b = RnsPoly::from_signed(&c, &b_c, 1, false);
        a.to_ntt(&c);
        b.to_ntt(&c);
        a.mul_assign(&c, &b);
        a.from_ntt(&c);
        let got = a.to_centered_f64(&c);
        // Naive negacyclic in i128.
        let mut expect = vec![0i128; n];
        for i in 0..n {
            for j in 0..n {
                let p = a_c[i] as i128 * b_c[j] as i128;
                let k = i + j;
                if k < n {
                    expect[k] += p;
                } else {
                    expect[k - n] -= p;
                }
            }
        }
        for i in 0..n {
            assert_eq!(got[i], expect[i] as f64, "coeff {i}");
        }
    }

    #[test]
    fn rescale_divides_by_top_prime() {
        let c = ctx();
        let lvl = c.params.max_level();
        let q_top = c.q(lvl) as i128;
        // value exactly divisible: x = k * q_top
        let mut coeffs = vec![0i128; c.n()];
        coeffs[0] = 42 * q_top;
        coeffs[1] = -7 * q_top;
        coeffs[2] = 5 * q_top + 3; // rounds to 5
        let mut p = RnsPoly::from_signed_wide(&c, &coeffs, lvl, false);
        p.rescale(&c);
        assert_eq!(p.level, lvl - 1);
        let back = p.to_centered_f64(&c);
        assert_eq!(back[0], 42.0);
        assert_eq!(back[1], -7.0);
        assert_eq!(back[2], 5.0);
    }

    #[test]
    fn ntt_domain_automorphism_matches_coeff_domain() {
        // On every limb (different primes), the NTT-slot permutation
        // must equal the coefficient-domain automorphism.
        let c = ctx();
        let mut rng = Xoshiro256pp::new(88);
        let mut scratch = Scratch::new();
        for g in [5usize, 25, 2 * c.n() - 1, 125] {
            let mut a = RnsPoly::sample_uniform(&c, &mut rng, c.params.max_level(), true, false);
            let mut coeff_path = a.clone();
            coeff_path.automorphism(&c, g);
            coeff_path.to_ntt(&c);
            a.to_ntt(&c);
            a.automorphism_ntt(&c, &c.galois_perm(g), &mut scratch);
            assert_eq!(a.data(), coeff_path.data(), "g={g}");
        }
    }

    #[test]
    fn mod_down_ntt_matches_coeff_path() {
        let c = ctx();
        let mut rng = Xoshiro256pp::new(77);
        let mut a = RnsPoly::sample_uniform(&c, &mut rng, 1, true, false);
        a.to_ntt(&c);
        let mut coeff_path = a.clone();
        coeff_path.mod_down_special(&c);
        let mut ntt_path = a;
        ntt_path.mod_down_special_ntt(&c);
        assert!(ntt_path.is_ntt);
        ntt_path.from_ntt(&c);
        coeff_path.from_ntt(&c);
        assert_eq!(ntt_path.data(), coeff_path.data());
    }

    #[test]
    fn limb_parallel_ops_are_worker_count_invariant() {
        // The same op sequence at workers ∈ {1, 3, 4} must produce
        // bit-identical limbs (limbs are independent by construction).
        let c = ctx();
        let mut rng = Xoshiro256pp::new(99);
        let base = RnsPoly::sample_uniform(&c, &mut rng, c.params.max_level(), true, true);
        let other = RnsPoly::sample_uniform(&c, &mut rng, c.params.max_level(), true, true);
        let run = |workers: usize| {
            c.set_workers(workers);
            let mut scratch = Scratch::new();
            let mut p = base.clone();
            p.mul_assign(&c, &other);
            p.automorphism_ntt(&c, &c.galois_perm(5), &mut scratch);
            p.mod_down_special_ntt(&c);
            p.rescale(&c);
            p.from_ntt(&c);
            p
        };
        let serial = run(1);
        for w in [3usize, 4] {
            let par = run(w);
            assert_eq!(par.data(), serial.data(), "workers={w}");
        }
        c.set_workers(1);
    }

    #[test]
    fn automorphism_is_ring_homomorphism() {
        // (a*b)(X^g) == a(X^g) * b(X^g)
        let c = ctx();
        let n = c.n();
        let mut rng = Xoshiro256pp::new(8);
        let a_c: Vec<i64> = (0..n).map(|_| rng.next_below(50) as i64 - 25).collect();
        let b_c: Vec<i64> = (0..n).map(|_| rng.next_below(50) as i64 - 25).collect();
        let g = 5usize;
        let mk = |coef: &Vec<i64>| RnsPoly::from_signed(&c, coef, 0, false);
        // lhs: multiply then automorph
        let mut a1 = mk(&a_c);
        let mut b1 = mk(&b_c);
        a1.to_ntt(&c);
        b1.to_ntt(&c);
        a1.mul_assign(&c, &b1);
        a1.automorphism(&c, g);
        a1.from_ntt(&c);
        // rhs: automorph then multiply
        let mut a2 = mk(&a_c);
        let mut b2 = mk(&b_c);
        a2.automorphism(&c, g);
        b2.automorphism(&c, g);
        a2.to_ntt(&c);
        b2.to_ntt(&c);
        a2.mul_assign(&c, &b2);
        a2.from_ntt(&c);
        assert_eq!(a1.data(), a2.data());
    }

    #[test]
    fn galois_perm_prewarm_fills_cache() {
        let c = ctx();
        assert_eq!(c.galois_perms_cached(), 0);
        c.galois_perm_prewarm(&[1, 2, 0, 2]);
        assert_eq!(c.galois_perms_cached(), 2);
        // Subsequent lookups are read-path hits of the same Arc.
        let g1 = super::super::modops::galois_element(1, 2 * c.n());
        let p = c.galois_perm(g1);
        assert_eq!(c.galois_perms_cached(), 2);
        assert!(Arc::strong_count(&p) >= 2);
    }

    #[test]
    fn crt_recon_scratch_variant_matches_allocating_path() {
        let c = ctx();
        let primes: Vec<u64> = (0..=c.params.max_level()).map(|i| c.q(i)).collect();
        let recon = CrtRecon::new(&primes);
        let mut rng = Xoshiro256pp::new(123);
        let mut digits = vec![0u64; primes.len()];
        for _ in 0..200 {
            let residues: Vec<u64> = primes.iter().map(|&q| rng.next_below(q)).collect();
            let a = recon.centered_f64(&residues);
            let b = recon.centered_f64_with(&residues, &mut digits);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn double_assign_matches_add_self() {
        let c = ctx();
        let mut rng = Xoshiro256pp::new(321);
        let a = RnsPoly::sample_uniform(&c, &mut rng, c.params.max_level(), false, true);
        let mut doubled = a.clone();
        doubled.double_assign(&c);
        let mut summed = a.clone();
        summed.add_assign(&c, &a);
        assert_eq!(doubled.data(), summed.data());
    }

    #[test]
    fn bigint_ops() {
        let a = BigUintLite::from_u64(u64::MAX);
        let b = a.add_u64(1); // 2^64
        assert_eq!(b.0, vec![0, 1]);
        let c2 = b.mul_u64(u64::MAX);
        let d = c2.add(&b);
        // (2^64)(2^64-1) + 2^64 = 2^128
        assert_eq!(d.0, vec![0, 0, 1]);
        assert_eq!(d.shr1().0, vec![0, 1u64 << 63]);
        assert_eq!(d.sub(&b).0, c2.0);
        assert!((d.to_f64() - 3.402823669209385e38).abs() / 3.4e38 < 1e-12);
    }
}
