//! RNS ("double-CRT") polynomials over the CKKS modulus chain.
//!
//! A [`RnsPoly`] stores one residue limb per active prime. The active
//! basis is `q_0..q_level` plus, transiently during key-switching, the
//! special prime. Polynomials live either in coefficient form or in
//! NTT (evaluation) form; element-wise ring multiplication requires
//! NTT form.
//!
//! The module also owns [`CkksContext`] (parameter set + NTT tables +
//! rescale precomputations) and the exact CRT → centered big-integer →
//! f64 reconstruction used on decode ([`BigUintLite`]).

use super::modops::{add_mod, inv_mod, mul_mod, neg_mod, sub_mod};
use super::ntt::NttTable;
use super::params::ParamsRef;
use crate::rng::Xoshiro256pp;
use std::sync::Arc;

/// Shared immutable context: parameters, NTT tables (one per chain
/// prime + special), and per-level precomputations.
pub struct CkksContext {
    pub params: ParamsRef,
    /// NTT tables for moduli[0..] (chain order).
    pub tables: Vec<NttTable>,
    /// NTT table for the special key-switching prime.
    pub special_table: NttTable,
    /// inv(q_j) mod q_i for rescale: inv_q_to[j][i] = q_j^{-1} mod q_i (i < j).
    inv_q_to: Vec<Vec<u64>>,
    /// inv(special) mod q_i.
    inv_special: Vec<u64>,
    /// ψ-exponent of each NTT output slot: slot i holds c(ψ^{ntt_exp[i]}).
    /// The pattern is determined by the butterfly structure alone, so
    /// one table serves every prime.
    ntt_exp: Vec<usize>,
    /// Inverse map: odd exponent e (mod 2N) → NTT slot index.
    exp_to_slot: Vec<u32>,
    /// Cached NTT-domain Galois permutations, keyed by Galois element.
    galois_perms: std::sync::RwLock<std::collections::HashMap<usize, Arc<Vec<u32>>>>,
}

pub type ContextRef = Arc<CkksContext>;

impl CkksContext {
    pub fn new(params: ParamsRef) -> ContextRef {
        let n = params.n;
        let tables: Vec<NttTable> = params.moduli.iter().map(|&q| NttTable::new(q, n)).collect();
        let special_table = NttTable::new(params.special, n);
        let inv_q_to = params
            .moduli
            .iter()
            .enumerate()
            .map(|(j, &qj)| {
                params.moduli[..j]
                    .iter()
                    .map(|&qi| inv_mod(qj % qi, qi))
                    .collect()
            })
            .collect();
        let inv_special = params
            .moduli
            .iter()
            .map(|&qi| inv_mod(params.special % qi, qi))
            .collect();
        // Probe the NTT's evaluation order: NTT(X) gives ψ^{e_i} in
        // slot i; match against the power table to recover e_i.
        let (ntt_exp, exp_to_slot) = {
            let q = params.moduli[0];
            let t = &tables[0];
            let mut probe = vec![0u64; n];
            probe[1] = 1; // the monomial X
            t.forward(&mut probe);
            let two_n = 2 * n;
            let psi = {
                // recover ψ as the value with exponent 1: build the
                // power→exponent map from any generator found in slot 0
                // wouldn't be unique; instead rebuild ψ directly.
                super::modops::primitive_2nth_root(q, two_n as u64)
            };
            let mut pow_to_exp = std::collections::HashMap::with_capacity(two_n);
            let mut acc = 1u64;
            for e in 0..two_n {
                pow_to_exp.insert(acc, e);
                acc = super::modops::mul_mod(acc, psi, q);
            }
            let ntt_exp: Vec<usize> = probe
                .iter()
                .map(|v| *pow_to_exp.get(v).expect("NTT slot is not a ψ power"))
                .collect();
            let mut exp_to_slot = vec![u32::MAX; two_n];
            for (i, &e) in ntt_exp.iter().enumerate() {
                exp_to_slot[e] = i as u32;
            }
            (ntt_exp, exp_to_slot)
        };
        Arc::new(CkksContext {
            params,
            tables,
            special_table,
            inv_q_to,
            inv_special,
            ntt_exp,
            exp_to_slot,
            galois_perms: std::sync::RwLock::new(std::collections::HashMap::new()),
        })
    }

    /// NTT-domain permutation for the Galois automorphism X→X^g:
    /// `out[i] = in[perm[i]]` applied per limb (cached per g).
    pub fn galois_perm(&self, g: usize) -> Arc<Vec<u32>> {
        if let Some(p) = self.galois_perms.read().unwrap().get(&g) {
            return p.clone();
        }
        let two_n = 2 * self.n();
        let perm: Vec<u32> = self
            .ntt_exp
            .iter()
            .map(|&e| {
                let src_exp = (e * g) % two_n;
                let j = self.exp_to_slot[src_exp];
                debug_assert!(j != u32::MAX, "even exponent in Galois map");
                j
            })
            .collect();
        let perm = Arc::new(perm);
        self.galois_perms.write().unwrap().insert(g, perm.clone());
        perm
    }

    pub fn n(&self) -> usize {
        self.params.n
    }

    /// Modulus of chain limb `i`.
    pub fn q(&self, i: usize) -> u64 {
        self.params.moduli[i]
    }
}

/// Polynomial in RNS representation.
#[derive(Clone, Debug)]
pub struct RnsPoly {
    /// Highest active chain-prime index; active chain limbs = level+1.
    pub level: usize,
    /// Whether a special-prime limb is appended after the chain limbs.
    pub special: bool,
    /// NTT (evaluation) form?
    pub is_ntt: bool,
    /// Residue limbs, chain order, special last if present.
    pub limbs: Vec<Vec<u64>>,
}

impl RnsPoly {
    pub fn n_limbs(level: usize, special: bool) -> usize {
        level + 1 + special as usize
    }

    pub fn zero(ctx: &CkksContext, level: usize, special: bool, is_ntt: bool) -> Self {
        RnsPoly {
            level,
            special,
            is_ntt,
            limbs: vec![vec![0u64; ctx.n()]; Self::n_limbs(level, special)],
        }
    }

    fn modulus_of(&self, ctx: &CkksContext, limb: usize) -> u64 {
        if self.special && limb == self.limbs.len() - 1 {
            ctx.params.special
        } else {
            ctx.params.moduli[limb]
        }
    }

    /// Build from small signed coefficients (keys, errors).
    pub fn from_signed(ctx: &CkksContext, coeffs: &[i64], level: usize, special: bool) -> Self {
        let mut p = Self::zero(ctx, level, special, false);
        let nl = p.limbs.len();
        for li in 0..nl {
            let q = p.modulus_of(ctx, li);
            let limb = &mut p.limbs[li];
            for (i, &c) in coeffs.iter().enumerate() {
                limb[i] = if c >= 0 {
                    (c as u64) % q
                } else {
                    q - (((-c) as u64) % q)
                } % q;
            }
        }
        p
    }

    /// Build from big signed coefficients (encoded plaintexts). i128
    /// covers every scale this library produces (|coeff| < 2^120).
    pub fn from_signed_wide(
        ctx: &CkksContext,
        coeffs: &[i128],
        level: usize,
        special: bool,
    ) -> Self {
        let mut p = Self::zero(ctx, level, special, false);
        let nl = p.limbs.len();
        for li in 0..nl {
            let q = p.modulus_of(ctx, li) as i128;
            let limb = &mut p.limbs[li];
            for (i, &c) in coeffs.iter().enumerate() {
                let r = c.rem_euclid(q);
                limb[i] = r as u64;
            }
        }
        p
    }

    /// Uniform random poly over the active basis (public-key `a`,
    /// key-switching randomness).
    pub fn sample_uniform(
        ctx: &CkksContext,
        rng: &mut Xoshiro256pp,
        level: usize,
        special: bool,
        is_ntt: bool,
    ) -> Self {
        let mut p = Self::zero(ctx, level, special, is_ntt);
        let nl = p.limbs.len();
        for li in 0..nl {
            let q = p.modulus_of(ctx, li);
            for x in p.limbs[li].iter_mut() {
                *x = rng.next_below(q);
            }
        }
        p
    }

    /// Ternary secret polynomial (coeff domain).
    pub fn sample_ternary(
        ctx: &CkksContext,
        rng: &mut Xoshiro256pp,
        level: usize,
        special: bool,
    ) -> Self {
        let coeffs: Vec<i64> = (0..ctx.n()).map(|_| rng.ternary()).collect();
        Self::from_signed(ctx, &coeffs, level, special)
    }

    /// Discrete-Gaussian error polynomial (coeff domain).
    pub fn sample_error(
        ctx: &CkksContext,
        rng: &mut Xoshiro256pp,
        level: usize,
        special: bool,
    ) -> Self {
        let sigma = ctx.params.sigma;
        let coeffs: Vec<i64> = (0..ctx.n()).map(|_| rng.discrete_gaussian(sigma)).collect();
        Self::from_signed(ctx, &coeffs, level, special)
    }

    pub fn to_ntt(&mut self, ctx: &CkksContext) {
        if self.is_ntt {
            return;
        }
        let n_limbs = self.limbs.len();
        for li in 0..n_limbs {
            let table = if self.special && li == n_limbs - 1 {
                &ctx.special_table
            } else {
                &ctx.tables[li]
            };
            table.forward(&mut self.limbs[li]);
        }
        self.is_ntt = true;
    }

    pub fn from_ntt(&mut self, ctx: &CkksContext) {
        if !self.is_ntt {
            return;
        }
        let n_limbs = self.limbs.len();
        for li in 0..n_limbs {
            let table = if self.special && li == n_limbs - 1 {
                &ctx.special_table
            } else {
                &ctx.tables[li]
            };
            table.inverse(&mut self.limbs[li]);
        }
        self.is_ntt = false;
    }

    fn assert_compat(&self, other: &Self) {
        debug_assert_eq!(self.level, other.level);
        debug_assert_eq!(self.special, other.special);
        debug_assert_eq!(self.is_ntt, other.is_ntt);
    }

    pub fn add_assign(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        for li in 0..self.limbs.len() {
            let q = self.modulus_of(ctx, li);
            let (a, b) = (&mut self.limbs[li], &other.limbs[li]);
            for i in 0..a.len() {
                a[i] = add_mod(a[i], b[i], q);
            }
        }
    }

    pub fn sub_assign(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        for li in 0..self.limbs.len() {
            let q = self.modulus_of(ctx, li);
            let (a, b) = (&mut self.limbs[li], &other.limbs[li]);
            for i in 0..a.len() {
                a[i] = sub_mod(a[i], b[i], q);
            }
        }
    }

    pub fn neg_assign(&mut self, ctx: &CkksContext) {
        for li in 0..self.limbs.len() {
            let q = self.modulus_of(ctx, li);
            for x in self.limbs[li].iter_mut() {
                *x = neg_mod(*x, q);
            }
        }
    }

    /// Element-wise ring multiplication; both operands must be in NTT form.
    pub fn mul_assign(&mut self, ctx: &CkksContext, other: &Self) {
        self.assert_compat(other);
        debug_assert!(self.is_ntt, "ring mul requires NTT form");
        for li in 0..self.limbs.len() {
            let q = self.modulus_of(ctx, li);
            let (a, b) = (&mut self.limbs[li], &other.limbs[li]);
            for i in 0..a.len() {
                a[i] = mul_mod(a[i], b[i], q);
            }
        }
    }

    /// Multiply by a scalar integer (same in every limb).
    pub fn mul_scalar_assign(&mut self, ctx: &CkksContext, s: u64) {
        for li in 0..self.limbs.len() {
            let q = self.modulus_of(ctx, li);
            let sq = s % q;
            for x in self.limbs[li].iter_mut() {
                *x = mul_mod(*x, sq, q);
            }
        }
    }

    /// Drop down to `new_level` by discarding upper chain limbs (no
    /// scaling) — used to align operand levels before add/mul.
    pub fn drop_to_level(&mut self, new_level: usize) {
        debug_assert!(new_level <= self.level);
        debug_assert!(!self.special);
        self.limbs.truncate(new_level + 1);
        self.level = new_level;
    }

    /// Rescale: divide by the top chain prime `q_level` with centered
    /// rounding, dropping one level. Input/output in coefficient form
    /// handled internally (caller may pass NTT form; returned in NTT
    /// form if input was).
    pub fn rescale(&mut self, ctx: &CkksContext) {
        debug_assert!(!self.special);
        debug_assert!(self.level >= 1, "cannot rescale at level 0");
        let was_ntt = self.is_ntt;
        self.from_ntt(ctx);
        let q_last = ctx.q(self.level);
        let half = q_last / 2;
        let last = self.limbs.pop().unwrap();
        self.level -= 1;
        for li in 0..=self.level {
            let q = ctx.q(li);
            let inv = ctx.inv_q_to[self.level + 1][li];
            let limb = &mut self.limbs[li];
            for i in 0..limb.len() {
                let r = last[i];
                // centered remainder: subtract r, or add (q_last - r)
                let adjusted = if r <= half {
                    sub_mod(limb[i], r % q, q)
                } else {
                    add_mod(limb[i], (q_last - r) % q, q)
                };
                limb[i] = mul_mod(adjusted, inv, q);
            }
        }
        if was_ntt {
            self.to_ntt(ctx);
        }
    }

    /// Mod-down: divide by the special prime with centered rounding,
    /// removing the special limb (end of key-switching).
    pub fn mod_down_special(&mut self, ctx: &CkksContext) {
        debug_assert!(self.special);
        let was_ntt = self.is_ntt;
        self.from_ntt(ctx);
        let p = ctx.params.special;
        let half = p / 2;
        let last = self.limbs.pop().unwrap();
        self.special = false;
        for li in 0..=self.level {
            let q = ctx.q(li);
            let inv = ctx.inv_special[li];
            let limb = &mut self.limbs[li];
            for i in 0..limb.len() {
                let r = last[i];
                let adjusted = if r <= half {
                    sub_mod(limb[i], r % q, q)
                } else {
                    add_mod(limb[i], (p - r) % q, q)
                };
                limb[i] = mul_mod(adjusted, inv, q);
            }
        }
        if was_ntt {
            self.to_ntt(ctx);
        }
    }

    /// Mod-down by the special prime for an **NTT-form** poly, leaving
    /// it in NTT form. Only the special limb round-trips through
    /// coefficient space: the centered remainder `r` is NTT'd once per
    /// chain limb instead of converting every limb both ways
    /// (1 + (ℓ+1) NTTs per poly instead of 2(ℓ+2) — §Perf step 2).
    pub fn mod_down_special_ntt(&mut self, ctx: &CkksContext) {
        debug_assert!(self.special);
        debug_assert!(self.is_ntt);
        let p = ctx.params.special;
        let half = p / 2;
        let mut last = self.limbs.pop().unwrap();
        self.special = false;
        ctx.special_table.inverse(&mut last);
        // Centered remainder as signed integers.
        let n = last.len();
        let mut r_mod_q = vec![0u64; n];
        for li in 0..=self.level {
            let q = ctx.q(li);
            // r centered: r <= p/2 -> subtract r ; r > p/2 -> add p - r
            for i in 0..n {
                let r = last[i];
                r_mod_q[i] = if r <= half {
                    neg_mod(r % q, q) // -r mod q  (will be added)
                } else {
                    (p - r) % q
                };
            }
            ctx.tables[li].forward(&mut r_mod_q);
            let inv = ctx.inv_special[li];
            let limb = &mut self.limbs[li];
            for i in 0..n {
                limb[i] = mul_mod(add_mod(limb[i], r_mod_q[i], q), inv, q);
            }
        }
    }

    /// Galois automorphism X -> X^g (g odd), coefficient domain
    /// internally; preserves the caller's NTT-form flag.
    pub fn automorphism(&mut self, ctx: &CkksContext, g: usize) {
        let was_ntt = self.is_ntt;
        self.from_ntt(ctx);
        let n = ctx.n();
        let two_n = 2 * n;
        debug_assert_eq!(g % 2, 1);
        for li in 0..self.limbs.len() {
            let q = self.modulus_of(ctx, li);
            let src = &self.limbs[li];
            let mut dst = vec![0u64; n];
            for i in 0..n {
                let j = (i * g) % two_n;
                if j < n {
                    dst[j] = src[i];
                } else {
                    dst[j - n] = neg_mod(src[i], q);
                }
            }
            self.limbs[li] = dst;
        }
        if was_ntt {
            self.to_ntt(ctx);
        }
    }

    /// Galois automorphism applied **in the NTT domain**: a pure slot
    /// permutation (evaluation points get permuted, signs absorbed).
    /// Used by hoisted rotations (§Perf step 3).
    pub fn automorphism_ntt(&mut self, perm: &[u32]) {
        debug_assert!(self.is_ntt);
        for limb in self.limbs.iter_mut() {
            let src = limb.clone();
            for (i, x) in limb.iter_mut().enumerate() {
                *x = src[perm[i] as usize];
            }
        }
    }

    /// Exact centered CRT reconstruction of every coefficient as f64
    /// (coefficient form required). Used only on decode.
    pub fn to_centered_f64(&self, ctx: &CkksContext) -> Vec<f64> {
        debug_assert!(!self.is_ntt);
        debug_assert!(!self.special);
        let primes: Vec<u64> = (0..=self.level).map(|i| ctx.q(i)).collect();
        let recon = CrtRecon::new(&primes);
        let n = ctx.n();
        let mut out = vec![0.0f64; n];
        let mut residues = vec![0u64; primes.len()];
        for i in 0..n {
            for (li, r) in residues.iter_mut().enumerate() {
                *r = self.limbs[li][i];
            }
            out[i] = recon.centered_f64(&residues);
        }
        out
    }
}

// ---------------------------------------------------------------------
// CRT reconstruction via Garner's mixed-radix algorithm + a tiny
// unsigned big integer for the final centered comparison.
// ---------------------------------------------------------------------

/// Little-endian base-2^64 unsigned integer (decode-path only).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUintLite(pub Vec<u64>);

impl BigUintLite {
    pub fn zero() -> Self {
        BigUintLite(vec![])
    }

    pub fn from_u64(x: u64) -> Self {
        if x == 0 {
            BigUintLite(vec![])
        } else {
            BigUintLite(vec![x])
        }
    }

    fn trim(&mut self) {
        while self.0.last() == Some(&0) {
            self.0.pop();
        }
    }

    pub fn mul_u64(&self, m: u64) -> Self {
        let mut out = Vec::with_capacity(self.0.len() + 1);
        let mut carry: u128 = 0;
        for &d in &self.0 {
            let v = d as u128 * m as u128 + carry;
            out.push(v as u64);
            carry = v >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn add_u64(&self, a: u64) -> Self {
        let mut out = self.0.clone();
        let mut carry = a;
        for d in out.iter_mut() {
            let (s, c) = d.overflowing_add(carry);
            *d = s;
            carry = c as u64;
            if carry == 0 {
                break;
            }
        }
        if carry > 0 {
            out.push(carry);
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn add(&self, other: &Self) -> Self {
        let (long, short) = if self.0.len() >= other.0.len() {
            (&self.0, &other.0)
        } else {
            (&other.0, &self.0)
        };
        let mut out = long.clone();
        let mut carry = 0u64;
        for i in 0..out.len() {
            let b = if i < short.len() { short[i] } else { 0 };
            let (s1, c1) = out[i].overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
            if carry == 0 && i >= short.len() {
                break;
            }
        }
        if carry > 0 {
            out.push(carry);
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    /// self - other, requires self >= other.
    pub fn sub(&self, other: &Self) -> Self {
        debug_assert!(self.cmp_big(other) != std::cmp::Ordering::Less);
        let mut out = self.0.clone();
        let mut borrow = 0u64;
        for i in 0..out.len() {
            let b = if i < other.0.len() { other.0[i] } else { 0 };
            let (d1, b1) = out[i].overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out[i] = d2;
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn cmp_big(&self, other: &Self) -> std::cmp::Ordering {
        if self.0.len() != other.0.len() {
            return self.0.len().cmp(&other.0.len());
        }
        for i in (0..self.0.len()).rev() {
            match self.0[i].cmp(&other.0[i]) {
                std::cmp::Ordering::Equal => continue,
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    }

    pub fn shr1(&self) -> Self {
        let mut out = vec![0u64; self.0.len()];
        let mut carry = 0u64;
        for i in (0..self.0.len()).rev() {
            out[i] = (self.0[i] >> 1) | (carry << 63);
            carry = self.0[i] & 1;
        }
        let mut r = BigUintLite(out);
        r.trim();
        r
    }

    pub fn to_f64(&self) -> f64 {
        let mut v = 0.0f64;
        for &d in self.0.iter().rev() {
            v = v * 1.8446744073709552e19 + d as f64; // 2^64
        }
        v
    }
}

/// Garner-style CRT reconstruction over a fixed prime basis.
pub struct CrtRecon {
    primes: Vec<u64>,
    /// inv_prefix[i] = (q_0*...*q_{i-1})^{-1} mod q_i
    inv_prefix: Vec<u64>,
    /// q_big = product of all primes; half = floor(q_big/2)
    q_big: BigUintLite,
    half: BigUintLite,
    /// prefix products as bigints: prefix[i] = q_0*...*q_{i-1}
    prefix: Vec<BigUintLite>,
}

impl CrtRecon {
    pub fn new(primes: &[u64]) -> Self {
        let mut inv_prefix = Vec::with_capacity(primes.len());
        for (i, &qi) in primes.iter().enumerate() {
            let mut prod = 1u64;
            for &qj in &primes[..i] {
                prod = mul_mod(prod, qj % qi, qi);
            }
            inv_prefix.push(if i == 0 { 1 } else { inv_mod(prod, qi) });
        }
        let mut prefix = Vec::with_capacity(primes.len());
        let mut acc = BigUintLite::from_u64(1);
        for &q in primes {
            prefix.push(acc.clone());
            acc = acc.mul_u64(q);
        }
        let q_big = acc;
        let half = q_big.shr1();
        CrtRecon {
            primes: primes.to_vec(),
            inv_prefix,
            q_big,
            half,
            prefix,
        }
    }

    /// Reconstruct x in [0, Q) from residues, return centered value
    /// (x or x - Q) as f64.
    pub fn centered_f64(&self, residues: &[u64]) -> f64 {
        // Garner: mixed-radix digits a_i with
        //   x = a_0 + a_1 q_0 + a_2 q_0 q_1 + ...
        let k = self.primes.len();
        let mut digits = vec![0u64; k];
        for i in 0..k {
            let qi = self.primes[i];
            // t = (r_i - (a_0 + a_1 q_0 + ...)) * inv_prefix mod q_i
            let mut acc = 0u64;
            let mut radix = 1u64;
            for j in 0..i {
                acc = add_mod(acc, mul_mod(digits[j] % qi, radix, qi), qi);
                radix = mul_mod(radix, self.primes[j] % qi, qi);
            }
            let t = sub_mod(residues[i] % qi, acc, qi);
            digits[i] = mul_mod(t, self.inv_prefix[i], qi);
        }
        // Assemble bigint.
        let mut x = BigUintLite::zero();
        for i in 0..k {
            x = x.add(&self.prefix[i].mul_u64(digits[i]).add_u64(0));
        }
        // Center.
        if x.cmp_big(&self.half) == std::cmp::Ordering::Greater {
            -(self.q_big.sub(&x).to_f64())
        } else {
            x.to_f64()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;

    fn ctx() -> ContextRef {
        CkksContext::new(CkksParams::toy())
    }

    #[test]
    fn signed_roundtrip_via_crt() {
        let c = ctx();
        let vals: Vec<i64> = vec![0, 1, -1, 123456789, -987654321, i32::MAX as i64];
        let mut coeffs = vec![0i64; c.n()];
        coeffs[..vals.len()].copy_from_slice(&vals);
        let p = RnsPoly::from_signed(&c, &coeffs, c.params.max_level(), false);
        let back = p.to_centered_f64(&c);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(back[i], v as f64, "coeff {i}");
        }
    }

    #[test]
    fn wide_roundtrip() {
        let c = ctx();
        let vals: Vec<i128> = vec![1i128 << 90, -(1i128 << 90) - 12345, (1i128 << 99) + 7];
        let mut coeffs = vec![0i128; c.n()];
        coeffs[..vals.len()].copy_from_slice(&vals);
        let p = RnsPoly::from_signed_wide(&c, &coeffs, c.params.max_level(), false);
        let back = p.to_centered_f64(&c);
        for (i, &v) in vals.iter().enumerate() {
            let rel = (back[i] - v as f64).abs() / (v as f64).abs();
            assert!(rel < 1e-12, "coeff {i}: {} vs {}", back[i], v);
        }
    }

    #[test]
    fn ntt_roundtrip_preserves() {
        let c = ctx();
        let mut rng = Xoshiro256pp::new(5);
        let mut p = RnsPoly::sample_uniform(&c, &mut rng, 1, false, false);
        let orig = p.clone();
        p.to_ntt(&c);
        p.from_ntt(&c);
        assert_eq!(p.limbs, orig.limbs);
    }

    #[test]
    fn add_mul_consistency_with_integers() {
        // (small a) * (small b) via NTT == integer negacyclic product.
        let c = ctx();
        let n = c.n();
        let mut rng = Xoshiro256pp::new(6);
        let a_c: Vec<i64> = (0..n).map(|_| rng.next_below(100) as i64 - 50).collect();
        let b_c: Vec<i64> = (0..n).map(|_| rng.next_below(100) as i64 - 50).collect();
        let mut a = RnsPoly::from_signed(&c, &a_c, 1, false);
        let mut b = RnsPoly::from_signed(&c, &b_c, 1, false);
        a.to_ntt(&c);
        b.to_ntt(&c);
        a.mul_assign(&c, &b);
        a.from_ntt(&c);
        let got = a.to_centered_f64(&c);
        // Naive negacyclic in i128.
        let mut expect = vec![0i128; n];
        for i in 0..n {
            for j in 0..n {
                let p = a_c[i] as i128 * b_c[j] as i128;
                let k = i + j;
                if k < n {
                    expect[k] += p;
                } else {
                    expect[k - n] -= p;
                }
            }
        }
        for i in 0..n {
            assert_eq!(got[i], expect[i] as f64, "coeff {i}");
        }
    }

    #[test]
    fn rescale_divides_by_top_prime() {
        let c = ctx();
        let lvl = c.params.max_level();
        let q_top = c.q(lvl) as i128;
        // value exactly divisible: x = k * q_top
        let mut coeffs = vec![0i128; c.n()];
        coeffs[0] = 42 * q_top;
        coeffs[1] = -7 * q_top;
        coeffs[2] = 5 * q_top + 3; // rounds to 5
        let mut p = RnsPoly::from_signed_wide(&c, &coeffs, lvl, false);
        p.rescale(&c);
        assert_eq!(p.level, lvl - 1);
        let back = p.to_centered_f64(&c);
        assert_eq!(back[0], 42.0);
        assert_eq!(back[1], -7.0);
        assert_eq!(back[2], 5.0);
    }

    #[test]
    fn ntt_domain_automorphism_matches_coeff_domain() {
        // On every limb (different primes), the NTT-slot permutation
        // must equal the coefficient-domain automorphism.
        let c = ctx();
        let mut rng = Xoshiro256pp::new(88);
        for g in [5usize, 25, 2 * c.n() - 1, 125] {
            let mut a = RnsPoly::sample_uniform(&c, &mut rng, c.params.max_level(), true, false);
            let mut coeff_path = a.clone();
            coeff_path.automorphism(&c, g);
            coeff_path.to_ntt(&c);
            a.to_ntt(&c);
            a.automorphism_ntt(&c.galois_perm(g));
            assert_eq!(a.limbs, coeff_path.limbs, "g={g}");
        }
    }

    #[test]
    fn mod_down_ntt_matches_coeff_path() {
        let c = ctx();
        let mut rng = Xoshiro256pp::new(77);
        let mut a = RnsPoly::sample_uniform(&c, &mut rng, 1, true, false);
        a.to_ntt(&c);
        let mut coeff_path = a.clone();
        coeff_path.mod_down_special(&c);
        let mut ntt_path = a;
        ntt_path.mod_down_special_ntt(&c);
        assert!(ntt_path.is_ntt);
        ntt_path.from_ntt(&c);
        coeff_path.from_ntt(&c);
        assert_eq!(ntt_path.limbs, coeff_path.limbs);
    }

    #[test]
    fn automorphism_is_ring_homomorphism() {
        // (a*b)(X^g) == a(X^g) * b(X^g)
        let c = ctx();
        let n = c.n();
        let mut rng = Xoshiro256pp::new(8);
        let a_c: Vec<i64> = (0..n).map(|_| rng.next_below(50) as i64 - 25).collect();
        let b_c: Vec<i64> = (0..n).map(|_| rng.next_below(50) as i64 - 25).collect();
        let g = 5usize;
        let mk = |coef: &Vec<i64>| RnsPoly::from_signed(&c, coef, 0, false);
        // lhs: multiply then automorph
        let mut a1 = mk(&a_c);
        let mut b1 = mk(&b_c);
        a1.to_ntt(&c);
        b1.to_ntt(&c);
        a1.mul_assign(&c, &b1);
        a1.automorphism(&c, g);
        a1.from_ntt(&c);
        // rhs: automorph then multiply
        let mut a2 = mk(&a_c);
        let mut b2 = mk(&b_c);
        a2.automorphism(&c, g);
        b2.automorphism(&c, g);
        a2.to_ntt(&c);
        b2.to_ntt(&c);
        a2.mul_assign(&c, &b2);
        a2.from_ntt(&c);
        assert_eq!(a1.limbs, a2.limbs);
    }

    #[test]
    fn bigint_ops() {
        let a = BigUintLite::from_u64(u64::MAX);
        let b = a.add_u64(1); // 2^64
        assert_eq!(b.0, vec![0, 1]);
        let c2 = b.mul_u64(u64::MAX);
        let d = c2.add(&b);
        // (2^64)(2^64-1) + 2^64 = 2^128
        assert_eq!(d.0, vec![0, 0, 1]);
        assert_eq!(d.shr1().0, vec![0, 1u64 << 63]);
        assert_eq!(d.sub(&b).0, c2.0);
        assert!((d.to_f64() - 3.402823669209385e38).abs() / 3.4e38 < 1e-12);
    }
}
