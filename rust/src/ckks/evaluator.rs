//! Homomorphic operations over ciphertexts, with per-operation
//! counters (the paper's Table 1 is regenerated from these).
//!
//! Scale discipline: `mul`/`mul_plain` produce scale `s_a·s_b`; callers
//! rescale to return near Δ. `add` requires operands at the same level
//! and (approximately) equal scales — the evaluator aligns levels by
//! dropping limbs and treats a relative scale mismatch < 1e-9 as equal
//! (the residual mismatch is far below the noise floor).

use super::encoder::Encoder;
use super::encrypt::{Ciphertext, Plaintext};
use super::keys::{apply_ksw, apply_ksw_decomposed, decompose, GaloisKeys, RelinKey};
use super::rns::{ContextRef, RnsPoly};
use super::scratch::Scratch;

/// Homomorphic operation counters (Table 1 of the paper).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub add: u64,
    pub add_plain: u64,
    pub mul: u64,
    pub mul_plain: u64,
    pub rotate: u64,
    pub rescale: u64,
    pub relin: u64,
    /// Fused plaintext-multiply-and-rescale ops
    /// ([`Evaluator::mul_plain_rescale`], emitted by the
    /// `FuseMulRescale` schedule pass): one kernel invocation that is
    /// counted here *instead of* in `mul_plain` + `rescale`.
    pub fused_mul_rescale: u64,
}

impl OpCounts {
    pub fn diff(&self, earlier: &OpCounts) -> OpCounts {
        OpCounts {
            add: self.add - earlier.add,
            add_plain: self.add_plain - earlier.add_plain,
            mul: self.mul - earlier.mul,
            mul_plain: self.mul_plain - earlier.mul_plain,
            rotate: self.rotate - earlier.rotate,
            rescale: self.rescale - earlier.rescale,
            relin: self.relin - earlier.relin,
            fused_mul_rescale: self.fused_mul_rescale - earlier.fused_mul_rescale,
        }
    }

    /// Additions as the paper counts them (ct+ct and ct+pt).
    pub fn additions(&self) -> u64 {
        self.add + self.add_plain
    }

    /// Multiplications as the paper counts them (ct·ct and ct·pt; a
    /// fused multiply-rescale contains exactly one ct·pt multiply).
    pub fn multiplications(&self) -> u64 {
        self.mul + self.mul_plain + self.fused_mul_rescale
    }

    /// Total modulus switches (stand-alone rescales plus the one
    /// inside each fused multiply-rescale).
    pub fn rescales(&self) -> u64 {
        self.rescale + self.fused_mul_rescale
    }
}

impl std::ops::AddAssign for OpCounts {
    /// Field-wise accumulation — the single merge point for every
    /// place counts are combined (layer accounting, schedule dry-runs,
    /// bench aggregation).
    fn add_assign(&mut self, o: OpCounts) {
        self.add += o.add;
        self.add_plain += o.add_plain;
        self.mul += o.mul;
        self.mul_plain += o.mul_plain;
        self.rotate += o.rotate;
        self.rescale += o.rescale;
        self.relin += o.relin;
        self.fused_mul_rescale += o.fused_mul_rescale;
    }
}

impl std::ops::Add for OpCounts {
    type Output = OpCounts;
    fn add(mut self, o: OpCounts) -> OpCounts {
        self += o;
        self
    }
}

/// The server-side evaluator. Owns the context reference, counters and
/// a [`Scratch`] handle into the shared slab pool that recycles every
/// temporary the hot ops make (tensor products, key-switch digits,
/// hoisted rotations, retired activation powers); key material is
/// passed per call (it belongs to the client session — see
/// `coordinator::session`).
pub struct Evaluator {
    pub ctx: ContextRef,
    pub counts: OpCounts,
    /// Handle into the shared slab pool ([`crate::mem`]) for the hot
    /// paths. The handle is owned per evaluator (per worker thread) —
    /// the backing free lists are shared and byte-budgeted.
    /// Crate-private so the zeroing/recycling invariants stay behind
    /// the evaluator's entry points.
    pub(crate) scratch: Scratch,
}

impl Evaluator {
    pub fn new(ctx: ContextRef) -> Self {
        Evaluator {
            ctx,
            counts: OpCounts::default(),
            scratch: Scratch::new(),
        }
    }

    /// An evaluator seeded with an existing scratch handle — the
    /// per-worker construction path of the op-parallel DAG driver.
    /// Since [`Scratch`] became a façade over the shared slab pool
    /// the handle carries no buffers of its own, but the seam is kept
    /// so callers can pin workers to a specific pool (tests use
    /// `Scratch::in_pool` with a private one).
    pub fn with_scratch(ctx: ContextRef, scratch: Scratch) -> Self {
        Evaluator {
            ctx,
            counts: OpCounts::default(),
            scratch,
        }
    }

    /// Split a worker evaluator off this one: same context, zeroed
    /// counters, and a clone of *this* evaluator's scratch handle
    /// (same backing pool and home shard — warm buffers keep flowing
    /// through a borrowed-`&mut Evaluator` API boundary because the
    /// pool itself is shared). Pair with [`merge`](Evaluator::merge)
    /// to fold counters back.
    pub fn split_off(&mut self) -> Evaluator {
        Evaluator {
            ctx: self.ctx.clone(),
            counts: OpCounts::default(),
            scratch: self.scratch.clone(),
        }
    }

    /// Fold a worker evaluator (from [`split_off`](Evaluator::split_off)
    /// or [`with_scratch`](Evaluator::with_scratch)) back in: counters
    /// accumulate. The worker's recycled buffers already live in the
    /// shared slab pool, so there is nothing else to reclaim.
    pub fn merge(&mut self, worker: Evaluator) {
        self.counts += worker.counts;
        self.scratch.absorb(worker.scratch);
    }

    /// Consume the evaluator, yielding its scratch handle (the
    /// [`ScratchPool`](crate::ckks::ScratchPool) façade retires it; the
    /// warm buffers of a retiring DAG worker are already resident in
    /// the shared slab pool).
    pub fn into_scratch(self) -> Scratch {
        self.scratch
    }

    /// Recycle a ciphertext's limb buffers into the pool.
    fn recycle_ct(&mut self, ct: Ciphertext) {
        self.scratch.put(ct.c0.into_data());
        self.scratch.put(ct.c1.into_data());
    }

    /// Clone a ciphertext with pool-backed limb buffers.
    fn clone_ct_in(&mut self, ct: &Ciphertext) -> Ciphertext {
        Ciphertext {
            c0: ct.c0.clone_in(&mut self.scratch),
            c1: ct.c1.clone_in(&mut self.scratch),
            level: ct.level,
            scale: ct.scale,
        }
    }

    pub fn reset_counts(&mut self) {
        self.counts = OpCounts::default();
    }

    fn scales_match(a: f64, b: f64) {
        debug_assert!(
            ((a - b) / a).abs() < 1e-9,
            "scale mismatch: {a} vs {b}"
        );
    }

    /// Align two ciphertexts to the lower of their levels.
    fn align(&self, a: &mut Ciphertext, b: &mut Ciphertext) {
        let lvl = a.level.min(b.level);
        for ct in [&mut *a, &mut *b] {
            if ct.level > lvl {
                ct.c0.drop_to_level_ntt(&self.ctx, lvl);
                ct.c1.drop_to_level_ntt(&self.ctx, lvl);
                ct.level = lvl;
            }
        }
    }

    pub fn add(&mut self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let (mut a, mut b) = (a.clone(), b.clone());
        self.align(&mut a, &mut b);
        Self::scales_match(a.scale, b.scale);
        a.c0.add_assign(&self.ctx, &b.c0);
        a.c1.add_assign(&self.ctx, &b.c1);
        self.counts.add += 1;
        a
    }

    pub fn add_inplace(&mut self, a: &mut Ciphertext, b: &Ciphertext) {
        if a.level != b.level {
            let mut b2 = b.clone();
            self.align(a, &mut b2);
            Self::scales_match(a.scale, b2.scale);
            a.c0.add_assign(&self.ctx, &b2.c0);
            a.c1.add_assign(&self.ctx, &b2.c1);
            self.recycle_ct(b2);
        } else {
            Self::scales_match(a.scale, b.scale);
            a.c0.add_assign(&self.ctx, &b.c0);
            a.c1.add_assign(&self.ctx, &b.c1);
        }
        self.counts.add += 1;
    }

    pub fn sub(&mut self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let mut a = a.clone();
        self.sub_inplace(&mut a, b);
        a
    }

    /// In-place `a -= b` (same level alignment rules as
    /// [`Evaluator::add_inplace`]).
    pub fn sub_inplace(&mut self, a: &mut Ciphertext, b: &Ciphertext) {
        if a.level != b.level {
            let mut b2 = b.clone();
            self.align(a, &mut b2);
            Self::scales_match(a.scale, b2.scale);
            a.c0.sub_assign(&self.ctx, &b2.c0);
            a.c1.sub_assign(&self.ctx, &b2.c1);
            self.recycle_ct(b2);
        } else {
            Self::scales_match(a.scale, b.scale);
            a.c0.sub_assign(&self.ctx, &b.c0);
            a.c1.sub_assign(&self.ctx, &b.c1);
        }
        self.counts.add += 1;
    }

    pub fn negate(&mut self, a: &Ciphertext) -> Ciphertext {
        let mut a = a.clone();
        self.negate_inplace(&mut a);
        a
    }

    /// In-place negation.
    pub fn negate_inplace(&mut self, a: &mut Ciphertext) {
        a.c0.neg_assign(&self.ctx);
        a.c1.neg_assign(&self.ctx);
    }

    /// ct + pt. The plaintext must be encoded at the ciphertext's level
    /// and scale (use [`Evaluator::encode_for`]).
    pub fn add_plain(&mut self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        let mut a = a.clone();
        self.add_plain_inplace(&mut a, pt);
        a
    }

    pub fn add_plain_inplace(&mut self, a: &mut Ciphertext, pt: &Plaintext) {
        debug_assert_eq!(a.level, pt.poly.level, "add_plain level mismatch");
        Self::scales_match(a.scale, pt.scale);
        a.c0.add_assign(&self.ctx, &pt.poly);
        self.counts.add_plain += 1;
    }

    pub fn sub_plain_inplace(&mut self, a: &mut Ciphertext, pt: &Plaintext) {
        debug_assert_eq!(a.level, pt.poly.level);
        Self::scales_match(a.scale, pt.scale);
        a.c0.sub_assign(&self.ctx, &pt.poly);
        self.counts.add_plain += 1;
    }

    /// ct · pt (element-wise in slots). Result scale = s_ct · s_pt;
    /// caller usually rescales right after.
    pub fn mul_plain(&mut self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        let mut a = a.clone();
        self.mul_plain_inplace(&mut a, pt);
        a
    }

    pub fn mul_plain_inplace(&mut self, a: &mut Ciphertext, pt: &Plaintext) {
        debug_assert_eq!(a.level, pt.poly.level, "mul_plain level mismatch");
        a.c0.mul_assign(&self.ctx, &pt.poly);
        a.c1.mul_assign(&self.ctx, &pt.poly);
        a.scale *= pt.scale;
        self.counts.mul_plain += 1;
    }

    /// ct · ct with relinearization. Result scale = s_a · s_b.
    /// Temporaries come from and return to the evaluator's scratch
    /// pool — one multiplication allocates nothing at steady state.
    pub fn mul(&mut self, a: &Ciphertext, b: &Ciphertext, rlk: &RelinKey) -> Ciphertext {
        let (mut a, mut b) = (self.clone_ct_in(a), self.clone_ct_in(b));
        self.align(&mut a, &mut b);
        let (level, scale) = (a.level, a.scale * b.scale);
        // Fused tensor kernel: d0 = a0 b0, d1 = a0 b1 + a1 b0,
        // d2 = a1 b1 in one limb-parallel pass that reads each operand
        // limb exactly once (the cross term reduces once from its
        // 128-bit sum — bit-identical to mul + add_assign, which is
        // also fully reduced).
        let (mut d0, mut d1, d2) =
            RnsPoly::tensor(&self.ctx, &a.c0, &a.c1, &b.c0, &b.c1, &mut self.scratch);
        self.recycle_ct(a);
        self.recycle_ct(b);
        // Relinearize d2: (k0, k1) ≈ d2·s² under s.
        let (k0, k1) = apply_ksw(&self.ctx, &d2, &rlk.0, &mut self.scratch);
        d2.recycle(&mut self.scratch);
        d0.add_assign(&self.ctx, &k0);
        d1.add_assign(&self.ctx, &k1);
        k0.recycle(&mut self.scratch);
        k1.recycle(&mut self.scratch);
        self.counts.mul += 1;
        self.counts.relin += 1;
        Ciphertext {
            c0: d0,
            c1: d1,
            level,
            scale,
        }
    }

    /// Square (saves one ring multiplication vs `mul(a, a)`).
    pub fn square(&mut self, a: &Ciphertext, rlk: &RelinKey) -> Ciphertext {
        // Fused squaring tensor: (a0², 2·a0·a1, a1²) straight off the
        // operand limbs — no clones, and the doubled cross term reduces
        // once (bit-identical to mul + double_assign: both are fully
        // reduced and congruent mod q).
        let (mut d0, mut d1, d2) =
            RnsPoly::tensor_square(&self.ctx, &a.c0, &a.c1, &mut self.scratch);
        let (k0, k1) = apply_ksw(&self.ctx, &d2, &rlk.0, &mut self.scratch);
        d2.recycle(&mut self.scratch);
        d0.add_assign(&self.ctx, &k0);
        d1.add_assign(&self.ctx, &k1);
        k0.recycle(&mut self.scratch);
        k1.recycle(&mut self.scratch);
        self.counts.mul += 1;
        self.counts.relin += 1;
        Ciphertext {
            c0: d0,
            c1: d1,
            level: a.level,
            scale: a.scale * a.scale,
        }
    }

    /// Rescale: divide by the top chain prime, dropping one level.
    pub fn rescale(&mut self, a: &mut Ciphertext) {
        self.rescale_uncounted(a);
        self.counts.rescale += 1;
    }

    fn rescale_uncounted(&mut self, a: &mut Ciphertext) {
        let q_top = self.ctx.q(a.level) as f64;
        a.c0.rescale(&self.ctx);
        a.c1.rescale(&self.ctx);
        a.level -= 1;
        a.scale /= q_top;
    }

    /// Fused plaintext-multiply-and-rescale: one invocation covering
    /// both primitives (the execution target of the `FuseMulRescale`
    /// schedule pass). The ring multiplies run **lazily** ([0, 2q)
    /// residues, one conditional-subtraction sweep per limb skipped)
    /// and the inverse NTT at the head of the rescale consumes the lazy
    /// domain and reduces exactly, so fused and unfused executions stay
    /// bit-identical (pinned in `tests/modops_kernels.rs`); the
    /// accounting books the pair as a single `fused_mul_rescale` op
    /// instead of `mul_plain` + `rescale`.
    pub fn mul_plain_rescale(&mut self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        debug_assert_eq!(a.level, pt.poly.level, "mul_plain level mismatch");
        let mut r = self.clone_ct_in(a);
        r.c0.mul_assign_lazy(&self.ctx, &pt.poly);
        r.c1.mul_assign_lazy(&self.ctx, &pt.poly);
        r.scale *= pt.scale;
        self.rescale_uncounted(&mut r);
        self.counts.fused_mul_rescale += 1;
        r
    }

    /// Rotate slots left by `r` (paper's `Rotation(z, r)`).
    pub fn rotate(&mut self, a: &Ciphertext, r: usize, gk: &GaloisKeys) -> Ciphertext {
        if r == 0 {
            return a.clone();
        }
        let g = *gk
            .elements
            .get(&r)
            .unwrap_or_else(|| panic!("no galois key for rotation {r}"));
        let ksw = &gk.keys[&r];
        let mut c0 = a.c0.clone_in(&mut self.scratch);
        let mut c1 = a.c1.clone_in(&mut self.scratch);
        c0.automorphism(&self.ctx, g);
        c1.automorphism(&self.ctx, g);
        let (k0, k1) = apply_ksw(&self.ctx, &c1, ksw, &mut self.scratch);
        c1.recycle(&mut self.scratch);
        c0.add_assign(&self.ctx, &k0);
        k0.recycle(&mut self.scratch);
        self.counts.rotate += 1;
        Ciphertext {
            c0,
            c1: k1,
            level: a.level,
            scale: a.scale,
        }
    }

    /// Precompute the key-switch decomposition of a ciphertext for
    /// repeated rotations of the *same* input ("hoisting", §Perf
    /// step 3): the expensive iNTT + per-digit NTTs happen once and
    /// every subsequent [`Evaluator::rotate_hoisted`] is a slot
    /// permutation + multiply-accumulate.
    pub fn hoist(&mut self, a: &Ciphertext) -> Vec<RnsPoly> {
        let mut c1 = a.c1.clone_in(&mut self.scratch);
        c1.from_ntt(&self.ctx);
        let digits = decompose(&self.ctx, &c1, &mut self.scratch);
        c1.recycle(&mut self.scratch);
        digits
    }

    /// Rotate using a hoisted decomposition (must come from
    /// [`Evaluator::hoist`] of the same ciphertext).
    pub fn rotate_hoisted(
        &mut self,
        a: &Ciphertext,
        digits: &[RnsPoly],
        r: usize,
        gk: &GaloisKeys,
    ) -> Ciphertext {
        if r == 0 {
            return a.clone();
        }
        let g = *gk
            .elements
            .get(&r)
            .unwrap_or_else(|| panic!("no galois key for rotation {r}"));
        let perm = self.ctx.galois_perm(g);
        // κ(D_j(c1)) stays a valid decomposition of κ(c1) (the digits
        // are small integer polys; automorphism commutes with the CRT
        // lift), so permute each digit in the NTT domain and MAC.
        let rotated: Vec<RnsPoly> = digits
            .iter()
            .map(|d| RnsPoly::automorphism_ntt_from(d, &self.ctx, &perm, &mut self.scratch))
            .collect();
        let (mut k0, k1) =
            apply_ksw_decomposed(&self.ctx, &rotated, &gk.keys[&r], &mut self.scratch);
        for d in rotated {
            d.recycle(&mut self.scratch);
        }
        let c0 = RnsPoly::automorphism_ntt_from(&a.c0, &self.ctx, &perm, &mut self.scratch);
        k0.add_assign(&self.ctx, &c0);
        c0.recycle(&mut self.scratch);
        self.counts.rotate += 1;
        Ciphertext {
            c0: k0,
            c1: k1,
            level: a.level,
            scale: a.scale,
        }
    }

    /// Σ over all `span` slots via log₂(span) rotate-and-adds
    /// (span must be a power of two; result: every slot of the first
    /// block holds the full sum — in particular slot 0).
    pub fn rotate_sum(&mut self, a: &Ciphertext, span: usize, gk: &GaloisKeys) -> Ciphertext {
        debug_assert!(span.is_power_of_two());
        let mut acc = a.clone();
        let mut step = 1usize;
        while step < span {
            let rot = self.rotate(&acc, step, gk);
            self.add_inplace(&mut acc, &rot);
            step <<= 1;
        }
        acc
    }

    /// Encode a plaintext vector at the level & scale of `ct` —
    /// the common companion to `add_plain` / `mul_plain`.
    pub fn encode_for(
        &self,
        enc: &Encoder,
        z: &[f64],
        ct: &Ciphertext,
        scale: f64,
    ) -> Plaintext {
        enc.encode(&self.ctx, z, ct.level, scale)
    }

    /// Evaluate a polynomial Σ c_i x^i on a ciphertext by Horner's
    /// rule: depth = deg(P) levels. (The BSGS variant below trades
    /// ct-ct muls for depth; Horner is kept as the reference path.)
    pub fn eval_poly_horner(
        &mut self,
        enc: &Encoder,
        x: &Ciphertext,
        coeffs: &[f64],
        rlk: &RelinKey,
    ) -> Ciphertext {
        assert!(coeffs.len() >= 2, "constant polynomial");
        let deg = coeffs.len() - 1;
        let delta = self.ctx.params.scale;
        // acc = c_deg (as plaintext constant times x) … operate:
        // acc = c_deg * x  + c_{deg-1}, then repeatedly acc = acc*x + c_i
        let c_top = enc.encode_constant(&self.ctx, coeffs[deg], x.level, delta);
        let mut acc = self.mul_plain(x, &c_top);
        self.rescale(&mut acc);
        let c_next = enc.encode_constant(&self.ctx, coeffs[deg - 1], acc.level, acc.scale);
        self.add_plain_inplace(&mut acc, &c_next);
        for i in (0..deg - 1).rev() {
            // acc = acc * x
            let mut x_at = x.clone();
            x_at.c0.drop_to_level_ntt(&self.ctx, acc.level);
            x_at.c1.drop_to_level_ntt(&self.ctx, acc.level);
            x_at.level = acc.level;
            let mut next = self.mul(&acc, &x_at, rlk);
            self.rescale(&mut next);
            let c_i = enc.encode_constant(&self.ctx, coeffs[i], next.level, next.scale);
            self.add_plain_inplace(&mut next, &c_i);
            acc = next;
        }
        acc
    }

    /// Evaluate a polynomial by the power-basis ("baby-step") method:
    /// precompute x^2, x^4 … so depth is ⌈log₂ deg⌉+1 instead of deg.
    /// Used by the HRF hot path (see EXPERIMENTS.md §Perf).
    pub fn eval_poly_power_basis(
        &mut self,
        enc: &Encoder,
        x: &Ciphertext,
        coeffs: &[f64],
        rlk: &RelinKey,
    ) -> Ciphertext {
        // Coefficients below this threshold are treated as zero (e.g.
        // the ~1e-17 even terms of odd tanh fits) — their powers are
        // never computed, saving both muls and levels.
        const EPS: f64 = 1e-12;
        let deg = coeffs
            .iter()
            .rposition(|c| c.abs() > EPS)
            .expect("all-zero polynomial");
        assert!(deg >= 1, "constant polynomial");
        if deg <= 2 {
            let trimmed: Vec<f64> = coeffs[..=deg].to_vec();
            return self.eval_poly_horner(enc, x, &trimmed, rlk);
        }
        let delta = self.ctx.params.scale;
        // Mark needed powers (nonzero coeff) plus the intermediates of
        // their binary decompositions.
        let mut needed = vec![false; deg + 1];
        for (i, c) in coeffs.iter().enumerate().skip(1).take(deg) {
            if c.abs() > EPS {
                needed[i] = true;
            }
        }
        for i in (2..=deg).rev() {
            if needed[i] && !i.is_power_of_two() {
                let hi = 1usize << (usize::BITS - 1 - i.leading_zeros());
                needed[hi] = true;
                needed[i - hi] = true;
            }
        }
        // Power-of-two intermediates below the largest needed pow2.
        let max_p2 = (1..=deg)
            .filter(|i| needed[*i] && i.is_power_of_two())
            .max()
            .unwrap_or(1);
        {
            let mut p = max_p2;
            while p > 1 {
                needed[p] = true;
                p >>= 1;
            }
        }
        let mut powers: Vec<Option<Ciphertext>> = vec![None; deg + 1];
        powers[1] = Some(x.clone());
        let mut p = 2usize;
        while p <= deg {
            if needed[p] {
                let half = powers[p / 2].as_ref().expect("half power computed");
                let mut sq = self.square(half, rlk);
                self.rescale(&mut sq);
                powers[p] = Some(sq);
            }
            p <<= 1;
        }
        // Fill non-power-of-two entries as x^hi * x^(i-hi).
        for i in 3..=deg {
            if !needed[i] || powers[i].is_some() {
                continue;
            }
            let hi = 1usize << (usize::BITS - 1 - i.leading_zeros());
            let a = powers[hi].as_ref().expect("power-of-two intermediate");
            let b = powers[i - hi].as_ref().expect("low-part intermediate");
            let mut prod = self.mul(a, b, rlk);
            self.rescale(&mut prod);
            powers[i] = Some(prod);
        }
        // Target level/scale: that of the deepest power used.
        let min_level = powers
            .iter()
            .flatten()
            .map(|c| c.level)
            .min()
            .unwrap();
        // Accumulate Σ c_i·x^i at min_level with matched scales. Each
        // power is consumed (moved out) at its single use; retired
        // intermediates are recycled into the scratch pool below.
        let mut acc: Option<Ciphertext> = None;
        for i in 1..=deg {
            if coeffs[i].abs() <= EPS {
                continue;
            }
            let mut term = powers[i].take().expect("needed power computed");
            if term.level > min_level {
                term.c0.drop_to_level_ntt(&self.ctx, min_level);
                term.c1.drop_to_level_ntt(&self.ctx, min_level);
                term.level = min_level;
            }
            let cpt = enc.encode_constant(&self.ctx, coeffs[i], term.level, delta);
            self.mul_plain_inplace(&mut term, &cpt);
            self.rescale(&mut term);
            match &mut acc {
                None => acc = Some(term),
                Some(a) => {
                    // force exact scale agreement: scales differ by
                    // <1e-9 relative (same prime chain); adopt a's.
                    term.scale = a.scale;
                    self.add_inplace(a, &term);
                    self.recycle_ct(term);
                }
            }
        }
        // Intermediates that only fed the binary decompositions.
        for leftover in powers.into_iter().flatten() {
            self.recycle_ct(leftover);
        }
        let mut acc = acc.expect("non-trivial polynomial");
        let c0pt = enc.encode_constant(&self.ctx, coeffs[0], acc.level, acc.scale);
        self.add_plain_inplace(&mut acc, &c0pt);
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encrypt::{Decryptor, Encryptor};
    use crate::ckks::keys::KeyGenerator;
    use crate::ckks::params::CkksParams;
    use crate::ckks::rns::CkksContext;
    use crate::rng::Xoshiro256pp;

    struct Setup {
        ctx: ContextRef,
        enc: Encoder,
        encryptor: Encryptor,
        decryptor: Decryptor,
        rlk: RelinKey,
        gk: GaloisKeys,
        ev: Evaluator,
    }

    fn setup(rotations: &[usize]) -> Setup {
        let ctx = CkksContext::new(CkksParams::toy());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 42);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, rotations);
        Setup {
            ev: Evaluator::new(ctx.clone()),
            encryptor: Encryptor::new(pk, 100),
            decryptor: Decryptor::new(kg.secret_key()),
            rlk,
            gk,
            enc,
            ctx,
        }
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut r = Xoshiro256pp::new(seed);
        (0..n).map(|_| r.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn homomorphic_add_sub() {
        let mut s = setup(&[]);
        let n = s.enc.slots();
        let (a, b) = (rand_vec(n, 1), rand_vec(n, 2));
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let cb = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &b);
        let sum = s.ev.add(&ca, &cb);
        let diff = s.ev.sub(&ca, &cb);
        let ds = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &sum);
        let dd = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &diff);
        for i in 0..n {
            assert!((ds[i] - (a[i] + b[i])).abs() < 1e-5);
            assert!((dd[i] - (a[i] - b[i])).abs() < 1e-5);
        }
        assert_eq!(s.ev.counts.add, 2);
    }

    #[test]
    fn homomorphic_mul_with_rescale() {
        let mut s = setup(&[]);
        let n = s.enc.slots();
        let (a, b) = (rand_vec(n, 3), rand_vec(n, 4));
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let cb = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &b);
        let mut prod = s.ev.mul(&ca, &cb, &s.rlk);
        s.ev.rescale(&mut prod);
        assert_eq!(prod.level, s.ctx.params.max_level() - 1);
        let dp = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &prod);
        for i in 0..n {
            assert!(
                (dp[i] - a[i] * b[i]).abs() < 1e-4,
                "slot {i}: {} vs {}",
                dp[i],
                a[i] * b[i]
            );
        }
    }

    #[test]
    fn homomorphic_mul_plain_and_add_plain() {
        let mut s = setup(&[]);
        let n = s.enc.slots();
        let (a, w) = (rand_vec(n, 5), rand_vec(n, 6));
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let pw = s.ev.encode_for(&s.enc, &w, &ca, s.ctx.params.scale);
        let mut prod = s.ev.mul_plain(&ca, &pw);
        s.ev.rescale(&mut prod);
        let pb = s.ev.encode_for(&s.enc, &w, &prod, prod.scale);
        s.ev.add_plain_inplace(&mut prod, &pb);
        let d = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &prod);
        for i in 0..n {
            assert!(
                (d[i] - (a[i] * w[i] + w[i])).abs() < 1e-4,
                "slot {i}"
            );
        }
    }

    #[test]
    fn square_matches_mul_self() {
        let mut s = setup(&[]);
        let n = s.enc.slots();
        let a = rand_vec(n, 7);
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let mut sq = s.ev.square(&ca, &s.rlk);
        s.ev.rescale(&mut sq);
        let d = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &sq);
        for i in 0..n {
            assert!((d[i] - a[i] * a[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_shifts_left() {
        let mut s = setup(&[1, 2, 4]);
        let n = s.enc.slots();
        let a: Vec<f64> = (0..n).map(|i| (i % 31) as f64 / 31.0).collect();
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        for &r in &[1usize, 2, 4] {
            let rot = s.ev.rotate(&ca, r, &s.gk);
            let d = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &rot);
            for i in 0..n {
                assert!(
                    (d[i] - a[(i + r) % n]).abs() < 1e-5,
                    "r={r} slot {i}"
                );
            }
        }
        assert_eq!(s.ev.counts.rotate, 3);
    }

    #[test]
    fn hoisted_rotation_matches_plain_rotation() {
        let mut s = setup(&[1, 3, 7]);
        let n = s.enc.slots();
        let a: Vec<f64> = (0..n).map(|i| ((i * 29) % 83) as f64 / 83.0).collect();
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let digits = s.ev.hoist(&ca);
        for &r in &[1usize, 3, 7] {
            let fast = s.ev.rotate_hoisted(&ca, &digits, r, &s.gk);
            let slow = s.ev.rotate(&ca, r, &s.gk);
            let df = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &fast);
            let ds = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &slow);
            for i in 0..n {
                assert!(
                    (df[i] - a[(i + r) % n]).abs() < 1e-5,
                    "hoisted r={r} slot {i}: {} vs {}",
                    df[i],
                    a[(i + r) % n]
                );
                assert!((df[i] - ds[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn rotate_sum_totals_slots() {
        let mut s = setup(&[1, 2, 4, 8]);
        let n = s.enc.slots();
        let mut a = vec![0.0f64; n];
        for (i, v) in a.iter_mut().enumerate().take(16) {
            *v = (i + 1) as f64 * 0.01;
        }
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let summed = s.ev.rotate_sum(&ca, 16, &s.gk);
        let d = s.decryptor.decrypt_slots(&s.ctx, &s.enc, &summed);
        let expect: f64 = (1..=16).map(|i| i as f64 * 0.01).sum();
        assert!((d[0] - expect).abs() < 1e-4, "{} vs {expect}", d[0]);
    }

    #[test]
    fn poly_eval_horner_matches_plain() {
        let mut s = setup(&[]);
        let n = s.enc.slots();
        let a = rand_vec(n, 8);
        // P(x) = 0.5 - 0.3x + 0.2x² + 0.1x³  on [-1,1]
        let coeffs = [0.5, -0.3, 0.2, 0.1];
        let _ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        // toy params have depth 2 — need depth 3 for cubic Horner; use
        // fast() context instead.
        drop(s);
        let ctx = CkksContext::new(CkksParams::fast());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 9);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let mut encryptor = Encryptor::new(pk, 10);
        let decryptor = Decryptor::new(kg.secret_key());
        let mut ev = Evaluator::new(ctx.clone());
        let n = enc.slots();
        let a = rand_vec(n, 8);
        let ca = encryptor.encrypt_slots(&ctx, &enc, &a);
        let out = ev.eval_poly_horner(&enc, &ca, &coeffs, &rlk);
        let d = decryptor.decrypt_slots(&ctx, &enc, &out);
        for i in 0..n {
            let x = a[i];
            let expect = 0.5 - 0.3 * x + 0.2 * x * x + 0.1 * x * x * x;
            assert!(
                (d[i] - expect).abs() < 1e-3,
                "slot {i}: {} vs {expect}",
                d[i]
            );
        }
        let _ = ca;
    }

    #[test]
    fn poly_eval_power_basis_matches_horner() {
        let ctx = CkksContext::new(CkksParams::fast());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 11);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let mut encryptor = Encryptor::new(pk, 12);
        let decryptor = Decryptor::new(kg.secret_key());
        let mut ev = Evaluator::new(ctx.clone());
        let n = enc.slots();
        let a = rand_vec(n, 13);
        let coeffs = [0.1, 0.7, -0.2, 0.05, -0.3];
        let ca = encryptor.encrypt_slots(&ctx, &enc, &a);
        let out = ev.eval_poly_power_basis(&enc, &ca, &coeffs, &rlk);
        let d = decryptor.decrypt_slots(&ctx, &enc, &out);
        for i in 0..n {
            let x = a[i];
            let expect = coeffs[0]
                + coeffs[1] * x
                + coeffs[2] * x * x
                + coeffs[3] * x * x * x
                + coeffs[4] * x * x * x * x;
            assert!(
                (d[i] - expect).abs() < 1e-3,
                "slot {i}: {} vs {expect}",
                d[i]
            );
        }
        // power-basis for deg 4 consumes 3 levels (x², x⁴, + coeff mul)
        assert!(out.level >= ctx.params.max_level().saturating_sub(3));
    }

    #[test]
    fn op_counters_track() {
        let mut s = setup(&[1]);
        let n = s.enc.slots();
        let a = rand_vec(n, 14);
        let ca = s.encryptor.encrypt_slots(&s.ctx, &s.enc, &a);
        let before = s.ev.counts;
        let _ = s.ev.add(&ca, &ca);
        let _ = s.ev.rotate(&ca, 1, &s.gk);
        let pw = s.ev.encode_for(&s.enc, &a, &ca, s.ctx.params.scale);
        let _ = s.ev.mul_plain(&ca, &pw);
        let d = s.ev.counts.diff(&before);
        assert_eq!(d.add, 1);
        assert_eq!(d.rotate, 1);
        assert_eq!(d.mul_plain, 1);
    }
}
