//! Explicitly-chunked, lazy-reduction batch kernels over whole RNS
//! limbs (§Perf step 7: vectorized modular kernels).
//!
//! Every element-wise hot loop in the data plane — ring multiplies,
//! the key-switch inner product, rescale / mod-down adjustments,
//! ct×ct tensoring — routes through this module instead of open-coding
//! per-coefficient arithmetic. Each kernel processes one limb (a
//! stride-`N` slice) in explicit [`LANES`]-wide unrolled blocks with a
//! scalar tail, so LLVM sees constant-trip inner loops it can
//! autovectorize; with the nightly-only `wide` cargo feature the pure
//! add/sub kernels switch to explicit `std::simd` vectors (bit-identical
//! outputs either way — modular add/sub is exact arithmetic).
//!
//! # Residue domains
//!
//! A value belongs to one of three domains, and every kernel boundary
//! states (and `debug_assert!`s) which it consumes and produces:
//!
//! * **reduced** — `[0, q)`. The public `RnsPoly` invariant: every poly
//!   observable outside an op is fully reduced.
//! * **lazy** — `[0, 2q)`. One conditional subtraction deferred. Legal
//!   only *between* fused steps whose consumer tolerates or re-reduces
//!   it: the inverse NTT accepts lazy inputs (its butterflies hold
//!   values `< 2q` anyway and its final `inv_n` pass reduces exactly),
//!   and Shoup multiplication ([`mul_mod_shoup`]) is exact for *any*
//!   u64 left operand. `q < 2^62` (enforced by `params::build`), so
//!   lazy values never overflow u64.
//! * **accumulator** — a per-coefficient `(lo, hi)` u128 split across
//!   two limb-sized slices. Products accumulate with carry and *no*
//!   reductions ([`mac_acc_slice`]); a single [`barrett_reduce_128`]
//!   per coefficient ([`reduce_acc_slice`]) converts back to reduced.
//!
//! Chaining rules: reduced ⊂ lazy (a reduced value is valid wherever a
//! lazy one is); a lazy value must reach a fully-reducing consumer
//! (inverse NTT, Shoup multiply, [`reduce_acc_slice`]) before the
//! result becomes externally observable. Kernels never *return* lazy
//! values except those documented to (the `_lazy` suffix).
//!
//! # Digit headroom for the lazy MAC
//!
//! The key-switch inner product Σ_j digit_j ⊙ key_j accumulates one
//! u128 product per digit into the accumulator domain before its
//! single reduction. Each term is at most `(2q−1)²` (both operands
//! lazy-domain), so the accumulator is exact as long as the term count
//! stays within [`mac_headroom`]`(q) = ⌊u128::MAX / (2q−1)²⌋`. For the
//! ~2^60 anchor/special primes that is ≥ 64 terms; the digit count is
//! at most `max_level + 1` (+1 for the carried-in accumulator word),
//! which `params::build` asserts against every prime of every set at
//! construction and [`mac_acc_slice`] re-checks per call in debug
//! builds. The payoff: `digits × N × limbs` Barrett reductions become
//! `N × limbs` — exactly one reduction per (coefficient, limb)
//! regardless of digit count (pinned by the debug-build reduction
//! counter in [`counters`]).

use super::modops::{
    add_mod, barrett_reduce_128, barrett_reduce_64, mul_mod_barrett, mul_mod_barrett_lazy,
    mul_mod_shoup, sub_mod,
};

/// Unroll width of every batch kernel: 8 × u64 = one 64-byte cache
/// line per block, and wide enough for 512-bit vector units.
pub const LANES: usize = 8;

/// Maximum number of lazy-domain (`[0, 2q)`) products that can be
/// accumulated into one u128 before [`reduce_acc_slice`] must run:
/// each term is at most `(2q−1)²`, so `⌊u128::MAX / (2q−1)²⌋` terms
/// can never overflow the accumulator.
pub fn mac_headroom(q: u64) -> usize {
    debug_assert!(q < 1 << 62);
    let m = (2 * q - 1) as u128;
    (u128::MAX / (m * m)).min(usize::MAX as u128) as usize
}

/// Debug-build instrumentation pinning the "one Barrett reduction per
/// (coefficient, limb)" contract of the lazy MAC: every
/// [`reduce_acc_slice`] call bumps a thread-local counter by the
/// number of coefficients it reduced. Compiled out of release builds.
#[cfg(debug_assertions)]
pub mod counters {
    use std::cell::Cell;

    thread_local! {
        static MAC_REDUCTIONS: Cell<u64> = const { Cell::new(0) };
    }

    pub(super) fn bump(n: u64) {
        MAC_REDUCTIONS.with(|c| c.set(c.get() + n));
    }

    /// Total coefficients reduced by `reduce_acc_slice` on this thread
    /// so far (meaningful with `ckks_workers == 1`, where all limbs
    /// run on the calling thread).
    pub fn mac_reductions() -> u64 {
        MAC_REDUCTIONS.with(|c| c.get())
    }
}

/// Debug-only domain guard: every residue of `s` must be below
/// `bound`. Free in release builds.
#[inline]
fn assert_domain(s: &[u64], bound: u64, what: &str) {
    debug_assert!(
        s.iter().all(|&v| v < bound),
        "kernel domain violation: {what} holds a residue >= {bound}"
    );
}

// ---------------------------------------------------------------------
// Element-wise add / sub (reduced -> reduced)
// ---------------------------------------------------------------------

/// `a[i] = a[i] + b[i] mod q`. Reduced in, reduced out.
#[cfg(not(feature = "wide"))]
pub fn add_mod_slice(a: &mut [u64], b: &[u64], q: u64) {
    debug_assert_eq!(a.len(), b.len());
    assert_domain(a, q, "add_mod_slice lhs");
    assert_domain(b, q, "add_mod_slice rhs");
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at_mut(split);
    let (bh, bt) = b.split_at(split);
    for (aw, bw) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        for l in 0..LANES {
            aw[l] = add_mod(aw[l], bw[l], q);
        }
    }
    for (x, &y) in at.iter_mut().zip(bt.iter()) {
        *x = add_mod(*x, y, q);
    }
}

/// `a[i] = a[i] + b[i] mod q` via explicit `std::simd` vectors.
/// Bit-identical to the unrolled-scalar variant: modular add is exact.
#[cfg(feature = "wide")]
pub fn add_mod_slice(a: &mut [u64], b: &[u64], q: u64) {
    use std::simd::cmp::SimdPartialOrd;
    use std::simd::u64x8;
    debug_assert_eq!(a.len(), b.len());
    assert_domain(a, q, "add_mod_slice lhs");
    assert_domain(b, q, "add_mod_slice rhs");
    let qv = u64x8::splat(q);
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at_mut(split);
    let (bh, bt) = b.split_at(split);
    for (aw, bw) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        let s = u64x8::from_slice(aw) + u64x8::from_slice(bw);
        let r = s.simd_ge(qv).select(s - qv, s);
        r.copy_to_slice(aw);
    }
    for (x, &y) in at.iter_mut().zip(bt.iter()) {
        *x = add_mod(*x, y, q);
    }
}

/// `a[i] = a[i] - b[i] mod q`. Reduced in, reduced out.
#[cfg(not(feature = "wide"))]
pub fn sub_mod_slice(a: &mut [u64], b: &[u64], q: u64) {
    debug_assert_eq!(a.len(), b.len());
    assert_domain(a, q, "sub_mod_slice lhs");
    assert_domain(b, q, "sub_mod_slice rhs");
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at_mut(split);
    let (bh, bt) = b.split_at(split);
    for (aw, bw) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        for l in 0..LANES {
            aw[l] = sub_mod(aw[l], bw[l], q);
        }
    }
    for (x, &y) in at.iter_mut().zip(bt.iter()) {
        *x = sub_mod(*x, y, q);
    }
}

/// `a[i] = a[i] - b[i] mod q` via explicit `std::simd` vectors.
#[cfg(feature = "wide")]
pub fn sub_mod_slice(a: &mut [u64], b: &[u64], q: u64) {
    use std::simd::cmp::SimdPartialOrd;
    use std::simd::u64x8;
    debug_assert_eq!(a.len(), b.len());
    assert_domain(a, q, "sub_mod_slice lhs");
    assert_domain(b, q, "sub_mod_slice rhs");
    let qv = u64x8::splat(q);
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at_mut(split);
    let (bh, bt) = b.split_at(split);
    for (aw, bw) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        let av = u64x8::from_slice(aw);
        let bv = u64x8::from_slice(bw);
        let r = av.simd_ge(bv).select(av - bv, (av + qv) - bv);
        r.copy_to_slice(aw);
    }
    for (x, &y) in at.iter_mut().zip(bt.iter()) {
        *x = sub_mod(*x, y, q);
    }
}

// ---------------------------------------------------------------------
// Element-wise multiply (Barrett)
// ---------------------------------------------------------------------

/// `a[i] = a[i] * b[i] mod q` (Barrett). Any u64 in, reduced out.
pub fn mul_mod_slice(a: &mut [u64], b: &[u64], q: u64, ratio: (u64, u64)) {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at_mut(split);
    let (bh, bt) = b.split_at(split);
    for (aw, bw) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        for l in 0..LANES {
            aw[l] = mul_mod_barrett(aw[l], bw[l], q, ratio);
        }
    }
    for (x, &y) in at.iter_mut().zip(bt.iter()) {
        *x = mul_mod_barrett(*x, y, q, ratio);
    }
    assert_domain(a, q, "mul_mod_slice output");
}

/// `a[i] = a[i] * b[i] mod q` leaving results in the **lazy** `[0, 2q)`
/// domain (final conditional subtraction skipped). The caller must feed
/// the output into a fully-reducing consumer — in practice the inverse
/// NTT at the head of `rescale` / `mod_down_special`.
pub fn mul_mod_slice_lazy(a: &mut [u64], b: &[u64], q: u64, ratio: (u64, u64)) {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at_mut(split);
    let (bh, bt) = b.split_at(split);
    for (aw, bw) in ah.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        for l in 0..LANES {
            aw[l] = mul_mod_barrett_lazy(aw[l], bw[l], q, ratio);
        }
    }
    for (x, &y) in at.iter_mut().zip(bt.iter()) {
        *x = mul_mod_barrett_lazy(*x, y, q, ratio);
    }
    assert_domain(a, 2 * q, "mul_mod_slice_lazy output");
}

// ---------------------------------------------------------------------
// Lazy u128 multiply-accumulate (the key-switch inner product)
// ---------------------------------------------------------------------

#[inline(always)]
fn mac_acc_at(lo: &mut [u64], hi: &mut [u64], x: &[u64], k: &[u64], i: usize) {
    let p = x[i] as u128 * k[i] as u128;
    let s = lo[i] as u128 + (p as u64) as u128;
    lo[i] = s as u64;
    let (h1, o1) = hi[i].overflowing_add((p >> 64) as u64);
    let (h2, o2) = h1.overflowing_add((s >> 64) as u64);
    debug_assert!(
        !(o1 || o2),
        "lazy MAC accumulator overflow — mac_headroom bound violated"
    );
    hi[i] = h2;
}

/// Accumulate `x[i] * k[i]` into the per-coefficient `(lo, hi)` u128
/// accumulator pair — **no reductions**. Operands may be lazy-domain
/// (`< two_q`); the caller is responsible for keeping the total term
/// count within [`mac_headroom`] (re-checked per element in debug
/// builds via the carry flags).
pub fn mac_acc_slice(lo: &mut [u64], hi: &mut [u64], x: &[u64], k: &[u64], two_q: u64) {
    let n = lo.len();
    debug_assert!(hi.len() == n && x.len() == n && k.len() == n);
    assert_domain(x, two_q, "mac_acc_slice digit operand");
    assert_domain(k, two_q, "mac_acc_slice key operand");
    let (hi, x, k) = (&mut hi[..n], &x[..n], &k[..n]);
    let mut i = 0;
    while i + LANES <= n {
        for j in i..i + LANES {
            mac_acc_at(lo, hi, x, k, j);
        }
        i += LANES;
    }
    for j in i..n {
        mac_acc_at(lo, hi, x, k, j);
    }
}

/// Reduce the `(lo, hi)` u128 accumulator to the reduced domain: one
/// [`barrett_reduce_128`] per coefficient — the *only* reduction the
/// whole inner product performs, regardless of how many
/// [`mac_acc_slice`] calls fed it.
pub fn reduce_acc_slice(out: &mut [u64], lo: &[u64], hi: &[u64], q: u64, ratio: (u64, u64)) {
    let n = out.len();
    debug_assert!(lo.len() == n && hi.len() == n);
    let (lo, hi) = (&lo[..n], &hi[..n]);
    let mut i = 0;
    while i + LANES <= n {
        for j in i..i + LANES {
            out[j] = barrett_reduce_128(lo[j], hi[j], q, ratio);
        }
        i += LANES;
    }
    for j in i..n {
        out[j] = barrett_reduce_128(lo[j], hi[j], q, ratio);
    }
    #[cfg(debug_assertions)]
    counters::bump(n as u64);
    assert_domain(out, q, "reduce_acc_slice output");
}

// ---------------------------------------------------------------------
// Fused dyadic tensor (ct×ct and square)
// ---------------------------------------------------------------------

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tensor_at(
    a0: &[u64],
    a1: &[u64],
    b0: &[u64],
    b1: &[u64],
    d0: &mut [u64],
    d1: &mut [u64],
    d2: &mut [u64],
    q: u64,
    ratio: (u64, u64),
    i: usize,
) {
    let p0 = a0[i] as u128 * b0[i] as u128;
    d0[i] = barrett_reduce_128(p0 as u64, (p0 >> 64) as u64, q, ratio);
    // Cross term as one 128-bit sum, reduced once: 2(q−1)² < 2^125 for
    // q < 2^62, so the sum cannot overflow u128.
    let cross = a0[i] as u128 * b1[i] as u128 + a1[i] as u128 * b0[i] as u128;
    d1[i] = barrett_reduce_128(cross as u64, (cross >> 64) as u64, q, ratio);
    let p2 = a1[i] as u128 * b1[i] as u128;
    d2[i] = barrett_reduce_128(p2 as u64, (p2 >> 64) as u64, q, ratio);
}

/// Fused ct×ct dyadic tensor over one limb: writes `d0 = a0·b0`,
/// `d1 = a0·b1 + a1·b0` (single reduction of the 128-bit sum) and
/// `d2 = a1·b1` in one pass that reads each operand limb exactly once.
/// Reduced in, reduced out.
#[allow(clippy::too_many_arguments)]
pub fn tensor_limb(
    a0: &[u64],
    a1: &[u64],
    b0: &[u64],
    b1: &[u64],
    d0: &mut [u64],
    d1: &mut [u64],
    d2: &mut [u64],
    q: u64,
    ratio: (u64, u64),
) {
    let n = d0.len();
    debug_assert!(
        a0.len() == n && a1.len() == n && b0.len() == n && b1.len() == n,
        "tensor operand length mismatch"
    );
    debug_assert!(d1.len() == n && d2.len() == n);
    assert_domain(a0, q, "tensor_limb a0");
    assert_domain(a1, q, "tensor_limb a1");
    assert_domain(b0, q, "tensor_limb b0");
    assert_domain(b1, q, "tensor_limb b1");
    let (a0, a1, b0, b1) = (&a0[..n], &a1[..n], &b0[..n], &b1[..n]);
    let (d1, d2) = (&mut d1[..n], &mut d2[..n]);
    let mut i = 0;
    while i + LANES <= n {
        for j in i..i + LANES {
            tensor_at(a0, a1, b0, b1, d0, d1, d2, q, ratio, j);
        }
        i += LANES;
    }
    for j in i..n {
        tensor_at(a0, a1, b0, b1, d0, d1, d2, q, ratio, j);
    }
}

#[inline(always)]
fn square_at(
    a0: &[u64],
    a1: &[u64],
    d0: &mut [u64],
    d1: &mut [u64],
    d2: &mut [u64],
    q: u64,
    ratio: (u64, u64),
    i: usize,
) {
    let p0 = a0[i] as u128 * a0[i] as u128;
    d0[i] = barrett_reduce_128(p0 as u64, (p0 >> 64) as u64, q, ratio);
    // 2·a0·a1 < 2^125 for q < 2^62 — one reduction covers the doubling.
    let cross = 2 * (a0[i] as u128 * a1[i] as u128);
    d1[i] = barrett_reduce_128(cross as u64, (cross >> 64) as u64, q, ratio);
    let p2 = a1[i] as u128 * a1[i] as u128;
    d2[i] = barrett_reduce_128(p2 as u64, (p2 >> 64) as u64, q, ratio);
}

/// Fused squaring tensor over one limb: `d0 = a0²`, `d1 = 2·a0·a1`
/// (single reduction), `d2 = a1²`. Reduced in, reduced out.
#[allow(clippy::too_many_arguments)]
pub fn square_limb(
    a0: &[u64],
    a1: &[u64],
    d0: &mut [u64],
    d1: &mut [u64],
    d2: &mut [u64],
    q: u64,
    ratio: (u64, u64),
) {
    let n = d0.len();
    debug_assert!(a0.len() == n && a1.len() == n && d1.len() == n && d2.len() == n);
    assert_domain(a0, q, "square_limb a0");
    assert_domain(a1, q, "square_limb a1");
    let (a0, a1) = (&a0[..n], &a1[..n]);
    let (d1, d2) = (&mut d1[..n], &mut d2[..n]);
    let mut i = 0;
    while i + LANES <= n {
        for j in i..i + LANES {
            square_at(a0, a1, d0, d1, d2, q, ratio, j);
        }
        i += LANES;
    }
    for j in i..n {
        square_at(a0, a1, d0, d1, d2, q, ratio, j);
    }
}

// ---------------------------------------------------------------------
// Rescale / mod-down adjustment kernels
// ---------------------------------------------------------------------

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn rescale_adjust_one(
    x: u64,
    r: u64,
    q: u64,
    r_hi: u64,
    q_last: u64,
    half: u64,
    inv: u64,
    inv_sh: u64,
) -> u64 {
    // Centered remainder, kept lazy: `x + q − red(r)` (subtract side)
    // or `x + red(q_last − r)` (add side) lands in [0, 2q) — the
    // conditional correction of add_mod/sub_mod is skipped, and the
    // Shoup multiply (exact for any u64 left operand) fully reduces.
    let lazy = if r <= half {
        x + q - barrett_reduce_64(r, q, r_hi)
    } else {
        x + barrett_reduce_64(q_last - r, q, r_hi)
    };
    mul_mod_shoup(lazy, inv, inv_sh, q)
}

/// Rescale / mod-down adjustment of one chain limb against the dropped
/// limb `last` (modulus `q_last`): subtract the centered remainder and
/// multiply by the precomputed inverse `inv` (Shoup pair). Reduced in,
/// reduced out; the intermediate stays lazy between the two steps.
#[allow(clippy::too_many_arguments)]
pub fn rescale_adjust_slice(
    limb: &mut [u64],
    last: &[u64],
    q: u64,
    r_hi: u64,
    q_last: u64,
    half: u64,
    inv: u64,
    inv_sh: u64,
) {
    debug_assert_eq!(limb.len(), last.len());
    assert_domain(limb, q, "rescale_adjust_slice limb");
    assert_domain(last, q_last, "rescale_adjust_slice dropped limb");
    let split = limb.len() - limb.len() % LANES;
    let (lh, lt) = limb.split_at_mut(split);
    let (rh, rt) = last.split_at(split);
    for (lw, rw) in lh.chunks_exact_mut(LANES).zip(rh.chunks_exact(LANES)) {
        for l in 0..LANES {
            lw[l] = rescale_adjust_one(lw[l], rw[l], q, r_hi, q_last, half, inv, inv_sh);
        }
    }
    for (x, &r) in lt.iter_mut().zip(rt.iter()) {
        *x = rescale_adjust_one(*x, r, q, r_hi, q_last, half, inv, inv_sh);
    }
    assert_domain(limb, q, "rescale_adjust_slice output");
}

#[inline(always)]
fn centered_neg_one(r: u64, p: u64, half: u64, q: u64, r_hi: u64) -> u64 {
    // The negated centered remainder of r (mod p), reduced mod q:
    // r <= p/2 → −r mod q ; r > p/2 → +(p − r) mod q.
    if r <= half {
        let red = barrett_reduce_64(r, q, r_hi);
        if red == 0 {
            0
        } else {
            q - red
        }
    } else {
        barrett_reduce_64(p - r, q, r_hi)
    }
}

/// Build the negated centered remainder of the special limb `last`
/// (modulus `p`) reduced into modulus `q` — the coefficient-domain prep
/// of the NTT-form mod-down. Reduced out.
pub fn centered_neg_slice(dst: &mut [u64], last: &[u64], p: u64, half: u64, q: u64, r_hi: u64) {
    debug_assert_eq!(dst.len(), last.len());
    assert_domain(last, p, "centered_neg_slice special limb");
    let split = dst.len() - dst.len() % LANES;
    let (dh, dt) = dst.split_at_mut(split);
    let (rh, rt) = last.split_at(split);
    for (dw, rw) in dh.chunks_exact_mut(LANES).zip(rh.chunks_exact(LANES)) {
        for l in 0..LANES {
            dw[l] = centered_neg_one(rw[l], p, half, q, r_hi);
        }
    }
    for (x, &r) in dt.iter_mut().zip(rt.iter()) {
        *x = centered_neg_one(r, p, half, q, r_hi);
    }
    assert_domain(dst, q, "centered_neg_slice output");
}

/// `limb[i] = (limb[i] + r[i]) * inv mod q` with the sum kept lazy
/// (`< 2q`, no conditional) and the Shoup multiply reducing exactly —
/// the per-limb finish of the NTT-form mod-down. Reduced in, reduced
/// out.
pub fn add_then_mul_shoup_slice(limb: &mut [u64], r: &[u64], q: u64, inv: u64, inv_sh: u64) {
    debug_assert_eq!(limb.len(), r.len());
    assert_domain(limb, q, "add_then_mul_shoup_slice limb");
    assert_domain(r, q, "add_then_mul_shoup_slice addend");
    let split = limb.len() - limb.len() % LANES;
    let (lh, lt) = limb.split_at_mut(split);
    let (rh, rt) = r.split_at(split);
    for (lw, rw) in lh.chunks_exact_mut(LANES).zip(rh.chunks_exact(LANES)) {
        for l in 0..LANES {
            lw[l] = mul_mod_shoup(lw[l] + rw[l], inv, inv_sh, q);
        }
    }
    for (x, &y) in lt.iter_mut().zip(rt.iter()) {
        *x = mul_mod_shoup(*x + y, inv, inv_sh, q);
    }
    assert_domain(limb, q, "add_then_mul_shoup_slice output");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::modops::{barrett_precompute, mul_mod};
    use crate::rng::Xoshiro256pp;

    #[test]
    fn headroom_bound_is_tight() {
        for q in [(1u64 << 60) + 0x4001, (1u64 << 40) + 0x1_0001, (1 << 61) - 1] {
            let h = mac_headroom(q) as u128;
            let term = ((2 * q - 1) as u128) * ((2 * q - 1) as u128);
            // h terms fit exactly; h+1 terms would overflow.
            assert!(term.checked_mul(h).is_some(), "q={q}");
            assert!(term.checked_mul(h + 1).is_none(), "q={q}");
        }
    }

    #[test]
    fn mac_accumulate_then_reduce_matches_serial_chain() {
        let q = (1u64 << 60) + 0x4001u64; // odd, not prime; arithmetic only
        let ratio = barrett_precompute(q);
        let mut r = Xoshiro256pp::new(42);
        let n = 67; // exercises the scalar tail
        for digits in [1usize, 3, 9] {
            let xs: Vec<Vec<u64>> = (0..digits)
                .map(|_| (0..n).map(|_| r.next_below(2 * q)).collect())
                .collect();
            let ks: Vec<Vec<u64>> = (0..digits)
                .map(|_| (0..n).map(|_| r.next_below(2 * q)).collect())
                .collect();
            let mut lo = vec![0u64; n];
            let mut hi = vec![0u64; n];
            for (x, k) in xs.iter().zip(ks.iter()) {
                mac_acc_slice(&mut lo, &mut hi, x, k, 2 * q);
            }
            let mut out = vec![0u64; n];
            reduce_acc_slice(&mut out, &lo, &hi, q, ratio);
            for i in 0..n {
                let mut want = 0u64;
                for (x, k) in xs.iter().zip(ks.iter()) {
                    want = add_mod(want, mul_mod(x[i] % q, k[i] % q, q), q);
                }
                assert_eq!(out[i], want, "digits={digits} i={i}");
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    fn reduction_counter_is_digit_count_independent() {
        let q = ((1u64 << 59) + 0x9801) | 1;
        let ratio = barrett_precompute(q);
        let n = 32;
        for digits in [1usize, 4, 10] {
            let before = counters::mac_reductions();
            let mut lo = vec![0u64; n];
            let mut hi = vec![0u64; n];
            let x = vec![q - 1; n];
            for _ in 0..digits {
                mac_acc_slice(&mut lo, &mut hi, &x, &x, 2 * q);
            }
            let mut out = vec![0u64; n];
            reduce_acc_slice(&mut out, &lo, &hi, q, ratio);
            assert_eq!(
                counters::mac_reductions() - before,
                n as u64,
                "digits={digits}"
            );
        }
    }
}
