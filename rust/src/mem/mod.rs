//! The memory plane: one bounded arena for the whole data plane.
//!
//! * [`slab`] — the sharded, size-classed, byte-budgeted [`SlabPool`]
//!   backing every [`crate::ckks::Scratch`] handle. One process-wide
//!   pool ([`global_pool`]) replaces the per-evaluator warm lists, so
//!   peak idle limb-buffer memory is capped and observable
//!   (`slab_resident_bytes` in `MetricsSnapshot`) instead of
//!   multiplying with `op_workers × ckks_workers`.
//!
//! The disk half of the memory plane — the keycache spill tier that
//! demotes `KeysEvicted` to "spill tier full too" — lives in
//! [`crate::keycache::spill`] next to the cache it extends.
//!
//! Budget knobs: `CoordinatorConfig::slab_budget_bytes` (authoritative
//! when serving) or the `CRYPTOTREE_SLAB_BUDGET` environment variable
//! (bytes, read once at first pool touch); default
//! [`DEFAULT_SLAB_BUDGET_BYTES`].

pub mod slab;

pub use slab::{SlabPool, SlabStats, SlabStatsSnapshot};

use std::sync::{Arc, OnceLock};

/// Default global slab budget: 256 MiB of idle limb buffers. Generous
/// for the demo parameter sets (one N=4096 depth-4 key-switch
/// temporary is ~200 KiB) while still bounding a many-worker server.
pub const DEFAULT_SLAB_BUDGET_BYTES: u64 = 256 * 1024 * 1024;

/// Shard count of the global pool: comfortably above the realistic
/// `op_workers × ckks_workers` product so home shards rarely collide.
pub const DEFAULT_SLAB_SHARDS: usize = 16;

static GLOBAL: OnceLock<Arc<SlabPool>> = OnceLock::new();

/// The process-wide slab pool. Initialized on first touch; the budget
/// comes from `CRYPTOTREE_SLAB_BUDGET` (bytes) when set to a positive
/// integer, else [`DEFAULT_SLAB_BUDGET_BYTES`]. `Coordinator::start`
/// re-budgets it from `CoordinatorConfig::slab_budget_bytes`.
pub fn global_pool() -> &'static Arc<SlabPool> {
    GLOBAL.get_or_init(|| {
        let budget = std::env::var("CRYPTOTREE_SLAB_BUDGET")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .filter(|&b| b > 0)
            .unwrap_or(DEFAULT_SLAB_BUDGET_BYTES);
        Arc::new(SlabPool::new(DEFAULT_SLAB_SHARDS, budget))
    })
}
