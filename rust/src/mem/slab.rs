//! Sharded, size-classed slab pool for limb buffers.
//!
//! Every CKKS temporary in this codebase is a flat `Vec<u64>` of
//! `limbs × N` words ([`crate::ckks::rns::RnsPoly`]), so the whole
//! data plane recycles buffers from a small, highly regular set of
//! sizes. Before this pool existed each [`crate::ckks::Evaluator`]
//! owned a private warm list, which meant peak resident scratch
//! multiplied with `op_workers × ckks_workers`: every DAG op worker
//! and every limb-parallel worker pinned its own copies of the same
//! size classes. The slab pool replaces all of those with **one
//! bounded arena**:
//!
//! * **Sharded**: `num_shards` independent free lists, each behind its
//!   own mutex. A [`crate::ckks::Scratch`] handle is pinned to one
//!   *home* shard (round-robin at construction), so on the hot path a
//!   checkout touches exactly one uncontended lock. Only when the home
//!   shard has nothing suitable does it scan the other shards
//!   (one lock at a time) before falling back to a fresh allocation.
//! * **Size-classed**: free buffers are keyed by capacity in words
//!   (`BTreeMap<usize, SizeClass>`); a request pops the smallest class
//!   that fits (`range(len..)`), so a 6-limb buffer can serve a
//!   5-limb request after a rescale without reallocating.
//! * **Byte-budgeted**: a global budget caps the bytes parked in free
//!   lists. The gauge is maintained with a reserve-then-insert CAS
//!   loop, so `resident_bytes ≤ budget` holds at **every instant**,
//!   not just between operations — the concurrency property test in
//!   `tests/mem_props.rs` samples the gauge continuously. When a
//!   returned buffer would overflow the budget the pool trims the
//!   least-recently-touched size class first (LRU at class
//!   granularity: one tick per class, bumped on insert), and drops the
//!   incoming buffer only if trimming frees nothing.
//!
//! The pool holds only *idle* buffers. Checked-out buffers are plain
//! owned `Vec<u64>`s — the type every caller already used — so no hot
//! kernel changed signature, and a buffer that is never returned is
//! simply freed by its owner as before.

use crate::lockutil::lock_unpoisoned;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared counters for one [`SlabPool`] (lock-free; cloned into
/// `coordinator::Metrics` so snapshots never touch the shard locks).
#[derive(Default)]
pub struct SlabStats {
    /// Checkouts served from a free list (home shard or steal scan).
    pub hits: AtomicU64,
    /// Checkouts that fell back to a fresh allocation.
    pub misses: AtomicU64,
    /// Bytes currently parked in free lists. Never exceeds the budget.
    pub resident_bytes: AtomicU64,
    /// Buffers freed by the LRU trimmer to make room under the budget.
    pub trims: AtomicU64,
    /// Returned buffers dropped because trimming could not make room.
    pub dropped: AtomicU64,
}

impl SlabStats {
    pub fn snapshot(&self) -> SlabStatsSnapshot {
        SlabStatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            trims: self.trims.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`SlabStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlabStatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub resident_bytes: u64,
    pub trims: u64,
    pub dropped: u64,
}

impl SlabStatsSnapshot {
    /// Fraction of checkouts served from a free list.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One free list of identically-sized buffers.
struct SizeClass {
    bufs: Vec<Vec<u64>>,
    /// Logical timestamp of the last insert into this class; the
    /// trimmer evicts from the class with the smallest tick.
    tick: u64,
}

/// One shard: size classes keyed by buffer capacity in words.
#[derive(Default)]
struct SlabShard {
    classes: BTreeMap<usize, SizeClass>,
}

impl SlabShard {
    /// Pop a buffer from the smallest class with capacity ≥ `len`.
    fn pop_fit(&mut self, len: usize) -> Option<Vec<u64>> {
        let cap = *self.classes.range(len..).next()?.0;
        let class = self.classes.get_mut(&cap)?;
        let buf = class.bufs.pop();
        if class.bufs.is_empty() {
            self.classes.remove(&cap);
        }
        buf
    }

    fn idle_buffers(&self) -> usize {
        self.classes.values().map(|c| c.bufs.len()).sum()
    }

    fn idle_bytes(&self) -> u64 {
        self.classes
            .values()
            .flat_map(|c| c.bufs.iter())
            .map(|b| b.capacity() as u64 * 8)
            .sum()
    }
}

/// The sharded, byte-budgeted slab pool. See the module docs.
pub struct SlabPool {
    shards: Vec<Mutex<SlabShard>>,
    budget_bytes: AtomicU64,
    clock: AtomicU64,
    stats: Arc<SlabStats>,
}

impl SlabPool {
    pub fn new(num_shards: usize, budget_bytes: u64) -> Self {
        let num_shards = num_shards.max(1);
        SlabPool {
            shards: (0..num_shards).map(|_| Mutex::new(SlabShard::default())).collect(),
            budget_bytes: AtomicU64::new(budget_bytes),
            clock: AtomicU64::new(0),
            stats: Arc::new(SlabStats::default()),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes.load(Ordering::Acquire)
    }

    /// Shared counters (cheap handle; no locks on snapshot).
    pub fn stats(&self) -> Arc<SlabStats> {
        self.stats.clone()
    }

    /// Bytes currently parked in free lists.
    pub fn resident_bytes(&self) -> u64 {
        self.stats.resident_bytes.load(Ordering::Acquire)
    }

    /// Re-budget the pool, trimming down immediately if shrinking.
    /// (Lowering the budget while other threads are returning buffers
    /// can transiently leave the gauge above the *new* budget for the
    /// duration of one in-flight `put`; it converges as soon as the
    /// trim loop below wins.)
    pub fn set_budget_bytes(&self, budget_bytes: u64) {
        self.budget_bytes.store(budget_bytes, Ordering::Release);
        while self.resident_bytes() > budget_bytes {
            if !self.trim_one() {
                break;
            }
        }
    }

    /// Checkout: a buffer of exactly `len` zeroed words.
    pub fn take(&self, home: usize, len: usize) -> Vec<u64> {
        match self.pop_recycled(home, len) {
            Some(mut b) => {
                b.clear();
                b.resize(len, 0);
                b
            }
            None => vec![0u64; len],
        }
    }

    /// Checkout: a buffer holding a copy of `src` (single memcpy, no
    /// zeroing).
    pub fn take_copy(&self, home: usize, src: &[u64]) -> Vec<u64> {
        match self.pop_recycled(home, src.len()) {
            Some(mut b) => {
                b.clear();
                b.extend_from_slice(src);
                b
            }
            None => src.to_vec(),
        }
    }

    fn pop_recycled(&self, home: usize, len: usize) -> Option<Vec<u64>> {
        let n = self.shards.len();
        let home = home % n;
        // Home shard first (the hot path: one uncontended lock), then
        // steal-scan the rest one lock at a time.
        for i in 0..n {
            let shard = &self.shards[(home + i) % n];
            let popped = lock_unpoisoned(shard).pop_fit(len);
            if let Some(b) = popped {
                self.stats
                    .resident_bytes
                    .fetch_sub(b.capacity() as u64 * 8, Ordering::AcqRel);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                return Some(b);
            }
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Return a buffer to `home`'s free lists. The budget is enforced
    /// *before* the bytes become resident: a CAS reserves room, the
    /// trimmer evicts cold classes to make it, and the buffer is
    /// dropped outright only when the pool cannot be trimmed below
    /// `budget - capacity` (e.g. the buffer alone exceeds the budget).
    pub fn put(&self, home: usize, buf: Vec<u64>) {
        let bytes = buf.capacity() as u64 * 8;
        if bytes == 0 {
            return;
        }
        loop {
            let cur = self.stats.resident_bytes.load(Ordering::Acquire);
            let budget = self.budget_bytes.load(Ordering::Acquire);
            if cur + bytes > budget {
                if self.trim_one() {
                    continue;
                }
                self.stats.dropped.fetch_add(1, Ordering::Relaxed);
                return; // drops `buf`
            }
            if self
                .stats
                .resident_bytes
                .compare_exchange(cur, cur + bytes, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                break;
            }
        }
        let tick = self.clock.fetch_add(1, Ordering::Relaxed);
        let cap = buf.capacity();
        let mut shard = lock_unpoisoned(&self.shards[home % self.shards.len()]);
        let class = shard.classes.entry(cap).or_insert_with(|| SizeClass {
            bufs: Vec::new(),
            tick,
        });
        class.bufs.push(buf);
        class.tick = tick;
    }

    /// Free one buffer from the globally least-recently-touched size
    /// class. Returns `false` when every shard is empty. Scans with
    /// one lock held at a time and re-checks under the lock before
    /// popping, retrying if a concurrent checkout emptied the winner.
    fn trim_one(&self) -> bool {
        loop {
            let mut best: Option<(usize, usize, u64)> = None; // (shard, cap, tick)
            for (i, m) in self.shards.iter().enumerate() {
                let shard = lock_unpoisoned(m);
                for (&cap, class) in shard.classes.iter() {
                    if best.map_or(true, |(_, _, t)| class.tick < t) {
                        best = Some((i, cap, class.tick));
                    }
                }
            }
            let (i, cap, _) = match best {
                Some(b) => b,
                None => return false,
            };
            let mut shard = lock_unpoisoned(&self.shards[i]);
            if let Some(class) = shard.classes.get_mut(&cap) {
                if let Some(b) = class.bufs.pop() {
                    if class.bufs.is_empty() {
                        shard.classes.remove(&cap);
                    }
                    drop(shard);
                    self.stats
                        .resident_bytes
                        .fetch_sub(b.capacity() as u64 * 8, Ordering::AcqRel);
                    self.stats.trims.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                shard.classes.remove(&cap); // defensively clear an empty class
            }
            // The chosen class raced away between scan and re-lock;
            // rescan for a new victim.
        }
    }

    /// Idle buffers parked in one shard (test/introspection hook).
    pub fn idle_buffers_in(&self, shard: usize) -> usize {
        lock_unpoisoned(&self.shards[shard % self.shards.len()]).idle_buffers()
    }

    /// Idle buffers across all shards (test/introspection hook).
    pub fn idle_buffers(&self) -> usize {
        self.shards.iter().map(|m| lock_unpoisoned(m).idle_buffers()).sum()
    }

    /// Recount resident bytes by walking every free list. Equals
    /// [`SlabPool::resident_bytes`] whenever the pool is quiescent
    /// (no `put` mid-flight between its CAS reservation and the shard
    /// insert); the accounting property test asserts exactly that
    /// after joining all workers.
    pub fn audit_resident_bytes(&self) -> u64 {
        self.shards.iter().map(|m| lock_unpoisoned(m).idle_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(shards: usize, budget: u64) -> SlabPool {
        SlabPool::new(shards, budget)
    }

    #[test]
    fn take_miss_then_hit_reuses_capacity() {
        let p = pool(2, 1 << 20);
        let mut b = p.take(0, 16);
        assert!(b.iter().all(|&x| x == 0));
        b.iter_mut().for_each(|x| *x = 7);
        let cap = b.capacity();
        p.put(0, b);
        assert_eq!(p.idle_buffers_in(0), 1);
        let b2 = p.take(0, 8);
        assert!(b2.capacity() >= 8 && cap >= b2.capacity());
        assert!(b2.iter().all(|&x| x == 0), "recycled buffer not zeroed");
        assert_eq!(p.idle_buffers_in(0), 0);
        let s = p.stats().snapshot();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn first_fit_picks_smallest_sufficient_class() {
        let p = pool(1, 1 << 20);
        p.put(0, Vec::with_capacity(8));
        p.put(0, Vec::with_capacity(32));
        p.put(0, Vec::with_capacity(64));
        let b = p.take(0, 16);
        assert_eq!(b.capacity(), 32, "expected the 32-word class, not 64");
        assert_eq!(p.idle_buffers(), 2);
    }

    #[test]
    fn steal_scan_crosses_shards() {
        let p = pool(4, 1 << 20);
        p.put(3, vec![1u64; 16]);
        let b = p.take(0, 16); // home shard 0 is empty; steals from 3
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&x| x == 0));
        assert_eq!(p.stats().snapshot().hits, 1);
        assert_eq!(p.idle_buffers(), 0);
    }

    #[test]
    fn budget_never_exceeded_and_lru_class_trimmed_first() {
        // Budget fits exactly two 64-word buffers (64 * 8 = 512 B).
        let p = pool(1, 1024);
        let mk = || vec![0u64; 64];
        p.put(0, mk()); // class 64, tick 0
        let b = p.take(0, 32); // leaves the class empty
        p.put(0, b); // class 64 again, fresh tick
        p.put(0, vec![0u64; 48]); // class 48: 384 + 512 = 896 B ≤ 1024, fits
        assert!(p.resident_bytes() <= 1024);
        // A third large buffer must trim the oldest class to fit.
        p.put(0, mk());
        assert!(p.resident_bytes() <= 1024, "budget exceeded: {}", p.resident_bytes());
        let s = p.stats().snapshot();
        assert!(s.trims >= 1, "expected at least one LRU trim");
        assert_eq!(p.audit_resident_bytes(), p.resident_bytes());
    }

    #[test]
    fn oversized_buffer_is_dropped_not_pooled() {
        let p = pool(2, 100); // budget below one 64-word buffer
        p.put(0, vec![0u64; 64]);
        assert_eq!(p.resident_bytes(), 0);
        assert_eq!(p.idle_buffers(), 0);
        assert_eq!(p.stats().snapshot().dropped, 1);
    }

    #[test]
    fn shrinking_budget_trims_down() {
        let p = pool(2, 1 << 20);
        for i in 0..8 {
            p.put(i % 2, vec![0u64; 128]);
        }
        let before = p.resident_bytes();
        assert_eq!(before, 8 * 128 * 8);
        p.set_budget_bytes(2 * 128 * 8);
        assert!(p.resident_bytes() <= 2 * 128 * 8);
        assert_eq!(p.audit_resident_bytes(), p.resident_bytes());
    }

    #[test]
    fn zero_capacity_buffers_are_ignored() {
        let p = pool(1, 1024);
        p.put(0, Vec::new());
        assert_eq!(p.idle_buffers(), 0);
        assert_eq!(p.resident_bytes(), 0);
    }
}
