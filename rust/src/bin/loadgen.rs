//! `cryptotree-loadgen` — multi-process load harness for the serving
//! tier.
//!
//! The parent re-execs itself with a hidden `worker` subcommand so
//! the load comes from genuinely separate OS processes (separate
//! allocators, separate sockets — the shape a real client fleet has),
//! not threads sharing the parent's address space. Each worker opens
//! `--sessions` sessions sequentially and drives `--requests` scoring
//! requests per session, printing one `LAT <µs>` line per request;
//! the parent aggregates exact percentiles from the merged samples
//! and writes `BENCH_serving_tier.json` via the bench harness.
//!
//! ```text
//! cryptotree-loadgen --spawn-server --processes 2 --sessions 2 \
//!     --requests 8 --mode enc --params demo
//! ```
//!
//! * `--mode enc` (default): per-session keygen, key registration,
//!   encrypted submissions through the `KeysEvicted`-recovering
//!   client — give the spawned server `--key-budget-mb 1` (or point
//!   at one so configured) and sessions evict each other, exercising
//!   re-registration over the wire under load.
//! * `--mode plain`: plaintext fast path — cheap enough for CI smoke.
//! * `--churn N`: drop and reconnect the TCP connection every N
//!   requests (session ids survive reconnects by design).
//! * `--spawn-server`: launch a sibling `cryptotree-serve` on an
//!   ephemeral port, scrape `LISTENING <addr>`, and shut it down
//!   (checking its exit status) when the run ends. Server-side knobs
//!   (`--key-budget-mb`, `--spill-dir`, `--spill-budget-mb`,
//!   `--slab-budget-mb`, …) are forwarded — pair a tiny key budget
//!   with `--spill-dir` to drive the disk spill tier under load.
//!
//! Exits non-zero if any worker process fails, any request errors, or
//! a spawned server reports an unclean shutdown.

use cryptotree::bench_harness::{fmt_dur, write_json, BenchRecord};
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{MetricsSnapshot, SubmitError};
use cryptotree::hrf::client::{reshuffle_and_pack, EvalKeys};
use cryptotree::net::args::Args;
use cryptotree::net::client::{NetClient, NetError};
use cryptotree::net::workload::{self, WorkloadSpec};
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Busy retries per request before counting it as failed.
const MAX_BUSY_RETRIES: u32 = 1000;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) == Some("worker") {
        worker_main(&argv[1..]);
    } else {
        parent_main(&argv);
    }
}

// ------------------------------------------------------------- worker

fn worker_main(rest: &[String]) {
    let args = Args::parse(rest);
    let spec = WorkloadSpec::from_args(&args);
    let addr = args.get_str("addr", "127.0.0.1:7814");
    let proc_id = args.get("proc", 0u64);
    let sessions = args.get("sessions", 1usize);
    let requests = args.get("requests", 4usize);
    let mode = args.get_str("mode", "enc");
    let churn = args.get("churn", 0usize);

    let wl = workload::build(&spec);
    let enc = Encoder::new(&wl.ctx);
    let (mut ok, mut err, mut recovered) = (0u64, 0u64, 0u64);

    for m in 0..sessions {
        let connect = || match NetClient::connect(&addr, wl.ctx.clone()) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("worker {proc_id}: connect {addr} failed: {e}");
                std::process::exit(3);
            }
        };
        let mut client = connect();
        let seed = spec.seed + 1000 * proc_id + 7 * m as u64;

        if mode == "plain" {
            for r in 0..requests {
                let row = (proc_id as usize * 31 + m * 17 + r) % wl.data.x.len();
                let x = wl.data.x[row].clone();
                let t0 = Instant::now();
                match client.submit_plain(x) {
                    Ok(_scores) => {
                        ok += 1;
                        println!("LAT {}", t0.elapsed().as_micros());
                    }
                    Err(e) => {
                        err += 1;
                        eprintln!("worker {proc_id}: plain submit failed: {e}");
                    }
                }
                if churn > 0 && (r + 1) % churn == 0 && r + 1 < requests {
                    client = connect();
                }
            }
            continue;
        }

        // Encrypted mode: the session's keys cover exactly the
        // rotation steps the server advertises for its batch target.
        let info = match client.model_info() {
            Ok(i) => i,
            Err(e) => {
                eprintln!("worker {proc_id}: model_info failed: {e}");
                std::process::exit(3);
            }
        };
        assert_eq!(
            info.params_name,
            wl.params.name,
            "server params mismatch: pass the same --params to serve and loadgen"
        );
        let rotations: Vec<usize> = info.rotations.iter().map(|&r| r as usize).collect();
        let mut kg = KeyGenerator::new(&wl.ctx, seed + 100);
        let pk = kg.gen_public_key(&wl.ctx);
        let keys = EvalKeys {
            relin: kg.gen_relin_key(&wl.ctx),
            galois: kg.gen_galois_keys(&wl.ctx, &rotations),
        };
        let mut encryptor = Encryptor::new(pk, seed + 200);
        let sid = match client.register_keys(&keys) {
            Ok(id) => id,
            Err(e) => {
                eprintln!("worker {proc_id}: register failed: {e}");
                std::process::exit(3);
            }
        };

        for r in 0..requests {
            let row = (proc_id as usize * 31 + m * 17 + r) % wl.data.x.len();
            let slots = reshuffle_and_pack(&wl.server.model, &wl.data.x[row]);
            let ct = encryptor.encrypt_slots(&wl.ctx, &enc, &slots);
            let mut busy = 0u32;
            loop {
                let t0 = Instant::now();
                match client.submit_encrypted_recovering(sid, &ct, &keys) {
                    Ok((_scores, rec)) => {
                        ok += 1;
                        recovered += rec as u64;
                        println!("LAT {}", t0.elapsed().as_micros());
                        break;
                    }
                    Err(NetError::Submit(SubmitError::Busy)) if busy < MAX_BUSY_RETRIES => {
                        busy += 1;
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(e) => {
                        err += 1;
                        eprintln!("worker {proc_id}: submit failed: {e}");
                        break;
                    }
                }
            }
            if churn > 0 && (r + 1) % churn == 0 && r + 1 < requests {
                client = connect();
            }
        }
    }

    println!("SUMMARY ok={ok} err={err} recovered={recovered}");
    if err > 0 {
        std::process::exit(4);
    }
}

// ------------------------------------------------------------- parent

/// Per-worker results streamed back over stdout.
#[derive(Default)]
struct WorkerStats {
    lat_us: Vec<u64>,
    ok: u64,
    err: u64,
    recovered: u64,
}

fn parent_main(argv: &[String]) {
    let args = Args::parse(argv);
    let spec = WorkloadSpec::from_args(&args);
    let processes = args.get("processes", 2usize);
    let sessions = args.get("sessions", 2usize);
    let requests = args.get("requests", 8usize);
    let mode = args.get_str("mode", "enc");
    let churn = args.get("churn", 0usize);
    let json_path = args.get_str("json", "BENCH_serving_tier.json");
    let exe = std::env::current_exe().expect("current_exe");

    let mut server_child: Option<Child> = None;
    let mut addr = args.get_str("addr", "127.0.0.1:7814");
    if args.has("spawn-server") {
        let serve_exe = exe
            .parent()
            .expect("binary dir")
            .join(format!("cryptotree-serve{}", std::env::consts::EXE_SUFFIX));
        let mut cmd = Command::new(serve_exe);
        cmd.args(["--addr", "127.0.0.1:0"]);
        for flag in [
            "params",
            "trees",
            "depth",
            "rows",
            "seed",
            "workers",
            "enc-batch",
            "queue",
            "key-budget-mb",
            "key-shards",
            "spill-dir",
            "spill-budget-mb",
            "slab-budget-mb",
            "max-conns",
            "trace",
            "stats-interval",
        ] {
            if args.has(flag) {
                cmd.args([format!("--{flag}"), args.get_str(flag, "")]);
            }
        }
        cmd.stdout(Stdio::piped()).stderr(Stdio::inherit());
        let mut child = cmd.spawn().expect("spawn cryptotree-serve");
        let stdout = child.stdout.take().expect("server stdout");
        let mut lines = BufReader::new(stdout).lines();
        addr = loop {
            match lines.next() {
                Some(Ok(line)) => {
                    if let Some(a) = line.strip_prefix("LISTENING ") {
                        break a.to_string();
                    }
                    println!("[serve] {line}");
                }
                _ => {
                    let _ = child.kill();
                    panic!("server exited before LISTENING line");
                }
            }
        };
        // Keep draining so the server never blocks on a full pipe.
        std::thread::spawn(move || {
            for line in lines.map_while(Result::ok) {
                println!("[serve] {line}");
            }
        });
        server_child = Some(child);
        eprintln!("spawned server on {addr}");
    }

    eprintln!(
        "driving {processes} process(es) × {sessions} session(s) × {requests} request(s), \
         mode={mode}, against {addr}"
    );
    let t0 = Instant::now();
    let mut readers = Vec::new();
    let mut children = Vec::new();
    for p in 0..processes {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker").args([
            "--addr",
            &addr,
            "--proc",
            &p.to_string(),
            "--sessions",
            &sessions.to_string(),
            "--requests",
            &requests.to_string(),
            "--mode",
            &mode,
            "--churn",
            &churn.to_string(),
        ]);
        for flag in ["params", "trees", "depth", "rows", "seed"] {
            if args.has(flag) {
                cmd.args([format!("--{flag}"), args.get_str(flag, "")]);
            }
        }
        cmd.stdout(Stdio::piped()).stderr(Stdio::inherit());
        let mut child = cmd.spawn().expect("spawn worker");
        let stdout = child.stdout.take().expect("worker stdout");
        readers.push(std::thread::spawn(move || collect_worker(stdout)));
        children.push(child);
    }

    let mut stats = WorkerStats::default();
    for r in readers {
        let s = r.join().expect("reader thread");
        stats.lat_us.extend(s.lat_us);
        stats.ok += s.ok;
        stats.err += s.err;
        stats.recovered += s.recovered;
    }
    let mut workers_failed = false;
    for mut c in children {
        let status = c.wait().expect("wait worker");
        if !status.success() {
            workers_failed = true;
            eprintln!("worker exited with {status}");
        }
    }
    let elapsed = t0.elapsed();

    // End-of-run server-side view: scrape the metrics snapshot over
    // the wire so the bench JSON pairs the server's queue/service
    // split with the client-observed latencies. Best-effort — a
    // scrape failure degrades the report, never the run.
    let server_snap: Option<MetricsSnapshot> = {
        let ctx = CkksContext::new(workload::params_by_name(&spec.params));
        match NetClient::connect(&addr, ctx) {
            Ok(mut c) => match c.metrics_snapshot() {
                Ok(s) => Some(s),
                Err(e) => {
                    eprintln!("server metrics scrape failed: {e}");
                    None
                }
            },
            Err(e) => {
                eprintln!("server metrics scrape connect failed: {e}");
                None
            }
        }
    };

    report(
        &spec,
        &mode,
        processes,
        &json_path,
        &stats,
        elapsed,
        server_snap.as_ref(),
    );

    // Shut the server down over the wire; a spawned one must also
    // exit cleanly (it exits non-zero on any worker panic).
    let mut server_failed = false;
    if server_child.is_some() || args.has("shutdown-server") {
        let ctx = CkksContext::new(workload::params_by_name(&spec.params));
        match NetClient::connect(&addr, ctx) {
            Ok(mut c) => {
                if let Err(e) = c.shutdown_server() {
                    eprintln!("shutdown request failed: {e}");
                    server_failed = true;
                }
            }
            Err(e) => {
                eprintln!("shutdown connect failed: {e}");
                server_failed = true;
            }
        }
    }
    if let Some(mut child) = server_child {
        let status = child.wait().expect("wait server");
        if !status.success() {
            eprintln!("server exited with {status}");
            server_failed = true;
        }
    }

    if workers_failed || server_failed || stats.err > 0 {
        std::process::exit(1);
    }
}

fn collect_worker(stdout: std::process::ChildStdout) -> WorkerStats {
    let mut s = WorkerStats::default();
    for line in BufReader::new(stdout).lines().map_while(Result::ok) {
        if let Some(us) = line.strip_prefix("LAT ") {
            if let Ok(v) = us.trim().parse::<u64>() {
                s.lat_us.push(v);
            }
        } else if let Some(rest) = line.strip_prefix("SUMMARY ") {
            for part in rest.split_whitespace() {
                if let Some((k, v)) = part.split_once('=') {
                    let v: u64 = v.parse().unwrap_or(0);
                    match k {
                        "ok" => s.ok = v,
                        "err" => s.err = v,
                        "recovered" => s.recovered = v,
                        _ => {}
                    }
                }
            }
        }
    }
    s
}

fn report(
    spec: &WorkloadSpec,
    mode: &str,
    processes: usize,
    json_path: &str,
    stats: &WorkerStats,
    elapsed: Duration,
    server: Option<&MetricsSnapshot>,
) {
    let mut lats = stats.lat_us.clone();
    lats.sort_unstable();
    if lats.is_empty() {
        eprintln!("no latency samples collected");
        return;
    }
    // Exact percentiles from the full sorted sample set.
    let pct = |q: f64| lats[(((lats.len() as f64) * q) as usize).min(lats.len() - 1)];
    let (p50, p95, p99) = (pct(0.50), pct(0.95), pct(0.99));
    let mean = lats.iter().sum::<u64>() as f64 / lats.len() as f64;
    let rps = stats.ok as f64 / elapsed.as_secs_f64();

    let dur = |us: u64| fmt_dur(Duration::from_micros(us));
    println!(
        "{} ok, {} err, {} eviction recoveries in {}",
        stats.ok,
        stats.err,
        stats.recovered,
        fmt_dur(elapsed)
    );
    println!(
        "latency p50 {} p95 {} p99 {} mean {} | throughput {rps:.2} req/s",
        dur(p50),
        dur(p95),
        dur(p99),
        dur(mean as u64)
    );

    let label = &spec.params;
    let rec = |op: &str, us: f64| BenchRecord::from_ns(op, us * 1e3, processes, label);
    let mut records = vec![
        rec(&format!("serving/{mode}/latency_p50"), p50 as f64),
        rec(&format!("serving/{mode}/latency_p95"), p95 as f64),
        rec(&format!("serving/{mode}/latency_p99"), p99 as f64),
        rec(&format!("serving/{mode}/latency_mean"), mean),
        // Inverse throughput in the same ns/op unit as every other
        // bench record (wall-clock across all processes per request).
        rec(
            &format!("serving/{mode}/wall_per_req"),
            elapsed.as_micros() as f64 / stats.ok.max(1) as f64,
        ),
    ];
    // Server-side records: scraped over the wire, same ns/op unit.
    // Client latency includes the network and the serialized
    // connection; the server split explains where the time went
    // (admission queueing vs HE/slot evaluation).
    if let Some(s) = server {
        println!(
            "server: {} enc / {} plain completed; enc queue mean {:?} service mean {:?}; \
             traces {} recorded, {} dropped",
            s.encrypted_completed,
            s.plain_completed,
            s.encrypted_queue_mean,
            s.encrypted_service_mean,
            s.traces_recorded,
            s.traces_dropped
        );
        let srec = |op: &str, d: Duration| {
            BenchRecord::from_ns(op, d.as_nanos() as f64, processes, label)
        };
        records.extend([
            srec(&format!("serving/{mode}/server/enc_p50"), s.encrypted_p50),
            srec(&format!("serving/{mode}/server/enc_p99"), s.encrypted_p99),
            srec(
                &format!("serving/{mode}/server/enc_queue_mean"),
                s.encrypted_queue_mean,
            ),
            srec(
                &format!("serving/{mode}/server/enc_service_mean"),
                s.encrypted_service_mean,
            ),
            srec(&format!("serving/{mode}/server/plain_p50"), s.plain_p50),
            srec(
                &format!("serving/{mode}/server/plain_queue_mean"),
                s.plain_queue_mean,
            ),
            srec(
                &format!("serving/{mode}/server/plain_service_mean"),
                s.plain_service_mean,
            ),
        ]);
    }
    if let Err(e) = write_json(json_path, &records) {
        eprintln!("writing {json_path} failed: {e}");
    }
}
