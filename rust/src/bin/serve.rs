//! `cryptotree-serve` — the networked HRF serving tier.
//!
//! Builds the deterministic demo workload (same flags as
//! `cryptotree-loadgen`, so clients encrypt against an identical
//! model), starts the coordinator, and serves the wire protocol until
//! a client sends `Shutdown`.
//!
//! ```text
//! cryptotree-serve --addr 127.0.0.1:0 --params demo --workers 2
//! ```
//!
//! Prints `LISTENING <addr>` once the socket is bound (machine-
//! parsable: the load generator's `--spawn-server` mode reads it to
//! discover the ephemeral port). Exits non-zero if any worker — HE or
//! network — panicked during the run, so harnesses cannot mistake a
//! crashed-but-restarted worker pool for a clean run.
//!
//! Flags beyond the shared workload set:
//!
//! * `--addr` (default `127.0.0.1:7814`), `--max-conns`,
//!   `--max-frame-mb` — acceptor knobs.
//! * `--workers`, `--enc-batch`, `--queue` — coordinator knobs.
//! * `--key-budget-mb` — evaluation-key cache budget; `0` (default)
//!   disables eviction, small values exercise the
//!   `KeysEvicted`/re-register protocol under load.
//! * `--spill-dir` / `--spill-budget-mb` — keycache disk spill tier:
//!   budget-evicted session keys demote to files under the directory
//!   (wiped at startup) and reload transparently on the next lookup;
//!   the budget (default 1024 MiB) caps the directory size. Unset
//!   `--spill-dir` keeps eviction in-memory-only.
//! * `--slab-budget-mb` — resident-byte budget for the shared CKKS
//!   scratch slab pool (`0`, the default, keeps the
//!   `CRYPTOTREE_SLAB_BUDGET` / built-in default).
//! * `--trace` — span-trace ring capacity (default 256; `0` disables
//!   tracing); dump over the wire with `Request::TraceDump`.
//! * `--stats-interval` — seconds between `STATS {...}` one-line JSON
//!   metrics snapshots on stdout (`0`, the default, disables them).

use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager};
use cryptotree::keycache::KeyCacheConfig;
use cryptotree::net::args::Args;
use cryptotree::net::server::{NetServer, NetServerConfig};
use cryptotree::net::workload::{self, WorkloadSpec};
use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let spec = WorkloadSpec::from_args(&args);

    let workers = args.get("workers", 2usize);
    let enc_batch = args.get("enc-batch", 2usize);
    let queue = args.get("queue", 64usize);
    let key_budget_mb = args.get("key-budget-mb", 0u64);
    let max_conns = args.get("max-conns", 64usize);
    let max_frame_mb = args.get("max-frame-mb", 256usize);
    let trace_capacity = args.get("trace", 256usize);
    let stats_interval = args.get("stats-interval", 0u64);
    let spill_dir = args.get_opt_str("spill-dir").map(std::path::PathBuf::from);
    let spill_budget_mb = args.get("spill-budget-mb", 1024u64);
    let slab_budget_mb = args.get("slab-budget-mb", 0u64);

    eprintln!(
        "building workload: params={} trees={} depth={} rows={} seed={}",
        spec.params, spec.trees, spec.depth, spec.rows, spec.seed
    );
    let wl = workload::build(&spec);
    eprintln!(
        "model: {} features, {} classes, {} sample groups/ct ({})",
        wl.server.model.plan.d,
        wl.server.model.plan.c,
        wl.server.model.plan.groups,
        wl.params.name
    );

    let sessions = if key_budget_mb == 0 {
        Arc::new(SessionManager::new())
    } else {
        Arc::new(SessionManager::with_config(KeyCacheConfig {
            num_shards: args.get("key-shards", 4usize),
            budget_bytes: key_budget_mb * 1024 * 1024,
        }))
    };

    let coord = Coordinator::start(
        CoordinatorConfig {
            workers,
            queue_capacity: queue,
            enc_batch,
            trace_capacity,
            slab_budget_bytes: slab_budget_mb * 1024 * 1024,
            spill_budget_bytes: spill_budget_mb * 1024 * 1024,
            // A flag beats the env default; absent flag keeps it
            // (CoordinatorConfig::default reads CRYPTOTREE_SPILL_DIR).
            spill_dir: spill_dir.or_else(|| CoordinatorConfig::default().spill_dir),
            ..Default::default()
        },
        wl.ctx.clone(),
        wl.server.clone(),
        sessions,
        None,
    );

    let net = NetServer::start(
        NetServerConfig {
            addr: args.get_str("addr", "127.0.0.1:7814"),
            max_connections: max_conns,
            max_frame: max_frame_mb * 1024 * 1024,
            ..Default::default()
        },
        wl.ctx.clone(),
        wl.server.clone(),
        coord,
        enc_batch,
    )
    .unwrap_or_else(|e| {
        eprintln!("bind failed: {e}");
        std::process::exit(2);
    });

    // Machine-parsable: loadgen --spawn-server scrapes this line for
    // the resolved (possibly ephemeral) port.
    println!("LISTENING {}", net.local_addr());
    std::io::stdout().flush().ok();

    let metrics = net.metrics();
    // Serve until a client requests shutdown, emitting periodic
    // one-line JSON snapshots when --stats-interval is set (each line
    // is independently parsable: `STATS {<MetricsSnapshot>}`).
    let stats_every = (stats_interval > 0).then(|| Duration::from_secs(stats_interval));
    let mut next_stats = stats_every.map(|d| Instant::now() + d);
    while !net.shutdown_requested() {
        std::thread::sleep(Duration::from_millis(50));
        if let (Some(every), Some(due)) = (stats_every, next_stats) {
            if Instant::now() >= due {
                println!("STATS {}", metrics.snapshot().to_json_line());
                std::io::stdout().flush().ok();
                next_stats = Some(Instant::now() + every);
            }
        }
    }
    let report = net.shutdown();

    let s = metrics.snapshot();
    println!(
        "served: {} encrypted ({} batches, mean fill {:.2}), {} plain",
        s.encrypted_completed, s.enc_batches_flushed, s.mean_enc_batch_fill, s.plain_completed
    );
    println!(
        "latency: enc mean {:?} p95 {:?}; plain mean {:?} p95 {:?}",
        s.encrypted_mean, s.encrypted_p95, s.plain_mean, s.plain_p95
    );
    println!(
        "network: {} accepted, {} refused overload; rejected: {} busy, {} no-session, {} evicted",
        s.net_connections_accepted,
        s.net_rejected_overload,
        s.rejected_backpressure,
        s.rejected_no_session,
        s.rejected_keys_evicted
    );
    println!(
        "keycache: {} hits, {} misses, {} evictions, {} resident bytes",
        s.keycache_hits, s.keycache_misses, s.keycache_evictions, s.keycache_resident_bytes
    );
    println!(
        "memory plane: slab {} resident bytes ({} hits, {} misses); spill {} bytes, {} reloads, {} corrupt",
        s.slab_resident_bytes,
        s.slab_hits,
        s.slab_misses,
        s.keycache_spilled_bytes,
        s.keycache_spill_hits,
        s.keycache_spill_corrupt
    );

    if !report.is_clean() {
        for (name, msg) in &report.worker_panics {
            eprintln!("worker `{name}` panicked: {msg}");
        }
        std::process::exit(1);
    }
}
