//! Poison-recovering lock acquisition.
//!
//! The serving tier holds long-lived [`std::sync::Mutex`]es (metrics
//! histograms, key-cache shards, plaintext caches) that are shared
//! between request handlers and HE worker threads. With the standard
//! `lock().unwrap()` idiom, a single panicking worker poisons the mutex
//! and every *subsequent* request on unrelated sessions panics too —
//! fatal for a long-lived TCP server.
//!
//! None of those locks guard multi-step invariants that a mid-update
//! panic could corrupt in a dangerous way: histograms and LRU maps are
//! at worst missing one sample or one refresh. Recovering the guard
//! from [`PoisonError`] is therefore strictly better than propagating
//! the panic, and the worker panic itself is still surfaced through
//! `Coordinator::shutdown`'s [`ShutdownReport`].
//!
//! [`ShutdownReport`]: crate::coordinator::ShutdownReport

use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Acquire a mutex, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Acquire a read lock, recovering the guard if a writer panicked.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Acquire a write lock, recovering the guard if a previous holder
/// panicked.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn mutex_recovers_after_poison() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        // `lock().unwrap()` would panic here; the helper recovers.
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn rwlock_recovers_after_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read_unpoisoned(&l).len(), 3);
        write_unpoisoned(&l).push(4);
        assert_eq!(read_unpoisoned(&l).len(), 4);
    }
}
