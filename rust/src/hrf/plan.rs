//! Slot-layout planning for packed HRF evaluation.
//!
//! # Tree blocks (paper §2.1)
//!
//! Every tree occupies a contiguous block of `2K−1` slots:
//!
//! ```text
//!   [ comp_0 … comp_{K-2} | 0 | comp_0 … comp_{K-2} ]   (length 2K−1)
//!     --- K slots ----------^   ^--- K−1 replicated slots
//! ```
//!
//! The replication makes the `K` global rotations of Algorithm 1 read
//! correct windows inside every block simultaneously (paper §2.1's
//! wrap-around fix), which is what lets `L` trees be evaluated for the
//! price of one `K×K` diagonal matmul.
//!
//! # Sample groups (cross-instance SIMD batching)
//!
//! One model uses `L(2K−1)` slots, but a ciphertext carries `N/2`. The
//! remaining slots are organized into **sample groups**: the `L`-block
//! layout above is replicated at every multiple of `group_span` (the
//! power of two covering `L(2K−1)`), and each group carries an
//! *independent* observation:
//!
//! ```text
//!   slot 0                group_span            2·group_span
//!   ├──────────────────────┼──────────────────────┼── …
//!   │ sample 0             │ sample 1             │ sample 2 …
//!   │ [T0][T1]…[T_{L-1}] 0 │ [T0][T1]…[T_{L-1}] 0 │
//!   │  └─ L·(2K−1) used ─┘ │  └─ same layout ───┘ │
//!   └──────────────────────┴──────────────────────┴── …
//!        groups = slots / group_span   (a power of two ≥ 1)
//! ```
//!
//! Group locality is what keeps samples from mixing:
//!
//! * Algorithm 1's rotations (`1..K−1`) only *read across* a group
//!   boundary at slots where every diagonal operand is zero, because
//!   nonzero diagonal entries sit in the first `K` slots of a block and
//!   `block_start(L−1) + K − 1 + (K−1) = used_slots − 1 < group_span`;
//! * Algorithm 2's rotate-and-sum runs over `group_span` (not the whole
//!   ciphertext), so the score landing in `score_slot(g) = g·group_span`
//!   is the sum of group `g`'s slots only.
//!
//! Batching `B ≤ groups` observations into one ciphertext therefore
//! amortizes the entire homomorphic evaluation ~`B×` — the same
//! cross-instance SIMD batching CryptoNets-style systems use, applied
//! to the HRF layout.

use std::collections::BTreeSet;

/// Packing plan for one HRF model on one parameter set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HrfPlan {
    /// Leaves per tree (power of two).
    pub k: usize,
    /// Number of trees L.
    pub l: usize,
    /// Number of classes C.
    pub c: usize,
    /// Input dimension d (for the client's reshuffle).
    pub d: usize,
    /// Slots per tree block = 2K−1.
    pub block: usize,
    /// Slots used by one sample group = L·(2K−1).
    pub used_slots: usize,
    /// Power-of-two span of one sample group: covers `used_slots` and
    /// bounds the Algorithm 2 rotate-and-sum, so the reduction never
    /// crosses into the next group.
    pub reduce_span: usize,
    /// Number of independent sample groups per ciphertext
    /// (= slots / reduce_span, a power of two ≥ 1).
    pub groups: usize,
    /// Total slots available (N/2).
    pub slots: usize,
}

impl HrfPlan {
    /// Build and validate a plan. Errors if the packing constraint
    /// `L(2K−1) ≤ N/2` (paper §2.1) is violated.
    pub fn new(k: usize, l: usize, c: usize, d: usize, slots: usize) -> Result<Self, String> {
        if !k.is_power_of_two() {
            return Err(format!("K={k} must be a power of two"));
        }
        if !slots.is_power_of_two() {
            return Err(format!("slot count {slots} must be a power of two"));
        }
        let block = 2 * k - 1;
        let used = l * block;
        if used > slots {
            return Err(format!(
                "packing constraint violated: L(2K-1) = {used} > {slots} slots"
            ));
        }
        let reduce_span = used.next_power_of_two();
        if reduce_span > slots {
            return Err(format!(
                "reduction span {reduce_span} exceeds {slots} slots"
            ));
        }
        Ok(HrfPlan {
            k,
            l,
            c,
            d,
            block,
            used_slots: used,
            reduce_span,
            groups: slots / reduce_span,
            slots,
        })
    }

    /// Slot offset of tree `l`'s block within sample group 0. Add
    /// [`HrfPlan::group_start`] for other groups.
    pub fn block_start(&self, l: usize) -> usize {
        l * self.block
    }

    /// First slot of sample group `g`.
    pub fn group_start(&self, g: usize) -> usize {
        debug_assert!(g < self.groups);
        g * self.reduce_span
    }

    /// Slot where sample group `g`'s class score lands after the
    /// group-local Algorithm 2 reduction.
    pub fn score_slot(&self, g: usize) -> usize {
        self.group_start(g)
    }

    /// Rotation steps used *during* one (possibly batched) evaluation:
    /// `1..K−1` (Algorithm 1) plus the powers of two up to
    /// `reduce_span/2` (the group-local Algorithm 2 reduction). Every
    /// step is `< reduce_span`, and Algorithm 1 steps only read across
    /// a group boundary where the diagonal operands are zero.
    ///
    /// Closed-form twin of the compiled schedule's derived step set
    /// (`HrfSchedule::rotation_steps`), retained as a cross-check —
    /// production key requirements come from the schedule
    /// (`HrfServer::eval_key_requirements`).
    pub fn eval_rotations(&self) -> Vec<usize> {
        let mut rots: BTreeSet<usize> = (1..self.k).collect();
        let mut step = 1usize;
        while step < self.reduce_span {
            rots.insert(step);
            step <<= 1;
        }
        rots.into_iter().collect()
    }

    /// Rotation steps the server needs Galois keys for in the
    /// single-sample protocol (kept as the stable name every key-gen
    /// call site uses).
    pub fn rotations_needed(&self) -> Vec<usize> {
        self.eval_rotations()
    }

    /// Extra rotation steps needed to serve a packed group of up to
    /// `b` samples: for each occupied group `g ≥ 1`,
    /// `slots − g·reduce_span` places sample `g` (a right-shift of the
    /// fresh group-0 ciphertext) and `g·reduce_span` extracts its score
    /// back to slot 0. These run *outside* the evaluation proper.
    pub fn batch_rotations(&self, b: usize) -> Vec<usize> {
        let b = b.min(self.groups);
        let mut rots = BTreeSet::new();
        for g in 1..b {
            let place = self.slots - g * self.reduce_span;
            let extract = g * self.reduce_span;
            for r in [place, extract] {
                if r > 0 {
                    rots.insert(r);
                }
            }
        }
        rots.into_iter().collect()
    }

    /// All rotation steps for a session that will submit packed groups
    /// of up to `b` samples (evaluation + placement + extraction).
    ///
    /// This is the *unfolded* (legacy slot-0) protocol's set; the
    /// folded schedule needs no extraction steps (see
    /// `HrfServer::eval_key_requirements`). Retained as the hand
    /// cross-check for `HrfSchedule::rotation_steps`.
    pub fn rotations_needed_batched(&self, b: usize) -> Vec<usize> {
        let mut rots: BTreeSet<usize> = self.eval_rotations().into_iter().collect();
        rots.extend(self.batch_rotations(b));
        rots.into_iter().collect()
    }

    /// Paper Table 1 formulas for this plan (additions,
    /// multiplications, rotations) per layer.
    pub fn table1_formulas(&self) -> [(u64, u64, u64); 3] {
        let k = self.k as u64;
        let c = self.c as u64;
        let log_span = (self.used_slots as f64).log2().ceil() as u64;
        [
            (1, 0, 0),
            (k, k, k),
            (c * log_span, c, c * log_span),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_plan() {
        let p = HrfPlan::new(16, 64, 2, 14, 8192).unwrap();
        assert_eq!(p.block, 31);
        assert_eq!(p.used_slots, 1984);
        assert_eq!(p.reduce_span, 2048);
        assert_eq!(p.groups, 4);
        assert_eq!(p.block_start(3), 93);
        assert_eq!(p.group_start(1), 2048);
        assert_eq!(p.score_slot(3), 6144);
    }

    #[test]
    fn default_adult_plan_has_two_groups() {
        // The paper's adult configuration on N=8192 (4096 slots):
        // L=64 trees of K=16 leaves fill 1984 slots -> span 2048 ->
        // 2 samples per ciphertext.
        let p = HrfPlan::new(16, 64, 2, 14, 4096).unwrap();
        assert_eq!(p.groups, 2);
    }

    #[test]
    fn rejects_overfull_packing() {
        // L(2K-1) = 100*31 = 3100 > 2048
        assert!(HrfPlan::new(16, 100, 2, 14, 2048).is_err());
    }

    #[test]
    fn rejects_non_power_of_two_k() {
        assert!(HrfPlan::new(12, 4, 2, 14, 8192).is_err());
    }

    #[test]
    fn rotations_cover_alg1_and_reduction() {
        let p = HrfPlan::new(8, 10, 2, 5, 4096).unwrap();
        let rots = p.rotations_needed();
        for r in 1..8 {
            assert!(rots.contains(&r), "missing Algorithm 1 rotation {r}");
        }
        // used = 150 -> span 256 -> steps 1..128
        for s in [16, 32, 64, 128] {
            assert!(rots.contains(&s), "missing reduction step {s}");
        }
        assert!(!rots.contains(&256));
    }

    #[test]
    fn eval_rotations_stay_group_local() {
        for (k, l, slots) in [(8usize, 10usize, 4096usize), (16, 64, 8192), (4, 3, 2048)] {
            let p = HrfPlan::new(k, l, 2, 5, slots).unwrap();
            for r in p.eval_rotations() {
                assert!(
                    r < p.reduce_span,
                    "eval rotation {r} spans a whole group (span {})",
                    p.reduce_span
                );
            }
            // Algorithm 1 windows: the furthest nonzero-diagonal read is
            // from the last block's K-th slot plus K-1 — inside the group.
            assert!(p.block_start(l - 1) + p.k - 1 + (p.k - 1) < p.reduce_span);
        }
    }

    #[test]
    fn batch_rotations_cover_place_and_extract() {
        let p = HrfPlan::new(8, 10, 2, 5, 4096).unwrap();
        // span 256, groups 16
        assert_eq!(p.groups, 16);
        let rots = p.batch_rotations(3);
        assert!(rots.contains(&256), "extract rotation for group 1");
        assert!(rots.contains(&512), "extract rotation for group 2");
        assert!(rots.contains(&(4096 - 256)), "place rotation for group 1");
        assert!(rots.contains(&(4096 - 512)), "place rotation for group 2");
        assert_eq!(rots.len(), 4);
        // b beyond groups is clamped.
        assert_eq!(p.batch_rotations(100), p.batch_rotations(16));
        // b <= 1 needs nothing extra.
        assert!(p.batch_rotations(1).is_empty());
        // The combined set is deduplicated and sorted.
        let all = p.rotations_needed_batched(3);
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(all, sorted);
    }

    #[test]
    fn table1_matches_paper_shapes() {
        let p = HrfPlan::new(16, 64, 2, 14, 8192).unwrap();
        let [l1, l2, l3] = p.table1_formulas();
        assert_eq!(l1, (1, 0, 0));
        assert_eq!(l2, (16, 16, 16));
        // C⌈log2 L(2K-1)⌉ = 2*11
        assert_eq!(l3, (22, 2, 22));
    }
}
