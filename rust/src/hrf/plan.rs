//! Slot-layout planning for packed HRF evaluation.
//!
//! Every tree occupies a contiguous block of `2K−1` slots:
//!
//! ```text
//!   [ comp_0 … comp_{K-2} | 0 | comp_0 … comp_{K-2} ]   (length 2K−1)
//!     --- K slots ----------^   ^--- K−1 replicated slots
//! ```
//!
//! The replication makes the `K` global rotations of Algorithm 1 read
//! correct windows inside every block simultaneously (paper §2.1's
//! wrap-around fix), which is what lets `L` trees be evaluated for the
//! price of one `K×K` diagonal matmul.

/// Packing plan for one HRF model on one parameter set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HrfPlan {
    /// Leaves per tree (power of two).
    pub k: usize,
    /// Number of trees L.
    pub l: usize,
    /// Number of classes C.
    pub c: usize,
    /// Input dimension d (for the client's reshuffle).
    pub d: usize,
    /// Slots per tree block = 2K−1.
    pub block: usize,
    /// Total used slots = L·(2K−1).
    pub used_slots: usize,
    /// Power-of-two span covering `used_slots` for the Algorithm 2
    /// rotate-and-sum.
    pub reduce_span: usize,
    /// Total slots available (N/2).
    pub slots: usize,
}

impl HrfPlan {
    /// Build and validate a plan. Errors if the packing constraint
    /// `L(2K−1) ≤ N/2` (paper §2.1) is violated.
    pub fn new(k: usize, l: usize, c: usize, d: usize, slots: usize) -> Result<Self, String> {
        if !k.is_power_of_two() {
            return Err(format!("K={k} must be a power of two"));
        }
        let block = 2 * k - 1;
        let used = l * block;
        if used > slots {
            return Err(format!(
                "packing constraint violated: L(2K-1) = {used} > {slots} slots"
            ));
        }
        let reduce_span = used.next_power_of_two();
        if reduce_span > slots {
            return Err(format!(
                "reduction span {reduce_span} exceeds {slots} slots"
            ));
        }
        Ok(HrfPlan {
            k,
            l,
            c,
            d,
            block,
            used_slots: used,
            reduce_span,
            slots,
        })
    }

    /// Slot offset of tree `l`'s block.
    pub fn block_start(&self, l: usize) -> usize {
        l * self.block
    }

    /// Rotation steps the server needs Galois keys for:
    /// `1..K−1` (Algorithm 1) plus the powers of two up to
    /// `reduce_span/2` (Algorithm 2).
    pub fn rotations_needed(&self) -> Vec<usize> {
        let mut rots: Vec<usize> = (1..self.k).collect();
        let mut step = 1usize;
        while step < self.reduce_span {
            if !rots.contains(&step) {
                rots.push(step);
            }
            step <<= 1;
        }
        rots.sort_unstable();
        rots
    }

    /// Paper Table 1 formulas for this plan (additions,
    /// multiplications, rotations) per layer.
    pub fn table1_formulas(&self) -> [(u64, u64, u64); 3] {
        let k = self.k as u64;
        let c = self.c as u64;
        let log_span = (self.used_slots as f64).log2().ceil() as u64;
        [
            (1, 0, 0),
            (k, k, k),
            (c * log_span, c, c * log_span),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_plan() {
        let p = HrfPlan::new(16, 64, 2, 14, 8192).unwrap();
        assert_eq!(p.block, 31);
        assert_eq!(p.used_slots, 1984);
        assert_eq!(p.reduce_span, 2048);
        assert_eq!(p.block_start(3), 93);
    }

    #[test]
    fn rejects_overfull_packing() {
        // L(2K-1) = 100*31 = 3100 > 2048
        assert!(HrfPlan::new(16, 100, 2, 14, 2048).is_err());
    }

    #[test]
    fn rejects_non_power_of_two_k() {
        assert!(HrfPlan::new(12, 4, 2, 14, 8192).is_err());
    }

    #[test]
    fn rotations_cover_alg1_and_reduction() {
        let p = HrfPlan::new(8, 10, 2, 5, 4096).unwrap();
        let rots = p.rotations_needed();
        for r in 1..8 {
            assert!(rots.contains(&r), "missing Algorithm 1 rotation {r}");
        }
        // used = 150 -> span 256 -> steps 1..128
        for s in [16, 32, 64, 128] {
            assert!(rots.contains(&s), "missing reduction step {s}");
        }
        assert!(!rots.contains(&256));
    }

    #[test]
    fn table1_matches_paper_shapes() {
        let p = HrfPlan::new(16, 64, 2, 14, 8192).unwrap();
        let [l1, l2, l3] = p.table1_formulas();
        assert_eq!(l1, (1, 0, 0));
        assert_eq!(l2, (16, 16, 16));
        // C⌈log2 L(2K-1)⌉ = 2*11
        assert_eq!(l3, (22, 2, 22));
    }
}
