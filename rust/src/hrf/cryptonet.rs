//! CryptoNet-style HE-MLP baseline (paper §5 comparison).
//!
//! CryptoNets (Dowlin et al. 2016) batch one *sample per slot*: each
//! input feature is its own ciphertext carrying that feature's value
//! for all `N/2` samples. Dense layers are plaintext-weight
//! mul-and-adds across ciphertexts (no rotations); activations are
//! squarings. The consequence the paper highlights: latency is the
//! same whether the batch holds 1 or 8192 samples — amortized
//! throughput is great, single-observation latency is terrible.
//!
//! This module reproduces that trade-off on our CKKS substrate with a
//! small MLP (d → hidden → C, square activations) over the same
//! structured data the HRF serves.

use crate::ckks::evaluator::Evaluator;
use crate::ckks::keys::RelinKey;
use crate::ckks::rns::CkksContext;
use crate::ckks::{Ciphertext, Encoder, Encryptor};
use crate::rng::Xoshiro256pp;

/// Plaintext MLP weights (trained or random — the §5 comparison is
/// about *cost*, not accuracy).
#[derive(Clone, Debug)]
pub struct MlpWeights {
    pub w1: Vec<Vec<f64>>, // hidden × d
    pub b1: Vec<f64>,
    pub w2: Vec<Vec<f64>>, // C × hidden
    pub b2: Vec<f64>,
}

impl MlpWeights {
    pub fn random(d: usize, hidden: usize, c: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        fn mat(rng: &mut Xoshiro256pp, rows: usize, cols: usize) -> Vec<Vec<f64>> {
            (0..rows)
                .map(|_| (0..cols).map(|_| rng.normal_ms(0.0, 0.4)).collect())
                .collect()
        }
        let w1 = mat(&mut rng, hidden, d);
        let b1 = (0..hidden).map(|_| rng.normal_ms(0.0, 0.1)).collect();
        let w2 = mat(&mut rng, c, hidden);
        let b2 = (0..c).map(|_| rng.normal_ms(0.0, 0.1)).collect();
        MlpWeights { w1, b1, w2, b2 }
    }

    /// Plaintext reference forward for one sample.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let h: Vec<f64> = self
            .w1
            .iter()
            .zip(&self.b1)
            .map(|(row, b)| {
                let z: f64 = row.iter().zip(x).map(|(w, x)| w * x).sum::<f64>() + b;
                z * z
            })
            .collect();
        self.w2
            .iter()
            .zip(&self.b2)
            .map(|(row, b)| row.iter().zip(&h).map(|(w, h)| w * h).sum::<f64>() + b)
            .collect()
    }
}

/// Encrypt a batch in CryptoNet layout: ciphertext `j` holds feature
/// `j` of every sample (batch ≤ slots; remaining slots zero).
pub fn encrypt_batch_per_feature(
    ctx: &CkksContext,
    enc: &Encoder,
    encryptor: &mut Encryptor,
    batch: &[Vec<f64>],
) -> Vec<Ciphertext> {
    let d = batch[0].len();
    let slots = ctx.n() / 2;
    assert!(batch.len() <= slots);
    (0..d)
        .map(|j| {
            let mut col = vec![0.0f64; slots];
            for (i, row) in batch.iter().enumerate() {
                col[i] = row[j];
            }
            encryptor.encrypt_slots(ctx, enc, &col)
        })
        .collect()
}

/// Evaluate the MLP on per-feature ciphertexts. Returns one ciphertext
/// per class; slot `i` of each holds sample `i`'s class score.
/// Depth: 4 levels (dense·rescale, square·rescale, dense·rescale).
pub fn eval_mlp(
    ev: &mut Evaluator,
    enc: &Encoder,
    inputs: &[Ciphertext],
    w: &MlpWeights,
    rlk: &RelinKey,
) -> Vec<Ciphertext> {
    let delta = ev.ctx.params.scale;
    let ctx = ev.ctx.clone();
    // Hidden layer: z_h = Σ_j w1[h][j]·x_j + b1[h], then square.
    let mut hidden = Vec::with_capacity(w.w1.len());
    for (row, &b) in w.w1.iter().zip(&w.b1) {
        let mut acc: Option<Ciphertext> = None;
        for (ct, &wj) in inputs.iter().zip(row) {
            let w_pt = enc.encode_constant(&ctx, wj, ct.level, delta);
            let mut term = ev.mul_plain(ct, &w_pt);
            match &mut acc {
                None => acc = Some(term),
                Some(a) => {
                    term.scale = a.scale;
                    ev.add_inplace(a, &term);
                }
            }
        }
        let mut z = acc.expect("d >= 1");
        ev.rescale(&mut z);
        let b_pt = enc.encode_constant(&ctx, b, z.level, z.scale);
        ev.add_plain_inplace(&mut z, &b_pt);
        let mut sq = ev.square(&z, rlk);
        ev.rescale(&mut sq);
        hidden.push(sq);
    }
    // Output layer.
    let mut outs = Vec::with_capacity(w.w2.len());
    for (row, &b) in w.w2.iter().zip(&w.b2) {
        let mut acc: Option<Ciphertext> = None;
        for (ct, &wh) in hidden.iter().zip(row) {
            let w_pt = enc.encode_constant(&ctx, wh, ct.level, delta);
            let mut term = ev.mul_plain(ct, &w_pt);
            match &mut acc {
                None => acc = Some(term),
                Some(a) => {
                    term.scale = a.scale;
                    ev.add_inplace(a, &term);
                }
            }
        }
        let mut z = acc.expect("hidden >= 1");
        ev.rescale(&mut z);
        let b_pt = enc.encode_constant(&ctx, b, z.level, z.scale);
        ev.add_plain_inplace(&mut z, &b_pt);
        outs.push(z);
    }
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::{CkksParams, Decryptor, KeyGenerator};

    #[test]
    fn he_mlp_matches_plain_forward_batched() {
        let ctx = CkksContext::new(CkksParams::fast());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 91);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let mut encryptor = Encryptor::new(pk, 92);
        let decryptor = Decryptor::new(kg.secret_key());
        let mut ev = Evaluator::new(ctx.clone());

        let d = 6;
        let hidden = 4;
        let c = 2;
        let w = MlpWeights::random(d, hidden, c, 93);
        let mut rng = Xoshiro256pp::new(94);
        let batch: Vec<Vec<f64>> = (0..32)
            .map(|_| (0..d).map(|_| rng.uniform(0.0, 1.0)).collect())
            .collect();
        let cts = encrypt_batch_per_feature(&ctx, &enc, &mut encryptor, &batch);
        let outs = eval_mlp(&mut ev, &enc, &cts, &w, &rlk);
        assert_eq!(outs.len(), c);
        for ci in 0..c {
            let slots = decryptor.decrypt_slots(&ctx, &enc, &outs[ci]);
            for (i, sample) in batch.iter().enumerate() {
                let expect = w.forward(sample)[ci];
                assert!(
                    (slots[i] - expect).abs() < 1e-2,
                    "sample {i} class {ci}: {} vs {expect}",
                    slots[i]
                );
            }
        }
    }
}
