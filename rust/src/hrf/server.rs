//! Server half of Algorithm 3: the homomorphic evaluation.
//!
//! ```text
//! layer 1:  u  = P(x̃ − t̃)                      (1 pt-sub + activation)
//! layer 2:  v  = P(Σ_{j<K} diag_j ⊙ rot(u,j) + b̃)   (Algorithm 1)
//! layer 3:  ŷ_c = ⟨W̃_c, v⟩ + β_c                    (Algorithm 2, ×C)
//! ```
//!
//! Since the engine refactor the server is a thin shell around
//! compiled [`HrfSchedule`]s executed by the generic schedule engine:
//! [`HrfServer::execute`] compiles the schedule for the request's
//! batch size (once, cached — the way `pt_cache` caches encoded
//! plaintexts), runs the server's [`PassPipeline`] over it, and
//! replays the op list through
//! [`Engine::run`](crate::runtime::engine::Engine::run) on a
//! [`CkksBackend`] wrapping the [`Evaluator`], the plaintext cache and
//! the session keys. The server itself contains **no** op dispatch —
//! the engine owns the single `ScheduleOp` match, shared with the f32
//! slot backend and the dry-run counter. Galois-key requirements
//! ([`HrfServer::eval_key_requirements`], [`HrfServer::can_batch`])
//! and Table-1 predictions ([`HrfServer::predicted_counts`]) are
//! derived from the same compiled program, so the op stream, the key
//! set and the cost model cannot drift apart.
//!
//! The legacy entry points `eval` / `eval_batch` / `eval_batch_folded`
//! survive as thin deprecated wrappers over [`HrfServer::execute`]
//! with the matching [`EncRequest`] shape.
//!
//! Per-layer [`LayerCounts`] snapshots regenerate the paper's Table 1.
//! The activation polynomial is evaluated with the power-basis method
//! (depth ⌈log₂ m⌉+1), so the whole pipeline fits the depth-8 default
//! parameter set with degree-4 activations.
//!
//! # Sample-group batching and the extraction fold
//!
//! All three layers operate slot-wise or group-locally and the model
//! operands are replicated into every sample group (see
//! [`HrfPlan`](super::plan::HrfPlan)), so one evaluation of a
//! ciphertext packed with `B ≤ plan.groups` observations scores all of
//! them at once — sample `g`'s class-`c` score lands at slot
//! `plan.score_slot(g)` of output `c`.
//!
//! [`EncRequest::group`] (the folded contract) serves the
//! coordinator's hot path: the per-sample extraction rotations are
//! folded into the layer-3 reduction (see
//! [`schedule`](super::schedule)), the per-class outputs stay
//! slot-addressed ([`EncScores`] carries the slot), and the batch
//! saves exactly `C·(B−1)` key-switches over eval+extract.
//! [`EncRequest::group_slot0`] keeps the legacy slot-0 response
//! contract by running the unfolded schedule, whose `Extract` segment
//! hoists each class's score ciphertext once and replays the
//! extraction rotations as cheap hoisted key-switches.
//!
//! The pre-refactor hand-written path survives as
//! [`HrfServer::eval_reference`] / [`HrfServer::eval_batch_reference`]
//! — the bit-identity oracle for `tests/schedule_props.rs` and the
//! baseline the rotation-count bench compares against.

use super::pack::HrfModel;
use super::schedule::{HrfSchedule, PlainOperand, Segment};
use crate::ckks::evaluator::{Evaluator, OpCounts};
use crate::ckks::keys::{GaloisKeys, RelinKey};
use crate::ckks::rns::CkksContext;
use crate::ckks::{Ciphertext, Encoder, Plaintext, ScratchPool};
use crate::lockutil::lock_unpoisoned;
use crate::obs::{OpProfile, TimingBackend};
use crate::runtime::engine::dag::{op_workers_from_env, DagStats};
use crate::runtime::engine::{
    CkksBackend, CostModel, Engine, EngineRun, PassPipeline, ScheduleDag,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Table-1 measurement: op counts per HRF **linear** layer (the paper's
/// Table 1 counts the linear layers; activation-polynomial costs are
/// tracked separately in `activations`, batching overheads in
/// `pack` / `extract`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerCounts {
    pub layer1: OpCounts,
    pub layer2: OpCounts,
    pub layer3: OpCounts,
    /// Combined cost of the two activation-polynomial evaluations.
    pub activations: OpCounts,
    /// Server-side placement of a packed batch (`B−1` rotations+adds).
    pub pack: OpCounts,
    /// Legacy slot-0 score extraction (zero for folded schedules).
    pub extract: OpCounts,
}

impl LayerCounts {
    /// (additions, multiplications, rotations) per layer — the exact
    /// columns of Table 1.
    pub fn table1_rows(&self) -> [(u64, u64, u64); 3] {
        let row = |c: &OpCounts| (c.additions(), c.multiplications(), c.rotate);
        [row(&self.layer1), row(&self.layer2), row(&self.layer3)]
    }

    /// Whole-pipeline totals (layers + activations + pack + extract).
    pub fn total(&self) -> OpCounts {
        self.layer1 + self.layer2 + self.layer3 + self.activations + self.pack + self.extract
    }

    /// The accounting bucket a schedule segment's ops land in — the
    /// single mapping shared by the executor's measured counts and the
    /// dry-run interpreter's predictions, so the two cannot drift.
    pub fn bucket_mut(&mut self, seg: Segment) -> &mut OpCounts {
        match seg {
            Segment::Pack => &mut self.pack,
            Segment::Layer1 => &mut self.layer1,
            Segment::Act1 | Segment::Act2 => &mut self.activations,
            Segment::Layer2 => &mut self.layer2,
            Segment::Layer3 => &mut self.layer3,
            Segment::Extract => &mut self.extract,
        }
    }

    /// Read-only view of [`bucket_mut`](LayerCounts::bucket_mut)'s
    /// mapping (per-segment comparisons in tests and the op-profile
    /// plane).
    pub fn bucket(&self, seg: Segment) -> &OpCounts {
        match seg {
            Segment::Pack => &self.pack,
            Segment::Layer1 => &self.layer1,
            Segment::Act1 | Segment::Act2 => &self.activations,
            Segment::Layer2 => &self.layer2,
            Segment::Layer3 => &self.layer3,
            Segment::Extract => &self.extract,
        }
    }
}

impl std::ops::AddAssign for LayerCounts {
    /// Bucket-wise accumulation — how the op-parallel driver merges
    /// each worker's locally-metered segment counts into one
    /// [`LayerCounts`] equal to the serial measurement.
    fn add_assign(&mut self, o: LayerCounts) {
        self.layer1 += o.layer1;
        self.layer2 += o.layer2;
        self.layer3 += o.layer3;
        self.activations += o.activations;
        self.pack += o.pack;
        self.extract += o.extract;
    }
}

/// Per-class score ciphertexts plus the slot each caller should read —
/// the response payload of the folded batched protocol. `slot == 0`
/// for single-sample and legacy-extracted responses; a folded batch
/// response points caller `g` at `plan.score_slot(g)` of the shared
/// per-class ciphertexts (decrypt with
/// `HrfClient::decrypt_scores_at` / `decrypt_response`).
#[derive(Clone, Debug)]
pub struct EncScores {
    /// One ciphertext per class.
    pub scores: Vec<Ciphertext>,
    /// Slot of each ciphertext carrying this response's score.
    pub slot: usize,
}

/// An encrypted execution request: which ciphertexts to score and
/// under which output contract. The single entry point
/// [`HrfServer::execute`] replaces the old `eval` / `eval_batch` /
/// `eval_batch_folded` trio.
#[derive(Clone, Copy)]
pub struct EncRequest<'a> {
    /// Fresh single-sample ciphertexts to pack and score together
    /// (`1 ≤ len ≤ plan.groups`). A pre-packed multi-sample ciphertext
    /// is submitted as a single input (its scores stay at the group
    /// score slots).
    pub cts: &'a [Ciphertext],
    /// `true` → folded schedule, slot-addressed outputs (the modern
    /// contract); `false` → unfolded schedule with the legacy slot-0
    /// `Extract` segment. `len == 1` normalizes to folded.
    pub fold: bool,
}

impl<'a> EncRequest<'a> {
    /// Score one ciphertext (single sample, or client-side packed
    /// group whose callers read the group score slots).
    pub fn single(ct: &'a Ciphertext) -> Self {
        EncRequest {
            cts: std::slice::from_ref(ct),
            fold: true,
        }
    }

    /// Pack-and-score a group under the folded slot-addressed
    /// contract — the coordinator's hot path.
    pub fn group(cts: &'a [Ciphertext]) -> Self {
        EncRequest { cts, fold: true }
    }

    /// Pack-and-score a group under the legacy slot-0 contract (one
    /// extracted ciphertext set per sample).
    pub fn group_slot0(cts: &'a [Ciphertext]) -> Self {
        EncRequest { cts, fold: false }
    }
}

/// Result of one [`HrfServer::execute`]: the distinct per-class
/// ciphertext groups the schedule produced plus, for every input
/// sample, which group and slot carry its score. A folded execution
/// has **one** group shared by all samples (nothing was deep-cloned);
/// an unfolded execution has one group per sample at slot 0.
pub struct EncExecution {
    groups: Vec<Vec<Ciphertext>>,
    /// Per sample: (index into `groups`, score slot).
    samples: Vec<(usize, usize)>,
    /// Per-layer op counts measured at segment boundaries (these match
    /// `HrfSchedule::predicted_counts` exactly).
    pub counts: LayerCounts,
}

impl EncExecution {
    pub fn n_samples(&self) -> usize {
        self.samples.len()
    }

    /// The slot sample `g` reads its score from.
    pub fn slot(&self, sample: usize) -> usize {
        self.samples[sample].1
    }

    /// Response payload for one sample (clones the shared group when
    /// the execution was folded; prefer [`EncExecution::into_responses`]
    /// when draining all samples).
    pub fn response(&self, sample: usize) -> EncScores {
        let (gi, slot) = self.samples[sample];
        EncScores {
            scores: self.groups[gi].clone(),
            slot,
        }
    }

    /// One [`EncScores`] per input sample. Shared (folded) groups are
    /// cloned for all but their last user, so exactly
    /// `samples − groups` deep clones happen — none for `B = 1`.
    pub fn into_responses(self) -> Vec<EncScores> {
        let EncExecution {
            groups, samples, ..
        } = self;
        let mut last_use = vec![0usize; groups.len()];
        for (i, (gi, _)) in samples.iter().enumerate() {
            last_use[*gi] = i;
        }
        let mut groups: Vec<Option<Vec<Ciphertext>>> = groups.into_iter().map(Some).collect();
        samples
            .iter()
            .enumerate()
            .map(|(i, &(gi, slot))| {
                let scores = if last_use[gi] == i {
                    groups[gi].take().expect("group moved twice")
                } else {
                    groups[gi].as_ref().expect("group gone").clone()
                };
                EncScores { scores, slot }
            })
            .collect()
    }

    /// The folded execution's shared per-class ciphertexts (sample
    /// `g`'s score at `plan.score_slot(g)`). Panics on unfolded
    /// multi-sample executions, which have one group per sample.
    pub fn into_class_scores(mut self) -> Vec<Ciphertext> {
        assert_eq!(
            self.groups.len(),
            1,
            "into_class_scores needs a single shared output group"
        );
        self.groups.pop().expect("one group")
    }

    /// Per-sample per-class ciphertexts in sample order — the legacy
    /// slot-0 batch shape. Panics on folded multi-sample executions
    /// (their samples share one group; use
    /// [`EncExecution::into_responses`] or
    /// [`EncExecution::into_class_scores`]).
    pub fn into_per_sample(self) -> Vec<Vec<Ciphertext>> {
        assert_eq!(
            self.groups.len(),
            self.samples.len(),
            "into_per_sample needs one output group per sample"
        );
        self.groups
    }
}

/// Server-side evaluator bound to one packed model.
pub struct HrfServer {
    pub model: HrfModel,
    /// Encoded-plaintext cache: the model operands are fixed and the
    /// pipeline's (level, scale) schedule is deterministic, so each
    /// operand is FFT-encoded exactly once per schedule point
    /// (§Perf step 5 — encodes were ~40 % of an eval).
    pt_cache: Mutex<HashMap<(u32, usize, u64), Plaintext>>,
    /// Compiled-schedule cache, keyed by (batch size, folded) — the
    /// schedule analogue of `pt_cache`. Cached schedules are already
    /// pass-optimized.
    schedules: Mutex<HashMap<(usize, bool), Arc<HrfSchedule>>>,
    /// Hazard-DAG cache, same key as `schedules` (a DAG is derived
    /// from the cached pass-optimized schedule on first parallel use).
    dags: Mutex<HashMap<(usize, bool), Arc<ScheduleDag>>>,
    /// Optimization passes applied to every compiled schedule.
    passes: PassPipeline,
    /// Op-parallel worker count for [`HrfServer::execute`] (`1` =
    /// serial engine). Seeded from `CRYPTOTREE_OP_WORKERS`; overridden
    /// by `CoordinatorConfig::op_workers`.
    op_workers: AtomicUsize,
    /// Ready-queue cost weights for the DAG driver. Starts at the
    /// static table; every [`HrfServer::execute_profiled`] re-seeds it
    /// from the measured `OpProfile` (the profile-feedback loop).
    cost_model: Mutex<CostModel>,
    /// Checkout façade for per-worker `Scratch` handles. The warm
    /// limb buffers live in the global slab pool (`crate::mem`), so
    /// DAG workers share one byte-budgeted arena across requests and
    /// across servers instead of pinning private warm sets.
    scratch_pool: ScratchPool,
}

/// Cache operand ids.
const PT_T: u32 = 0;
const PT_B: u32 = 1;
const PT_DIAG0: u32 = 10; // +j
const PT_W0: u32 = 1_000; // +c

fn operand_cache_id(op: PlainOperand) -> u32 {
    match op {
        PlainOperand::Thresholds => PT_T,
        PlainOperand::Biases => PT_B,
        PlainOperand::Diag(j) => PT_DIAG0 + j as u32,
        PlainOperand::ClassWeights(c) => PT_W0 + c as u32,
    }
}

impl HrfServer {
    /// Server with the standard pass pipeline (schedule-level fusion
    /// on). Use [`HrfServer::with_passes`] to customize.
    pub fn new(model: HrfModel) -> Self {
        HrfServer::with_passes(model, PassPipeline::standard())
    }

    /// Server with an explicit optimization pipeline
    /// (`PassPipeline::empty()` executes schedules exactly as
    /// compiled — the parity tests' unoptimized baseline).
    pub fn with_passes(model: HrfModel, passes: PassPipeline) -> Self {
        HrfServer {
            model,
            pt_cache: Mutex::new(HashMap::new()),
            schedules: Mutex::new(HashMap::new()),
            dags: Mutex::new(HashMap::new()),
            passes,
            op_workers: AtomicUsize::new(op_workers_from_env()),
            cost_model: Mutex::new(CostModel::static_default()),
            scratch_pool: ScratchPool::new(),
        }
    }

    /// Set the op-parallel worker count (`1` = serial engine; clamped
    /// to ≥ 1). Outputs are bit-identical at every setting.
    pub fn set_op_workers(&self, workers: usize) {
        self.op_workers.store(workers.max(1), Ordering::Relaxed);
    }

    /// Current op-parallel worker count.
    pub fn op_workers(&self) -> usize {
        self.op_workers.load(Ordering::Relaxed)
    }

    /// Encode-with-cache. `scale` is quantized to bits for the key
    /// (exact f64 scales at a given schedule point are identical).
    fn cached_encode(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        id: u32,
        slots: &[f64],
        level: usize,
        scale: f64,
    ) -> Plaintext {
        let key = (id, level, scale.to_bits());
        if let Some(pt) = lock_unpoisoned(&self.pt_cache).get(&key) {
            return pt.clone();
        }
        let pt = enc.encode(ctx, slots, level, scale);
        lock_unpoisoned(&self.pt_cache).insert(key, pt.clone());
        pt
    }

    /// Resolve a schedule operand to its cached encoded plaintext at
    /// the requested (level, scale) — the `CkksBackend`'s window into
    /// the server's operand store.
    pub(crate) fn encode_operand(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        operand: PlainOperand,
        level: usize,
        scale: f64,
    ) -> Plaintext {
        self.cached_encode(
            ctx,
            enc,
            operand_cache_id(operand),
            self.model.operand_slots(operand),
            level,
            scale,
        )
    }

    /// The compiled, pass-optimized schedule for a `b`-sample batch,
    /// compiled on first use and cached. `b` is clamped to the plan's
    /// group capacity; `b = 1` normalizes to the folded form (there is
    /// nothing to extract).
    pub fn schedule(&self, b: usize, fold: bool) -> Arc<HrfSchedule> {
        let b = b.clamp(1, self.model.plan.groups);
        let fold = fold || b == 1;
        let mut cache = lock_unpoisoned(&self.schedules);
        cache
            .entry((b, fold))
            .or_insert_with(|| {
                Arc::new(HrfSchedule::compile(&self.model, b, fold).optimize(self.passes.passes()))
            })
            .clone()
    }

    /// The hazard dependency DAG of [`schedule(b, fold)`]
    /// (`HrfServer::schedule`), built on first use and cached under the
    /// same normalized key.
    pub fn dag(&self, b: usize, fold: bool) -> Arc<ScheduleDag> {
        let b = b.clamp(1, self.model.plan.groups);
        let fold = fold || b == 1;
        if let Some(d) = lock_unpoisoned(&self.dags).get(&(b, fold)) {
            return d.clone();
        }
        // Build outside the dags lock: schedule() takes its own lock
        // and DAG construction is the slow part.
        let dag = Arc::new(ScheduleDag::build(&self.schedule(b, fold)));
        lock_unpoisoned(&self.dags)
            .entry((b, fold))
            .or_insert(dag)
            .clone()
    }

    /// DAG shape (ops / waves / width) for a batch size — what the
    /// coordinator stamps into its metrics gauges.
    pub fn dag_stats(&self, b: usize, fold: bool) -> DagStats {
        self.dag(b, fold).stats()
    }

    /// Execute an encrypted request through the schedule engine: look
    /// up (or compile + optimize) the schedule matching the request's
    /// batch size and contract, then replay it on a [`CkksBackend`]
    /// bound to this server, the evaluator and the session keys.
    ///
    /// With [`op_workers`](HrfServer::op_workers) `> 1` the replay
    /// goes through the op-parallel DAG driver
    /// ([`Engine::run_parallel`]) instead of the serial loop — the
    /// outputs (and the measured counts) are bit-identical either way,
    /// at any `op_workers × ckks_workers` combination; a worker panic
    /// is re-raised here exactly as the serial path would raise it.
    ///
    /// This is the single encrypted entry point; the legacy
    /// `eval` / `eval_batch` / `eval_batch_folded` names are thin
    /// deprecated wrappers over it.
    pub fn execute(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        req: &EncRequest<'_>,
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> EncExecution {
        assert!(
            !req.cts.is_empty() && req.cts.len() <= self.model.plan.groups,
            "batch of {} outside 1..={}",
            req.cts.len(),
            self.model.plan.groups
        );
        let workers = self.op_workers();
        if workers > 1 {
            return self.execute_parallel(ev, enc, req, rlk, gk, workers);
        }
        let sched = self.schedule(req.cts.len(), req.fold);
        let mut backend = CkksBackend::new(self, ev.split_off(), enc, req.cts, rlk, gk);
        let EngineRun { regs, counts } = Engine::run(&sched, &mut backend);
        ev.merge(backend.into_evaluator());
        self.collect_outputs(&sched, regs, counts)
    }

    /// The op-parallel execution path: replay the schedule's hazard
    /// DAG across `workers` threads, each owning a [`CkksBackend`]
    /// with its own evaluator and a `Scratch` handle checked out of
    /// the server's [`ScratchPool`] façade — all handles draw from
    /// the one byte-budgeted slab arena (`crate::mem`). Worker op
    /// counters merge back into `ev` (its monotone totals advance
    /// exactly as the serial path's would); recycled limb buffers are
    /// already resident in the shared pool when a worker retires.
    fn execute_parallel(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        req: &EncRequest<'_>,
        rlk: &RelinKey,
        gk: &GaloisKeys,
        workers: usize,
    ) -> EncExecution {
        let sched = self.schedule(req.cts.len(), req.fold);
        let dag = self.dag(req.cts.len(), req.fold);
        let cost = lock_unpoisoned(&self.cost_model).clone();
        let ctx = ev.ctx.clone();
        let run = Engine::run_parallel(&sched, &dag, &cost, workers, |_w| {
            let wev = Evaluator::with_scratch(ctx.clone(), self.scratch_pool.checkout());
            CkksBackend::new(self, wev, enc, req.cts, rlk, gk)
        });
        match run {
            Ok((EngineRun { regs, counts }, backends)) => {
                for backend in backends {
                    let wev = backend.into_evaluator();
                    ev.counts += wev.counts;
                    self.scratch_pool.restore(wev.into_scratch());
                }
                self.collect_outputs(&sched, regs, counts)
            }
            // Parity with the serial engine's failure mode: a panic
            // inside an op propagates to the caller (the coordinator's
            // worker supervision catches it). The typed error surface
            // is `Engine::run_parallel` for callers that want it.
            Err(e) => panic!("{e}"),
        }
    }

    /// [`HrfServer::execute`] with the CKKS backend wrapped in the
    /// op-profile [`TimingBackend`]: every schedule primitive's wall
    /// time lands in `profile`, keyed by (segment, op kind), with op
    /// multiplicities diffed from the evaluator's own counters — so
    /// `profile.layer_counts()` equals the returned
    /// `EncExecution::counts` and the `CountingBackend` prediction.
    /// Profiles accumulate: pass the same `profile` across requests to
    /// tighten the timing histograms.
    ///
    /// Strictly opt-in and off the hot path — [`HrfServer::execute`]
    /// never constructs the decorator, so disabling profiling costs
    /// nothing there.
    pub fn execute_profiled(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        req: &EncRequest<'_>,
        rlk: &RelinKey,
        gk: &GaloisKeys,
        profile: &mut OpProfile,
    ) -> EncExecution {
        assert!(
            !req.cts.is_empty() && req.cts.len() <= self.model.plan.groups,
            "batch of {} outside 1..={}",
            req.cts.len(),
            self.model.plan.groups
        );
        let sched = self.schedule(req.cts.len(), req.fold);
        let inner = CkksBackend::new(self, ev.split_off(), enc, req.cts, rlk, gk);
        let mut backend = TimingBackend::new(inner, profile);
        let EngineRun { regs, counts } = Engine::run(&sched, &mut backend);
        ev.merge(backend.into_inner().into_evaluator());
        // Feed the measured per-kind means back into the DAG driver's
        // ready-queue priorities (the ROADMAP's profile-feedback loop).
        *lock_unpoisoned(&self.cost_model) = CostModel::from_profile(profile);
        self.collect_outputs(&sched, regs, counts)
    }

    /// Move the schedule's output registers into an [`EncExecution`] —
    /// the marshalling tail shared by [`execute`](HrfServer::execute)
    /// and [`execute_profiled`](HrfServer::execute_profiled).
    fn collect_outputs(
        &self,
        sched: &HrfSchedule,
        mut regs: Vec<Option<Ciphertext>>,
        counts: LayerCounts,
    ) -> EncExecution {
        let mut groups: Vec<Vec<Ciphertext>> = Vec::new();
        let mut samples: Vec<(usize, usize)> = Vec::new();
        if sched.folded {
            // C·B outputs alias C class registers — move each distinct
            // register out once; samples share the group and address
            // their own score slot.
            let class_cts: Vec<Ciphertext> = sched
                .outputs
                .iter()
                .filter(|r| r.sample == 0)
                .map(|r| regs[r.reg].take().expect("output register"))
                .collect();
            groups.push(class_cts);
            for g in 0..sched.b {
                samples.push((0, self.model.plan.score_slot(g)));
            }
        } else {
            // One distinct register per (class, sample), score at
            // slot 0 — class-major per sample.
            let mut per_sample: Vec<Vec<Ciphertext>> =
                (0..sched.b).map(|_| Vec::new()).collect();
            for r in &sched.outputs {
                per_sample[r.sample].push(regs[r.reg].take().expect("output register"));
            }
            for (g, cts) in per_sample.into_iter().enumerate() {
                groups.push(cts);
                samples.push((g, 0));
            }
        }
        EncExecution {
            groups,
            samples,
            counts,
        }
    }

    /// Evaluate the HRF on an encrypted input. Returns one ciphertext
    /// per class (score in slot 0) plus per-layer op counts.
    #[deprecated(note = "use HrfServer::execute with EncRequest::single")]
    pub fn eval(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        ct_in: &Ciphertext,
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        let ex = self.execute(ev, enc, &EncRequest::single(ct_in), rlk, gk);
        let counts = ex.counts;
        (ex.into_class_scores(), counts)
    }

    /// Evaluate a packed group under the **legacy slot-0 contract**:
    /// one `Vec<Ciphertext>` (length C, score in slot 0) per input
    /// sample.
    #[deprecated(note = "use HrfServer::execute with EncRequest::group_slot0")]
    pub fn eval_batch(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Vec<Ciphertext>>, LayerCounts) {
        let ex = self.execute(ev, enc, &EncRequest::group_slot0(cts), rlk, gk);
        let counts = ex.counts;
        (ex.into_per_sample(), counts)
    }

    /// Evaluate a packed group with the extraction **folded** into the
    /// layer-3 reduction: one ciphertext per class, sample `g`'s score
    /// at `plan.score_slot(g)`.
    #[deprecated(note = "use HrfServer::execute with EncRequest::group")]
    pub fn eval_batch_folded(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        let ex = self.execute(ev, enc, &EncRequest::group(cts), rlk, gk);
        let counts = ex.counts;
        (ex.into_class_scores(), counts)
    }

    /// Combine `B ≤ plan.groups` *fresh single-sample* ciphertexts
    /// (each observation packed in group 0, all remaining slots zero,
    /// identical level & scale) into one group-packed ciphertext:
    /// sample `g` is right-shifted into group `g` and the shifts are
    /// summed. Costs `B−1` rotations + `B−1` additions — far below one
    /// full evaluation, which is what makes server-side batching pay.
    ///
    /// This is the stand-alone form of the compiled schedule's `Pack`
    /// segment (the equivalence is pinned by a unit test in
    /// [`schedule`](super::schedule)); the session's Galois keys must
    /// cover the placement steps in
    /// [`HrfServer::eval_key_requirements`].
    pub fn pack_group(
        &self,
        ev: &mut Evaluator,
        cts: &[Ciphertext],
        gk: &GaloisKeys,
    ) -> Ciphertext {
        let p = &self.model.plan;
        assert!(!cts.is_empty() && cts.len() <= p.groups);
        let mut acc = cts[0].clone();
        for (g, ct) in cts.iter().enumerate().skip(1) {
            // Left-rotation by slots − g·span == right-shift by g·span:
            // slot g·span + j of the result reads slot j of the input.
            let placed = ev.rotate(ct, p.slots - g * p.reduce_span, gk);
            ev.add_inplace(&mut acc, &placed);
        }
        acc
    }

    /// Rotate sample `g`'s score (slot `plan.score_slot(g)`) back to
    /// slot 0 — the legacy-contract helper the unfolded schedule's
    /// `Extract` segment mirrors (the folded protocol never calls it).
    pub fn extract_sample(
        &self,
        ev: &mut Evaluator,
        ct: &Ciphertext,
        g: usize,
        gk: &GaloisKeys,
    ) -> Ciphertext {
        let slot = self.model.plan.score_slot(g);
        if slot == 0 {
            ct.clone()
        } else {
            ev.rotate(ct, slot, gk)
        }
    }

    /// Compile (and pass-optimize) the folded schedules this server
    /// will serve and pre-warm the context's Galois-permutation cache
    /// with every rotation step they use, so the serving hot path only
    /// ever takes the **read** side of the permutation `RwLock`. The
    /// step set of every batch size `b ≤ max_b` is a subset of the
    /// `max_b` set (placement steps grow with `b`; reduction steps are
    /// batch-independent), so one warm-up covers all group sizes.
    ///
    /// Called by the coordinator at start-up; harmless to call again.
    pub fn prewarm(&self, ctx: &CkksContext, max_b: usize) {
        let max_b = max_b.clamp(1, self.model.plan.groups);
        let steps: Vec<usize> = self.schedule(max_b, true).rotation_steps().into_iter().collect();
        ctx.galois_perm_prewarm(&steps);
    }

    /// Rotation steps a session must cover in its registered Galois
    /// keys to use this server with packed groups of up to `b` samples
    /// (`b ≤ 1` is the single-sample set) — what a client should
    /// generate for registration *and* re-registration after a
    /// `SubmitError::KeysEvicted`.
    ///
    /// Derived from the compiled **folded** schedule's op list, so it
    /// contains no extraction steps — smaller key uploads and key-cache
    /// footprints than the legacy `rotations_needed_batched` set.
    pub fn eval_key_requirements(&self, b: usize) -> Vec<usize> {
        self.schedule(b.max(1), true).rotation_steps().into_iter().collect()
    }

    /// Whether `gk` holds every Galois key the folded `b`-sample
    /// schedule needs (schedule-derived; a stale or single-sample key
    /// set makes the coordinator fall back to smaller chunks or
    /// per-request evaluation).
    pub fn can_batch(&self, gk: &GaloisKeys, b: usize) -> bool {
        self.schedule(b, true)
            .rotation_steps()
            .iter()
            .all(|r| gk.keys.contains_key(r))
    }

    /// Dry-run Table-1 prediction for a `b`-sample batch — the op
    /// counts executing the compiled schedule will produce, derived
    /// from the schedule itself rather than hand formulas.
    pub fn predicted_counts(&self, b: usize, fold: bool) -> LayerCounts {
        self.schedule(b, fold).predicted_counts()
    }

    /// The pre-schedule hand-written evaluation, retained verbatim as
    /// the bit-identity oracle for the compiled path (see
    /// `tests/schedule_props.rs`) and the legacy baseline in
    /// `benches/table1_opcounts.rs`.
    pub fn eval_reference(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        ct_in: &Ciphertext,
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        let m = &self.model;
        let p = &m.plan;
        let delta = ev.ctx.params.scale;
        let mut counts = LayerCounts::default();
        let snap0 = ev.counts;

        // ---- Layer 1: u = P(x̃ − t̃) --------------------------------
        let t_pt = self.cached_encode(&ev.ctx, enc, PT_T, &m.t_slots, ct_in.level, ct_in.scale);
        let mut diff = ct_in.clone();
        ev.sub_plain_inplace(&mut diff, &t_pt);
        counts.layer1 = ev.counts.diff(&snap0);
        let act0 = ev.counts;
        let u = ev.eval_poly_power_basis(enc, &diff, &m.act_coeffs, rlk);
        counts.activations = ev.counts.diff(&act0);
        let snap1 = ev.counts;

        // ---- Layer 2: Algorithm 1 (packed diagonal matmul) ---------
        // acc = Σ_j diag_j ⊙ rot(u, j), products kept at scale u.scale·Δ,
        // single rescale at the end, then + b̃ and activation.
        // All K−1 rotations share the input u → hoist its key-switch
        // decomposition once (§Perf step 3).
        let hoisted = ev.hoist(&u);
        let mut acc: Option<Ciphertext> = None;
        for (j, diag) in m.diag_slots.iter().enumerate() {
            let rotated = if j == 0 {
                u.clone()
            } else {
                ev.rotate_hoisted(&u, &hoisted, j, gk)
            };
            let d_pt = self.cached_encode(
                &ev.ctx,
                enc,
                PT_DIAG0 + j as u32,
                diag,
                rotated.level,
                delta,
            );
            let mut term = ev.mul_plain(&rotated, &d_pt);
            match &mut acc {
                None => acc = Some(term),
                Some(a) => {
                    term.scale = a.scale;
                    ev.add_inplace(a, &term);
                }
            }
        }
        let mut lin = acc.expect("K >= 1 diagonals");
        ev.rescale(&mut lin);
        let b_pt = self.cached_encode(&ev.ctx, enc, PT_B, &m.b_slots, lin.level, lin.scale);
        ev.add_plain_inplace(&mut lin, &b_pt);
        counts.layer2 = ev.counts.diff(&snap1);
        let act1 = ev.counts;
        let v = ev.eval_poly_power_basis(enc, &lin, &m.act_coeffs, rlk);
        counts.activations += ev.counts.diff(&act1);
        let snap2 = ev.counts;

        // ---- Layer 3: Algorithm 2 per class ------------------------
        // The rotate-and-sum spans one sample group (`reduce_span`),
        // NOT the whole ciphertext: slot g·span accumulates exactly
        // group g's masked slots, so packed samples stay independent.
        let mut outputs = Vec::with_capacity(p.c);
        for ci in 0..p.c {
            let w_pt = self.cached_encode(
                &ev.ctx,
                enc,
                PT_W0 + ci as u32,
                &m.w_slots[ci],
                v.level,
                delta,
            );
            let mut masked = ev.mul_plain(&v, &w_pt);
            ev.rescale(&mut masked);
            let summed = ev.rotate_sum(&masked, p.reduce_span, gk);
            let beta_pt = enc.encode_constant(&ev.ctx, m.betas[ci], summed.level, summed.scale);
            let mut out = summed;
            ev.add_plain_inplace(&mut out, &beta_pt);
            outputs.push(out);
        }
        counts.layer3 = ev.counts.diff(&snap2);

        (outputs, counts)
    }

    /// Legacy eval+extract batch path (pack → [`eval_reference`] →
    /// per-sample slot-0 extraction with plain rotations) — the
    /// baseline the folded schedule is measured against.
    ///
    /// [`eval_reference`]: HrfServer::eval_reference
    pub fn eval_batch_reference(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Vec<Ciphertext>>, LayerCounts) {
        if cts.len() == 1 {
            let (outs, counts) = self.eval_reference(ev, enc, &cts[0], rlk, gk);
            return (vec![outs], counts);
        }
        let packed = self.pack_group(ev, cts, gk);
        let (outs, counts) = self.eval_reference(ev, enc, &packed, rlk, gk);
        let per_sample = (0..cts.len())
            .map(|g| {
                outs.iter()
                    .map(|class_ct| self.extract_sample(ev, class_ct, g, gk))
                    .collect()
            })
            .collect();
        (per_sample, counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::rns::CkksContext;
    use crate::ckks::{CkksParams, Decryptor, Encryptor, KeyGenerator};
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::hrf::client::{reshuffle_and_pack, HrfClient};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    /// Full small-scale end-to-end: train, pack, encrypt, evaluate
    /// (compiled schedule), decrypt, compare with the plaintext slot
    /// model AND the retained hand-written reference path.
    #[test]
    fn hrf_eval_matches_plain_slot_model() {
        let ds = adult::generate(1_500, 81);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 6,
                tree: crate::forest::tree::TreeConfig {
                    max_depth: 3,
                    ..Default::default()
                },
                ..Default::default()
            },
            82,
        );
        let params = std::sync::Arc::new(CkksParams::build(
            "test-n8192-d8",
            8192,
            60,
            40,
            8,
            3.2,
        ));
        let ctx = CkksContext::new(params);
        let enc = Encoder::new(&ctx);

        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), ctx.n() / 2).unwrap();
        let plan = hm.plan;

        let mut kg = KeyGenerator::new(&ctx, 83);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
        let mut client = HrfClient::new(
            Encryptor::new(pk, 84),
            Decryptor::new(kg.secret_key()),
        );
        let server = HrfServer::new(hm);
        // Pre-warm the Galois-permutation cache from the compiled
        // schedule: the evaluations below then only read the cache.
        server.prewarm(&ctx, plan.groups);
        assert!(
            ctx.galois_perms_cached() >= server.eval_key_requirements(plan.groups).len(),
            "prewarm left schedule rotations cold"
        );
        let mut ev = Evaluator::new(ctx.clone());

        for x in ds.x.iter().take(3) {
            let ct = client.encrypt_input(&ctx, &enc, &server.model, x);
            let ex = server.execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk);
            let counts = ex.counts;
            let outs = ex.into_class_scores();
            let (scores, _) = client.decrypt_scores(&ctx, &enc, &outs);
            let x_slots = reshuffle_and_pack(&server.model, x);
            let expect = server.model.forward_slots_plain(&x_slots);
            for (g, e) in scores.iter().zip(&expect) {
                assert!(
                    (g - e).abs() < 5e-3,
                    "HE deviates from plain slot model: {scores:?} vs {expect:?}"
                );
            }
            // Table 1 shape checks (layer 2: K muls, K-1 rotations).
            let [_, l2, l3] = counts.table1_rows();
            assert_eq!(l2.1, plan.k as u64, "layer2 multiplications");
            assert_eq!(l2.2, (plan.k - 1) as u64, "layer2 rotations");
            assert_eq!(l3.1, plan.c as u64, "layer3 multiplications");
            // Measured counts equal the schedule's dry-run prediction.
            assert_eq!(
                counts,
                server.predicted_counts(1, true),
                "dry-run prediction deviates from measured execution"
            );
        }

        // The compiled path (fused by the standard pipeline) is
        // bit-identical to the hand-written reference path.
        let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[0]);
        let a = server
            .execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
            .into_class_scores();
        let (b, _) = server.eval_reference(&mut ev, &enc, &ct, &rlk, &gk);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.level, y.level);
            assert_eq!(x.scale.to_bits(), y.scale.to_bits());
            assert_eq!(x.c0.data(), y.c0.data(), "c0 deviates from reference");
            assert_eq!(x.c1.data(), y.c1.data(), "c1 deviates from reference");
        }
    }

    #[test]
    fn key_requirements_are_schedule_derived_and_extraction_free() {
        let ds = adult::generate(400, 87);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                ..Default::default()
            },
            88,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 2048).unwrap();
        let p = hm.plan;
        let server = HrfServer::new(hm);
        for b in 1..=p.groups.min(4) {
            let req = server.eval_key_requirements(b);
            let hand = p.rotations_needed_batched(b);
            // Schedule-derived ⊆ hand formula, and the dropped steps
            // are exactly the extraction rotations g·span.
            for r in &req {
                assert!(hand.contains(r), "requirement {r} outside hand set");
            }
            for &r in &hand {
                if req.contains(&r) {
                    continue;
                }
                assert_eq!(r % p.reduce_span, 0, "dropped non-extraction step {r}");
            }
        }
    }
}
