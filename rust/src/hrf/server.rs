//! Server half of Algorithm 3: the homomorphic evaluation.
//!
//! ```text
//! layer 1:  u  = P(x̃ − t̃)                      (1 pt-sub + activation)
//! layer 2:  v  = P(Σ_{j<K} diag_j ⊙ rot(u,j) + b̃)   (Algorithm 1)
//! layer 3:  ŷ_c = ⟨W̃_c, v⟩ + β_c                    (Algorithm 2, ×C)
//! ```
//!
//! Per-layer [`OpCounts`] snapshots regenerate the paper's Table 1.
//! The activation polynomial is evaluated with the power-basis method
//! (depth ⌈log₂ m⌉+1), so the whole pipeline fits the depth-8 default
//! parameter set with degree-4 activations.
//!
//! # Sample-group batching
//!
//! All three layers operate slot-wise or group-locally, and the model
//! operands are replicated into every sample group (see
//! [`HrfPlan`](super::plan::HrfPlan)), so one [`HrfServer::eval`] call
//! on a ciphertext packed with `B ≤ plan.groups` observations scores
//! all of them at once: layer 3's rotate-and-sum runs over
//! `plan.reduce_span` — one **group**, not the whole ciphertext — so
//! samples never mix, and sample `g`'s class-`c` score lands at slot
//! `plan.score_slot(g)` of output `c`.
//!
//! Two helpers serve the coordinator's server-side batching:
//! [`HrfServer::pack_group`] combines `B` fresh single-sample
//! ciphertexts (each sample in group 0) into one packed ciphertext with
//! `B−1` rotations, and [`HrfServer::extract_sample`] rotates a packed
//! score back to slot 0 so every caller keeps the single-sample
//! response contract.

use super::pack::HrfModel;
use crate::ckks::evaluator::{Evaluator, OpCounts};
use crate::ckks::keys::{GaloisKeys, RelinKey};
use crate::ckks::rns::CkksContext;
use crate::ckks::{Ciphertext, Encoder, Plaintext};
use std::collections::HashMap;
use std::sync::Mutex;

/// Table-1 measurement: op counts per HRF **linear** layer (the paper's
/// Table 1 counts the linear layers; activation-polynomial costs are
/// tracked separately in `activations`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerCounts {
    pub layer1: OpCounts,
    pub layer2: OpCounts,
    pub layer3: OpCounts,
    /// Combined cost of the two activation-polynomial evaluations.
    pub activations: OpCounts,
}

impl LayerCounts {
    /// (additions, multiplications, rotations) per layer — the exact
    /// columns of Table 1.
    pub fn table1_rows(&self) -> [(u64, u64, u64); 3] {
        let row = |c: &OpCounts| (c.additions(), c.multiplications(), c.rotate);
        [row(&self.layer1), row(&self.layer2), row(&self.layer3)]
    }
}

/// Server-side evaluator bound to one packed model.
pub struct HrfServer {
    pub model: HrfModel,
    /// Encoded-plaintext cache: the model operands are fixed and the
    /// pipeline's (level, scale) schedule is deterministic, so each
    /// operand is FFT-encoded exactly once per schedule point
    /// (§Perf step 5 — encodes were ~40 % of an eval).
    pt_cache: Mutex<HashMap<(u32, usize, u64), Plaintext>>,
}

/// Cache operand ids.
const PT_T: u32 = 0;
const PT_B: u32 = 1;
const PT_DIAG0: u32 = 10; // +j
const PT_W0: u32 = 1_000; // +c

impl HrfServer {
    pub fn new(model: HrfModel) -> Self {
        HrfServer {
            model,
            pt_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Encode-with-cache. `scale` is quantized to bits for the key
    /// (exact f64 scales at a given schedule point are identical).
    fn cached_encode(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        id: u32,
        slots: &[f64],
        level: usize,
        scale: f64,
    ) -> Plaintext {
        let key = (id, level, scale.to_bits());
        if let Some(pt) = self.pt_cache.lock().unwrap().get(&key) {
            return pt.clone();
        }
        let pt = enc.encode(ctx, slots, level, scale);
        self.pt_cache
            .lock()
            .unwrap()
            .insert(key, pt.clone());
        pt
    }

    /// Evaluate the HRF on an encrypted input. Returns one ciphertext
    /// per class (score in slot 0) plus per-layer op counts.
    ///
    /// Key material (`rlk`, `gk`) belongs to the client session.
    pub fn eval(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        ct_in: &Ciphertext,
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        let m = &self.model;
        let p = &m.plan;
        let delta = ev.ctx.params.scale;
        let mut counts = LayerCounts::default();
        let snap0 = ev.counts;

        // ---- Layer 1: u = P(x̃ − t̃) --------------------------------
        let t_pt =
            self.cached_encode(&ev.ctx, enc, PT_T, &m.t_slots, ct_in.level, ct_in.scale);
        let mut diff = ct_in.clone();
        ev.sub_plain_inplace(&mut diff, &t_pt);
        counts.layer1 = ev.counts.diff(&snap0);
        let act0 = ev.counts;
        let u = ev.eval_poly_power_basis(enc, &diff, &m.act_coeffs, rlk);
        counts.activations = ev.counts.diff(&act0);
        let snap1 = ev.counts;

        // ---- Layer 2: Algorithm 1 (packed diagonal matmul) ---------
        // acc = Σ_j diag_j ⊙ rot(u, j), products kept at scale u.scale·Δ,
        // single rescale at the end, then + b̃ and activation.
        // All K−1 rotations share the input u → hoist its key-switch
        // decomposition once (§Perf step 3).
        let hoisted = ev.hoist(&u);
        let mut acc: Option<Ciphertext> = None;
        for (j, diag) in m.diag_slots.iter().enumerate() {
            let rotated = if j == 0 {
                u.clone()
            } else {
                ev.rotate_hoisted(&u, &hoisted, j, gk)
            };
            let d_pt = self.cached_encode(
                &ev.ctx,
                enc,
                PT_DIAG0 + j as u32,
                diag,
                rotated.level,
                delta,
            );
            let mut term = ev.mul_plain(&rotated, &d_pt);
            match &mut acc {
                None => acc = Some(term),
                Some(a) => {
                    term.scale = a.scale;
                    ev.add_inplace(a, &term);
                }
            }
        }
        let mut lin = acc.expect("K >= 1 diagonals");
        ev.rescale(&mut lin);
        let b_pt =
            self.cached_encode(&ev.ctx, enc, PT_B, &m.b_slots, lin.level, lin.scale);
        ev.add_plain_inplace(&mut lin, &b_pt);
        counts.layer2 = ev.counts.diff(&snap1);
        let act1 = ev.counts;
        let v = ev.eval_poly_power_basis(enc, &lin, &m.act_coeffs, rlk);
        {
            let a = ev.counts.diff(&act1);
            counts.activations = OpCounts {
                add: counts.activations.add + a.add,
                add_plain: counts.activations.add_plain + a.add_plain,
                mul: counts.activations.mul + a.mul,
                mul_plain: counts.activations.mul_plain + a.mul_plain,
                rotate: counts.activations.rotate + a.rotate,
                rescale: counts.activations.rescale + a.rescale,
                relin: counts.activations.relin + a.relin,
            };
        }
        let snap2 = ev.counts;

        // ---- Layer 3: Algorithm 2 per class ------------------------
        // The rotate-and-sum spans one sample group (`reduce_span`),
        // NOT the whole ciphertext: slot g·span accumulates exactly
        // group g's masked slots, so packed samples stay independent.
        let mut outputs = Vec::with_capacity(p.c);
        for ci in 0..p.c {
            let w_pt = self.cached_encode(
                &ev.ctx,
                enc,
                PT_W0 + ci as u32,
                &m.w_slots[ci],
                v.level,
                delta,
            );
            let mut masked = ev.mul_plain(&v, &w_pt);
            ev.rescale(&mut masked);
            let summed = ev.rotate_sum(&masked, p.reduce_span, gk);
            let beta_pt = enc.encode_constant(&ev.ctx, m.betas[ci], summed.level, summed.scale);
            let mut out = summed;
            ev.add_plain_inplace(&mut out, &beta_pt);
            outputs.push(out);
        }
        counts.layer3 = ev.counts.diff(&snap2);

        (outputs, counts)
    }

    /// Combine `B ≤ plan.groups` *fresh single-sample* ciphertexts
    /// (each observation packed in group 0, all remaining slots zero,
    /// identical level & scale) into one group-packed ciphertext:
    /// sample `g` is right-shifted into group `g` and the shifts are
    /// summed. Costs `B−1` rotations + `B−1` additions — far below one
    /// full evaluation, which is what makes server-side batching pay.
    ///
    /// The session's Galois keys must cover
    /// [`HrfPlan::batch_rotations`](super::plan::HrfPlan::batch_rotations)
    /// for `B` (see [`HrfServer::can_batch`]).
    pub fn pack_group(
        &self,
        ev: &mut Evaluator,
        cts: &[Ciphertext],
        gk: &GaloisKeys,
    ) -> Ciphertext {
        let p = &self.model.plan;
        assert!(!cts.is_empty() && cts.len() <= p.groups);
        let mut acc = cts[0].clone();
        for (g, ct) in cts.iter().enumerate().skip(1) {
            // Left-rotation by slots − g·span == right-shift by g·span:
            // slot g·span + j of the result reads slot j of the input.
            let placed = ev.rotate(ct, p.slots - g * p.reduce_span, gk);
            ev.add_inplace(&mut acc, &placed);
        }
        acc
    }

    /// Rotate sample `g`'s score (slot `plan.score_slot(g)`) back to
    /// slot 0, restoring the single-sample response contract.
    pub fn extract_sample(
        &self,
        ev: &mut Evaluator,
        ct: &Ciphertext,
        g: usize,
        gk: &GaloisKeys,
    ) -> Ciphertext {
        let slot = self.model.plan.score_slot(g);
        if slot == 0 {
            ct.clone()
        } else {
            ev.rotate(ct, slot, gk)
        }
    }

    /// Rotation steps a session must cover in its registered Galois
    /// keys to use this server with packed groups of up to `b` samples
    /// (`b ≤ 1` is the single-sample set) — what a client should
    /// generate for registration *and* re-registration after a
    /// `SubmitError::KeysEvicted` (the key cache evicts whole
    /// sessions, so recovery re-uploads this full set).
    pub fn eval_key_requirements(&self, b: usize) -> Vec<usize> {
        self.model.plan.rotations_needed_batched(b)
    }

    /// Whether `gk` holds every Galois key a `b`-sample packed
    /// evaluation needs (placement + extraction on top of the
    /// evaluation set).
    pub fn can_batch(&self, gk: &GaloisKeys, b: usize) -> bool {
        self.model
            .plan
            .batch_rotations(b)
            .iter()
            .all(|r| gk.keys.contains_key(r))
    }

    /// Evaluate a packed group of `B` fresh single-sample ciphertexts
    /// in one pass: combine ([`HrfServer::pack_group`]), run
    /// [`HrfServer::eval`] once, then extract each sample's per-class
    /// scores back to slot 0. Returns one `Vec<Ciphertext>` (length C,
    /// score in slot 0) per input sample.
    pub fn eval_batch(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Vec<Ciphertext>>, LayerCounts) {
        if cts.len() == 1 {
            let (outs, counts) = self.eval(ev, enc, &cts[0], rlk, gk);
            return (vec![outs], counts);
        }
        let packed = self.pack_group(ev, cts, gk);
        let (outs, counts) = self.eval(ev, enc, &packed, rlk, gk);
        let per_sample = (0..cts.len())
            .map(|g| {
                outs.iter()
                    .map(|class_ct| self.extract_sample(ev, class_ct, g, gk))
                    .collect()
            })
            .collect();
        (per_sample, counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::rns::CkksContext;
    use crate::ckks::{CkksParams, Decryptor, Encryptor, KeyGenerator};
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::hrf::client::{reshuffle_and_pack, HrfClient};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    /// Full small-scale end-to-end: train, pack, encrypt, evaluate,
    /// decrypt, compare with the plaintext slot model.
    #[test]
    fn hrf_eval_matches_plain_slot_model() {
        let ds = adult::generate(1_500, 81);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 6,
                tree: crate::forest::tree::TreeConfig {
                    max_depth: 3,
                    ..Default::default()
                },
                ..Default::default()
            },
            82,
        );
        // Degree-2 activation to fit the fast() depth-4 budget:
        // L1 act (2 levels: x², coeff) … here power-basis deg2 -> horner
        // deg2 = 2 levels; L2 mul+rescale 1; act 2 … exceeds depth 4, so
        // use a linear "activation" for the depth check? No — use
        // degree-2 and the hrf_default-like chain with N=8192:
        let params = std::sync::Arc::new(CkksParams::build(
            "test-n8192-d8",
            8192,
            60,
            40,
            8,
            3.2,
        ));
        let ctx = CkksContext::new(params);
        let enc = Encoder::new(&ctx);

        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), ctx.n() / 2).unwrap();
        let plan = hm.plan;

        let mut kg = KeyGenerator::new(&ctx, 83);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
        let mut client = HrfClient::new(
            Encryptor::new(pk, 84),
            Decryptor::new(kg.secret_key()),
        );
        let server = HrfServer::new(hm);
        let mut ev = Evaluator::new(ctx.clone());

        for x in ds.x.iter().take(3) {
            let ct = client.encrypt_input(&ctx, &enc, &server.model, x);
            let (outs, counts) = server.eval(&mut ev, &enc, &ct, &rlk, &gk);
            let (scores, _) = client.decrypt_scores(&ctx, &enc, &outs);
            let x_slots = reshuffle_and_pack(&server.model, x);
            let expect = server.model.forward_slots_plain(&x_slots);
            for (g, e) in scores.iter().zip(&expect) {
                assert!(
                    (g - e).abs() < 5e-3,
                    "HE deviates from plain slot model: {scores:?} vs {expect:?}"
                );
            }
            // Table 1 shape checks (layer 2: K muls, K-1 rotations).
            let [_, l2, l3] = counts.table1_rows();
            assert_eq!(l2.1, plan.k as u64, "layer2 multiplications");
            assert_eq!(l2.2, (plan.k - 1) as u64, "layer2 rotations");
            assert_eq!(l3.1, plan.c as u64, "layer3 multiplications");
        }
    }
}
