//! Server half of Algorithm 3: the homomorphic evaluation.
//!
//! ```text
//! layer 1:  u  = P(x̃ − t̃)                      (1 pt-sub + activation)
//! layer 2:  v  = P(Σ_{j<K} diag_j ⊙ rot(u,j) + b̃)   (Algorithm 1)
//! layer 3:  ŷ_c = ⟨W̃_c, v⟩ + β_c                    (Algorithm 2, ×C)
//! ```
//!
//! Since the schedule refactor the server is a thin shell around
//! compiled [`HrfSchedule`]s: [`HrfServer::eval`],
//! [`HrfServer::eval_batch`] and [`HrfServer::eval_batch_folded`]
//! compile (once, cached per batch size — the way `pt_cache` caches
//! encoded plaintexts) and then replay the op list against the CKKS
//! [`Evaluator`]. Galois-key requirements
//! ([`HrfServer::eval_key_requirements`], [`HrfServer::can_batch`])
//! and Table-1 predictions ([`HrfServer::predicted_counts`]) are
//! derived from the same compiled program, so the op stream, the key
//! set and the cost model cannot drift apart.
//!
//! Per-layer [`LayerCounts`] snapshots regenerate the paper's Table 1.
//! The activation polynomial is evaluated with the power-basis method
//! (depth ⌈log₂ m⌉+1), so the whole pipeline fits the depth-8 default
//! parameter set with degree-4 activations.
//!
//! # Sample-group batching and the extraction fold
//!
//! All three layers operate slot-wise or group-locally and the model
//! operands are replicated into every sample group (see
//! [`HrfPlan`](super::plan::HrfPlan)), so one evaluation of a
//! ciphertext packed with `B ≤ plan.groups` observations scores all of
//! them at once — sample `g`'s class-`c` score lands at slot
//! `plan.score_slot(g)` of output `c`.
//!
//! [`HrfServer::eval_batch_folded`] serves the coordinator's hot path:
//! the per-sample extraction rotations are folded into the layer-3
//! reduction (see [`schedule`](super::schedule)), the per-class
//! outputs stay slot-addressed ([`EncScores`] carries the slot), and
//! the batch saves exactly `C·(B−1)` key-switches over eval+extract.
//! [`HrfServer::eval_batch`] keeps the legacy slot-0 response contract
//! by running the unfolded schedule, whose `Extract` segment hoists
//! each class's score ciphertext once and replays the extraction
//! rotations as cheap hoisted key-switches.
//!
//! The pre-refactor hand-written path survives as
//! [`HrfServer::eval_reference`] / [`HrfServer::eval_batch_reference`]
//! — the bit-identity oracle for `tests/schedule_props.rs` and the
//! baseline the rotation-count bench compares against.

use super::pack::HrfModel;
use super::schedule::{HrfSchedule, PlainOperand, Reg, ScheduleOp, Segment};
use crate::ckks::evaluator::{Evaluator, OpCounts};
use crate::ckks::keys::{GaloisKeys, RelinKey};
use crate::ckks::rns::{CkksContext, RnsPoly};
use crate::ckks::{Ciphertext, Encoder, Plaintext};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Table-1 measurement: op counts per HRF **linear** layer (the paper's
/// Table 1 counts the linear layers; activation-polynomial costs are
/// tracked separately in `activations`, batching overheads in
/// `pack` / `extract`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerCounts {
    pub layer1: OpCounts,
    pub layer2: OpCounts,
    pub layer3: OpCounts,
    /// Combined cost of the two activation-polynomial evaluations.
    pub activations: OpCounts,
    /// Server-side placement of a packed batch (`B−1` rotations+adds).
    pub pack: OpCounts,
    /// Legacy slot-0 score extraction (zero for folded schedules).
    pub extract: OpCounts,
}

impl LayerCounts {
    /// (additions, multiplications, rotations) per layer — the exact
    /// columns of Table 1.
    pub fn table1_rows(&self) -> [(u64, u64, u64); 3] {
        let row = |c: &OpCounts| (c.additions(), c.multiplications(), c.rotate);
        [row(&self.layer1), row(&self.layer2), row(&self.layer3)]
    }

    /// Whole-pipeline totals (layers + activations + pack + extract).
    pub fn total(&self) -> OpCounts {
        self.layer1 + self.layer2 + self.layer3 + self.activations + self.pack + self.extract
    }

    /// The accounting bucket a schedule segment's ops land in — the
    /// single mapping shared by the executor's measured counts and the
    /// dry-run interpreter's predictions, so the two cannot drift.
    pub fn bucket_mut(&mut self, seg: Segment) -> &mut OpCounts {
        match seg {
            Segment::Pack => &mut self.pack,
            Segment::Layer1 => &mut self.layer1,
            Segment::Act1 | Segment::Act2 => &mut self.activations,
            Segment::Layer2 => &mut self.layer2,
            Segment::Layer3 => &mut self.layer3,
            Segment::Extract => &mut self.extract,
        }
    }
}

/// Per-class score ciphertexts plus the slot each caller should read —
/// the response payload of the folded batched protocol. `slot == 0`
/// for single-sample and legacy-extracted responses; a folded batch
/// response points caller `g` at `plan.score_slot(g)` of the shared
/// per-class ciphertexts (decrypt with
/// `HrfClient::decrypt_scores_at` / `decrypt_response`).
#[derive(Clone, Debug)]
pub struct EncScores {
    /// One ciphertext per class.
    pub scores: Vec<Ciphertext>,
    /// Slot of each ciphertext carrying this response's score.
    pub slot: usize,
}

/// Server-side evaluator bound to one packed model.
pub struct HrfServer {
    pub model: HrfModel,
    /// Encoded-plaintext cache: the model operands are fixed and the
    /// pipeline's (level, scale) schedule is deterministic, so each
    /// operand is FFT-encoded exactly once per schedule point
    /// (§Perf step 5 — encodes were ~40 % of an eval).
    pt_cache: Mutex<HashMap<(u32, usize, u64), Plaintext>>,
    /// Compiled-schedule cache, keyed by (batch size, folded) — the
    /// schedule analogue of `pt_cache`.
    schedules: Mutex<HashMap<(usize, bool), Arc<HrfSchedule>>>,
}

/// Cache operand ids.
const PT_T: u32 = 0;
const PT_B: u32 = 1;
const PT_DIAG0: u32 = 10; // +j
const PT_W0: u32 = 1_000; // +c

fn operand_cache_id(op: PlainOperand) -> u32 {
    match op {
        PlainOperand::Thresholds => PT_T,
        PlainOperand::Biases => PT_B,
        PlainOperand::Diag(j) => PT_DIAG0 + j as u32,
        PlainOperand::ClassWeights(c) => PT_W0 + c as u32,
    }
}

/// Disjoint mutable access to two registers.
fn two_regs(
    regs: &mut [Option<Ciphertext>],
    a: usize,
    b: usize,
) -> (&mut Ciphertext, &mut Ciphertext) {
    assert_ne!(a, b, "aliasing register pair");
    if a < b {
        let (lo, hi) = regs.split_at_mut(b);
        (lo[a].as_mut().expect("reg a"), hi[0].as_mut().expect("reg b"))
    } else {
        let (lo, hi) = regs.split_at_mut(a);
        (hi[0].as_mut().expect("reg a"), lo[b].as_mut().expect("reg b"))
    }
}

impl HrfServer {
    pub fn new(model: HrfModel) -> Self {
        HrfServer {
            model,
            pt_cache: Mutex::new(HashMap::new()),
            schedules: Mutex::new(HashMap::new()),
        }
    }

    /// Encode-with-cache. `scale` is quantized to bits for the key
    /// (exact f64 scales at a given schedule point are identical).
    fn cached_encode(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        id: u32,
        slots: &[f64],
        level: usize,
        scale: f64,
    ) -> Plaintext {
        let key = (id, level, scale.to_bits());
        if let Some(pt) = self.pt_cache.lock().unwrap().get(&key) {
            return pt.clone();
        }
        let pt = enc.encode(ctx, slots, level, scale);
        self.pt_cache.lock().unwrap().insert(key, pt.clone());
        pt
    }

    /// The compiled schedule for a `b`-sample batch, compiled on first
    /// use and cached. `b` is clamped to the plan's group capacity;
    /// `b = 1` normalizes to the folded form (there is nothing to
    /// extract).
    pub fn schedule(&self, b: usize, fold: bool) -> Arc<HrfSchedule> {
        let b = b.clamp(1, self.model.plan.groups);
        let fold = fold || b == 1;
        let mut cache = self.schedules.lock().unwrap();
        cache
            .entry((b, fold))
            .or_insert_with(|| Arc::new(HrfSchedule::compile(&self.model, b, fold)))
            .clone()
    }

    /// Execute a compiled schedule against the evaluator. Returns the
    /// final register file (callers move the registers named by
    /// `sched.outputs` out — no output ciphertext is deep-cloned) plus
    /// per-layer op counts measured at segment boundaries (these match
    /// `sched.predicted_counts()` exactly).
    fn run_schedule(
        &self,
        sched: &HrfSchedule,
        ev: &mut Evaluator,
        enc: &Encoder,
        inputs: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Option<Ciphertext>>, LayerCounts) {
        assert!(
            inputs.len() >= sched.b,
            "schedule packs {} inputs, got {}",
            sched.b,
            inputs.len()
        );
        let delta = ev.ctx.params.scale;
        let mut regs: Vec<Option<Ciphertext>> = vec![None; sched.n_regs];
        let mut hoists: HashMap<Reg, Vec<RnsPoly>> = HashMap::new();
        let mut counts = LayerCounts::default();
        let mut cur_seg: Option<Segment> = None;
        let mut snap = ev.counts;

        for (seg, op) in &sched.ops {
            if cur_seg != Some(*seg) {
                if let Some(s) = cur_seg {
                    *counts.bucket_mut(s) += ev.counts.diff(&snap);
                }
                snap = ev.counts;
                cur_seg = Some(*seg);
            }
            match *op {
                ScheduleOp::LoadInput { dst, input } => {
                    regs[dst] = Some(inputs[input].clone());
                }
                ScheduleOp::Rotate { dst, src, step } => {
                    let r = ev.rotate(regs[src].as_ref().expect("reg"), step, gk);
                    regs[dst] = Some(r);
                }
                ScheduleOp::Hoist { src } => {
                    let digits = ev.hoist(regs[src].as_ref().expect("reg"));
                    hoists.insert(src, digits);
                }
                ScheduleOp::RotateHoisted { dst, src, step }
                | ScheduleOp::ExtractScore {
                    dst,
                    src,
                    slot: step,
                } => {
                    let digits = hoists.get(&src).expect("hoisted register");
                    let r = ev.rotate_hoisted(regs[src].as_ref().expect("reg"), digits, step, gk);
                    regs[dst] = Some(r);
                }
                ScheduleOp::AddAssign { dst, src } => {
                    let (d, s) = two_regs(&mut regs, dst, src);
                    // Same-schedule-point scales differ by < 1e-9
                    // relative; adopt the accumulator's (the legacy
                    // accumulator discipline).
                    s.scale = d.scale;
                    ev.add_inplace(d, s);
                }
                ScheduleOp::SubPlain { reg, operand } => {
                    let (level, scale) = {
                        let ct = regs[reg].as_ref().expect("reg");
                        (ct.level, ct.scale)
                    };
                    let pt = self.cached_encode(
                        &ev.ctx,
                        enc,
                        operand_cache_id(operand),
                        self.model.operand_slots(operand),
                        level,
                        scale,
                    );
                    ev.sub_plain_inplace(regs[reg].as_mut().expect("reg"), &pt);
                }
                ScheduleOp::AddPlain { reg, operand } => {
                    let (level, scale) = {
                        let ct = regs[reg].as_ref().expect("reg");
                        (ct.level, ct.scale)
                    };
                    let pt = self.cached_encode(
                        &ev.ctx,
                        enc,
                        operand_cache_id(operand),
                        self.model.operand_slots(operand),
                        level,
                        scale,
                    );
                    ev.add_plain_inplace(regs[reg].as_mut().expect("reg"), &pt);
                }
                ScheduleOp::MulPlainCached { dst, src, operand } => {
                    let level = regs[src].as_ref().expect("reg").level;
                    let pt = self.cached_encode(
                        &ev.ctx,
                        enc,
                        operand_cache_id(operand),
                        self.model.operand_slots(operand),
                        level,
                        delta,
                    );
                    let r = ev.mul_plain(regs[src].as_ref().expect("reg"), &pt);
                    regs[dst] = Some(r);
                }
                ScheduleOp::AddConst { reg, value } => {
                    let (level, scale) = {
                        let ct = regs[reg].as_ref().expect("reg");
                        (ct.level, ct.scale)
                    };
                    let pt = enc.encode_constant(&ev.ctx, value, level, scale);
                    ev.add_plain_inplace(regs[reg].as_mut().expect("reg"), &pt);
                }
                ScheduleOp::Rescale { reg } => {
                    ev.rescale(regs[reg].as_mut().expect("reg"));
                }
                ScheduleOp::PolyActivation { dst, src } => {
                    let r = ev.eval_poly_power_basis(
                        enc,
                        regs[src].as_ref().expect("reg"),
                        &self.model.act_coeffs,
                        rlk,
                    );
                    regs[dst] = Some(r);
                }
                ScheduleOp::RotateSumGrouped { dst, src, span } => {
                    let r = ev.rotate_sum(regs[src].as_ref().expect("reg"), span, gk);
                    regs[dst] = Some(r);
                }
            }
        }
        if let Some(s) = cur_seg {
            *counts.bucket_mut(s) += ev.counts.diff(&snap);
        }
        (regs, counts)
    }

    /// Evaluate the HRF on an encrypted input. Returns one ciphertext
    /// per class (score in slot 0) plus per-layer op counts.
    ///
    /// Thin wrapper over the compiled `B = 1` schedule. Key material
    /// (`rlk`, `gk`) belongs to the client session.
    pub fn eval(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        ct_in: &Ciphertext,
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        let sched = self.schedule(1, true);
        let (mut regs, counts) =
            self.run_schedule(&sched, ev, enc, std::slice::from_ref(ct_in), rlk, gk);
        // B=1 outputs reference one distinct register per class.
        let outs = sched
            .outputs
            .iter()
            .map(|r| regs[r.reg].take().expect("output register"))
            .collect();
        (outs, counts)
    }

    /// Evaluate a packed group of `B` fresh single-sample ciphertexts
    /// under the **legacy slot-0 contract**: combine, run the pipeline
    /// once, extract each sample's per-class scores back to slot 0
    /// (hoisted rotations). Returns one `Vec<Ciphertext>` (length C,
    /// score in slot 0) per input sample.
    ///
    /// The folded variant ([`HrfServer::eval_batch_folded`]) skips the
    /// `C·(B−1)` extraction rotations entirely — prefer it wherever
    /// the caller can address a slot.
    pub fn eval_batch(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Vec<Ciphertext>>, LayerCounts) {
        assert!(!cts.is_empty() && cts.len() <= self.model.plan.groups);
        let sched = self.schedule(cts.len(), false);
        let (mut regs, counts) = self.run_schedule(&sched, ev, enc, cts, rlk, gk);
        // Unfolded outputs name one distinct register per (class,
        // sample) — move each out, class-major order per sample.
        let mut per_sample: Vec<Vec<Ciphertext>> = (0..cts.len()).map(|_| Vec::new()).collect();
        for r in &sched.outputs {
            per_sample[r.sample].push(regs[r.reg].take().expect("output register"));
        }
        (per_sample, counts)
    }

    /// Evaluate a packed group with the extraction **folded** into the
    /// layer-3 reduction: one ciphertext per class is returned, with
    /// sample `g`'s score at `plan.score_slot(g)` — exactly `C·(B−1)`
    /// fewer rotations than [`HrfServer::eval_batch`]. Pair each
    /// caller's response with its score slot via [`EncScores`].
    pub fn eval_batch_folded(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        assert!(!cts.is_empty() && cts.len() <= self.model.plan.groups);
        let sched = self.schedule(cts.len(), true);
        let (mut regs, counts) = self.run_schedule(&sched, ev, enc, cts, rlk, gk);
        // A folded schedule's C·B outputs alias C class registers —
        // move each distinct register out once (no per-sample clones;
        // sample g reads its score from slot `plan.score_slot(g)`).
        let per_class = sched
            .outputs
            .iter()
            .filter(|r| r.sample == 0)
            .map(|r| regs[r.reg].take().expect("output register"))
            .collect();
        (per_class, counts)
    }

    /// Combine `B ≤ plan.groups` *fresh single-sample* ciphertexts
    /// (each observation packed in group 0, all remaining slots zero,
    /// identical level & scale) into one group-packed ciphertext:
    /// sample `g` is right-shifted into group `g` and the shifts are
    /// summed. Costs `B−1` rotations + `B−1` additions — far below one
    /// full evaluation, which is what makes server-side batching pay.
    ///
    /// This is the stand-alone form of the compiled schedule's `Pack`
    /// segment (the equivalence is pinned by a unit test below); the
    /// session's Galois keys must cover the placement steps in
    /// [`HrfServer::eval_key_requirements`].
    pub fn pack_group(
        &self,
        ev: &mut Evaluator,
        cts: &[Ciphertext],
        gk: &GaloisKeys,
    ) -> Ciphertext {
        let p = &self.model.plan;
        assert!(!cts.is_empty() && cts.len() <= p.groups);
        let mut acc = cts[0].clone();
        for (g, ct) in cts.iter().enumerate().skip(1) {
            // Left-rotation by slots − g·span == right-shift by g·span:
            // slot g·span + j of the result reads slot j of the input.
            let placed = ev.rotate(ct, p.slots - g * p.reduce_span, gk);
            ev.add_inplace(&mut acc, &placed);
        }
        acc
    }

    /// Rotate sample `g`'s score (slot `plan.score_slot(g)`) back to
    /// slot 0 — the legacy-contract helper the unfolded schedule's
    /// `Extract` segment mirrors (the folded protocol never calls it).
    pub fn extract_sample(
        &self,
        ev: &mut Evaluator,
        ct: &Ciphertext,
        g: usize,
        gk: &GaloisKeys,
    ) -> Ciphertext {
        let slot = self.model.plan.score_slot(g);
        if slot == 0 {
            ct.clone()
        } else {
            ev.rotate(ct, slot, gk)
        }
    }

    /// Rotation steps a session must cover in its registered Galois
    /// keys to use this server with packed groups of up to `b` samples
    /// (`b ≤ 1` is the single-sample set) — what a client should
    /// generate for registration *and* re-registration after a
    /// `SubmitError::KeysEvicted`.
    ///
    /// Derived from the compiled **folded** schedule's op list, so it
    /// contains no extraction steps — smaller key uploads and key-cache
    /// footprints than the legacy `rotations_needed_batched` set.
    pub fn eval_key_requirements(&self, b: usize) -> Vec<usize> {
        self.schedule(b.max(1), true).rotation_steps().into_iter().collect()
    }

    /// Whether `gk` holds every Galois key the folded `b`-sample
    /// schedule needs (schedule-derived; a stale or single-sample key
    /// set makes the coordinator fall back to smaller chunks or
    /// per-request evaluation).
    pub fn can_batch(&self, gk: &GaloisKeys, b: usize) -> bool {
        self.schedule(b, true)
            .rotation_steps()
            .iter()
            .all(|r| gk.keys.contains_key(r))
    }

    /// Dry-run Table-1 prediction for a `b`-sample batch — the op
    /// counts executing the compiled schedule will produce, derived
    /// from the schedule itself rather than hand formulas.
    pub fn predicted_counts(&self, b: usize, fold: bool) -> LayerCounts {
        self.schedule(b, fold).predicted_counts()
    }

    /// The pre-schedule hand-written evaluation, retained verbatim as
    /// the bit-identity oracle for the compiled path (see
    /// `tests/schedule_props.rs`) and the legacy baseline in
    /// `benches/table1_opcounts.rs`.
    pub fn eval_reference(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        ct_in: &Ciphertext,
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Ciphertext>, LayerCounts) {
        let m = &self.model;
        let p = &m.plan;
        let delta = ev.ctx.params.scale;
        let mut counts = LayerCounts::default();
        let snap0 = ev.counts;

        // ---- Layer 1: u = P(x̃ − t̃) --------------------------------
        let t_pt = self.cached_encode(&ev.ctx, enc, PT_T, &m.t_slots, ct_in.level, ct_in.scale);
        let mut diff = ct_in.clone();
        ev.sub_plain_inplace(&mut diff, &t_pt);
        counts.layer1 = ev.counts.diff(&snap0);
        let act0 = ev.counts;
        let u = ev.eval_poly_power_basis(enc, &diff, &m.act_coeffs, rlk);
        counts.activations = ev.counts.diff(&act0);
        let snap1 = ev.counts;

        // ---- Layer 2: Algorithm 1 (packed diagonal matmul) ---------
        // acc = Σ_j diag_j ⊙ rot(u, j), products kept at scale u.scale·Δ,
        // single rescale at the end, then + b̃ and activation.
        // All K−1 rotations share the input u → hoist its key-switch
        // decomposition once (§Perf step 3).
        let hoisted = ev.hoist(&u);
        let mut acc: Option<Ciphertext> = None;
        for (j, diag) in m.diag_slots.iter().enumerate() {
            let rotated = if j == 0 {
                u.clone()
            } else {
                ev.rotate_hoisted(&u, &hoisted, j, gk)
            };
            let d_pt = self.cached_encode(
                &ev.ctx,
                enc,
                PT_DIAG0 + j as u32,
                diag,
                rotated.level,
                delta,
            );
            let mut term = ev.mul_plain(&rotated, &d_pt);
            match &mut acc {
                None => acc = Some(term),
                Some(a) => {
                    term.scale = a.scale;
                    ev.add_inplace(a, &term);
                }
            }
        }
        let mut lin = acc.expect("K >= 1 diagonals");
        ev.rescale(&mut lin);
        let b_pt = self.cached_encode(&ev.ctx, enc, PT_B, &m.b_slots, lin.level, lin.scale);
        ev.add_plain_inplace(&mut lin, &b_pt);
        counts.layer2 = ev.counts.diff(&snap1);
        let act1 = ev.counts;
        let v = ev.eval_poly_power_basis(enc, &lin, &m.act_coeffs, rlk);
        counts.activations += ev.counts.diff(&act1);
        let snap2 = ev.counts;

        // ---- Layer 3: Algorithm 2 per class ------------------------
        // The rotate-and-sum spans one sample group (`reduce_span`),
        // NOT the whole ciphertext: slot g·span accumulates exactly
        // group g's masked slots, so packed samples stay independent.
        let mut outputs = Vec::with_capacity(p.c);
        for ci in 0..p.c {
            let w_pt = self.cached_encode(
                &ev.ctx,
                enc,
                PT_W0 + ci as u32,
                &m.w_slots[ci],
                v.level,
                delta,
            );
            let mut masked = ev.mul_plain(&v, &w_pt);
            ev.rescale(&mut masked);
            let summed = ev.rotate_sum(&masked, p.reduce_span, gk);
            let beta_pt = enc.encode_constant(&ev.ctx, m.betas[ci], summed.level, summed.scale);
            let mut out = summed;
            ev.add_plain_inplace(&mut out, &beta_pt);
            outputs.push(out);
        }
        counts.layer3 = ev.counts.diff(&snap2);

        (outputs, counts)
    }

    /// Legacy eval+extract batch path (pack → [`eval_reference`] →
    /// per-sample slot-0 extraction with plain rotations) — the
    /// baseline the folded schedule is measured against.
    ///
    /// [`eval_reference`]: HrfServer::eval_reference
    pub fn eval_batch_reference(
        &self,
        ev: &mut Evaluator,
        enc: &Encoder,
        cts: &[Ciphertext],
        rlk: &RelinKey,
        gk: &GaloisKeys,
    ) -> (Vec<Vec<Ciphertext>>, LayerCounts) {
        if cts.len() == 1 {
            let (outs, counts) = self.eval_reference(ev, enc, &cts[0], rlk, gk);
            return (vec![outs], counts);
        }
        let packed = self.pack_group(ev, cts, gk);
        let (outs, counts) = self.eval_reference(ev, enc, &packed, rlk, gk);
        let per_sample = (0..cts.len())
            .map(|g| {
                outs.iter()
                    .map(|class_ct| self.extract_sample(ev, class_ct, g, gk))
                    .collect()
            })
            .collect();
        (per_sample, counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::rns::CkksContext;
    use crate::ckks::{CkksParams, Decryptor, Encryptor, KeyGenerator};
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::hrf::client::{reshuffle_and_pack, HrfClient};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    /// Full small-scale end-to-end: train, pack, encrypt, evaluate
    /// (compiled schedule), decrypt, compare with the plaintext slot
    /// model AND the retained hand-written reference path.
    #[test]
    fn hrf_eval_matches_plain_slot_model() {
        let ds = adult::generate(1_500, 81);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 6,
                tree: crate::forest::tree::TreeConfig {
                    max_depth: 3,
                    ..Default::default()
                },
                ..Default::default()
            },
            82,
        );
        let params = std::sync::Arc::new(CkksParams::build(
            "test-n8192-d8",
            8192,
            60,
            40,
            8,
            3.2,
        ));
        let ctx = CkksContext::new(params);
        let enc = Encoder::new(&ctx);

        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), ctx.n() / 2).unwrap();
        let plan = hm.plan;

        let mut kg = KeyGenerator::new(&ctx, 83);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
        let mut client = HrfClient::new(
            Encryptor::new(pk, 84),
            Decryptor::new(kg.secret_key()),
        );
        let server = HrfServer::new(hm);
        let mut ev = Evaluator::new(ctx.clone());

        for x in ds.x.iter().take(3) {
            let ct = client.encrypt_input(&ctx, &enc, &server.model, x);
            let (outs, counts) = server.eval(&mut ev, &enc, &ct, &rlk, &gk);
            let (scores, _) = client.decrypt_scores(&ctx, &enc, &outs);
            let x_slots = reshuffle_and_pack(&server.model, x);
            let expect = server.model.forward_slots_plain(&x_slots);
            for (g, e) in scores.iter().zip(&expect) {
                assert!(
                    (g - e).abs() < 5e-3,
                    "HE deviates from plain slot model: {scores:?} vs {expect:?}"
                );
            }
            // Table 1 shape checks (layer 2: K muls, K-1 rotations).
            let [_, l2, l3] = counts.table1_rows();
            assert_eq!(l2.1, plan.k as u64, "layer2 multiplications");
            assert_eq!(l2.2, (plan.k - 1) as u64, "layer2 rotations");
            assert_eq!(l3.1, plan.c as u64, "layer3 multiplications");
            // Measured counts equal the schedule's dry-run prediction.
            assert_eq!(
                counts,
                server.predicted_counts(1, true),
                "dry-run prediction deviates from measured execution"
            );
        }

        // The compiled path is bit-identical to the reference path.
        let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[0]);
        let (a, _) = server.eval(&mut ev, &enc, &ct, &rlk, &gk);
        let (b, _) = server.eval_reference(&mut ev, &enc, &ct, &rlk, &gk);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.level, y.level);
            assert_eq!(x.scale.to_bits(), y.scale.to_bits());
            assert_eq!(x.c0.limbs, y.c0.limbs, "c0 deviates from reference");
            assert_eq!(x.c1.limbs, y.c1.limbs, "c1 deviates from reference");
        }
    }

    #[test]
    fn pack_segment_matches_pack_group_rotations() {
        // The stand-alone pack_group helper and the schedule's Pack
        // segment must perform the same placement rotations in the
        // same order.
        let ds = adult::generate(400, 85);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                ..Default::default()
            },
            86,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 2048).unwrap();
        let p = hm.plan;
        assert!(p.groups >= 3);
        let server = HrfServer::new(hm);
        let sched = server.schedule(3, true);
        let pack_steps: Vec<usize> = sched
            .ops
            .iter()
            .filter_map(|(seg, op)| match (seg, op) {
                (Segment::Pack, ScheduleOp::Rotate { step, .. }) => Some(*step),
                _ => None,
            })
            .collect();
        let expect: Vec<usize> = (1..3).map(|g| p.slots - g * p.reduce_span).collect();
        assert_eq!(pack_steps, expect);
    }

    #[test]
    fn key_requirements_are_schedule_derived_and_extraction_free() {
        let ds = adult::generate(400, 87);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                ..Default::default()
            },
            88,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 2048).unwrap();
        let p = hm.plan;
        let server = HrfServer::new(hm);
        for b in 1..=p.groups.min(4) {
            let req = server.eval_key_requirements(b);
            let hand = p.rotations_needed_batched(b);
            // Schedule-derived ⊆ hand formula, and the dropped steps
            // are exactly the extraction rotations g·span.
            for r in &req {
                assert!(hand.contains(r), "requirement {r} outside hand set");
            }
            for &r in &hand {
                if req.contains(&r) {
                    continue;
                }
                assert_eq!(r % p.reduce_span, 0, "dropped non-extraction step {r}");
            }
        }
    }
}
