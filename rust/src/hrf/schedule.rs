//! Compiled HE op schedules for the HRF pipeline: a small homomorphic
//! program IR, the `HrfPlan` → schedule compiler, and a dry-run
//! interpreter.
//!
//! # Why compile?
//!
//! `HrfServer::eval` used to be a hand-written monolith whose rotation
//! and key requirements were duplicated by hand in `HrfPlan`
//! (`eval_rotations` / `batch_rotations` / …) — a drift-prone parallel
//! structure. Batched tree-ensemble HE systems instead compile
//! inference into an explicit homomorphic program and derive
//! everything else (key sets, op counts, cost models) from that single
//! artifact. [`HrfSchedule`] is that artifact here, and since the
//! engine refactor it is executed by exactly **one** interpreter —
//! [`Engine::run`](crate::runtime::engine::Engine::run) — against
//! pluggable [`ScheduleBackend`](crate::runtime::engine::ScheduleBackend)s:
//!
//! * the **CKKS backend** (`runtime::engine::CkksBackend`, driven by
//!   `HrfServer::execute`) replays the ops against the homomorphic
//!   [`Evaluator`](crate::ckks::evaluator::Evaluator);
//! * the **slot backend** (`runtime::engine::SlotBackend`, driving
//!   `runtime::slot_model`) runs the very same op list over f32 slot
//!   vectors, so the python↔rust golden parity holds by construction
//!   — both sides run one program;
//! * the **counting backend** makes [`HrfSchedule::rotation_steps`]
//!   (Galois-key requirements) and [`HrfSchedule::predicted_counts`]
//!   (Table-1 predictions) dry-run replays of the op list instead of
//!   hand-maintained formulas. The old `HrfPlan` formulas are retained
//!   only as cross-check tests.
//!
//! Peephole transforms are [`SchedulePass`]es applied through
//! [`HrfSchedule::optimize`]; because execution is centralized, a pass
//! is written once and holds on every backend.
//!
//! # The IR
//!
//! A schedule is a straight-line register program (`Vec<(Segment,
//! ScheduleOp)>`): ops read/write virtual registers holding one
//! ciphertext each. There is no control flow — the HRF pipeline is a
//! fixed DAG per batch size `B`, so loops are unrolled at compile
//! time. Each op is tagged with the [`Segment`] (pack / layer /
//! activation / extract) it belongs to, which is how the executor
//! rebuilds the per-layer [`LayerCounts`](super::server::LayerCounts)
//! of the paper's Table 1.
//!
//! # The extraction fold (rotation-count reduction)
//!
//! For a packed batch of `B > 1` samples the legacy path ran the
//! group-local layer-3 reduction (scores landing at
//! `plan.score_slot(g) = g·reduce_span`) and then spent one extraction
//! rotation per (class, sample) to move each score back to slot 0 —
//! `C·(B−1)` key-switches per batch.
//!
//! The folding transform applied by [`HrfSchedule::compile`] with
//! `fold = true` uses the rewrite
//!
//! ```text
//!   Read(Rotate(x, r), slot 0)  ≡  Read(x, slot r)
//! ```
//!
//! the extraction rotation of sample `g` composed with the slot-0 read
//! is just a slot-`g·span` read of the reduction's own output, so the
//! final step of each group's rotate-and-sum *already holds* every
//! sample's score. The folded schedule therefore emits **no** physical
//! `ExtractScore` ops; instead each output ([`ScoreRef`]) records the
//! slot carrying its score, and the response contract carries that
//! slot to the client (`EncScores::slot` →
//! `HrfClient::decrypt_scores_at`). Net effect: exactly `C·(B−1)`
//! fewer key-switch rotations than eval+extract, verified op-for-op in
//! `tests/schedule_props.rs` and reported by
//! `benches/table1_opcounts.rs`.
//!
//! The unfolded schedule (`fold = false`) keeps the legacy slot-0
//! contract: it appends an `Extract` segment that hoists each class's
//! summed ciphertext once and replays the `g·span` rotations as
//! [`ScheduleOp::ExtractScore`] ops (hoisted key-switches — cheaper in
//! wall time than the legacy per-rotation decomposition, same count).
//!
//! # Key-requirement derivation
//!
//! [`HrfSchedule::rotation_steps`] walks the op list and collects
//! every rotation amount (expanding `RotateSumGrouped` into its
//! power-of-two step chain). `HrfServer::eval_key_requirements` and
//! `HrfServer::can_batch` are defined on top of the *folded* schedule,
//! so clients no longer generate (and the key cache no longer pays
//! for) Galois keys for extraction steps the folded path never takes.

use super::pack::HrfModel;
use super::server::LayerCounts;
use crate::ckks::evaluator::OpCounts;
use crate::runtime::engine::{CountingBackend, Engine, SchedulePass};
use std::collections::BTreeSet;
use std::fmt;

/// Virtual register index (one ciphertext per register).
pub type Reg = usize;

/// A model operand resolved against [`HrfModel`] at execution time
/// (the executor encodes it at the consuming op's level/scale through
/// the server's plaintext cache).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlainOperand {
    /// Replicated threshold vector `t̃` (layer 1).
    Thresholds,
    /// Leaf-bias vector `b̃` (layer 2).
    Biases,
    /// Generalized diagonal `j` of the packed `V` matrices (layer 2).
    Diag(usize),
    /// Per-class output mask `W̃_c` (layer 3).
    ClassWeights(usize),
}

/// Pipeline stage an op belongs to — drives per-layer op accounting.
/// Ordered and hashable so observability tables (`crate::obs`) can
/// key on it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Segment {
    /// Server-side placement of B fresh single-sample ciphertexts.
    Pack,
    /// Layer 1: `x̃ − t̃`.
    Layer1,
    /// First activation `P(·)`.
    Act1,
    /// Layer 2: Algorithm 1 diagonal matmul + bias.
    Layer2,
    /// Second activation `P(·)`.
    Act2,
    /// Layer 3: per-class mask, grouped reduce, output bias.
    Layer3,
    /// Legacy slot-0 extraction (absent from folded schedules).
    Extract,
}

/// One step of the homomorphic program.
#[derive(Clone, Copy, Debug)]
pub enum ScheduleOp {
    /// `r[dst] := inputs[input]`.
    LoadInput { dst: Reg, input: usize },
    /// `r[dst] := rot(r[src], step)` — plain key-switch rotation.
    Rotate { dst: Reg, src: Reg, step: usize },
    /// Precompute `r[src]`'s key-switch decomposition for subsequent
    /// `RotateHoisted` / `ExtractScore` ops on the same register.
    Hoist { src: Reg },
    /// `r[dst] := rot(r[src], step)` using `src`'s hoisted digits.
    RotateHoisted { dst: Reg, src: Reg, step: usize },
    /// `r[dst] += r[src]` (ct+ct; `src` adopts `dst`'s scale, matching
    /// the legacy accumulator discipline).
    AddAssign { dst: Reg, src: Reg },
    /// `r[reg] -= operand` (operand encoded at `r[reg]`'s scale).
    SubPlain { reg: Reg, operand: PlainOperand },
    /// `r[reg] += operand` (operand encoded at `r[reg]`'s scale).
    AddPlain { reg: Reg, operand: PlainOperand },
    /// `r[dst] := r[src] ⊙ operand` (operand encoded at scale Δ;
    /// resolved through the server's cached-plaintext store).
    MulPlainCached {
        dst: Reg,
        src: Reg,
        operand: PlainOperand,
    },
    /// `r[dst] := rescale(r[src] ⊙ operand)` — the fused form emitted
    /// by the `FuseMulRescale` pass: one backend invocation, metered
    /// as a single fused op, bit-identical to the unfused pair.
    MulPlainRescale {
        dst: Reg,
        src: Reg,
        operand: PlainOperand,
    },
    /// `r[reg] += value` (constant encoded at `r[reg]`'s scale).
    AddConst { reg: Reg, value: f64 },
    /// Rescale `r[reg]` by the top chain prime (drops one level).
    Rescale { reg: Reg },
    /// `r[dst] := P(r[src])` — the model's activation polynomial,
    /// evaluated with the power-basis method.
    PolyActivation { dst: Reg, src: Reg },
    /// `r[dst] := group-local rotate-and-sum of r[src]` over `span`
    /// (`log₂ span` rotate+add steps; slot `g·span` of the result
    /// holds group `g`'s total).
    RotateSumGrouped { dst: Reg, src: Reg, span: usize },
    /// `r[dst] := rot(r[src], slot)` — legacy slot-0 score extraction
    /// (hoisted; only emitted by unfolded schedules).
    ExtractScore { dst: Reg, src: Reg, slot: usize },
}

/// Where one (class, sample) score lives after execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScoreRef {
    pub class: usize,
    pub sample: usize,
    /// Register holding the score ciphertext.
    pub reg: Reg,
    /// Slot of that register carrying the score (0 unless the
    /// extraction was folded into the grouped reduction).
    pub slot: usize,
}

/// A compiled HRF evaluation for one batch size.
#[derive(Clone, Debug)]
pub struct HrfSchedule {
    /// Batch size this schedule packs and scores.
    pub b: usize,
    /// Whether extraction was folded into the grouped reduction.
    pub folded: bool,
    /// Group span of the layer-3 reduction.
    pub span: usize,
    /// Number of virtual registers the executor must allocate.
    pub n_regs: usize,
    pub ops: Vec<(Segment, ScheduleOp)>,
    /// One entry per (class, sample), class-major.
    pub outputs: Vec<ScoreRef>,
    /// Dry-run op counts of one activation-polynomial evaluation
    /// (computed once at compile time from the model's coefficients).
    pub act_counts: OpCounts,
}

// Fixed register layout (see `compile`); per-class registers follow.
const R_IN: Reg = 0;
const R_U: Reg = 1;
const R_ACC: Reg = 2;
const R_TMP: Reg = 3;
const R_V: Reg = 4;
const R_PACK: Reg = 5;
const R_CLASS0: Reg = 6;

impl HrfSchedule {
    /// Compile the HRF pipeline for a packed batch of `b ≤ plan.groups`
    /// samples. With `fold = true` the per-sample extraction rotations
    /// are folded into the layer-3 reduction (outputs become
    /// slot-addressed); with `fold = false` an `Extract` segment
    /// restores the legacy slot-0 contract. `b = 1` needs no
    /// extraction either way and compiles to the same program.
    pub fn compile(model: &HrfModel, b: usize, fold: bool) -> Self {
        let p = &model.plan;
        let b = b.clamp(1, p.groups);
        let fold = fold || b == 1;
        let c = p.c;
        let mut ops: Vec<(Segment, ScheduleOp)> = Vec::new();
        let mut outputs: Vec<ScoreRef> = Vec::new();

        // ---- Pack: place sample g in group g, sum ------------------
        ops.push((Segment::Pack, ScheduleOp::LoadInput { dst: R_IN, input: 0 }));
        for g in 1..b {
            ops.push((
                Segment::Pack,
                ScheduleOp::LoadInput {
                    dst: R_PACK,
                    input: g,
                },
            ));
            ops.push((
                Segment::Pack,
                ScheduleOp::Rotate {
                    dst: R_PACK,
                    src: R_PACK,
                    step: p.slots - g * p.reduce_span,
                },
            ));
            ops.push((
                Segment::Pack,
                ScheduleOp::AddAssign {
                    dst: R_IN,
                    src: R_PACK,
                },
            ));
        }

        // ---- Layer 1: u = P(x̃ − t̃) --------------------------------
        ops.push((
            Segment::Layer1,
            ScheduleOp::SubPlain {
                reg: R_IN,
                operand: PlainOperand::Thresholds,
            },
        ));
        ops.push((
            Segment::Act1,
            ScheduleOp::PolyActivation { dst: R_U, src: R_IN },
        ));

        // ---- Layer 2: Algorithm 1 (hoisted diagonal matmul) --------
        if p.k > 1 {
            ops.push((Segment::Layer2, ScheduleOp::Hoist { src: R_U }));
        }
        ops.push((
            Segment::Layer2,
            ScheduleOp::MulPlainCached {
                dst: R_ACC,
                src: R_U,
                operand: PlainOperand::Diag(0),
            },
        ));
        for j in 1..p.k {
            ops.push((
                Segment::Layer2,
                ScheduleOp::RotateHoisted {
                    dst: R_TMP,
                    src: R_U,
                    step: j,
                },
            ));
            ops.push((
                Segment::Layer2,
                ScheduleOp::MulPlainCached {
                    dst: R_TMP,
                    src: R_TMP,
                    operand: PlainOperand::Diag(j),
                },
            ));
            ops.push((
                Segment::Layer2,
                ScheduleOp::AddAssign {
                    dst: R_ACC,
                    src: R_TMP,
                },
            ));
        }
        ops.push((Segment::Layer2, ScheduleOp::Rescale { reg: R_ACC }));
        ops.push((
            Segment::Layer2,
            ScheduleOp::AddPlain {
                reg: R_ACC,
                operand: PlainOperand::Biases,
            },
        ));
        ops.push((
            Segment::Act2,
            ScheduleOp::PolyActivation {
                dst: R_V,
                src: R_ACC,
            },
        ));

        // ---- Layer 3: per-class mask + grouped reduce + bias -------
        for ci in 0..c {
            let rc = R_CLASS0 + ci;
            ops.push((
                Segment::Layer3,
                ScheduleOp::MulPlainCached {
                    dst: rc,
                    src: R_V,
                    operand: PlainOperand::ClassWeights(ci),
                },
            ));
            ops.push((Segment::Layer3, ScheduleOp::Rescale { reg: rc }));
            ops.push((
                Segment::Layer3,
                ScheduleOp::RotateSumGrouped {
                    dst: rc,
                    src: rc,
                    span: p.reduce_span,
                },
            ));
            ops.push((
                Segment::Layer3,
                ScheduleOp::AddConst {
                    reg: rc,
                    value: model.betas[ci],
                },
            ));
        }

        // ---- Outputs (folded: slot-addressed; else Extract segment) -
        let mut n_regs = R_CLASS0 + c;
        if fold {
            for ci in 0..c {
                for g in 0..b {
                    outputs.push(ScoreRef {
                        class: ci,
                        sample: g,
                        reg: R_CLASS0 + ci,
                        slot: p.score_slot(g),
                    });
                }
            }
        } else {
            for ci in 0..c {
                let rc = R_CLASS0 + ci;
                outputs.push(ScoreRef {
                    class: ci,
                    sample: 0,
                    reg: rc,
                    slot: 0,
                });
                ops.push((Segment::Extract, ScheduleOp::Hoist { src: rc }));
                for g in 1..b {
                    let re = n_regs;
                    n_regs += 1;
                    ops.push((
                        Segment::Extract,
                        ScheduleOp::ExtractScore {
                            dst: re,
                            src: rc,
                            slot: p.score_slot(g),
                        },
                    ));
                    outputs.push(ScoreRef {
                        class: ci,
                        sample: g,
                        reg: re,
                        slot: 0,
                    });
                }
            }
        }

        HrfSchedule {
            b,
            folded: fold,
            span: p.reduce_span,
            n_regs,
            ops,
            outputs,
            act_counts: poly_op_counts(&model.act_coeffs),
        }
    }

    /// Apply `passes` in order and return the optimized schedule.
    /// Passes preserve the register dataflow and the output slot
    /// addressing (pinned by the cross-backend parity tests); the
    /// derived key requirements and op-count predictions below stay
    /// truthful automatically because they replay the *transformed*
    /// op list.
    pub fn optimize(mut self, passes: &[Box<dyn SchedulePass>]) -> Self {
        for p in passes {
            p.run(&mut self);
        }
        self
    }

    /// Variant for executors that receive the whole batch as **one
    /// pre-packed slot vector** (input 0): the `Pack` segment's
    /// placement rotations would only shift all-zero vectors, so they
    /// are dropped and just the input load is kept. Register and
    /// output addressing are unchanged — on such inputs this is a pure
    /// dead-op elimination (the slot model applies it to its cached
    /// full-capacity schedule).
    pub fn assume_prepacked(mut self) -> Self {
        self.ops.retain(|(seg, op)| {
            *seg != Segment::Pack || matches!(op, ScheduleOp::LoadInput { input: 0, .. })
        });
        self
    }

    /// Every rotation step the schedule performs — the session's
    /// Galois keys must cover exactly this set. Derived by replaying
    /// the op list on the dry-run [`CountingBackend`] (the hand
    /// formulas in `HrfPlan` survive only as a cross-check test).
    pub fn rotation_steps(&self) -> BTreeSet<usize> {
        let mut backend = CountingBackend::new(self.act_counts);
        Engine::run(self, &mut backend);
        backend.into_rotation_steps()
    }

    /// Dry-run interpretation: the per-layer op counts executing this
    /// schedule will produce, without touching a ciphertext — one
    /// [`Engine::run`] over the [`CountingBackend`]. The CKKS
    /// executor's measured counts match these exactly (asserted in
    /// `tests/schedule_props.rs`), which is what lets Table 1 be
    /// *predicted* from the compiled program.
    pub fn predicted_counts(&self) -> LayerCounts {
        let mut backend = CountingBackend::new(self.act_counts);
        Engine::run(self, &mut backend).counts
    }

    /// Total predicted key-switch rotations for one execution.
    pub fn predicted_rotations(&self) -> u64 {
        self.predicted_counts().total().rotate
    }
}

impl fmt::Display for PlainOperand {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlainOperand::Thresholds => write!(f, "t̃"),
            PlainOperand::Biases => write!(f, "b̃"),
            PlainOperand::Diag(j) => write!(f, "diag[{j}]"),
            PlainOperand::ClassWeights(c) => write!(f, "W̃[{c}]"),
        }
    }
}

impl fmt::Display for HrfSchedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "HrfSchedule {{ B={}, folded={}, span={}, regs={}, ops={} }}",
            self.b,
            self.folded,
            self.span,
            self.n_regs,
            self.ops.len()
        )?;
        let mut cur: Option<Segment> = None;
        for (seg, op) in &self.ops {
            if cur != Some(*seg) {
                writeln!(f, "  -- {seg:?} --")?;
                cur = Some(*seg);
            }
            match *op {
                ScheduleOp::LoadInput { dst, input } => {
                    writeln!(f, "    r{dst} <- input[{input}]")?
                }
                ScheduleOp::Rotate { dst, src, step } => {
                    writeln!(f, "    r{dst} <- rot(r{src}, {step})")?
                }
                ScheduleOp::Hoist { src } => writeln!(f, "    hoist r{src}")?,
                ScheduleOp::RotateHoisted { dst, src, step } => {
                    writeln!(f, "    r{dst} <- rot_hoisted(r{src}, {step})")?
                }
                ScheduleOp::AddAssign { dst, src } => writeln!(f, "    r{dst} += r{src}")?,
                ScheduleOp::SubPlain { reg, operand } => writeln!(f, "    r{reg} -= {operand}")?,
                ScheduleOp::AddPlain { reg, operand } => writeln!(f, "    r{reg} += {operand}")?,
                ScheduleOp::MulPlainCached { dst, src, operand } => {
                    writeln!(f, "    r{dst} <- r{src} * {operand}")?
                }
                ScheduleOp::MulPlainRescale { dst, src, operand } => {
                    writeln!(f, "    r{dst} <- rescale(r{src} * {operand})  [fused]")?
                }
                ScheduleOp::AddConst { reg, value } => writeln!(f, "    r{reg} += {value:.6}")?,
                ScheduleOp::Rescale { reg } => writeln!(f, "    rescale r{reg}")?,
                ScheduleOp::PolyActivation { dst, src } => {
                    writeln!(f, "    r{dst} <- P(r{src})")?
                }
                ScheduleOp::RotateSumGrouped { dst, src, span } => {
                    writeln!(f, "    r{dst} <- rotate_sum_grouped(r{src}, span {span})")?
                }
                ScheduleOp::ExtractScore { dst, src, slot } => {
                    writeln!(f, "    r{dst} <- rot_hoisted(r{src}, {slot})  [extract]")?
                }
            }
        }
        for o in &self.outputs {
            writeln!(
                f,
                "  out class {} sample {} @ r{}[slot {}]",
                o.class, o.sample, o.reg, o.slot
            )?;
        }
        Ok(())
    }
}

/// Dry-run op counts of `Evaluator::eval_poly_power_basis` for the
/// given monomial coefficients — a faithful mirror of its power/Horner
/// selection logic (asserted against measured counts in
/// `tests/schedule_props.rs`).
pub fn poly_op_counts(coeffs: &[f64]) -> OpCounts {
    const EPS: f64 = 1e-12;
    let deg = coeffs
        .iter()
        .rposition(|c| c.abs() > EPS)
        .expect("all-zero polynomial");
    assert!(deg >= 1, "constant polynomial");
    let mut counts = OpCounts::default();
    if deg <= 2 {
        // Horner fallback: c_top mul_plain+rescale, c_next add_plain,
        // then (deg-1) iterations of mul+relin+rescale+add_plain.
        counts.mul_plain = 1;
        counts.rescale = deg as u64;
        counts.add_plain = deg as u64;
        counts.mul = (deg - 1) as u64;
        counts.relin = (deg - 1) as u64;
        return counts;
    }
    // Mirror of the power-basis "needed powers" marking.
    let mut needed = vec![false; deg + 1];
    for (i, c) in coeffs.iter().enumerate().skip(1).take(deg) {
        if c.abs() > EPS {
            needed[i] = true;
        }
    }
    for i in (2..=deg).rev() {
        if needed[i] && !i.is_power_of_two() {
            let hi = 1usize << (usize::BITS - 1 - i.leading_zeros());
            needed[hi] = true;
            needed[i - hi] = true;
        }
    }
    let max_p2 = (1..=deg)
        .filter(|i| needed[*i] && i.is_power_of_two())
        .max()
        .unwrap_or(1);
    {
        let mut p = max_p2;
        while p > 1 {
            needed[p] = true;
            p >>= 1;
        }
    }
    // Power-of-two squarings.
    let mut p = 2usize;
    while p <= deg {
        if needed[p] {
            counts.mul += 1;
            counts.relin += 1;
            counts.rescale += 1;
        }
        p <<= 1;
    }
    // Non-power-of-two products x^hi * x^(i-hi).
    for i in 3..=deg {
        if needed[i] && !i.is_power_of_two() {
            counts.mul += 1;
            counts.relin += 1;
            counts.rescale += 1;
        }
    }
    // Coefficient accumulation Σ c_i·x^i, then + c_0.
    let mut first = true;
    for c in coeffs.iter().take(deg + 1).skip(1) {
        if c.abs() <= EPS {
            continue;
        }
        counts.mul_plain += 1;
        counts.rescale += 1;
        if !first {
            counts.add += 1;
        }
        first = false;
    }
    counts.add_plain += 1;
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::{NeuralForest, NeuralTree};
    use crate::rng::Xoshiro256pp;

    fn synth_model(k: usize, l: usize, c: usize, slots: usize, seed: u64) -> HrfModel {
        let d = 8;
        let mut rng = Xoshiro256pp::new(seed);
        let trees = (0..l)
            .map(|_| NeuralTree {
                tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
                t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
                v: (0..k)
                    .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                    .collect(),
                b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
                w: (0..c)
                    .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                    .collect(),
                beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
                real_leaves: k,
                n_classes: c,
            })
            .collect();
        let nf = NeuralForest {
            trees,
            alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
            k,
            n_classes: c,
            activation: Activation::Poly {
                coeffs: chebyshev_fit_tanh(3.0, 4),
            },
        };
        HrfModel::from_neural_forest(&nf, d, slots).unwrap()
    }

    #[test]
    fn segments_appear_in_pipeline_order() {
        let hm = synth_model(8, 4, 2, 2048, 1);
        for (b, fold) in [(1usize, true), (3, true), (3, false)] {
            let s = HrfSchedule::compile(&hm, b, fold);
            let order = [
                Segment::Pack,
                Segment::Layer1,
                Segment::Act1,
                Segment::Layer2,
                Segment::Act2,
                Segment::Layer3,
                Segment::Extract,
            ];
            let mut last = 0usize;
            for (seg, _) in &s.ops {
                let idx = order.iter().position(|o| o == seg).unwrap();
                assert!(idx >= last, "segment {seg:?} out of order (B={b})");
                last = idx;
            }
        }
    }

    #[test]
    fn unfolded_rotation_steps_match_hand_formula() {
        // The retained HrfPlan formulas are the cross-check: the
        // unfolded schedule's derived step set must equal them exactly.
        let hm = synth_model(8, 5, 2, 4096, 2);
        let p = &hm.plan;
        for b in 1..=p.groups.min(5) {
            let sched = HrfSchedule::compile(&hm, b, false);
            let got: Vec<usize> = sched.rotation_steps().into_iter().collect();
            assert_eq!(
                got,
                p.rotations_needed_batched(b),
                "unfolded schedule B={b} deviates from the hand formula"
            );
        }
    }

    #[test]
    fn folded_drops_exactly_the_extraction_steps() {
        let hm = synth_model(8, 5, 2, 4096, 3);
        let p = &hm.plan;
        for b in 2..=p.groups.min(5) {
            let folded = HrfSchedule::compile(&hm, b, true);
            let unfolded = HrfSchedule::compile(&hm, b, false);
            let fs = folded.rotation_steps();
            let us = unfolded.rotation_steps();
            assert!(fs.is_subset(&us));
            // Everything dropped is an extraction step g·span.
            for step in us.difference(&fs) {
                assert_eq!(step % p.reduce_span, 0, "non-extraction step {step} dropped");
            }
            // Folded outputs are slot-addressed at the score slots.
            for o in &folded.outputs {
                assert_eq!(o.slot, p.score_slot(o.sample));
            }
            // Predicted rotation saving is exactly C·(B−1).
            assert_eq!(
                unfolded.predicted_rotations() - folded.predicted_rotations(),
                (p.c * (b - 1)) as u64
            );
            assert_eq!(folded.predicted_counts().extract, OpCounts::default());
        }
    }

    #[test]
    fn predicted_table1_shapes_match_paper() {
        let hm = synth_model(16, 6, 2, 4096, 4);
        let p = &hm.plan;
        let sched = HrfSchedule::compile(&hm, 1, true);
        let counts = sched.predicted_counts();
        let [l1, l2, l3] = counts.table1_rows();
        assert_eq!(l1, (1, 0, 0));
        assert_eq!(l2.1, p.k as u64, "layer2 multiplications = K");
        assert_eq!(l2.2, (p.k - 1) as u64, "layer2 rotations = K-1");
        let log_span = p.reduce_span.trailing_zeros() as u64;
        assert_eq!(l3.1, p.c as u64, "layer3 multiplications = C");
        assert_eq!(l3.2, p.c as u64 * log_span, "layer3 rotations");
    }

    #[test]
    fn poly_op_counts_shapes() {
        // deg 1 (identity-ish): Horner, one coeff mul.
        let c = poly_op_counts(&[0.0, 1.0]);
        assert_eq!((c.mul_plain, c.rescale, c.add_plain, c.mul), (1, 1, 1, 0));
        // deg 4 with all terms: x², x⁴, x³=x²·x ⇒ 3 ct-ct muls.
        let c = poly_op_counts(&[0.1, 0.7, -0.2, 0.05, -0.3]);
        assert_eq!(c.mul, 3);
        assert_eq!(c.mul_plain, 4);
        // Odd tanh fit: even coeffs ≈ 0 are skipped entirely.
        let c = poly_op_counts(&chebyshev_fit_tanh(3.0, 4));
        assert_eq!(c.mul_plain, 2, "only odd powers 1 and 3 have mass");
    }

    #[test]
    fn b1_schedule_is_fold_invariant_and_packs_nothing() {
        let hm = synth_model(8, 4, 2, 2048, 5);
        let a = HrfSchedule::compile(&hm, 1, true);
        let b = HrfSchedule::compile(&hm, 1, false);
        assert!(a.folded && b.folded, "B=1 normalizes to folded");
        assert_eq!(a.ops.len(), b.ops.len());
        assert_eq!(
            a.ops
                .iter()
                .filter(|(s, _)| *s == Segment::Pack)
                .count(),
            1,
            "B=1 pack segment is a single load"
        );
        assert_eq!(a.outputs.len(), hm.plan.c);
        assert!(a.outputs.iter().all(|o| o.slot == 0));
    }

    #[test]
    fn oversized_batch_is_clamped_to_groups() {
        let hm = synth_model(4, 3, 2, 1024, 6);
        let p = &hm.plan;
        let s = HrfSchedule::compile(&hm, p.groups + 7, true);
        assert_eq!(s.b, p.groups);
    }

    #[test]
    fn pack_segment_rotation_steps_match_placement_formula() {
        // The schedule's Pack segment must perform the same placement
        // rotations, in the same order, as the stand-alone
        // `HrfServer::pack_group` helper.
        let hm = synth_model(8, 4, 2, 2048, 7);
        let p = &hm.plan;
        assert!(p.groups >= 3);
        let sched = HrfSchedule::compile(&hm, 3, true);
        let pack_steps: Vec<usize> = sched
            .ops
            .iter()
            .filter_map(|(seg, op)| match (seg, op) {
                (Segment::Pack, ScheduleOp::Rotate { step, .. }) => Some(*step),
                _ => None,
            })
            .collect();
        let expect: Vec<usize> = (1..3).map(|g| p.slots - g * p.reduce_span).collect();
        assert_eq!(pack_steps, expect);
    }

    #[test]
    fn fuse_mul_rescale_shrinks_schedule_and_rebooks_counts() {
        use crate::runtime::engine::PassPipeline;
        let hm = synth_model(8, 4, 2, 2048, 8);
        let c = hm.plan.c;
        for (b, fold) in [(1usize, true), (3, true), (3, false)] {
            let raw = HrfSchedule::compile(&hm, b, fold);
            let fused = raw.clone().optimize(PassPipeline::standard().passes());
            // Layer 3's C (mask-mul, rescale) pairs fuse; layer 2's
            // lazy rescale (K > 1) has no adjacent pair.
            assert_eq!(raw.ops.len() - fused.ops.len(), c, "B={b} fold={fold}");
            let rc = raw.predicted_counts().total();
            let fc = fused.predicted_counts().total();
            assert_eq!(fc.fused_mul_rescale, c as u64);
            assert_eq!(rc.mul_plain - fc.mul_plain, c as u64);
            assert_eq!(rc.rescale - fc.rescale, c as u64);
            // Semantically invariant aggregates.
            assert_eq!(rc.multiplications(), fc.multiplications());
            assert_eq!(rc.rescales(), fc.rescales());
            assert_eq!(rc.rotate, fc.rotate);
            assert_eq!(rc.additions(), fc.additions());
            // Keys and output addressing are untouched.
            assert_eq!(raw.rotation_steps(), fused.rotation_steps());
            assert_eq!(raw.outputs, fused.outputs);
            assert_eq!(raw.n_regs, fused.n_regs);
        }
    }

    #[test]
    fn assume_prepacked_strips_only_placement_ops() {
        let hm = synth_model(8, 4, 2, 2048, 10);
        let b = hm.plan.groups.min(4);
        assert!(b >= 2);
        let full = HrfSchedule::compile(&hm, b, true);
        let stripped = full.clone().assume_prepacked();
        // Pack collapses to the single input load; everything else —
        // registers, outputs, layer ops — is untouched.
        assert_eq!(
            stripped
                .ops
                .iter()
                .filter(|(s, _)| *s == Segment::Pack)
                .count(),
            1
        );
        assert_eq!(full.ops.len() - stripped.ops.len(), 3 * (b - 1));
        assert_eq!(stripped.outputs, full.outputs);
        assert_eq!(stripped.n_regs, full.n_regs);
        let fc = full.predicted_counts().total();
        let sc = stripped.predicted_counts().total();
        assert_eq!(fc.rotate - sc.rotate, (b - 1) as u64);
        assert_eq!(fc.add - sc.add, (b - 1) as u64);
    }

    #[test]
    fn fusion_is_idempotent() {
        use crate::runtime::engine::{FuseMulRescale, SchedulePass};
        let hm = synth_model(8, 4, 2, 2048, 9);
        let mut sched = HrfSchedule::compile(&hm, 2, true);
        assert!(FuseMulRescale.run(&mut sched), "first run must fuse");
        let len = sched.ops.len();
        assert!(!FuseMulRescale.run(&mut sched), "second run finds nothing");
        assert_eq!(sched.ops.len(), len);
    }
}
