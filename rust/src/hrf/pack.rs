//! NRF → packed HRF model (the server-side plaintext operands of
//! Algorithm 3).
//!
//! All parameters are laid out in the slot layout of [`HrfPlan`] and
//! **replicated into every sample group** (`plan.groups` copies at
//! `group_span` strides), so one ciphertext carrying up to
//! `plan.groups` independent observations is evaluated by the very same
//! plaintext operands:
//!
//! * `t_slots` — thresholds, replicated exactly like the input
//!   (`(t_τ | 0 | t_τ)` per block) so `x̃ − t̃` aligns;
//! * `diag_slots[j]` — the j-th generalized diagonal of every tree's
//!   `V` matrix, `diag_j[p] = V[p][(p+j) mod K]`, zero outside the
//!   first `K` slots of each block (Algorithm 1 operands);
//! * `b_slots` — leaf biases in the first `K` slots of each block;
//! * `w_slots[c]` — per-class output mask `α_l · W^{(l)}[c][k']`,
//!   zero on replicated/padding slots (also masks Algorithm 1's
//!   partial-sum garbage before the Algorithm 2 reduction);
//! * `betas[c] = Σ_l α_l β_c^{(l)}`.

use super::plan::HrfPlan;
use crate::nrf::NeuralForest;

/// Packed server-side HRF model (plaintext operands; encoding into
/// CKKS plaintexts happens lazily at the evaluation level/scale).
#[derive(Clone, Debug)]
pub struct HrfModel {
    pub plan: HrfPlan,
    /// Per-tree comparison feature indices (client's reshuffle τ).
    pub taus: Vec<Vec<usize>>,
    pub t_slots: Vec<f64>,
    pub diag_slots: Vec<Vec<f64>>,
    pub b_slots: Vec<f64>,
    pub w_slots: Vec<Vec<f64>>,
    pub betas: Vec<f64>,
    /// Monomial coefficients of the activation polynomial P.
    pub act_coeffs: Vec<f64>,
}

impl HrfModel {
    /// Pack a NeuralForest for `slots` available CKKS slots. The
    /// forest's activation must be polynomial (`Activation::Poly`) —
    /// build it with `NeuralForest::with_activation` if needed.
    pub fn from_neural_forest(
        nf: &NeuralForest,
        d: usize,
        slots: usize,
    ) -> Result<Self, String> {
        let act_coeffs = match &nf.activation {
            crate::nrf::Activation::Poly { coeffs } => coeffs.clone(),
            other => {
                return Err(format!(
                    "HRF requires a polynomial activation, got {other:?}"
                ))
            }
        };
        let k = nf.k;
        let l = nf.n_trees();
        let c = nf.n_classes;
        let plan = HrfPlan::new(k, l, c, d, slots)?;
        let block = plan.block;

        let mut taus = Vec::with_capacity(l);
        let mut t_slots = vec![0.0f64; slots];
        let mut diag_slots = vec![vec![0.0f64; slots]; k];
        let mut b_slots = vec![0.0f64; slots];
        let mut w_slots = vec![vec![0.0f64; slots]; c];
        let mut betas = vec![0.0f64; c];

        for (li, (nt, &alpha)) in nf.trees.iter().zip(&nf.alphas).enumerate() {
            assert_eq!(nt.k(), k, "trees must share padded K");
            taus.push(nt.tau.clone());
            // Write the tree's operands into every sample group: the
            // same model serves `plan.groups` packed observations.
            for g in 0..plan.groups {
                let base = plan.group_start(g) + li * block;
                // Thresholds replicated like the input block:
                // slots 0..K-1: t_0..t_{K-2}, 0 ; slots K..2K-2: t_0..t_{K-2}.
                for j in 0..k - 1 {
                    t_slots[base + j] = nt.t[j];
                    t_slots[base + k + j] = nt.t[j];
                }
                // t_slots[base + k - 1] stays 0 (padding comparison).

                // Diagonals of V (K×K; column K-1 is the zero padding
                // column since there are only K-1 comparisons).
                for j in 0..k {
                    for p in 0..k {
                        let col = (p + j) % k;
                        let w = if col < k - 1 { nt.v[p][col] } else { 0.0 };
                        diag_slots[j][base + p] = w;
                    }
                }
                // Leaf biases.
                for p in 0..k {
                    b_slots[base + p] = nt.b[p];
                }
                // Output masks.
                for ci in 0..c {
                    for p in 0..k {
                        w_slots[ci][base + p] = alpha * nt.w[ci][p];
                    }
                }
            }
            // Output biases (per class, not per slot — added once after
            // the group-local reduction).
            for ci in 0..c {
                betas[ci] += alpha * nt.beta[ci];
            }
        }

        Ok(HrfModel {
            plan,
            taus,
            t_slots,
            diag_slots,
            b_slots,
            w_slots,
            betas,
            act_coeffs,
        })
    }

    /// Resolve a schedule operand to its packed slot vector — the
    /// single lookup both executors (the CKKS one in `HrfServer` and
    /// the f32 one in `runtime::slot_model`) use, so a compiled
    /// schedule means the same thing on both sides.
    pub fn operand_slots(&self, op: crate::hrf::schedule::PlainOperand) -> &[f64] {
        use crate::hrf::schedule::PlainOperand;
        match op {
            PlainOperand::Thresholds => &self.t_slots,
            PlainOperand::Biases => &self.b_slots,
            PlainOperand::Diag(j) => &self.diag_slots[j],
            PlainOperand::ClassWeights(c) => &self.w_slots[c],
        }
    }

    /// Reference slot-level forward pass in plaintext f64, layer by
    /// layer — the oracle the HE evaluation, the AOT JAX slot model and
    /// the golden parity fixture are all checked against (same
    /// dataflow, no encryption).
    ///
    /// Returns `(u, v, group_scores)`: the two activated slot vectors
    /// and the per-group class scores (`group_scores[g][c]`).
    pub fn forward_slots_layers(
        &self,
        x_slots: &[f64],
    ) -> (Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
        let p = &self.plan;
        assert_eq!(x_slots.len(), p.slots, "input must span all slots");
        let act = |v: f64| crate::nrf::activation::horner(&self.act_coeffs, v);
        // Layer 1: u = P(x̃ − t̃)
        let u: Vec<f64> = x_slots
            .iter()
            .zip(&self.t_slots)
            .map(|(&x, &t)| act(x - t))
            .collect();
        // Layer 2: v = P(Σ_j diag_j ⊙ rot(u, j) + b̃)
        let n = x_slots.len();
        let mut lin = vec![0.0f64; n];
        for (j, diag) in self.diag_slots.iter().enumerate() {
            for i in 0..n {
                lin[i] += diag[i] * u[(i + j) % n];
            }
        }
        let v: Vec<f64> = lin
            .iter()
            .zip(&self.b_slots)
            .map(|(&s, &b)| act(s + b))
            .collect();
        // Layer 3: per group, per class — masked sum over the group's
        // span + β. Mirrors the HE side's group-local rotate-and-sum.
        let scores = (0..p.groups)
            .map(|g| {
                let lo = p.group_start(g);
                let hi = lo + p.reduce_span;
                (0..p.c)
                    .map(|ci| {
                        self.w_slots[ci][lo..hi]
                            .iter()
                            .zip(&v[lo..hi])
                            .map(|(w, v)| w * v)
                            .sum::<f64>()
                            + self.betas[ci]
                    })
                    .collect()
            })
            .collect();
        (u, v, scores)
    }

    /// Per-group class scores for a ciphertext packed with up to
    /// `plan.groups` observations (`result[g][c]`).
    pub fn forward_slots_plain_groups(&self, x_slots: &[f64]) -> Vec<Vec<f64>> {
        self.forward_slots_layers(x_slots).2
    }

    /// Single-observation forward (the observation lives in group 0 —
    /// the layout [`crate::hrf::client::reshuffle_and_pack`] produces).
    pub fn forward_slots_plain(&self, x_slots: &[f64]) -> Vec<f64> {
        self.forward_slots_plain_groups(x_slots)
            .into_iter()
            .next()
            .expect("plan has >= 1 group")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    fn packed() -> (crate::data::Dataset, NeuralForest, HrfModel) {
        let ds = adult::generate(3_000, 61);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 12,
                ..Default::default()
            },
            62,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 4096).unwrap();
        (ds, nf, hm)
    }

    #[test]
    fn rejects_non_polynomial_activation() {
        let ds = adult::generate(500, 63);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 2,
                ..Default::default()
            },
            64,
        );
        let nf = NeuralForest::from_forest(&rf, Activation::Hard);
        assert!(HrfModel::from_neural_forest(&nf, 14, 4096).is_err());
    }

    #[test]
    fn slot_forward_matches_nrf_forward() {
        // The packed slot dataflow must agree with the straightforward
        // per-tree NRF forward (same polynomial activation).
        let (ds, nf, hm) = packed();
        for x in ds.x.iter().take(100) {
            let x_slots = crate::hrf::client::reshuffle_and_pack(&hm, x);
            let got = hm.forward_slots_plain(&x_slots);
            let expect = nf.forward(x);
            for (g, e) in got.iter().zip(&expect) {
                assert!(
                    (g - e).abs() < 1e-9,
                    "slot model deviates: {got:?} vs {expect:?}"
                );
            }
        }
    }

    #[test]
    fn operands_replicated_across_groups() {
        let (_, _, hm) = packed();
        let p = &hm.plan;
        assert!(p.groups >= 2, "test needs a multi-group plan");
        let span = p.reduce_span;
        for g in 1..p.groups {
            let off = p.group_start(g);
            for s in 0..span {
                assert_eq!(hm.t_slots[off + s], hm.t_slots[s], "t group {g} slot {s}");
                assert_eq!(hm.b_slots[off + s], hm.b_slots[s], "b group {g} slot {s}");
                for d in &hm.diag_slots {
                    assert_eq!(d[off + s], d[s], "diag group {g} slot {s}");
                }
                for w in &hm.w_slots {
                    assert_eq!(w[off + s], w[s], "w group {g} slot {s}");
                }
            }
        }
    }

    #[test]
    fn masks_zero_outside_leaf_slots() {
        let (_, _, hm) = packed();
        let p = &hm.plan;
        for ci in 0..p.c {
            for g in 0..p.groups {
                let goff = p.group_start(g);
                for li in 0..p.l {
                    let base = goff + p.block_start(li);
                    for off in p.k..p.block {
                        assert_eq!(hm.w_slots[ci][base + off], 0.0);
                    }
                }
                // Group tail (beyond the L blocks) is zero.
                for s in (goff + p.used_slots)..(goff + p.reduce_span) {
                    assert_eq!(hm.w_slots[ci][s], 0.0);
                }
            }
        }
    }

    #[test]
    fn grouped_forward_is_per_sample_independent() {
        // Pack two different samples into groups 0 and 1: each group's
        // scores must equal the single-sample result.
        let (ds, _, hm) = packed();
        let p = hm.plan;
        assert!(p.groups >= 2);
        let xs: Vec<Vec<f64>> = ds.x.iter().take(2).cloned().collect();
        let packed = crate::hrf::client::reshuffle_and_pack_group(&hm, &xs);
        let grouped = hm.forward_slots_plain_groups(&packed);
        for (g, x) in xs.iter().enumerate() {
            let single = hm.forward_slots_plain(&crate::hrf::client::reshuffle_and_pack(&hm, x));
            for (a, b) in grouped[g].iter().zip(&single) {
                assert!((a - b).abs() < 1e-9, "group {g}: {grouped:?} vs {single:?}");
            }
        }
    }
}
