//! Homomorphic Random Forests — the paper's contribution (§3).
//!
//! * [`plan`] — the SIMD slot layout: one `2K−1`-slot block per tree,
//!   `L` blocks per **sample group**, `N/2 ÷ group_span` independent
//!   groups per ciphertext (cross-instance batching), constraint
//!   `L(2K−1) ≤ N/2`.
//! * [`pack`] — RF/NRF → packed server-side model: replicated
//!   threshold vector, the `K` generalized diagonals of all `V`
//!   matrices (Algorithm 1's operands), output masks and biases.
//! * [`schedule`] — the compiled HE-program IR: `HrfPlan` → explicit
//!   op schedule per batch size, with the B>1 extraction rotations
//!   folded into the layer-3 reduction. Execution belongs to the
//!   schedule engine
//!   ([`runtime::engine`](crate::runtime::engine)): one generic
//!   interpreter replays the op list on pluggable backends (CKKS, f32
//!   slots, dry-run counting), so Galois-key requirements and Table-1
//!   predictions are *derived* from the same program the evaluator
//!   runs, and peephole optimizations are `SchedulePass`es applied
//!   through [`HrfSchedule::optimize`] — written once, valid on every
//!   backend.
//! * [`client`] — Algorithm 3's client half: variable reshuffle τ,
//!   per-tree replication, encode + encrypt; decrypt + argmax
//!   (slot-addressed for folded batch responses).
//! * [`server`] — Algorithm 3's server half: a thin shell around the
//!   engine's CKKS backend. [`HrfServer::execute`] takes an
//!   [`EncRequest`] (single / folded group / legacy slot-0 group) and
//!   returns an [`EncExecution`] — comparisons, packed matrix
//!   multiplication (Algorithm 1), polynomial activations, per-class
//!   **group-local** homomorphic dot products (Algorithm 2) all flow
//!   through the one compiled schedule; per-layer op counts (Table 1)
//!   are measured at segment boundaries. The old
//!   `eval`/`eval_batch`/`eval_batch_folded` names remain as
//!   deprecated wrappers.
//! * [`cryptonet`] — the §5 comparison baseline: a CryptoNet-style
//!   HE-MLP with square activations, batched across slots.

pub mod client;
pub mod cryptonet;
pub mod pack;
pub mod plan;
pub mod schedule;
pub mod server;

pub use client::{EvalKeys, HrfClient};
pub use pack::HrfModel;
pub use plan::HrfPlan;
pub use schedule::{HrfSchedule, PlainOperand, ScheduleOp, ScoreRef, Segment};
pub use server::{EncExecution, EncRequest, EncScores, HrfServer, LayerCounts};
