//! Client half of Algorithm 3: variable reshuffle + replication +
//! encryption, and decryption + argmax of the returned scores.
//!
//! The reshuffle applies the model's τ in the clear — the paper's
//! design point: it is a high-cost operation under CKKS but leaks
//! nothing about the *data* when done client-side (§3), only requiring
//! the model owner to publish τ (which variables the forest compares,
//! not the thresholds).

use super::pack::HrfModel;
use crate::ckks::{Ciphertext, Decryptor, Encoder, Encryptor};
use crate::ckks::rns::CkksContext;

/// Build the packed slot vector `x̃` for one observation:
/// per tree block, `(x_τ | 0 | x_τ)` (Algorithm 3 lines 2–5).
pub fn reshuffle_and_pack(model: &HrfModel, x: &[f64]) -> Vec<f64> {
    let p = &model.plan;
    let mut slots = vec![0.0f64; p.slots];
    for (li, tau) in model.taus.iter().enumerate() {
        let base = p.block_start(li);
        for (j, &feat) in tau.iter().enumerate() {
            let v = x[feat];
            slots[base + j] = v; // first copy
            slots[base + p.k + j] = v; // replica
        }
        // slot base+k-1 stays 0 (padding comparison input).
    }
    slots
}

/// Client-side state: encoder + keys for one session.
pub struct HrfClient {
    pub encryptor: Encryptor,
    pub decryptor: Decryptor,
}

impl HrfClient {
    pub fn new(encryptor: Encryptor, decryptor: Decryptor) -> Self {
        HrfClient {
            encryptor,
            decryptor,
        }
    }

    /// Encrypt one observation for the given model.
    pub fn encrypt_input(
        &mut self,
        ctx: &CkksContext,
        enc: &Encoder,
        model: &HrfModel,
        x: &[f64],
    ) -> Ciphertext {
        let slots = reshuffle_and_pack(model, x);
        self.encryptor.encrypt_slots(ctx, enc, &slots)
    }

    /// Decrypt per-class score ciphertexts (score of class c lives in
    /// slot 0 of `cts[c]`) and return (scores, argmax).
    pub fn decrypt_scores(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        cts: &[Ciphertext],
    ) -> (Vec<f64>, usize) {
        let scores: Vec<f64> = cts
            .iter()
            .map(|ct| self.decryptor.decrypt_slots(ctx, enc, ct)[0])
            .collect();
        let pred = crate::forest::tree::argmax(&scores);
        (scores, pred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    #[test]
    fn packed_input_has_replicated_blocks() {
        let ds = adult::generate(500, 71);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 5,
                ..Default::default()
            },
            72,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 4096).unwrap();
        let x = &ds.x[0];
        let slots = reshuffle_and_pack(&hm, x);
        let p = &hm.plan;
        for li in 0..p.l {
            let base = p.block_start(li);
            // replication: slots[base+j] == slots[base+K+j]
            for j in 0..p.k - 1 {
                assert_eq!(slots[base + j], slots[base + p.k + j]);
                assert_eq!(slots[base + j], x[hm.taus[li][j]]);
            }
            assert_eq!(slots[base + p.k - 1], 0.0);
        }
        // tail zero
        for s in p.used_slots..p.slots {
            assert_eq!(slots[s], 0.0);
        }
    }
}
