//! Client half of Algorithm 3: variable reshuffle + replication +
//! encryption, and decryption + argmax of the returned scores.
//!
//! The reshuffle applies the model's τ in the clear — the paper's
//! design point: it is a high-cost operation under CKKS but leaks
//! nothing about the *data* when done client-side (§3), only requiring
//! the model owner to publish τ (which variables the forest compares,
//! not the thresholds).
//!
//! With the group layout of [`HrfPlan`](super::plan::HrfPlan) a client
//! can pack up to `plan.groups` observations into **one** ciphertext
//! ([`reshuffle_and_pack_group`] / [`HrfClient::encrypt_batch`]) and
//! read each observation's scores back from its group's score slot
//! ([`HrfClient::decrypt_scores_batch`]) — amortizing the whole
//! homomorphic evaluation across the batch.

use super::pack::HrfModel;
use crate::ckks::keys::{GaloisKeys, RelinKey};
use crate::ckks::rns::CkksContext;
use crate::ckks::{Ciphertext, Decryptor, Encoder, Encryptor};

/// Write one observation's reshuffled blocks into `slots` at group
/// offset `goff`: per tree block, `(x_τ | 0 | x_τ)` (Algorithm 3
/// lines 2–5).
fn pack_into_group(model: &HrfModel, x: &[f64], slots: &mut [f64], goff: usize) {
    let p = &model.plan;
    for (li, tau) in model.taus.iter().enumerate() {
        let base = goff + p.block_start(li);
        for (j, &feat) in tau.iter().enumerate() {
            let v = x[feat];
            slots[base + j] = v; // first copy
            slots[base + p.k + j] = v; // replica
        }
        // slot base+k-1 stays 0 (padding comparison input).
    }
}

/// Build the packed slot vector `x̃` for one observation in group 0.
pub fn reshuffle_and_pack(model: &HrfModel, x: &[f64]) -> Vec<f64> {
    let mut slots = vec![0.0f64; model.plan.slots];
    pack_into_group(model, x, &mut slots, 0);
    slots
}

/// Build the packed slot vector for up to `plan.groups` observations:
/// observation `g` occupies sample group `g`. Panics if more samples
/// than groups are supplied.
pub fn reshuffle_and_pack_group(model: &HrfModel, xs: &[Vec<f64>]) -> Vec<f64> {
    let p = &model.plan;
    assert!(
        xs.len() <= p.groups,
        "batch of {} exceeds {} sample groups",
        xs.len(),
        p.groups
    );
    let mut slots = vec![0.0f64; p.slots];
    for (g, x) in xs.iter().enumerate() {
        pack_into_group(model, x, &mut slots, p.group_start(g));
    }
    slots
}

/// The evaluation-key bundle a server session caches (relinearization
/// + Galois). Clients that retain a copy can recover from server-side
/// key eviction (`SubmitError::KeysEvicted`) without a fresh key
/// generation ceremony: hand [`HrfClient::eval_keys`] to the serving
/// layer's `SessionManager::register_keys` / `reregister_keys` — the
/// client half of the [`keycache`](crate::keycache) protocol.
#[derive(Clone, Debug)]
pub struct EvalKeys {
    pub relin: RelinKey,
    pub galois: GaloisKeys,
}

impl EvalKeys {
    /// Exact bytes the server's key cache will charge for this bundle.
    pub fn key_bytes(&self) -> usize {
        self.relin.key_bytes() + self.galois.key_bytes()
    }
}

/// Client-side state: encoder + keys for one session.
pub struct HrfClient {
    pub encryptor: Encryptor,
    pub decryptor: Decryptor,
    /// Retained for (re-)registration with the serving layer; None
    /// when the caller manages key material itself.
    eval_keys: Option<EvalKeys>,
}

impl HrfClient {
    pub fn new(encryptor: Encryptor, decryptor: Decryptor) -> Self {
        HrfClient {
            encryptor,
            decryptor,
            eval_keys: None,
        }
    }

    /// A client that retains its evaluation keys so sessions survive
    /// server-side eviction: on `SubmitError::KeysEvicted`, pass
    /// [`HrfClient::eval_keys`] to `SessionManager::reregister_keys`
    /// and resubmit under the same session id.
    pub fn with_eval_keys(
        encryptor: Encryptor,
        decryptor: Decryptor,
        relin: RelinKey,
        galois: GaloisKeys,
    ) -> Self {
        HrfClient {
            encryptor,
            decryptor,
            eval_keys: Some(EvalKeys { relin, galois }),
        }
    }

    /// The retained evaluation-key bundle (None for key-less clients).
    pub fn eval_keys(&self) -> Option<&EvalKeys> {
        self.eval_keys.as_ref()
    }

    /// Encrypt one observation for the given model.
    pub fn encrypt_input(
        &mut self,
        ctx: &CkksContext,
        enc: &Encoder,
        model: &HrfModel,
        x: &[f64],
    ) -> Ciphertext {
        let slots = reshuffle_and_pack(model, x);
        self.encryptor.encrypt_slots(ctx, enc, &slots)
    }

    /// Encrypt a batch of up to `plan.groups` observations into one
    /// ciphertext (observation `g` in sample group `g`).
    pub fn encrypt_batch(
        &mut self,
        ctx: &CkksContext,
        enc: &Encoder,
        model: &HrfModel,
        xs: &[Vec<f64>],
    ) -> Ciphertext {
        let slots = reshuffle_and_pack_group(model, xs);
        self.encryptor.encrypt_slots(ctx, enc, &slots)
    }

    /// Decrypt per-class score ciphertexts (score of class c lives in
    /// slot 0 of `cts[c]`) and return (scores, argmax).
    pub fn decrypt_scores(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        cts: &[Ciphertext],
    ) -> (Vec<f64>, usize) {
        self.decrypt_scores_at(ctx, enc, cts, 0)
    }

    /// Decrypt per-class score ciphertexts reading slot `slot` — the
    /// folded batched protocol's read: the server leaves sample `g`'s
    /// score at `plan.score_slot(g)` instead of spending a rotation
    /// moving it to slot 0, and tells the caller which slot to read
    /// (`EncScores::slot`).
    pub fn decrypt_scores_at(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        cts: &[Ciphertext],
        slot: usize,
    ) -> (Vec<f64>, usize) {
        let scores: Vec<f64> = cts
            .iter()
            .map(|ct| self.decryptor.decrypt_slots(ctx, enc, ct)[slot])
            .collect();
        let pred = crate::forest::tree::argmax(&scores);
        (scores, pred)
    }

    /// Decrypt a coordinator response (per-class ciphertexts + the
    /// slot carrying this request's score). Returns (scores, argmax).
    pub fn decrypt_response(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        resp: &crate::hrf::server::EncScores,
    ) -> (Vec<f64>, usize) {
        self.decrypt_scores_at(ctx, enc, &resp.scores, resp.slot)
    }

    /// Decrypt per-class score ciphertexts of a **packed batch**: the
    /// score of sample `g`, class `c` lives at `plan.score_slot(g)` of
    /// `cts[c]`. Returns `(scores, argmax)` per sample.
    pub fn decrypt_scores_batch(
        &self,
        ctx: &CkksContext,
        enc: &Encoder,
        model: &HrfModel,
        cts: &[Ciphertext],
        n_samples: usize,
    ) -> Vec<(Vec<f64>, usize)> {
        let p = &model.plan;
        assert!(n_samples <= p.groups);
        let decoded: Vec<Vec<f64>> = cts
            .iter()
            .map(|ct| self.decryptor.decrypt_slots(ctx, enc, ct))
            .collect();
        (0..n_samples)
            .map(|g| {
                let slot = p.score_slot(g);
                let scores: Vec<f64> = decoded.iter().map(|d| d[slot]).collect();
                let pred = crate::forest::tree::argmax(&scores);
                (scores, pred)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::adult;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::nrf::activation::{chebyshev_fit_tanh, Activation};
    use crate::nrf::NeuralForest;

    fn model() -> (crate::data::Dataset, HrfModel) {
        let ds = adult::generate(500, 71);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 5,
                ..Default::default()
            },
            72,
        );
        let coeffs = chebyshev_fit_tanh(3.0, 4);
        let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
        let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 4096).unwrap();
        (ds, hm)
    }

    #[test]
    fn packed_input_has_replicated_blocks() {
        let (ds, hm) = model();
        let x = &ds.x[0];
        let slots = reshuffle_and_pack(&hm, x);
        let p = &hm.plan;
        for li in 0..p.l {
            let base = p.block_start(li);
            // replication: slots[base+j] == slots[base+K+j]
            for j in 0..p.k - 1 {
                assert_eq!(slots[base + j], slots[base + p.k + j]);
                assert_eq!(slots[base + j], x[hm.taus[li][j]]);
            }
            assert_eq!(slots[base + p.k - 1], 0.0);
        }
        // Everything outside group 0's used region is zero.
        for s in p.used_slots..p.slots {
            assert_eq!(slots[s], 0.0);
        }
    }

    #[test]
    fn group_pack_places_each_sample_in_its_group() {
        let (ds, hm) = model();
        let p = hm.plan;
        assert!(p.groups >= 3, "need multiple groups for this test");
        let xs: Vec<Vec<f64>> = ds.x.iter().take(3).cloned().collect();
        let slots = reshuffle_and_pack_group(&hm, &xs);
        for (g, x) in xs.iter().enumerate() {
            let single = reshuffle_and_pack(&hm, x);
            let off = p.group_start(g);
            for s in 0..p.reduce_span {
                assert_eq!(
                    slots[off + s],
                    single[s],
                    "group {g} slot {s} differs from single-sample layout"
                );
            }
        }
        // Unoccupied groups stay zero.
        for g in xs.len()..p.groups {
            let off = p.group_start(g);
            for s in 0..p.reduce_span {
                assert_eq!(slots[off + s], 0.0);
            }
        }
    }

    #[test]
    fn retained_eval_keys_are_exposed_with_exact_bytes() {
        use crate::ckks::rns::CkksContext;
        use crate::ckks::{CkksParams, Decryptor, Encryptor, KeyGenerator};
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, 91);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, &[1, 2]);
        let expected_bytes = rlk.key_bytes() + gk.key_bytes();
        let client = HrfClient::with_eval_keys(
            Encryptor::new(pk, 92),
            Decryptor::new(kg.secret_key()),
            rlk,
            gk,
        );
        let keys = client.eval_keys().expect("keys retained");
        assert_eq!(keys.key_bytes(), expected_bytes);
        // A key-less client retains nothing to (re-)register.
        let ctx2 = CkksContext::new(CkksParams::toy());
        let mut kg2 = KeyGenerator::new(&ctx2, 93);
        let pk2 = kg2.gen_public_key(&ctx2);
        let bare = HrfClient::new(Encryptor::new(pk2, 94), Decryptor::new(kg2.secret_key()));
        assert!(bare.eval_keys().is_none());
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn group_pack_rejects_oversized_batch() {
        let (ds, hm) = model();
        let xs: Vec<Vec<f64>> = (0..hm.plan.groups + 1)
            .map(|i| ds.x[i % ds.len()].clone())
            .collect();
        let _ = reshuffle_and_pack_group(&hm, &xs);
    }
}
