//! Per-client HE key sessions, stored in the sharded
//! [`keycache`](crate::keycache).
//!
//! In the CKKS deployment model the client generates all key material,
//! keeps the secret key, and ships the server its *public* evaluation
//! keys: relinearization (for ct×ct) and Galois (for the rotations of
//! Algorithms 1–2). One [`Session`] holds those for one client; the
//! [`SessionManager`] is the registry the router consults.
//!
//! Storage is a [`KeyCache`]: sharded by `session_id % num_shards`,
//! with exact [`Session::key_bytes`] accounting against a global
//! memory budget and per-shard LRU eviction. Eviction never invalidates
//! a session *id* — an evicted session's submits fail with
//! `SubmitError::KeysEvicted` and the client recovers by pushing its
//! retained keys back under the same id ([`SessionManager::reregister`]).

use crate::ckks::keys::{GaloisKeys, RelinKey};
use crate::ckks::rns::ContextRef;
use crate::hrf::client::EvalKeys;
use crate::keycache::{CacheState, KeyCache, KeyCacheConfig, KeyCacheStats, SpillCodec, SpillConfig};
use crate::net::codec;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Server-side state for one client.
pub struct Session {
    pub id: u64,
    pub relin: RelinKey,
    pub galois: GaloisKeys,
}

impl Session {
    /// Exact resident bytes this session's keys occupy — what the key
    /// cache charges against its budget.
    pub fn key_bytes(&self) -> usize {
        self.relin.key_bytes() + self.galois.key_bytes()
    }
}

/// Thread-safe session registry backed by the sharded key cache.
pub struct SessionManager {
    next_id: AtomicU64,
    cache: KeyCache<Session>,
}

impl Default for SessionManager {
    fn default() -> Self {
        Self::with_config(KeyCacheConfig::default())
    }
}

impl SessionManager {
    /// Unbounded registry (default cache config: no memory budget).
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry with an explicit key-cache configuration (shard count
    /// + global key-byte budget).
    pub fn with_config(cfg: KeyCacheConfig) -> Self {
        SessionManager {
            next_id: AtomicU64::new(0),
            cache: KeyCache::new(cfg),
        }
    }

    /// Register a client's evaluation keys; returns the session id the
    /// client must present with every request. May evict the
    /// least-recently-used sessions' keys to fit the budget.
    pub fn register(&self, relin: RelinKey, galois: GaloisKeys) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let session = Session { id, relin, galois };
        let bytes = session.key_bytes();
        self.cache.insert(id, session, bytes);
        id
    }

    /// Re-upload evaluation keys for an existing session id after its
    /// keys were evicted (or proactively, e.g. to widen rotation
    /// coverage). Returns false if the id was never registered or was
    /// removed — re-registration never creates ids.
    pub fn reregister(&self, id: u64, relin: RelinKey, galois: GaloisKeys) -> bool {
        if !self.cache.is_known(id) {
            return false;
        }
        let session = Session { id, relin, galois };
        let bytes = session.key_bytes();
        self.cache.insert(id, session, bytes);
        true
    }

    /// [`SessionManager::register`] for a client-retained
    /// [`EvalKeys`] bundle (see `HrfClient::eval_keys`).
    pub fn register_keys(&self, keys: &EvalKeys) -> u64 {
        self.register(keys.relin.clone(), keys.galois.clone())
    }

    /// [`SessionManager::reregister`] for a client-retained
    /// [`EvalKeys`] bundle — the recovery step after a
    /// `SubmitError::KeysEvicted`.
    pub fn reregister_keys(&self, id: u64, keys: &EvalKeys) -> bool {
        self.reregister(id, keys.relin.clone(), keys.galois.clone())
    }

    /// Resident session (refreshes its LRU stamp). None when the keys
    /// are evicted or the id is unknown — use [`SessionManager::lookup`]
    /// to tell the two apart.
    pub fn get(&self, id: u64) -> Option<Arc<Session>> {
        self.cache.get(id)
    }

    /// [`SessionManager::get`] without hit/miss accounting: for
    /// fetches that follow an already-counted submission-gate lookup
    /// (the coordinator's workers), keeping the cache hit rate at one
    /// count per request.
    pub fn get_untracked(&self, id: u64) -> Option<Arc<Session>> {
        self.cache.get_untracked(id)
    }

    /// Full protocol state: resident / evicted / unknown.
    pub fn lookup(&self, id: u64) -> CacheState<Session> {
        self.cache.lookup(id)
    }

    /// [`SessionManager::lookup`] without LRU refresh or hit/miss
    /// accounting — the coordinator's mid-flight residency probe (and
    /// a test observation hook): safe to call from workers between
    /// chunks without perturbing eviction order or the hit rate.
    pub fn peek(&self, id: u64) -> CacheState<Session> {
        self.cache.peek(id)
    }

    /// Close a session entirely (id becomes unknown).
    pub fn remove(&self, id: u64) -> bool {
        self.cache.remove(id)
    }

    /// Sessions with resident keys.
    pub fn len(&self) -> usize {
        self.cache.resident_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Known session ids (resident + evicted).
    pub fn known_len(&self) -> usize {
        self.cache.known_len()
    }

    /// Current resident key bytes across all shards.
    pub fn resident_bytes(&self) -> u64 {
        self.cache.resident_bytes()
    }

    /// Shared cache counters, for wiring into serving metrics.
    pub fn keycache_stats(&self) -> Arc<KeyCacheStats> {
        self.cache.stats()
    }

    /// Attach the disk spill tier: evicted sessions serialize their
    /// evaluation keys (wire codec encoding, fully re-validated on
    /// reload) into `dir`, capped at `budget_bytes`, and reload
    /// transparently on the next lookup — `KeysEvicted` then means
    /// the spill tier is full too. `ctx` is needed to re-validate key
    /// polys against the server's modulus chain on reload. Returns
    /// `Ok(false)` if a tier was already enabled (no-op).
    pub fn enable_spill(
        &self,
        dir: PathBuf,
        budget_bytes: u64,
        ctx: ContextRef,
    ) -> std::io::Result<bool> {
        self.cache.enable_spill(
            SpillConfig { dir, budget_bytes },
            Box::new(SessionSpillCodec { ctx }),
        )
    }

    /// Whether the spill tier is attached.
    pub fn spill_enabled(&self) -> bool {
        self.cache.spill_enabled()
    }

    /// Bytes currently parked in the spill tier (0 when disabled).
    pub fn spilled_bytes(&self) -> u64 {
        self.cache.spilled_bytes()
    }

    /// Sessions currently in the spill tier (0 when disabled).
    pub fn spilled_len(&self) -> usize {
        self.cache.spilled_len()
    }
}

/// [`SpillCodec`] for [`Session`]s: the wire codec's evaluation-key
/// encoding prefixed with the session id. Decoding runs the same
/// defensive validation as a network key upload (residues checked
/// against the modulus chain, Galois elements recomputed, trailing
/// bytes rejected), so a torn or tampered spill file can never put
/// malformed limbs in front of the NTT kernels — it just reads as
/// corrupt and the session degrades to the re-register protocol.
struct SessionSpillCodec {
    ctx: ContextRef,
}

impl SpillCodec<Session> for SessionSpillCodec {
    fn encode(&self, s: &Session) -> Vec<u8> {
        codec::encode_session_keys(s.id, &s.relin, &s.galois)
    }

    fn decode(&self, id: u64, bytes: &[u8]) -> Option<Session> {
        let (sid, relin, galois) = codec::decode_session_keys(bytes, &self.ctx).ok()?;
        if sid != id {
            return None; // file does not belong to this session
        }
        Some(Session { id, relin, galois })
    }

    fn size_bytes(&self, s: &Session) -> usize {
        s.key_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::rns::CkksContext;
    use crate::ckks::{CkksParams, KeyGenerator};

    fn keys(seed: u64) -> (RelinKey, GaloisKeys) {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, seed);
        (kg.gen_relin_key(&ctx), kg.gen_galois_keys(&ctx, &[1]))
    }

    #[test]
    fn register_get_remove() {
        let mgr = SessionManager::new();
        let (r, g) = keys(1);
        let id = mgr.register(r, g);
        assert!(mgr.get(id).is_some());
        assert_eq!(mgr.len(), 1);
        assert!(mgr.remove(id));
        assert!(mgr.get(id).is_none());
        assert!(!mgr.remove(id));
    }

    #[test]
    fn ids_are_unique_across_threads() {
        let mgr = Arc::new(SessionManager::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let (r, g) = keys(100 + t);
                (0..8).map(|_| mgr.register(r.clone(), g.clone())).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        assert_eq!(before, all.len(), "duplicate session ids");
        assert_eq!(mgr.len(), 32);
    }

    #[test]
    fn eviction_keeps_id_and_reregistration_recovers() {
        let (r, g) = keys(7);
        let session_bytes = (r.key_bytes() + g.key_bytes()) as u64;
        // Budget admits one session (plus slack), not two.
        let mgr = SessionManager::with_config(KeyCacheConfig {
            num_shards: 2,
            budget_bytes: session_bytes * 3 / 2,
        });
        let id0 = mgr.register(r.clone(), g.clone());
        assert_eq!(mgr.resident_bytes(), session_bytes);
        let id1 = mgr.register(r.clone(), g.clone());
        // id0 was evicted, but its id survives.
        assert!(mgr.resident_bytes() <= session_bytes * 3 / 2);
        assert!(matches!(mgr.lookup(id0), CacheState::Evicted));
        assert!(mgr.get(id0).is_none());
        assert!(mgr.get(id1).is_some());
        assert_eq!(mgr.len(), 1);
        assert_eq!(mgr.known_len(), 2);
        // Re-registration restores the same id (evicting id1 in turn).
        assert!(mgr.reregister(id0, r.clone(), g.clone()));
        assert!(mgr.get(id0).is_some());
        assert!(matches!(mgr.lookup(id1), CacheState::Evicted));
        // Unknown ids cannot be re-registered.
        assert!(!mgr.reregister(9_999, r, g));
        let stats = mgr.keycache_stats().snapshot();
        assert!(stats.evictions >= 2);
        assert!(stats.misses >= 1);
    }

    #[test]
    fn eval_keys_bundle_registers_and_reregisters() {
        let (relin, galois) = keys(9);
        let bundle = crate::hrf::client::EvalKeys { relin, galois };
        let mgr = SessionManager::new();
        let id = mgr.register_keys(&bundle);
        assert!(mgr.get(id).is_some());
        // Re-registration is an update, not a new enrolment.
        assert!(mgr.reregister_keys(id, &bundle));
        assert_eq!(mgr.len(), 1);
        assert!(!mgr.reregister_keys(id + 100, &bundle));
    }

    #[test]
    fn spill_tier_reloads_evicted_session_keys_bit_identically() {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, 7);
        let r = kg.gen_relin_key(&ctx);
        let g = kg.gen_galois_keys(&ctx, &[1]);
        let session_bytes = (r.key_bytes() + g.key_bytes()) as u64;
        let mgr = SessionManager::with_config(KeyCacheConfig {
            num_shards: 2,
            budget_bytes: session_bytes * 3 / 2, // one session + slack
        });
        let dir = std::env::temp_dir().join(format!(
            "cryptotree-session-spill-test-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        assert!(mgr.enable_spill(dir.clone(), 1 << 30, ctx.clone()).unwrap());
        assert!(mgr.spill_enabled());
        // Enabling twice is a no-op, not an error.
        assert!(!mgr.enable_spill(dir.clone(), 1 << 30, ctx.clone()).unwrap());

        let id0 = mgr.register(r.clone(), g.clone());
        let golden = codec::encode_session_keys(id0, &r, &g);
        let _id1 = mgr.register(r.clone(), g.clone()); // evicts id0 → spills
        assert!(matches!(mgr.peek(id0), CacheState::Spilled));
        assert_eq!(mgr.spilled_len(), 1);
        assert!(mgr.spilled_bytes() > 0);

        // Lookup reloads from disk instead of reporting Evicted…
        let reloaded = match mgr.lookup(id0) {
            CacheState::Resident(s) => s,
            _ => panic!("expected transparent spill reload"),
        };
        // …and the keys are bit-identical to what was registered.
        let bytes = codec::encode_session_keys(id0, &reloaded.relin, &reloaded.galois);
        assert_eq!(bytes, golden, "reloaded keys must be bit-identical");
        let stats = mgr.keycache_stats().snapshot();
        assert_eq!(stats.spill_hits, 1);
        assert_eq!(stats.spill_corrupt, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn removed_session_is_unknown_not_evicted() {
        let mgr = SessionManager::new();
        let (r, g) = keys(8);
        let id = mgr.register(r.clone(), g.clone());
        assert!(mgr.remove(id));
        assert!(matches!(mgr.lookup(id), CacheState::Unknown));
        assert!(!mgr.reregister(id, r, g));
        assert_eq!(mgr.known_len(), 0);
    }
}
