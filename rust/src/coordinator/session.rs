//! Per-client HE key sessions.
//!
//! In the CKKS deployment model the client generates all key material,
//! keeps the secret key, and ships the server its *public* evaluation
//! keys: relinearization (for ct×ct) and Galois (for the rotations of
//! Algorithms 1–2). One [`Session`] holds those for one client; the
//! [`SessionManager`] is the thread-safe registry the router consults.

use crate::ckks::keys::{GaloisKeys, RelinKey};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Server-side state for one client.
pub struct Session {
    pub id: u64,
    pub relin: RelinKey,
    pub galois: GaloisKeys,
}

/// Thread-safe session registry.
#[derive(Default)]
pub struct SessionManager {
    next_id: AtomicU64,
    sessions: RwLock<HashMap<u64, Arc<Session>>>,
}

impl SessionManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a client's evaluation keys; returns the session id the
    /// client must present with every request.
    pub fn register(&self, relin: RelinKey, galois: GaloisKeys) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let session = Arc::new(Session { id, relin, galois });
        self.sessions.write().unwrap().insert(id, session);
        id
    }

    pub fn get(&self, id: u64) -> Option<Arc<Session>> {
        self.sessions.read().unwrap().get(&id).cloned()
    }

    pub fn remove(&self, id: u64) -> bool {
        self.sessions.write().unwrap().remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::rns::CkksContext;
    use crate::ckks::{CkksParams, KeyGenerator};

    fn keys(seed: u64) -> (RelinKey, GaloisKeys) {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut kg = KeyGenerator::new(&ctx, seed);
        (kg.gen_relin_key(&ctx), kg.gen_galois_keys(&ctx, &[1]))
    }

    #[test]
    fn register_get_remove() {
        let mgr = SessionManager::new();
        let (r, g) = keys(1);
        let id = mgr.register(r, g);
        assert!(mgr.get(id).is_some());
        assert_eq!(mgr.len(), 1);
        assert!(mgr.remove(id));
        assert!(mgr.get(id).is_none());
        assert!(!mgr.remove(id));
    }

    #[test]
    fn ids_are_unique_across_threads() {
        let mgr = Arc::new(SessionManager::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let mgr = mgr.clone();
            handles.push(std::thread::spawn(move || {
                let (r, g) = keys(100 + t);
                (0..8).map(|_| mgr.register(r.clone(), g.clone())).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        assert_eq!(before, all.len(), "duplicate session ids");
        assert_eq!(mgr.len(), 32);
    }
}
