//! Dynamic batching for the plaintext fast path.
//!
//! Requests accumulate until either the batch is full (`max_batch`,
//! normally the AOT artifact's compiled batch size) or the oldest
//! request has waited `max_delay` — the classic latency/throughput
//! dial. The policy logic is a pure state machine ([`BatchPolicy`])
//! so it can be property-tested without threads; the coordinator
//! drives it from the batcher thread. Each flush the policy triggers
//! is visible in the observability plane: the batcher stamps every
//! flushed request's span trace (`crate::obs`) with a shared flush id
//! and the group size.

use std::time::{Duration, Instant};

/// Decision state for one forming batch.
#[derive(Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: Duration,
    oldest: Option<Instant>,
    pending: usize,
}

/// What the driver should do after an event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchAction {
    /// Keep waiting (up to the returned deadline, if any).
    Wait,
    /// Flush the current batch now.
    Flush,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        assert!(max_batch >= 1);
        BatchPolicy {
            max_batch,
            max_delay,
            oldest: None,
            pending: 0,
        }
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Retarget the batch size (adaptive batching: the coordinator
    /// raises the target with queue depth so the system batches harder
    /// under load). Takes effect from the next arrival/tick; a target
    /// below the current pending count flushes on that event.
    pub fn set_max_batch(&mut self, n: usize) {
        self.max_batch = n.max(1);
    }

    /// A request arrived at `now`.
    pub fn on_arrival(&mut self, now: Instant) -> BatchAction {
        if self.pending == 0 {
            self.oldest = Some(now);
        }
        self.pending += 1;
        if self.pending >= self.max_batch {
            BatchAction::Flush
        } else {
            BatchAction::Wait
        }
    }

    /// Timer poll at `now`: flush if the oldest request has waited out.
    pub fn on_tick(&mut self, now: Instant) -> BatchAction {
        match self.oldest {
            Some(t0) if self.pending > 0 && now.duration_since(t0) >= self.max_delay => {
                BatchAction::Flush
            }
            _ => BatchAction::Wait,
        }
    }

    /// Deadline by which a tick must happen (None when empty).
    pub fn deadline(&self) -> Option<Instant> {
        self.oldest.map(|t0| t0 + self.max_delay)
    }

    /// The driver flushed `n` requests.
    pub fn on_flush(&mut self, n: usize) {
        debug_assert!(n <= self.pending);
        self.pending -= n;
        self.oldest = if self.pending == 0 {
            None
        } else {
            // Remaining requests arrived after the flushed ones; their
            // true arrival is unknown here, so restart the clock (the
            // conservative choice: never flushes *later* than true).
            Some(Instant::now())
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn flushes_exactly_at_max_batch() {
        let mut p = BatchPolicy::new(4, Duration::from_millis(100));
        let now = Instant::now();
        assert_eq!(p.on_arrival(now), BatchAction::Wait);
        assert_eq!(p.on_arrival(now), BatchAction::Wait);
        assert_eq!(p.on_arrival(now), BatchAction::Wait);
        assert_eq!(p.on_arrival(now), BatchAction::Flush);
        p.on_flush(4);
        assert_eq!(p.pending(), 0);
        assert!(p.deadline().is_none());
    }

    #[test]
    fn flushes_on_timeout() {
        let mut p = BatchPolicy::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        p.on_arrival(t0);
        assert_eq!(p.on_tick(t0 + Duration::from_millis(5)), BatchAction::Wait);
        assert_eq!(
            p.on_tick(t0 + Duration::from_millis(10)),
            BatchAction::Flush
        );
    }

    #[test]
    fn retargeting_raises_the_flush_threshold() {
        let mut p = BatchPolicy::new(2, Duration::from_millis(100));
        let now = Instant::now();
        assert_eq!(p.on_arrival(now), BatchAction::Wait);
        // Load spike: raise the target — the would-be-full batch keeps
        // accumulating.
        p.set_max_batch(4);
        assert_eq!(p.on_arrival(now), BatchAction::Wait);
        assert_eq!(p.on_arrival(now), BatchAction::Wait);
        assert_eq!(p.on_arrival(now), BatchAction::Flush);
        p.on_flush(4);
        // Floor at 1.
        p.set_max_batch(0);
        assert_eq!(p.on_arrival(now), BatchAction::Flush);
    }

    #[test]
    fn empty_never_flushes() {
        let mut p = BatchPolicy::new(2, Duration::from_millis(1));
        assert_eq!(
            p.on_tick(Instant::now() + Duration::from_secs(10)),
            BatchAction::Wait
        );
    }

    /// Property: under any arrival/tick sequence, pending never exceeds
    /// max_batch, and every flush is triggered by fullness or timeout.
    #[test]
    fn property_pending_bounded_and_flushes_justified() {
        let mut rng = Xoshiro256pp::new(77);
        for _case in 0..200 {
            let max_batch = 1 + rng.next_index(8);
            let delay = Duration::from_millis(1 + rng.next_below(20));
            let mut p = BatchPolicy::new(max_batch, delay);
            let mut now = Instant::now();
            for _ in 0..100 {
                now += Duration::from_millis(rng.next_below(5));
                let action = if rng.bernoulli(0.7) {
                    p.on_arrival(now)
                } else {
                    p.on_tick(now)
                };
                assert!(p.pending() <= max_batch, "pending exceeded max_batch");
                if action == BatchAction::Flush {
                    let n = p.pending();
                    assert!(n > 0, "flush of empty batch");
                    // justification: full, or oldest waited >= delay
                    let full = n >= max_batch;
                    let timed_out = p
                        .deadline()
                        .map(|d| now >= d)
                        .unwrap_or(false);
                    assert!(full || timed_out, "unjustified flush");
                    p.on_flush(n);
                }
            }
        }
    }
}
