//! Serving metrics: counters + latency histograms.
//!
//! Lock-free counters (atomics) with a small mutex-guarded log-scale
//! histogram per request class; cheap enough for the request path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log₂-bucketed latency histogram (µs buckets from 1µs to ~17min).
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [u64; 30],
    sum_us: u128,
    count: u64,
    max_us: u64,
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(29);
        self.buckets[idx] += 1;
        self.sum_us += us as u128;
        self.count += 1;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Shared metrics for one coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    pub encrypted_completed: AtomicU64,
    pub plain_completed: AtomicU64,
    pub rejected_backpressure: AtomicU64,
    pub rejected_no_session: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batch_fill_sum: AtomicU64,
    /// Encrypted-path group flushes (one packed HE evaluation each).
    pub enc_batches_flushed: AtomicU64,
    /// Samples carried by those flushes (fill = sum / flushed).
    pub enc_batch_fill_sum: AtomicU64,
    pub encrypted_latency: Mutex<Histogram>,
    pub plain_latency: Mutex<Histogram>,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub encrypted_completed: u64,
    pub plain_completed: u64,
    pub rejected_backpressure: u64,
    pub rejected_no_session: u64,
    pub batches_flushed: u64,
    pub mean_batch_fill: f64,
    pub enc_batches_flushed: u64,
    pub mean_enc_batch_fill: f64,
    pub encrypted_mean: Duration,
    pub encrypted_p95: Duration,
    pub plain_mean: Duration,
    pub plain_p95: Duration,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        let enc = self.encrypted_latency.lock().unwrap();
        let plain = self.plain_latency.lock().unwrap();
        let flushed = self.batches_flushed.load(Ordering::Relaxed);
        let enc_flushed = self.enc_batches_flushed.load(Ordering::Relaxed);
        MetricsSnapshot {
            encrypted_completed: self.encrypted_completed.load(Ordering::Relaxed),
            plain_completed: self.plain_completed.load(Ordering::Relaxed),
            rejected_backpressure: self.rejected_backpressure.load(Ordering::Relaxed),
            rejected_no_session: self.rejected_no_session.load(Ordering::Relaxed),
            batches_flushed: flushed,
            mean_batch_fill: if flushed == 0 {
                0.0
            } else {
                self.batch_fill_sum.load(Ordering::Relaxed) as f64 / flushed as f64
            },
            enc_batches_flushed: enc_flushed,
            mean_enc_batch_fill: if enc_flushed == 0 {
                0.0
            } else {
                self.enc_batch_fill_sum.load(Ordering::Relaxed) as f64 / enc_flushed as f64
            },
            encrypted_mean: enc.mean(),
            encrypted_p95: enc.quantile(0.95),
            plain_mean: plain.mean(),
            plain_p95: plain.quantile(0.95),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.quantile(0.5) >= Duration::from_millis(2));
        assert!(h.quantile(1.0) >= Duration::from_millis(100));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn snapshot_reads_counters() {
        let m = Metrics::default();
        m.encrypted_completed.fetch_add(3, Ordering::Relaxed);
        m.batches_flushed.fetch_add(2, Ordering::Relaxed);
        m.batch_fill_sum.fetch_add(9, Ordering::Relaxed);
        m.plain_latency
            .lock()
            .unwrap()
            .record(Duration::from_micros(500));
        let s = m.snapshot();
        assert_eq!(s.encrypted_completed, 3);
        assert!((s.mean_batch_fill - 4.5).abs() < 1e-12);
        assert!(s.plain_mean > Duration::ZERO);
    }
}
