//! Serving metrics: counters + latency histograms.
//!
//! Lock-free counters (atomics) with a small mutex-guarded log-scale
//! histogram per request class; cheap enough for the request path.

use crate::keycache::KeyCacheStats;
use crate::lockutil::lock_unpoisoned;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Log₂-bucketed latency histogram (µs buckets from 1µs to ~17min).
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [u64; 30],
    sum_us: u128,
    count: u64,
    max_us: u64,
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(29);
        self.buckets[idx] += 1;
        self.sum_us += us as u128;
        self.count += 1;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Shared metrics for one coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    pub encrypted_completed: AtomicU64,
    pub plain_completed: AtomicU64,
    pub rejected_backpressure: AtomicU64,
    pub rejected_no_session: AtomicU64,
    /// Submissions refused because the session's evaluation keys were
    /// evicted by the key cache (client must re-register).
    pub rejected_keys_evicted: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batch_fill_sum: AtomicU64,
    /// Encrypted-path group flushes (one packed HE evaluation each).
    pub enc_batches_flushed: AtomicU64,
    /// Samples carried by those flushes (fill = sum / flushed).
    pub enc_batch_fill_sum: AtomicU64,
    /// Configured plaintext batch capacity (for fill-ratio reporting;
    /// 0 until a coordinator starts).
    pub batch_capacity: AtomicU64,
    /// Configured encrypted group capacity (clamped `enc_batch`).
    pub enc_batch_capacity: AtomicU64,
    /// Encrypted requests admitted but not yet picked up by the
    /// enc-batcher — the queue-depth signal the adaptive batching
    /// target scales with (batch harder under load).
    pub enc_queue_depth: AtomicU64,
    /// TCP connections accepted by the serving tier (`crate::net`).
    pub net_connections_accepted: AtomicU64,
    /// Serving-tier connections currently open (gauge).
    pub net_connections_open: AtomicU64,
    /// Connections refused at accept because the serving tier's
    /// connection cap was reached (accept-path backpressure).
    pub net_rejected_overload: AtomicU64,
    /// Shared with the session key cache: hits / misses / evictions /
    /// resident bytes (see [`crate::keycache`]).
    pub keycache: Arc<KeyCacheStats>,
    pub encrypted_latency: Mutex<Histogram>,
    pub plain_latency: Mutex<Histogram>,
}

impl Metrics {
    /// Metrics wired to an existing key cache's counters (the
    /// coordinator shares the [`SessionManager`]'s cache stats so one
    /// snapshot covers the whole serving path).
    ///
    /// [`SessionManager`]: super::session::SessionManager
    pub fn with_keycache(keycache: Arc<KeyCacheStats>) -> Self {
        Metrics {
            keycache,
            ..Default::default()
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub encrypted_completed: u64,
    pub plain_completed: u64,
    pub rejected_backpressure: u64,
    pub rejected_no_session: u64,
    pub rejected_keys_evicted: u64,
    pub batches_flushed: u64,
    pub mean_batch_fill: f64,
    /// `mean_batch_fill / max_batch` — 1.0 means every flush was full;
    /// 0 when no capacity was recorded.
    pub batch_fill_ratio: f64,
    pub enc_batches_flushed: u64,
    pub mean_enc_batch_fill: f64,
    /// `mean_enc_batch_fill / enc_batch` (see `batch_fill_ratio`).
    pub enc_batch_fill_ratio: f64,
    /// Encrypted requests in flight between admission and batcher
    /// pickup at snapshot time.
    pub enc_queue_depth: u64,
    pub net_connections_accepted: u64,
    pub net_connections_open: u64,
    pub net_rejected_overload: u64,
    pub keycache_hits: u64,
    pub keycache_misses: u64,
    pub keycache_evictions: u64,
    pub keycache_resident_bytes: u64,
    pub encrypted_mean: Duration,
    pub encrypted_p95: Duration,
    pub plain_mean: Duration,
    pub plain_p95: Duration,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        let enc = lock_unpoisoned(&self.encrypted_latency);
        let plain = lock_unpoisoned(&self.plain_latency);
        let flushed = self.batches_flushed.load(Ordering::Relaxed);
        let enc_flushed = self.enc_batches_flushed.load(Ordering::Relaxed);
        let mean_batch_fill = if flushed == 0 {
            0.0
        } else {
            self.batch_fill_sum.load(Ordering::Relaxed) as f64 / flushed as f64
        };
        let mean_enc_batch_fill = if enc_flushed == 0 {
            0.0
        } else {
            self.enc_batch_fill_sum.load(Ordering::Relaxed) as f64 / enc_flushed as f64
        };
        let fill_ratio = |fill: f64, cap: u64| if cap == 0 { 0.0 } else { fill / cap as f64 };
        let kc = self.keycache.snapshot();
        MetricsSnapshot {
            encrypted_completed: self.encrypted_completed.load(Ordering::Relaxed),
            plain_completed: self.plain_completed.load(Ordering::Relaxed),
            rejected_backpressure: self.rejected_backpressure.load(Ordering::Relaxed),
            rejected_no_session: self.rejected_no_session.load(Ordering::Relaxed),
            rejected_keys_evicted: self.rejected_keys_evicted.load(Ordering::Relaxed),
            batches_flushed: flushed,
            mean_batch_fill,
            batch_fill_ratio: fill_ratio(
                mean_batch_fill,
                self.batch_capacity.load(Ordering::Relaxed),
            ),
            enc_batches_flushed: enc_flushed,
            mean_enc_batch_fill,
            enc_batch_fill_ratio: fill_ratio(
                mean_enc_batch_fill,
                self.enc_batch_capacity.load(Ordering::Relaxed),
            ),
            enc_queue_depth: self.enc_queue_depth.load(Ordering::Relaxed),
            net_connections_accepted: self.net_connections_accepted.load(Ordering::Relaxed),
            net_connections_open: self.net_connections_open.load(Ordering::Relaxed),
            net_rejected_overload: self.net_rejected_overload.load(Ordering::Relaxed),
            keycache_hits: kc.hits,
            keycache_misses: kc.misses,
            keycache_evictions: kc.evictions,
            keycache_resident_bytes: kc.resident_bytes,
            encrypted_mean: enc.mean(),
            encrypted_p95: enc.quantile(0.95),
            plain_mean: plain.mean(),
            plain_p95: plain.quantile(0.95),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.quantile(0.5) >= Duration::from_millis(2));
        assert!(h.quantile(1.0) >= Duration::from_millis(100));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn snapshot_reads_counters() {
        let m = Metrics::default();
        m.encrypted_completed.fetch_add(3, Ordering::Relaxed);
        m.batches_flushed.fetch_add(2, Ordering::Relaxed);
        m.batch_fill_sum.fetch_add(9, Ordering::Relaxed);
        lock_unpoisoned(&m.plain_latency).record(Duration::from_micros(500));
        m.net_connections_accepted.fetch_add(4, Ordering::Relaxed);
        m.net_connections_open.fetch_add(2, Ordering::Relaxed);
        m.net_rejected_overload.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.encrypted_completed, 3);
        assert!((s.mean_batch_fill - 4.5).abs() < 1e-12);
        assert!(s.plain_mean > Duration::ZERO);
        assert_eq!(s.net_connections_accepted, 4);
        assert_eq!(s.net_connections_open, 2);
        assert_eq!(s.net_rejected_overload, 1);
    }

    #[test]
    fn snapshot_survives_a_poisoned_histogram_lock() {
        // A panicking worker mid-`record` must not take every future
        // snapshot (or record) down with it.
        let m = std::sync::Arc::new(Metrics::default());
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.encrypted_latency.lock().unwrap();
            panic!("worker died holding the latency lock");
        })
        .join();
        assert!(m.encrypted_latency.is_poisoned());
        lock_unpoisoned(&m.encrypted_latency).record(Duration::from_micros(100));
        assert_eq!(m.snapshot().encrypted_completed, 0);
    }

    #[test]
    fn fill_ratios_and_keycache_wiring() {
        let m = Metrics::default();
        // No capacity recorded → ratios stay 0 instead of dividing.
        assert_eq!(m.snapshot().batch_fill_ratio, 0.0);
        m.batch_capacity.store(8, Ordering::Relaxed);
        m.enc_batch_capacity.store(4, Ordering::Relaxed);
        m.batches_flushed.fetch_add(2, Ordering::Relaxed);
        m.batch_fill_sum.fetch_add(8, Ordering::Relaxed); // mean fill 4
        m.enc_batches_flushed.fetch_add(1, Ordering::Relaxed);
        m.enc_batch_fill_sum.fetch_add(3, Ordering::Relaxed);
        m.keycache.hits.fetch_add(5, Ordering::Relaxed);
        m.keycache.evictions.fetch_add(2, Ordering::Relaxed);
        m.keycache.resident_bytes.fetch_add(1024, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.batch_fill_ratio - 0.5).abs() < 1e-12);
        assert!((s.enc_batch_fill_ratio - 0.75).abs() < 1e-12);
        assert_eq!(s.keycache_hits, 5);
        assert_eq!(s.keycache_evictions, 2);
        assert_eq!(s.keycache_resident_bytes, 1024);
        // Sharing a cache's stats: the same counters appear in both.
        let stats = std::sync::Arc::new(crate::keycache::KeyCacheStats::default());
        let m2 = Metrics::with_keycache(stats.clone());
        stats.misses.fetch_add(7, Ordering::Relaxed);
        assert_eq!(m2.snapshot().keycache_misses, 7);
    }
}
