//! Serving metrics: counters + latency histograms.
//!
//! Lock-free counters (atomics) with a small mutex-guarded log-scale
//! histogram per request class; cheap enough for the request path.
//! The companion span-timeline machinery lives in [`crate::obs`]; the
//! coordinator's [`TraceSink`] hangs off [`Metrics::trace`] so one
//! handle scrapes both planes.

use crate::keycache::KeyCacheStats;
use crate::lockutil::lock_unpoisoned;
use crate::obs::trace::TraceSink;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Log₂-bucketed histogram over positive integer values.
///
/// The buckets are unit-agnostic (bucket *i* covers `[2^i, 2^(i+1))`,
/// 30 buckets); the [`Duration`]-typed wrappers ([`record`],
/// [`mean`], [`max`], [`quantile`]) interpret values as **µs** — the
/// serving-latency convention — while the `_value` methods expose the
/// raw scale (the op-profile plane records **ns** through them).
///
/// [`record`]: Histogram::record
/// [`mean`]: Histogram::mean
/// [`max`]: Histogram::max
/// [`quantile`]: Histogram::quantile
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [u64; 30],
    sum: u128,
    count: u64,
    peak: u64,
}

impl Histogram {
    /// Record a latency in µs.
    pub fn record(&mut self, d: Duration) {
        self.record_value(d.as_micros() as u64);
    }

    /// Record a raw value (clamped up to 1 so log₂ is defined).
    pub fn record_value(&mut self, v: u64) {
        let v = v.max(1);
        let idx = (63 - v.leading_zeros() as usize).min(29);
        self.buckets[idx] += 1;
        self.sum += v as u128;
        self.count += 1;
        self.peak = self.peak.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of every recorded value.
    pub fn sum_value(&self) -> u128 {
        self.sum
    }

    /// Exact mean of the recorded values (0 when empty).
    pub fn mean_value(&self) -> u64 {
        if self.count == 0 {
            return 0;
        }
        (self.sum / self.count as u128) as u64
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_value(&self) -> u64 {
        self.peak
    }

    pub fn mean(&self) -> Duration {
        Duration::from_micros(self.mean_value())
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_value())
    }

    /// Approximate quantile, interpolated within the target bucket.
    ///
    /// The rank-`q` sample lands in some bucket `[2^i, 2^(i+1))`; its
    /// value is estimated at the rank's proportional position across
    /// that bucket (the k-th of c bucket occupants sits at
    /// `(k − ½)/c` of the span), clamped to the observed maximum.
    /// This removes the old upper-edge bias where the p50 of a single
    /// 1ms sample reported ~2ms. `q ≥ 1` returns the exact maximum.
    pub fn quantile_value(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q >= 1.0 {
            return self.peak;
        }
        let target = ((self.count as f64) * q.max(0.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = 1u64 << i;
                let hi = 1u64 << (i + 1);
                let frac = ((target - seen) as f64 - 0.5) / c as f64;
                let v = lo as f64 + frac * (hi - lo) as f64;
                return (v.round() as u64).clamp(lo, self.peak.max(lo));
            }
            seen += c;
        }
        self.peak
    }

    /// [`quantile_value`](Histogram::quantile_value) in µs.
    pub fn quantile(&self, q: f64) -> Duration {
        Duration::from_micros(self.quantile_value(q))
    }
}

/// Shared metrics for one coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    pub encrypted_completed: AtomicU64,
    pub plain_completed: AtomicU64,
    pub rejected_backpressure: AtomicU64,
    pub rejected_no_session: AtomicU64,
    /// Submissions refused because the session's evaluation keys were
    /// evicted by the key cache (client must re-register).
    pub rejected_keys_evicted: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batch_fill_sum: AtomicU64,
    /// Encrypted-path group flushes (one packed HE evaluation each).
    pub enc_batches_flushed: AtomicU64,
    /// Samples carried by those flushes (fill = sum / flushed).
    pub enc_batch_fill_sum: AtomicU64,
    /// Configured plaintext batch capacity (for fill-ratio reporting;
    /// 0 until a coordinator starts).
    pub batch_capacity: AtomicU64,
    /// Configured encrypted group capacity (clamped `enc_batch`).
    pub enc_batch_capacity: AtomicU64,
    /// Encrypted requests admitted but not yet picked up by the
    /// enc-batcher — the queue-depth signal the adaptive batching
    /// target scales with (batch harder under load).
    pub enc_queue_depth: AtomicU64,
    /// TCP connections accepted by the serving tier (`crate::net`).
    pub net_connections_accepted: AtomicU64,
    /// Serving-tier connections currently open (gauge; paired
    /// increment/decrement via [`Metrics::open_connection`] so an
    /// unwinding connection thread cannot leak it).
    pub net_connections_open: AtomicU64,
    /// Connections refused at accept because the serving tier's
    /// connection cap was reached (accept-path backpressure).
    pub net_rejected_overload: AtomicU64,
    /// Shared with the session key cache: hits / misses / evictions /
    /// resident bytes, plus the disk spill tier's counters (see
    /// [`crate::keycache`]).
    pub keycache: Arc<KeyCacheStats>,
    /// Shared with the slab pool backing every `Scratch` handle
    /// (see [`crate::mem`]); `Metrics::default()` wires in a detached
    /// all-zero instance, [`Metrics::with_keycache`] the global
    /// pool's.
    pub slab: Arc<crate::mem::SlabStats>,
    /// End-to-end latency (admission → response).
    pub encrypted_latency: Mutex<Histogram>,
    pub plain_latency: Mutex<Histogram>,
    /// Queue-time split: admission → worker pickup (encrypted path).
    pub encrypted_queue: Mutex<Histogram>,
    /// Service-time split: worker pickup → response (encrypted path).
    pub encrypted_service: Mutex<Histogram>,
    /// Queue-time split for the plaintext path.
    pub plain_queue: Mutex<Histogram>,
    /// Service-time split for the plaintext path.
    pub plain_service: Mutex<Histogram>,
    /// Completed-request span timelines (see [`crate::obs::trace`]).
    /// Disabled (capacity 0) by default; the coordinator installs a
    /// sized sink per `CoordinatorConfig::trace_capacity`.
    pub trace: Arc<TraceSink>,
    /// Op count of the schedule DAG most recently dispatched through
    /// the op-parallel executor (gauge; 0 until a DAG evaluation runs).
    pub dag_ops: AtomicU64,
    /// Wave (topological-level) count of that DAG — the executor's
    /// critical-path length in ops.
    pub dag_waves: AtomicU64,
    /// Widest wave of that DAG — the max op-parallelism the schedule
    /// exposes (more `op_workers` than this cannot help).
    pub dag_width: AtomicU64,
}

impl Metrics {
    /// Metrics wired to an existing key cache's counters (the
    /// coordinator shares the [`SessionManager`]'s cache stats so one
    /// snapshot covers the whole serving path).
    ///
    /// [`SessionManager`]: super::session::SessionManager
    pub fn with_keycache(keycache: Arc<KeyCacheStats>) -> Self {
        Metrics {
            keycache,
            // The serving path's scratch handles all draw from the
            // global slab pool, so its counters are the ones a
            // coordinator snapshot should report.
            slab: crate::mem::global_pool().stats(),
            ..Default::default()
        }
    }

    /// Book one serving-tier connection open and return the guard
    /// that closes it. The decrement runs in `Drop`, so an early
    /// error return — or a panic unwinding mid-request — cannot leak
    /// the `net_connections_open` gauge upward.
    pub fn open_connection(&self) -> GaugeGuard<'_> {
        self.net_connections_open.fetch_add(1, Ordering::Relaxed);
        GaugeGuard {
            gauge: &self.net_connections_open,
        }
    }
}

/// Decrement-on-drop half of a gauge increment
/// (see [`Metrics::open_connection`]).
#[derive(Debug)]
pub struct GaugeGuard<'a> {
    gauge: &'a AtomicU64,
}

impl Drop for GaugeGuard<'_> {
    fn drop(&mut self) {
        self.gauge.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub encrypted_completed: u64,
    pub plain_completed: u64,
    pub rejected_backpressure: u64,
    pub rejected_no_session: u64,
    pub rejected_keys_evicted: u64,
    pub batches_flushed: u64,
    pub mean_batch_fill: f64,
    /// `mean_batch_fill / max_batch` — 1.0 means every flush was full;
    /// 0 when no capacity was recorded.
    pub batch_fill_ratio: f64,
    pub enc_batches_flushed: u64,
    pub mean_enc_batch_fill: f64,
    /// `mean_enc_batch_fill / enc_batch` (see `batch_fill_ratio`).
    pub enc_batch_fill_ratio: f64,
    /// Encrypted requests in flight between admission and batcher
    /// pickup at snapshot time.
    pub enc_queue_depth: u64,
    pub net_connections_accepted: u64,
    pub net_connections_open: u64,
    pub net_rejected_overload: u64,
    pub keycache_hits: u64,
    pub keycache_misses: u64,
    pub keycache_evictions: u64,
    pub keycache_resident_bytes: u64,
    pub encrypted_mean: Duration,
    pub encrypted_p50: Duration,
    pub encrypted_p95: Duration,
    pub encrypted_p99: Duration,
    pub plain_mean: Duration,
    pub plain_p50: Duration,
    pub plain_p95: Duration,
    pub plain_p99: Duration,
    /// Queue-time vs service-time split (see the histogram fields on
    /// [`Metrics`]): queue = admission → worker pickup, service =
    /// worker pickup → response; queue + service ≈ end-to-end.
    pub encrypted_queue_mean: Duration,
    pub encrypted_queue_p95: Duration,
    pub encrypted_service_mean: Duration,
    pub encrypted_service_p95: Duration,
    pub plain_queue_mean: Duration,
    pub plain_service_mean: Duration,
    /// Completed traces pushed into the trace ring since start.
    pub traces_recorded: u64,
    /// Traces lost to ring wrap-around.
    pub traces_dropped: u64,
    /// Schedule-DAG shape of the most recent op-parallel evaluation
    /// (ops / waves / widest wave; all 0 until one runs).
    pub dag_ops: u64,
    pub dag_waves: u64,
    pub dag_width: u64,
    /// Memory plane — slab pool: bytes parked in free lists (gauge,
    /// never exceeds the slab budget) and checkout hit/miss counts.
    pub slab_resident_bytes: u64,
    pub slab_hits: u64,
    pub slab_misses: u64,
    /// Memory plane — keycache spill tier: bytes on disk (gauge),
    /// reloads that saved a client re-upload, and corrupt spill files
    /// detected (each deleted, degrading to the re-register protocol).
    pub keycache_spilled_bytes: u64,
    pub keycache_spill_hits: u64,
    pub keycache_spill_corrupt: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        let enc = lock_unpoisoned(&self.encrypted_latency);
        let plain = lock_unpoisoned(&self.plain_latency);
        let enc_queue = lock_unpoisoned(&self.encrypted_queue);
        let enc_service = lock_unpoisoned(&self.encrypted_service);
        let plain_queue = lock_unpoisoned(&self.plain_queue);
        let plain_service = lock_unpoisoned(&self.plain_service);
        let flushed = self.batches_flushed.load(Ordering::Relaxed);
        let enc_flushed = self.enc_batches_flushed.load(Ordering::Relaxed);
        let mean_batch_fill = if flushed == 0 {
            0.0
        } else {
            self.batch_fill_sum.load(Ordering::Relaxed) as f64 / flushed as f64
        };
        let mean_enc_batch_fill = if enc_flushed == 0 {
            0.0
        } else {
            self.enc_batch_fill_sum.load(Ordering::Relaxed) as f64 / enc_flushed as f64
        };
        let fill_ratio = |fill: f64, cap: u64| if cap == 0 { 0.0 } else { fill / cap as f64 };
        let kc = self.keycache.snapshot();
        let sl = self.slab.snapshot();
        MetricsSnapshot {
            encrypted_completed: self.encrypted_completed.load(Ordering::Relaxed),
            plain_completed: self.plain_completed.load(Ordering::Relaxed),
            rejected_backpressure: self.rejected_backpressure.load(Ordering::Relaxed),
            rejected_no_session: self.rejected_no_session.load(Ordering::Relaxed),
            rejected_keys_evicted: self.rejected_keys_evicted.load(Ordering::Relaxed),
            batches_flushed: flushed,
            mean_batch_fill,
            batch_fill_ratio: fill_ratio(
                mean_batch_fill,
                self.batch_capacity.load(Ordering::Relaxed),
            ),
            enc_batches_flushed: enc_flushed,
            mean_enc_batch_fill,
            enc_batch_fill_ratio: fill_ratio(
                mean_enc_batch_fill,
                self.enc_batch_capacity.load(Ordering::Relaxed),
            ),
            enc_queue_depth: self.enc_queue_depth.load(Ordering::Relaxed),
            net_connections_accepted: self.net_connections_accepted.load(Ordering::Relaxed),
            net_connections_open: self.net_connections_open.load(Ordering::Relaxed),
            net_rejected_overload: self.net_rejected_overload.load(Ordering::Relaxed),
            keycache_hits: kc.hits,
            keycache_misses: kc.misses,
            keycache_evictions: kc.evictions,
            keycache_resident_bytes: kc.resident_bytes,
            encrypted_mean: enc.mean(),
            encrypted_p50: enc.quantile(0.5),
            encrypted_p95: enc.quantile(0.95),
            encrypted_p99: enc.quantile(0.99),
            plain_mean: plain.mean(),
            plain_p50: plain.quantile(0.5),
            plain_p95: plain.quantile(0.95),
            plain_p99: plain.quantile(0.99),
            encrypted_queue_mean: enc_queue.mean(),
            encrypted_queue_p95: enc_queue.quantile(0.95),
            encrypted_service_mean: enc_service.mean(),
            encrypted_service_p95: enc_service.quantile(0.95),
            plain_queue_mean: plain_queue.mean(),
            plain_service_mean: plain_service.mean(),
            traces_recorded: self.trace.recorded(),
            traces_dropped: self.trace.dropped(),
            dag_ops: self.dag_ops.load(Ordering::Relaxed),
            dag_waves: self.dag_waves.load(Ordering::Relaxed),
            dag_width: self.dag_width.load(Ordering::Relaxed),
            slab_resident_bytes: sl.resident_bytes,
            slab_hits: sl.hits,
            slab_misses: sl.misses,
            keycache_spilled_bytes: kc.spilled_bytes,
            keycache_spill_hits: kc.spill_hits,
            keycache_spill_corrupt: kc.spill_corrupt,
        }
    }
}

impl MetricsSnapshot {
    /// One-line JSON rendering (stable field order, no dependencies) —
    /// what `cryptotree-serve --stats-interval N` prints.
    pub fn to_json_line(&self) -> String {
        let us = |d: Duration| d.as_micros() as u64;
        let mut out = String::with_capacity(1024);
        out.push('{');
        let mut put = |out: &mut String, key: &str, val: String| {
            if out.len() > 1 {
                out.push(',');
            }
            out.push('"');
            out.push_str(key);
            out.push_str("\":");
            out.push_str(&val);
        };
        put(&mut out, "encrypted_completed", self.encrypted_completed.to_string());
        put(&mut out, "plain_completed", self.plain_completed.to_string());
        put(&mut out, "rejected_backpressure", self.rejected_backpressure.to_string());
        put(&mut out, "rejected_no_session", self.rejected_no_session.to_string());
        put(&mut out, "rejected_keys_evicted", self.rejected_keys_evicted.to_string());
        put(&mut out, "batches_flushed", self.batches_flushed.to_string());
        put(&mut out, "mean_batch_fill", format!("{:.3}", self.mean_batch_fill));
        put(&mut out, "batch_fill_ratio", format!("{:.3}", self.batch_fill_ratio));
        put(&mut out, "enc_batches_flushed", self.enc_batches_flushed.to_string());
        put(&mut out, "mean_enc_batch_fill", format!("{:.3}", self.mean_enc_batch_fill));
        put(&mut out, "enc_batch_fill_ratio", format!("{:.3}", self.enc_batch_fill_ratio));
        put(&mut out, "enc_queue_depth", self.enc_queue_depth.to_string());
        put(&mut out, "net_connections_accepted", self.net_connections_accepted.to_string());
        put(&mut out, "net_connections_open", self.net_connections_open.to_string());
        put(&mut out, "net_rejected_overload", self.net_rejected_overload.to_string());
        put(&mut out, "keycache_hits", self.keycache_hits.to_string());
        put(&mut out, "keycache_misses", self.keycache_misses.to_string());
        put(&mut out, "keycache_evictions", self.keycache_evictions.to_string());
        put(&mut out, "keycache_resident_bytes", self.keycache_resident_bytes.to_string());
        put(&mut out, "encrypted_mean_us", us(self.encrypted_mean).to_string());
        put(&mut out, "encrypted_p50_us", us(self.encrypted_p50).to_string());
        put(&mut out, "encrypted_p95_us", us(self.encrypted_p95).to_string());
        put(&mut out, "encrypted_p99_us", us(self.encrypted_p99).to_string());
        put(&mut out, "plain_mean_us", us(self.plain_mean).to_string());
        put(&mut out, "plain_p50_us", us(self.plain_p50).to_string());
        put(&mut out, "plain_p95_us", us(self.plain_p95).to_string());
        put(&mut out, "plain_p99_us", us(self.plain_p99).to_string());
        put(&mut out, "encrypted_queue_mean_us", us(self.encrypted_queue_mean).to_string());
        put(&mut out, "encrypted_queue_p95_us", us(self.encrypted_queue_p95).to_string());
        put(&mut out, "encrypted_service_mean_us", us(self.encrypted_service_mean).to_string());
        put(&mut out, "encrypted_service_p95_us", us(self.encrypted_service_p95).to_string());
        put(&mut out, "plain_queue_mean_us", us(self.plain_queue_mean).to_string());
        put(&mut out, "plain_service_mean_us", us(self.plain_service_mean).to_string());
        put(&mut out, "traces_recorded", self.traces_recorded.to_string());
        put(&mut out, "traces_dropped", self.traces_dropped.to_string());
        put(&mut out, "dag_ops", self.dag_ops.to_string());
        put(&mut out, "dag_waves", self.dag_waves.to_string());
        put(&mut out, "dag_width", self.dag_width.to_string());
        put(&mut out, "slab_resident_bytes", self.slab_resident_bytes.to_string());
        put(&mut out, "slab_hits", self.slab_hits.to_string());
        put(&mut out, "slab_misses", self.slab_misses.to_string());
        put(&mut out, "keycache_spilled_bytes", self.keycache_spilled_bytes.to_string());
        put(&mut out, "keycache_spill_hits", self.keycache_spill_hits.to_string());
        put(&mut out, "keycache_spill_corrupt", self.keycache_spill_corrupt.to_string());
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.max() >= Duration::from_millis(100));
        // Interpolated p50: rank 3 of {1,2,4,8,100}ms sits in the
        // [2048,4096)µs bucket → ~3ms, not the old 4ms upper edge.
        assert!(h.quantile(0.5) >= Duration::from_millis(2));
        assert!(h.quantile(0.5) < Duration::from_millis(4));
        // q = 1 is the exact maximum, not a bucket edge.
        assert_eq!(h.quantile(1.0), Duration::from_millis(100));
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // The satellite case: one 1ms sample. Bucket [1024, 2048)µs;
        // the upper-edge-biased quantile reported 2048µs. The
        // midpoint estimate stays strictly inside the bucket and is
        // clamped to the observed max.
        let mut h = Histogram::default();
        h.record(Duration::from_millis(1));
        let p50 = h.quantile(0.5);
        assert!(p50 >= Duration::from_micros(1000), "p50 = {p50:?}");
        assert!(p50 < Duration::from_millis(2), "p50 = {p50:?}");

        // Many equal samples: every quantile clamps to the exact value.
        let mut h = Histogram::default();
        for _ in 0..100 {
            h.record_value(3000);
        }
        assert!(h.quantile_value(0.01) >= 2048);
        assert!(h.quantile_value(0.99) <= 3000);
        assert_eq!(h.quantile_value(1.0), 3000);

        // Raw-unit API used by the op-profile plane (ns).
        let mut h = Histogram::default();
        h.record_value(0); // clamps to 1
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_value(), 1);
        assert_eq!(h.quantile_value(0.5), 1);
        assert_eq!(h.sum_value(), 1);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn snapshot_reads_counters() {
        let m = Metrics::default();
        m.encrypted_completed.fetch_add(3, Ordering::Relaxed);
        m.batches_flushed.fetch_add(2, Ordering::Relaxed);
        m.batch_fill_sum.fetch_add(9, Ordering::Relaxed);
        lock_unpoisoned(&m.plain_latency).record(Duration::from_micros(500));
        m.net_connections_accepted.fetch_add(4, Ordering::Relaxed);
        m.net_connections_open.fetch_add(2, Ordering::Relaxed);
        m.net_rejected_overload.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.encrypted_completed, 3);
        assert!((s.mean_batch_fill - 4.5).abs() < 1e-12);
        assert!(s.plain_mean > Duration::ZERO);
        assert!(s.plain_p50 > Duration::ZERO);
        assert!(s.plain_p99 >= s.plain_p50);
        assert_eq!(s.net_connections_accepted, 4);
        assert_eq!(s.net_connections_open, 2);
        assert_eq!(s.net_rejected_overload, 1);
        let json = s.to_json_line();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"encrypted_completed\":3"));
        assert!(json.contains("\"plain_p50_us\":"));
        assert!(json.contains("\"traces_recorded\":0"));
    }

    #[test]
    fn queue_service_split_is_snapshotted() {
        let m = Metrics::default();
        lock_unpoisoned(&m.encrypted_queue).record(Duration::from_micros(300));
        lock_unpoisoned(&m.encrypted_service).record(Duration::from_micros(700));
        let s = m.snapshot();
        assert!(s.encrypted_queue_mean > Duration::ZERO);
        assert!(s.encrypted_service_mean > s.encrypted_queue_mean);
        assert_eq!(s.plain_queue_mean, Duration::ZERO);
    }

    #[test]
    fn snapshot_survives_a_poisoned_histogram_lock() {
        // A panicking worker mid-`record` must not take every future
        // snapshot (or record) down with it.
        let m = std::sync::Arc::new(Metrics::default());
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.encrypted_latency.lock().unwrap();
            panic!("worker died holding the latency lock");
        })
        .join();
        assert!(m.encrypted_latency.is_poisoned());
        lock_unpoisoned(&m.encrypted_latency).record(Duration::from_micros(100));
        assert_eq!(m.snapshot().encrypted_completed, 0);
    }

    #[test]
    fn connection_gauge_cannot_leak_on_panic() {
        let m = std::sync::Arc::new(Metrics::default());
        {
            let _g = m.open_connection();
            assert_eq!(m.net_connections_open.load(Ordering::Relaxed), 1);
        }
        assert_eq!(m.net_connections_open.load(Ordering::Relaxed), 0);
        // A handler thread that panics mid-request still decrements.
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.open_connection();
            panic!("handler died mid-request");
        })
        .join();
        assert_eq!(m.net_connections_open.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fill_ratios_and_keycache_wiring() {
        let m = Metrics::default();
        // No capacity recorded → ratios stay 0 instead of dividing.
        assert_eq!(m.snapshot().batch_fill_ratio, 0.0);
        m.batch_capacity.store(8, Ordering::Relaxed);
        m.enc_batch_capacity.store(4, Ordering::Relaxed);
        m.batches_flushed.fetch_add(2, Ordering::Relaxed);
        m.batch_fill_sum.fetch_add(8, Ordering::Relaxed); // mean fill 4
        m.enc_batches_flushed.fetch_add(1, Ordering::Relaxed);
        m.enc_batch_fill_sum.fetch_add(3, Ordering::Relaxed);
        m.keycache.hits.fetch_add(5, Ordering::Relaxed);
        m.keycache.evictions.fetch_add(2, Ordering::Relaxed);
        m.keycache.resident_bytes.fetch_add(1024, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.batch_fill_ratio - 0.5).abs() < 1e-12);
        assert!((s.enc_batch_fill_ratio - 0.75).abs() < 1e-12);
        assert_eq!(s.keycache_hits, 5);
        assert_eq!(s.keycache_evictions, 2);
        assert_eq!(s.keycache_resident_bytes, 1024);
        // Sharing a cache's stats: the same counters appear in both.
        let stats = std::sync::Arc::new(crate::keycache::KeyCacheStats::default());
        let m2 = Metrics::with_keycache(stats.clone());
        stats.misses.fetch_add(7, Ordering::Relaxed);
        assert_eq!(m2.snapshot().keycache_misses, 7);
    }

    #[test]
    fn memory_plane_fields_flow_into_snapshot_and_json() {
        let m = Metrics::default();
        m.slab.hits.fetch_add(9, Ordering::Relaxed);
        m.slab.resident_bytes.fetch_add(4096, Ordering::Relaxed);
        m.keycache.spilled_bytes.fetch_add(777, Ordering::Relaxed);
        m.keycache.spill_hits.fetch_add(2, Ordering::Relaxed);
        m.keycache.spill_corrupt.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.slab_hits, 9);
        assert_eq!(s.slab_resident_bytes, 4096);
        assert_eq!(s.keycache_spilled_bytes, 777);
        assert_eq!(s.keycache_spill_hits, 2);
        assert_eq!(s.keycache_spill_corrupt, 1);
        let json = s.to_json_line();
        assert!(json.contains("\"slab_resident_bytes\":4096"));
        assert!(json.contains("\"keycache_spilled_bytes\":777"));
        assert!(json.contains("\"keycache_spill_corrupt\":1"));
        // `with_keycache` wires the *global* pool's counters.
        let m2 = Metrics::with_keycache(std::sync::Arc::new(
            crate::keycache::KeyCacheStats::default(),
        ));
        assert!(std::sync::Arc::ptr_eq(
            &m2.slab,
            &crate::mem::global_pool().stats()
        ));
    }
}
