//! L3 serving coordinator — the deployment layer around the HRF.
//!
//! The paper (§5) argues HRF's advantage is single-observation latency
//! and notes "several inputs can be handled at the same time using a
//! multi-threaded server". This module is that server:
//!
//! * [`session`] — per-client HE key sessions: the server stores each
//!   client's *evaluation* keys (relinearization + Galois), never the
//!   secret key. Storage is the sharded, memory-budgeted
//!   [`keycache`](crate::keycache): under key-byte pressure the
//!   least-recently-used session's keys are evicted, submissions on it
//!   fail fast with [`SubmitError::KeysEvicted`], and the client
//!   recovers via [`SessionManager::reregister`] without losing its
//!   session id. Requests are rejected unless their session exists.
//! * [`core`] — the coordinator: a bounded ingress queue
//!   (backpressure), a router that sends encrypted work to the
//!   least-loaded HE worker and plaintext work to the batcher, a
//!   worker pool (one CKKS evaluator each), and graceful shutdown.
//! * [`batcher`] — dynamic batching for the plaintext fast path:
//!   flush on size `B` (the AOT artifact's batch) or on timeout,
//!   executed through the PJRT slot model when available, Rust slot
//!   math otherwise.
//! * [`metrics`] — latency histograms / throughput counters, the
//!   queue-time vs service-time split, and the span-trace ring
//!   ([`crate::obs::trace::TraceSink`]) every admitted request's
//!   timeline is recorded into.

pub mod batcher;
pub mod core;
pub mod metrics;
pub mod session;

pub use crate::keycache::CacheState;
pub use core::{
    panic_message, Coordinator, CoordinatorConfig, EncResponse, PlainResponse, ShutdownReport,
    SubmitError,
};
pub use metrics::MetricsSnapshot;
pub use session::{Session, SessionManager};
