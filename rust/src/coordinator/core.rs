//! The coordinator proper: ingress queue → router → workers/batcher.
//!
//! Topology (all std threads; tokio is unavailable offline and the
//! workloads are CPU-bound anyway):
//!
//! ```text
//!  submit_*() ──bounded channel──► router thread
//!      │ (backpressure: Busy)        │
//!      │                    ┌────────┴──────────┐
//!      │             encrypted → least-loaded   plain → batcher thread
//!      │                    HE worker 0..W-1       (size/timeout policy,
//!      │                    (own Evaluator)         PJRT batch or Rust
//!      ▼                                            slot math)
//!  Receiver<Response>  ◄── response channels ──────┘
//! ```
//!
//! Responses travel on per-request rendezvous channels, so a caller
//! can block (`recv`) or poll (`try_recv`).

use super::batcher::{BatchAction, BatchPolicy};
use super::metrics::Metrics;
use super::session::SessionManager;
use crate::ckks::rns::ContextRef;
use crate::ckks::{Ciphertext, Encoder, Evaluator};
use crate::hrf::client::reshuffle_and_pack;
use crate::hrf::HrfServer;
use crate::runtime::{SlotModel, SlotModelParams};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// HE worker threads.
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Plaintext batch size (≤ the AOT artifact's B when PJRT is used).
    pub max_batch: usize,
    /// Max time a plaintext request may wait for batch-mates.
    pub batch_delay: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_capacity: 64,
            max_batch: 8,
            batch_delay: Duration::from_millis(5),
        }
    }
}

/// Why a submission was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Ingress queue full — shed load upstream.
    Busy,
    /// Coordinator is shutting down.
    Closed,
    /// Unknown session id.
    NoSession,
}

/// Encrypted-path response: per-class score ciphertexts.
pub type EncResponse = Result<Vec<Ciphertext>, String>;
/// Plaintext-path response: per-class scores.
pub type PlainResponse = Result<Vec<f64>, String>;

enum Request {
    Encrypted {
        session_id: u64,
        ct: Box<Ciphertext>,
        enqueued: Instant,
        resp: SyncSender<EncResponse>,
    },
    Plain {
        x: Vec<f64>,
        enqueued: Instant,
        resp: SyncSender<PlainResponse>,
    },
}

/// Handle to a running coordinator.
pub struct Coordinator {
    ingress: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
    pub sessions: Arc<SessionManager>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start router, HE workers and the plaintext batcher.
    ///
    /// `artifacts_dir` enables the PJRT fast path: the batcher thread
    /// loads and compiles the AOT slot model locally (PJRT handles are
    /// not `Send`, so the model lives and dies on that thread). When
    /// `None` — or when loading fails (e.g. shape mismatch with the
    /// packed HRF) — the plaintext path computes the identical slot
    /// model in Rust.
    pub fn start(
        cfg: CoordinatorConfig,
        ctx: ContextRef,
        server: Arc<HrfServer>,
        sessions: Arc<SessionManager>,
        artifacts_dir: Option<PathBuf>,
    ) -> Self {
        assert!(cfg.workers >= 1);
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ingress_tx, ingress_rx) = sync_channel::<Request>(cfg.queue_capacity);
        let mut threads = Vec::new();

        // --- HE workers -------------------------------------------
        let mut worker_txs = Vec::new();
        let worker_loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..cfg.workers).map(|_| AtomicUsize::new(0)).collect());
        for w in 0..cfg.workers {
            let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
            worker_txs.push(tx);
            let ctx = ctx.clone();
            let server = server.clone();
            let sessions = sessions.clone();
            let metrics = metrics.clone();
            let loads = worker_loads.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("hrf-worker-{w}"))
                    .spawn(move || {
                        let enc = Encoder::new(&ctx);
                        let mut ev = Evaluator::new(ctx.clone());
                        while let Ok(req) = rx.recv() {
                            if let Request::Encrypted {
                                session_id,
                                ct,
                                enqueued,
                                resp,
                            } = req
                            {
                                let result = match sessions.get(session_id) {
                                    Some(sess) => {
                                        let (outs, _) = server.eval(
                                            &mut ev,
                                            &enc,
                                            &ct,
                                            &sess.relin,
                                            &sess.galois,
                                        );
                                        Ok(outs)
                                    }
                                    None => Err(format!("no session {session_id}")),
                                };
                                loads[w].fetch_sub(1, Ordering::Relaxed);
                                metrics.encrypted_completed.fetch_add(1, Ordering::Relaxed);
                                metrics
                                    .encrypted_latency
                                    .lock()
                                    .unwrap()
                                    .record(enqueued.elapsed());
                                let _ = resp.send(result);
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // --- plaintext batcher --------------------------------------
        let (batch_tx, batch_rx) = sync_channel::<Request>(cfg.queue_capacity);
        {
            let server = server.clone();
            let metrics = metrics.clone();
            let cfg_b = cfg;
            threads.push(
                std::thread::Builder::new()
                    .name("plain-batcher".into())
                    .spawn(move || {
                        // PJRT fast path, loaded on this thread only.
                        let slot_model: Option<(SlotModel, SlotModelParams)> =
                            artifacts_dir.and_then(|dir| {
                                match SlotModel::load(&dir) {
                                    Ok(sm) => {
                                        match SlotModelParams::from_hrf(&server.model, sm.shape)
                                        {
                                            Ok(p) => Some((sm, p)),
                                            Err(e) => {
                                                eprintln!(
                                                    "[batcher] PJRT params mismatch ({e}); using Rust slot math"
                                                );
                                                None
                                            }
                                        }
                                    }
                                    Err(e) => {
                                        eprintln!(
                                            "[batcher] PJRT load failed ({e}); using Rust slot math"
                                        );
                                        None
                                    }
                                }
                            });
                        let mut policy = BatchPolicy::new(cfg_b.max_batch, cfg_b.batch_delay);
                        let mut held: Vec<(Vec<f64>, Instant, SyncSender<PlainResponse>)> =
                            Vec::new();
                        let flush = |held: &mut Vec<(Vec<f64>, Instant, SyncSender<PlainResponse>)>| {
                            if held.is_empty() {
                                return 0usize;
                            }
                            let n = held.len();
                            let slot_inputs: Vec<Vec<f32>> = held
                                .iter()
                                .map(|(x, _, _)| {
                                    reshuffle_and_pack(&server.model, x)
                                        .iter()
                                        .map(|&v| v as f32)
                                        .collect()
                                })
                                .collect();
                            // PJRT fast path, Rust slot math fallback.
                            let scores: Vec<Vec<f64>> = match &slot_model {
                                Some(sm) => match sm.0.infer_batch(&slot_inputs, &sm.1) {
                                    Ok(rows) => rows
                                        .into_iter()
                                        .map(|r| r.iter().map(|&v| v as f64).collect())
                                        .collect(),
                                    Err(e) => {
                                        for (_, _, resp) in held.drain(..) {
                                            let _ = resp.send(Err(format!("pjrt: {e}")));
                                        }
                                        return n;
                                    }
                                },
                                None => held
                                    .iter()
                                    .map(|(x, _, _)| {
                                        let slots = reshuffle_and_pack(&server.model, x);
                                        server.model.forward_slots_plain(&slots)
                                    })
                                    .collect(),
                            };
                            // Batch accounting first: a caller that has
                            // received its response must already see the
                            // flush reflected in the metrics.
                            metrics.batches_flushed.fetch_add(1, Ordering::Relaxed);
                            metrics
                                .batch_fill_sum
                                .fetch_add(n as u64, Ordering::Relaxed);
                            for ((_, enq, resp), s) in held.drain(..).zip(scores) {
                                metrics.plain_completed.fetch_add(1, Ordering::Relaxed);
                                metrics.plain_latency.lock().unwrap().record(enq.elapsed());
                                let _ = resp.send(Ok(s));
                            }
                            n
                        };
                        loop {
                            let timeout = policy
                                .deadline()
                                .map(|d| d.saturating_duration_since(Instant::now()))
                                .unwrap_or(Duration::from_millis(50));
                            match batch_rx.recv_timeout(timeout) {
                                Ok(Request::Plain { x, enqueued, resp }) => {
                                    held.push((x, enqueued, resp));
                                    if policy.on_arrival(Instant::now()) == BatchAction::Flush {
                                        let n = flush(&mut held);
                                        policy.on_flush(n);
                                    }
                                }
                                Ok(_) => unreachable!("router sends only Plain here"),
                                Err(RecvTimeoutError::Timeout) => {
                                    if policy.on_tick(Instant::now()) == BatchAction::Flush {
                                        let n = flush(&mut held);
                                        policy.on_flush(n);
                                    }
                                }
                                Err(RecvTimeoutError::Disconnected) => {
                                    let n = flush(&mut held);
                                    policy.on_flush(n);
                                    break;
                                }
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }

        // --- router --------------------------------------------------
        {
            let loads = worker_loads;
            threads.push(
                std::thread::Builder::new()
                    .name("router".into())
                    .spawn(move || {
                        while let Ok(req) = ingress_rx.recv() {
                            match req {
                                enc @ Request::Encrypted { .. } => {
                                    // Least-outstanding-work routing.
                                    let (best, _) = loads
                                        .iter()
                                        .enumerate()
                                        .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
                                        .expect("workers >= 1");
                                    loads[best].fetch_add(1, Ordering::Relaxed);
                                    if worker_txs[best].send(enc).is_err() {
                                        loads[best].fetch_sub(1, Ordering::Relaxed);
                                    }
                                }
                                plain @ Request::Plain { .. } => {
                                    let _ = batch_tx.send(plain);
                                }
                            }
                        }
                        // ingress closed: drop worker/batcher senders so
                        // their loops terminate.
                    })
                    .expect("spawn router"),
            );
        }

        Coordinator {
            ingress: ingress_tx,
            metrics,
            sessions,
            shutdown,
            threads,
        }
    }

    /// Submit an encrypted inference. Fails fast on backpressure or a
    /// missing session (checked before queueing).
    pub fn submit_encrypted(
        &self,
        session_id: u64,
        ct: Ciphertext,
    ) -> Result<Receiver<EncResponse>, SubmitError> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(SubmitError::Closed);
        }
        if self.sessions.get(session_id).is_none() {
            self.metrics
                .rejected_no_session
                .fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::NoSession);
        }
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request::Encrypted {
            session_id,
            ct: Box::new(ct),
            enqueued: Instant::now(),
            resp: resp_tx,
        };
        match self.ingress.try_send(req) {
            Ok(()) => Ok(resp_rx),
            Err(TrySendError::Full(_)) => {
                self.metrics
                    .rejected_backpressure
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Submit a plaintext inference (features, not slots).
    pub fn submit_plain(&self, x: Vec<f64>) -> Result<Receiver<PlainResponse>, SubmitError> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(SubmitError::Closed);
        }
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request::Plain {
            x,
            enqueued: Instant::now(),
            resp: resp_tx,
        };
        match self.ingress.try_send(req) {
            Ok(()) => Ok(resp_rx),
            Err(TrySendError::Full(_)) => {
                self.metrics
                    .rejected_backpressure
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Dropping the ingress sender unblocks the router, which drops
        // worker/batcher senders in turn.
        drop(std::mem::replace(&mut self.ingress, {
            let (tx, _rx) = sync_channel(1);
            tx
        }));
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}
